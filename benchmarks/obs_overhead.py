"""Observability overhead benchmark: the <5%-on / zero-cost-off contract.

The telemetry layer (`repro.obs`) promises that attaching an
`Observability` to the engine costs under 5% of chunk wall time at smoke
size, and that the compiled computation is untouched either way.  This
suite runs the *same* engine workload twice — obs off, then obs on (full
timeline + metrics) — and records:

* ``obs_overhead_ratio`` — a *normalized verdict*, checked MODEL-class
  (rtol 1%) in `benchmarks.check_regression`: exactly ``1.0`` whenever the
  measured on/off wall ratio is within the 1.05 budget, else the raw ratio.
  Encoding the contract this way keeps the gate deterministic while the
  contract holds, yet any breach surfaces as a hard MODEL failure with the
  offending ratio in the diff;
* ``obs_overhead_raw`` + ``overhead_pct`` — the actual measured ratio,
  advisory (timing class) so the trend stays visible without flaking CI;
* ``timeline_events_per_chunk`` — events the instrumented host loop emits
  per chunk (deterministic: spans are structural), checked EXACT;
* ``n_compiles_on`` / ``n_compiles_off`` — both must be 1 (EXACT via
  ``n_jobs``-style structural check): obs must never force a recompile.

``--assert-overhead X`` turns the measured ratio into a hard local/CI
failure; the bench-smoke CI job runs with ``--assert-overhead 1.05``.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.core.ising import IsingSystem
from repro.engine.driver import Engine, EngineConfig
from repro.obs import Observability

GROUP = "obs"


def _build(length: int, r: int, chunk_intervals: int, obs):
    system = IsingSystem(length=length, update="checkerboard")
    config = EngineConfig(
        # chunks must carry real device work (64 sweeps here) for the
        # ratio to measure the contract rather than dispatch noise: the
        # obs cost is per-chunk-constant (~100us: one sync + a few spans),
        # so microscopic chunks would inflate the ratio meaninglessly
        n_replicas=r, swap_interval=8, chunk_intervals=chunk_intervals,
        # donation off so the same state object can be re-run for repeats
        donate=False,
    )
    return Engine(system, config, obs=obs)


def _run_once(engine, state, sweeps: int) -> float:
    import time

    t0 = time.perf_counter()
    out_state, _ = engine.run(state, sweeps)
    jax.block_until_ready(out_state.pt)
    return time.perf_counter() - t0


def measure(length: int = 32, r: int = 8, chunk_intervals: int = 8,
            sweeps: int = 512, repeats: int = 15) -> dict:
    temps = np.geomspace(1.5, 4.5, r)
    key = jax.random.key(0)

    obs = Observability.create(timeline=True)
    eng_off = _build(length, r, chunk_intervals, None)
    eng_on = _build(length, r, chunk_intervals, obs)
    st_off = eng_off.init(key, temps)
    st_on = eng_on.init(key, temps)
    # warm both (pays the compile outside the timed region)
    _run_once(eng_off, st_off, sweeps)
    _run_once(eng_on, st_on, sweeps)
    # Interleave the timed runs and compare *minima*: contention (co-tenants,
    # frequency drift, GC) only ever adds time, so the minimum of each series
    # is its least-noisy estimate of true wall time, and interleaving makes
    # slow machine-state drift hit both series alike.  Sampling deep (15
    # repeats by default) is what makes a <5% effect measurable on a noisy
    # CI runner where single-run wall time swings +-5%.
    off, on = [], []
    for _ in range(repeats):
        off.append(_run_once(eng_off, st_off, sweeps))
        on.append(_run_once(eng_on, st_on, sweeps))
    wall_off, wall_on = min(off), min(on)
    ratio = wall_on / wall_off if wall_off > 0 else float("inf")
    n_chunks = float(obs.metrics.snapshot()
                     ["engine_chunks_total"]["samples"][0]["value"])
    # spans only — metadata/instant bookkeeping events are one-time, and
    # span count per chunk is structural (device_wait + chunk per chunk,
    # compile once), so the per-chunk rate is deterministic at fixed config
    n_spans = sum(1 for ev in obs.timeline.events() if ev["ph"] == "X")
    return {
        "wall_off": wall_off,
        "wall_on": wall_on,
        "ratio": ratio,
        "events_per_chunk": round(n_spans / n_chunks, 6),
        "n_compiles_off": eng_off.n_compiles,
        "n_compiles_on": eng_on.n_compiles,
    }


def run(budget: float = 1.0, assert_overhead: float = 0.0) -> None:
    length, sweeps = (32, 512) if budget <= 1.0 else (48, 1024)
    m = measure(length=length, sweeps=sweeps)
    ratio = m["ratio"]
    # the MODEL-gated verdict: 1.0 while the contract holds, the raw ratio
    # (a guaranteed >1% drift) the moment it does not
    verdict = 1.0 if ratio <= 1.05 else ratio
    emit(
        f"obs_overhead_L{length}",
        m["wall_on"],
        derived=(
            f"off={m['wall_off'] * 1e3:.1f}ms on={m['wall_on'] * 1e3:.1f}ms "
            f"ratio={ratio:.3f}"
        ),
        group=GROUP,
        metrics={
            "obs_overhead_ratio": verdict,
            "obs_overhead_raw": ratio,
            "overhead_pct": (ratio - 1.0) * 100.0,
            "timeline_events_per_chunk": m["events_per_chunk"],
            "n_compiles_obs_off": m["n_compiles_off"],
            "n_compiles_obs_on": m["n_compiles_on"],
        },
    )
    write_bench_json(GROUP)
    if assert_overhead and ratio > assert_overhead:
        sys.exit(
            f"obs overhead ratio {ratio:.3f} exceeds the "
            f"--assert-overhead {assert_overhead} budget"
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--budget", type=float, default=1.0,
                    help=">1 runs the larger configuration")
    ap.add_argument("--assert-overhead", type=float, default=0.0,
                    help="fail (exit 1) if on/off wall ratio exceeds this")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(budget=args.budget, assert_overhead=args.assert_overhead)


if __name__ == "__main__":
    main()
