"""Shared benchmark utilities: timing, CSV rows, machine-readable records.

``emit`` keeps the human-readable ``name,us_per_call,derived`` CSV contract
every suite prints, and — when a ``group`` is given — also accumulates the
row as a structured record.  ``write_bench_json`` then lands the group as
``BENCH_<group>.json`` (name, seconds, derived string, parsed metrics, jax
backend/version), which is what lets the perf trajectory accumulate across
PRs: CI runs the suites at smoke sizes and uploads the JSONs as artifacts.

Benchmark timers ride the same telemetry layer as the engine
(`repro.obs`): with ``BENCH_TIMELINE`` set (or `enable_obs()` called),
every ``time_call`` iteration lands as a span on a shared timeline,
``write_bench_json`` drops a ``BENCH_<group>.trace.json`` next to the
record file, and each record is stamped with the metrics-snapshot digest
(``obs_digest``) so a bench row is traceable to the telemetry captured in
the same process.  Obs off (the default) records nothing and stamps
nothing — baselines are digest-free and unaffected.
"""
from __future__ import annotations

import json
import os
import time

import jax

# group -> list of record dicts, accumulated by `emit(..., group=...)`
_RECORDS: dict[str, list] = {}

# process-wide bench telemetry bundle (None = off, the default)
_OBS = None


def enable_obs(obs=None):
    """Attach a `repro.obs.Observability` to this bench process.

    Timers span onto its timeline and records are stamped with its metrics
    digest.  Called implicitly when ``$BENCH_TIMELINE`` is set.
    """
    global _OBS
    if obs is None:
        from repro.obs import Observability

        obs = Observability.create(timeline=True)
    _OBS = obs
    return obs


def get_obs():
    """The active bench bundle, auto-enabled from ``$BENCH_TIMELINE``."""
    if _OBS is None and os.environ.get("BENCH_TIMELINE"):
        enable_obs()
    return _OBS


def time_call(fn, *args, warmup: int = 1, iters: int = 3, span: str | None = None):
    """Median wall time of fn(*args) in seconds (blocks on results).

    With bench telemetry enabled every timed iteration is recorded as a
    span named ``span`` (default: the callable's name) on a ``bench``
    track — the same timeline engine spans land on, so a bench run's
    timing and its engine activity line up in one Perfetto view.
    """
    obs = get_obs()
    tl = obs.timeline if obs is not None else None
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    name = span or getattr(fn, "__name__", "call")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        if tl is not None:
            tl.complete(name, t0, dt, cat="bench", track="bench")
    times.sort()
    return times[len(times) // 2]


def emit(
    name: str,
    seconds: float,
    derived: str = "",
    group: str | None = None,
    metrics: dict | None = None,
):
    """One CSV row: name,us_per_call,derived.

    With ``group``, the row is also accumulated as a machine-readable record
    (plus any ``metrics`` — numeric derived values that would otherwise only
    exist inside the ``derived`` display string) for `write_bench_json`.
    """
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    if group is not None:
        record = {"name": name, "seconds": seconds, "derived": derived}
        if metrics:
            record["metrics"] = {k: float(v) for k, v in metrics.items()}
        obs = get_obs()
        if obs is not None:
            # provenance stamp, NOT a metric: top-level record keys are
            # invisible to check_regression, so digest churn can never
            # trip the baseline gate
            from repro.obs import snapshot_digest

            record["obs_digest"] = snapshot_digest(obs.metrics.snapshot())
        _RECORDS.setdefault(group, []).append(record)


def write_bench_json(group: str, out_dir: str | None = None) -> str:
    """Write the group's accumulated records to ``BENCH_<group>.json``.

    ``out_dir`` defaults to ``$BENCH_OUT_DIR`` or the working directory.
    Returns the written path; the write is atomic (tmp + rename) so a
    crashed suite never leaves a truncated record file behind.  Writing
    drains the group's accumulator, so a suite run twice in one process
    produces two clean files instead of one with duplicated records.
    """
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    payload = {
        "group": group,
        "jax_backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "created_unix": time.time(),
        # drained only after the rename lands (below): a failed write leaves
        # the accumulator intact, so the caller can retry without losing rows
        "records": list(_RECORDS.get(group, [])),
    }
    obs = get_obs()
    if obs is not None:
        from repro.obs import snapshot_digest

        payload["obs_digest"] = snapshot_digest(obs.metrics.snapshot())
    path = os.path.join(out_dir, f"BENCH_{group}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    _RECORDS.pop(group, None)
    if obs is not None and getattr(obs.timeline, "enabled", False):
        obs.timeline.write(os.path.join(out_dir, f"BENCH_{group}.trace.json"))
    return path
