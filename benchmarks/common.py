"""Shared benchmark utilities: timing, CSV rows, machine-readable records.

``emit`` keeps the human-readable ``name,us_per_call,derived`` CSV contract
every suite prints, and — when a ``group`` is given — also accumulates the
row as a structured record.  ``write_bench_json`` then lands the group as
``BENCH_<group>.json`` (name, seconds, derived string, parsed metrics, jax
backend/version), which is what lets the perf trajectory accumulate across
PRs: CI runs the suites at smoke sizes and uploads the JSONs as artifacts.
"""
from __future__ import annotations

import json
import os
import time

import jax

# group -> list of record dicts, accumulated by `emit(..., group=...)`
_RECORDS: dict[str, list] = {}


def time_call(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) in seconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(
    name: str,
    seconds: float,
    derived: str = "",
    group: str | None = None,
    metrics: dict | None = None,
):
    """One CSV row: name,us_per_call,derived.

    With ``group``, the row is also accumulated as a machine-readable record
    (plus any ``metrics`` — numeric derived values that would otherwise only
    exist inside the ``derived`` display string) for `write_bench_json`.
    """
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    if group is not None:
        record = {"name": name, "seconds": seconds, "derived": derived}
        if metrics:
            record["metrics"] = {k: float(v) for k, v in metrics.items()}
        _RECORDS.setdefault(group, []).append(record)


def write_bench_json(group: str, out_dir: str | None = None) -> str:
    """Write the group's accumulated records to ``BENCH_<group>.json``.

    ``out_dir`` defaults to ``$BENCH_OUT_DIR`` or the working directory.
    Returns the written path; the write is atomic (tmp + rename) so a
    crashed suite never leaves a truncated record file behind.  Writing
    drains the group's accumulator, so a suite run twice in one process
    produces two clean files instead of one with duplicated records.
    """
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    payload = {
        "group": group,
        "jax_backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "created_unix": time.time(),
        # drained only after the rename lands (below): a failed write leaves
        # the accumulator intact, so the caller can retry without losing rows
        "records": list(_RECORDS.get(group, [])),
    }
    path = os.path.join(out_dir, f"BENCH_{group}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    _RECORDS.pop(group, None)
    return path
