"""Fault-recovery benchmark: injected failures vs supervised recovery.

The resilience claim (DESIGN.md §Resilience): under injected faults the
serve scheduler either recovers every job **bit-equal** to its fault-free
run or quarantines the bucket with a typed failure — and the recovery
machinery's behaviour is deterministic, so its counts gate EXACT while only
the wall-clock of a recovery rides along as advisory.  Three scenarios:

* ``fault_recovery`` — transient chunk-launch faults plus a torn checkpoint
  write; the supervisor retries, restores from the last intact generation
  and finishes bit-equal.  ``retries_to_success``, ``faults_injected``,
  ``quarantined_buckets`` (0) and ``bit_equal`` (1) are EXACT;
  ``recovery_latency_s`` (wall from first failure to final bit-equal
  results) is advisory.
* ``fault_quarantine`` — a persistent fault exhausts ``max_attempts``; the
  bucket quarantines, every tenant fails typed, a ``quarantine.json``
  manifest lands.  ``quarantined_buckets``/``quarantined_jobs``/
  ``jobs_failed_typed`` are EXACT.
* ``fault_degrade`` — a fused-kernel compile failure degrades the engine to
  the per-sweep path, still bit-equal to a never-fused run.
  ``degraded_kernels`` and ``bit_equal`` are EXACT.

Rows land in ``BENCH_faults.json``; CI's chaos-smoke job re-runs this at
the same size and gates on the committed baseline.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.api.spec import (
    EngineSpec,
    LadderSpec,
    PhaseSpec,
    RunSpec,
    ScheduleSpec,
    SystemSpec,
)
from repro.resilience import Fault, FaultPlan
from repro.serve import JobFailedError, JobState, Scheduler

GROUP = "faults"


def make_spec(seed: int, length: int, r: int, sweeps: int) -> RunSpec:
    half = max(2, sweeps // 2 // 2 * 2)
    return RunSpec(
        system=SystemSpec("ising", {"length": length}),
        ladder=LadderSpec(kind="geometric", n_replicas=r, t_min=1.5, t_max=3.5),
        engine=EngineSpec(swap_interval=2, chunk_intervals=2),
        schedule=ScheduleSpec(phases=(
            PhaseSpec("burn", half),
            PhaseSpec("measure", half, reset_stats=True),
        )),
        observables=("absmag",),
        seed=seed,
    )


def run_serve(specs, faults=None, ckdir=None, **kw):
    kw.setdefault("retry_backoff_s", 0.001)
    sched = Scheduler(checkpoint_dir=ckdir, checkpoint_every_quanta=1,
                     faults=faults, **kw)
    handles = [sched.submit(s, job_id=f"j{i}") for i, s in enumerate(specs)]
    sched.run_until_idle()
    return sched, handles


def bit_equal(a, b) -> bool:
    if not np.array_equal(np.asarray(a.final_energy),
                          np.asarray(b.final_energy)):
        return False
    for pname, summary in b.phases.items():
        got = a.phases.get(pname, {})
        for k, v in summary.items():
            if not np.array_equal(np.asarray(got.get(k)), np.asarray(v)):
                return False
    return True


def scenario_recovery(specs, reference):
    plan = FaultPlan([
        Fault("engine.chunk.launch", at=(1, 5)),
        Fault("checkpoint.write.torn", at=(0,)),
    ])
    with tempfile.TemporaryDirectory() as ckdir:
        t0 = time.perf_counter()
        sched, handles = run_serve(specs, faults=plan, ckdir=ckdir)
        wall = time.perf_counter() - t0
    totals = sched.stats()["resilience"]
    equal = all(
        bit_equal(h.result(timeout=0), reference[h.id]) for h in handles
    )
    emit(
        "fault_recovery", wall,
        f"faults={plan.fired()};retries={totals['retries']}"
        f";recovery_s={totals['recovery_seconds']:.3f};bit_equal={equal}",
        group=GROUP,
        metrics={
            "n_jobs": len(handles),
            "faults_injected": plan.fired(),
            "retries_to_success": totals["retries"],
            "quarantined_buckets": totals["quarantined_buckets"],
            "checkpoint_fallback_depth": totals["fallback_depth"],
            "bit_equal": float(equal),
            "recovery_latency_s": totals["recovery_seconds"],
        },
    )


def scenario_quarantine(specs):
    plan = FaultPlan([Fault("engine.chunk.launch", at=tuple(range(64)))])
    with tempfile.TemporaryDirectory() as ckdir:
        t0 = time.perf_counter()
        sched, handles = run_serve(specs, faults=plan, ckdir=ckdir,
                                   max_attempts=2)
        wall = time.perf_counter() - t0
    totals = sched.stats()["resilience"]
    typed = 0
    for h in handles:
        try:
            h.result(timeout=0)
        except JobFailedError:
            typed += 1
    emit(
        "fault_quarantine", wall,
        f"faults={plan.fired()};quarantined={totals['quarantined_buckets']}"
        f";jobs_failed={typed}",
        group=GROUP,
        metrics={
            "n_jobs": len(handles),
            "quarantined_buckets": totals["quarantined_buckets"],
            "quarantined_jobs": totals["quarantined_jobs"],
            "jobs_failed_typed": float(typed),
        },
    )


def scenario_degrade(spec, reference):
    import dataclasses

    fused = dataclasses.replace(
        spec, system=SystemSpec("ising", dict(spec.system.params,
                                              use_fused=True)),
    )
    plan = FaultPlan([Fault("engine.compile", at=(0,))])
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        t0 = time.perf_counter()
        sched, handles = run_serve([fused], faults=plan)
        wall = time.perf_counter() - t0
    degraded = sum(
        1 for e in sched._engines.values() if getattr(e, "_degraded", False)
    )
    equal = bit_equal(handles[0].result(timeout=0), reference)
    emit(
        "fault_degrade", wall,
        f"degraded={degraded};bit_equal={equal}",
        group=GROUP,
        metrics={
            "degraded_kernels": float(degraded),
            "bit_equal": float(equal),
        },
    )


def run(n_jobs: int = 3, length: int = 4, r: int = 4, sweeps: int = 8,
        out_dir=None):
    specs = [make_spec(seed, length, r, sweeps) for seed in range(n_jobs)]
    _, clean = run_serve(specs)
    reference = {h.id: h.result(timeout=0) for h in clean}

    scenario_recovery(specs, reference)
    scenario_quarantine(specs)
    scenario_degrade(specs[0], reference["j0"])

    path = write_bench_json(GROUP, out_dir)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--length", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--sweeps", type=int, default=8)
    ap.add_argument("--out-dir", default=None,
                    help="where BENCH_faults.json lands (default: $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(n_jobs=args.jobs, length=args.length, r=args.replicas,
        sweeps=args.sweeps, out_dir=args.out_dir)
