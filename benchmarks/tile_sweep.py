"""Paper Fig. 6 analogue: CUDA block-size -> Pallas BlockSpec tile sweep.

The paper tunes replicas-per-CUDA-block; the TPU analogue is replicas per
VMEM-resident kernel tile (``r_blk``) — and, since the interval-fused
kernels (DESIGN.md §6), **sweeps per kernel launch** (``n_sweeps``), the
axis the paper's single-launch device residency actually lives on.  On this
CPU container kernel wall time is interpreter time (not indicative), so the
primary deliverables are *structural*: VMEM working set per tile (per-sweep
and fused models) vs the 16 MB budget, lane alignment of the lattice dim,
and the modeled HBM traffic collapse of fusing — 18 B/cell/sweep down to
2 B/cell/interval.  The XLA (oracle) paths are also timed as the executable
reference, and every row lands in ``BENCH_kernels.json``
(`benchmarks.common.write_bench_json`) so CI accumulates the perf
trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call, write_bench_json
from repro.core.ising import lattice_energy
from repro.kernels import ops, ref
from repro.kernels.ising_sweep import (
    hbm_bytes_per_cell_sweep,
    vmem_working_set_bytes,
    vmem_working_set_bytes_fused,
    vmem_working_set_bytes_packed,
)

VMEM_BYTES = 16 * 2**20
GROUP = "kernels"


def run(length: int = 300, r: int = 64, out_dir=None):
    key = jax.random.key(0)
    k1, k2, k3 = jax.random.split(key, 3)
    spins = jnp.where(jax.random.uniform(k1, (r, length, length)) < 0.5, 1, -1).astype(jnp.int8)
    u = jax.random.uniform(k2, (r, 2, length, length))
    betas = jax.random.uniform(k3, (r,), minval=0.25, maxval=1.0)
    cells = length * length

    xla = jax.jit(lambda s, u, b: ref.ising_sweep(s, u, b, j=1.0, b=0.0))
    t_ref = time_call(xla, spins, u, betas)
    emit(
        "fig6_xla_oracle", t_ref, f"L={length};R={r}",
        group=GROUP,
        metrics={"length": length, "n_replicas": r,
                 "hbm_bytes_per_cell_sweep": hbm_bytes_per_cell_sweep(fused=False)},
    )

    # -- replica-tile axis (the paper's Fig. 6 block-size knob) ----------------
    for r_blk in (1, 2, 4, 8, 16, 32):
        ws = vmem_working_set_bytes(r_blk, length)
        ws_fused = vmem_working_set_bytes_fused(r_blk, length)
        fits = "fits" if max(ws, ws_fused) <= VMEM_BYTES else "EXCEEDS"
        aligned = "aligned" if length % 128 == 0 else f"pad_to_{-(-length // 128) * 128}"
        # structural row; interpret-mode timing would not be meaningful.
        emit(
            f"fig6_rblk{r_blk}", ws / 819e9,  # VMEM fill time at HBM bw (s)
            f"vmem_bytes={ws};vmem_bytes_fused={ws_fused};{fits}"
            f";lanes={aligned};grid={r // min(r_blk, r)}",
            group=GROUP,
            metrics={"r_blk": r_blk, "vmem_bytes": ws,
                     "vmem_bytes_fused": ws_fused,
                     "fits_vmem": float(max(ws, ws_fused) <= VMEM_BYTES)},
        )

    # -- sweeps-per-launch axis (the interval-fusion knob) ---------------------
    # The XLA-oracle wall-clock per sweep is the executable reference for the
    # fused path (the counter-PRNG stream, one launch for S sweeps); modeled
    # HBM traffic shows the 18 -> 2/S B/cell/sweep collapse the kernel buys.
    for n_sweeps in (1, 4, 16, 64):
        fused_fn = jax.jit(lambda s, k, b: ops.ising_sweep_fused(
            s, k, jnp.int32(0), b, n_sweeps=n_sweeps, use_pallas=False
        ))
        t_fused = time_call(fused_fn, spins, key, betas)
        bytes_fused = hbm_bytes_per_cell_sweep(
            fused=True, sweeps_per_interval=n_sweeps
        )
        speedup = hbm_bytes_per_cell_sweep(fused=False) / bytes_fused
        emit(
            f"fig6_fused_s{n_sweeps}", t_fused / n_sweeps,
            f"L={length};R={r};hbm_B_cell_sweep={bytes_fused:.3f}"
            f";traffic_x{speedup:.0f}",
            group=GROUP,
            metrics={"n_sweeps": n_sweeps, "length": length, "n_replicas": r,
                     "seconds_per_sweep": t_fused / n_sweeps,
                     "hbm_bytes_per_cell_sweep": bytes_fused,
                     "traffic_reduction_x": speedup,
                     "modeled_hbm_bytes_per_sweep": bytes_fused * r * cells},
        )

    # -- bit-plane packing axis (multispin storage inside the fused kernel) ----
    # Packing is a VMEM/ALU density knob, bitwise-identical in trajectory:
    # 1 bit/replica spin planes cut the in-kernel state + neighbour-count
    # working set, letting larger replica tiles fit the 16 MB budget.
    for r_blk in (8, 32):
        ws_packed = vmem_working_set_bytes_packed(r_blk, length)
        ws_fused = vmem_working_set_bytes_fused(r_blk, length)
        fits = "fits" if ws_packed <= VMEM_BYTES else "EXCEEDS"
        emit(
            f"fig6_packed_rblk{r_blk}", ws_packed / 819e9,
            f"vmem_bytes_packed={ws_packed};vmem_bytes_fused={ws_fused};{fits}",
            group=GROUP,
            metrics={"r_blk": r_blk, "vmem_bytes_packed": ws_packed,
                     "vmem_bytes_fused": ws_fused,
                     "fits_vmem": float(ws_packed <= VMEM_BYTES)},
        )

    # -- rounds-per-launch axis (the whole-round fusion knob) ------------------
    # One launch = K full PT rounds (sweeps + in-kernel DEO exchange): the
    # state block amortizes over S*K sweeps, and no swap ever exits to host.
    # The pure-JAX round reference is the timed executable (interpret-mode
    # kernel timing is meaningless here); traffic is the analytic model.
    n_sweeps = 4
    rung = jnp.arange(r, dtype=jnp.int32)
    energy = lattice_energy(spins, 1.0, 0.0)
    betas_rung = jnp.sort(betas)[::-1]  # rung order: cold (max beta) -> hot
    for n_rounds in (1, 2, 4):
        round_fn = jax.jit(lambda s, k, ru, e, b, _n=n_rounds: ops.ising_round_fused(
            s, k, jnp.int32(0), jnp.int32(0), ru, e, b,
            n_sweeps=n_sweeps, n_rounds=_n, use_pallas=False
        ))
        t_round = time_call(round_fn, spins, key, rung, energy, betas_rung)
        bytes_round = hbm_bytes_per_cell_sweep(
            fused=True, sweeps_per_interval=n_sweeps,
            rounds_per_launch=n_rounds,
        )
        speedup = hbm_bytes_per_cell_sweep(fused=False) / bytes_round
        emit(
            f"fig6_round_k{n_rounds}", t_round / (n_sweeps * n_rounds),
            f"L={length};R={r};S={n_sweeps}"
            f";hbm_B_cell_sweep={bytes_round:.3f};traffic_x{speedup:.0f}",
            group=GROUP,
            metrics={"rounds_per_launch": n_rounds, "n_sweeps": n_sweeps,
                     "length": length, "n_replicas": r,
                     "seconds_per_sweep": t_round / (n_sweeps * n_rounds),
                     "hbm_bytes_per_cell_sweep": bytes_round,
                     "traffic_reduction_x": speedup,
                     "modeled_hbm_bytes_per_sweep": bytes_round * r * cells},
        )

    path = write_bench_json(GROUP, out_dir)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--length", type=int, default=300)
    ap.add_argument("--replicas", type=int, default=64)
    ap.add_argument("--out-dir", default=None,
                    help="where BENCH_kernels.json lands (default: $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(length=args.length, r=args.replicas, out_dir=args.out_dir)
