"""Paper Fig. 6 analogue: CUDA block-size -> Pallas BlockSpec tile sweep.

The paper tunes replicas-per-CUDA-block; the TPU analogue is replicas per
VMEM-resident kernel tile (`r_blk`).  On this CPU container kernel wall time
is interpreter time (not indicative), so the primary deliverable is the
*structural* table: VMEM working set per tile vs the 16 MB budget, plus lane
alignment of the lattice dim.  The XLA (oracle) path is also timed as the
executable reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels import ops, ref
from repro.kernels.ising_sweep import vmem_working_set_bytes

VMEM_BYTES = 16 * 2**20


def run(length: int = 300, r: int = 64):
    key = jax.random.key(0)
    k1, k2, k3 = jax.random.split(key, 3)
    spins = jnp.where(jax.random.uniform(k1, (r, length, length)) < 0.5, 1, -1).astype(jnp.int8)
    u = jax.random.uniform(k2, (r, 2, length, length))
    betas = jax.random.uniform(k3, (r,), minval=0.25, maxval=1.0)

    xla = jax.jit(lambda s, u, b: ref.ising_sweep(s, u, b, j=1.0, b=0.0))
    t_ref = time_call(xla, spins, u, betas)
    emit("fig6_xla_oracle", t_ref, f"L={length};R={r}")

    for r_blk in (1, 2, 4, 8, 16, 32):
        ws = vmem_working_set_bytes(r_blk, length)
        fits = "fits" if ws <= VMEM_BYTES else "EXCEEDS"
        aligned = "aligned" if length % 128 == 0 else f"pad_to_{-(-length // 128) * 128}"
        # structural row; interpret-mode timing would not be meaningful.
        emit(
            f"fig6_rblk{r_blk}", ws / 819e9,  # VMEM fill time at HBM bw (s)
            f"vmem_bytes={ws};{fits};lanes={aligned};grid={r // min(r_blk, r)}",
        )
