"""Inject the generated roofline table + perf-variant table into
EXPERIMENTS.md (replaces the <!-- ROOFLINE_TABLE --> block and the
<!-- PERF_TABLES -->...<!-- /PERF_TABLES --> span; surrounding prose is
hand-written and preserved).
EXPERIMENTS.md is a *generated artifact* — a skeleton is created on first
run; the curated perf notes live in DESIGN.md §Perf."""
from __future__ import annotations

import json
import os
import re

from benchmarks.roofline_report import load, markdown_table

SKELETON = """# EXPERIMENTS — generated measurement tables

(Produced by `python -m benchmarks.fill_experiments` from the dry-run JSONs
under `results/`; curated interpretation lives in DESIGN.md §Perf.)

<!-- ROOFLINE_TABLE -->

Reading of the baseline table: fraction-of-roofline close to 1 means the
analytic three-term model explains the measured step time.

<!-- PERF_TABLES -->
<!-- /PERF_TABLES -->
"""


def perf_variant_table(rows) -> str:
    """Baseline-vs-variant comparison for every non-baseline record."""
    base = {(r["arch"], r["shape"], r["mesh"]): r for r in rows
            if r.get("variant") == "baseline" and "roofline" in r}
    out = [
        "| cell | variant | T_comp | T_mem^an | T_coll | frac_an (base -> var) | useful |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        v = r.get("variant", "baseline")
        if v == "baseline" or "roofline" not in r:
            continue
        b = base.get((r["arch"], r["shape"], r["mesh"]))
        ro = r["roofline"]
        fb = b["roofline"]["fraction_of_roofline_analytic"] if b else float("nan")
        out.append(
            "| {a}/{s}/{m} | {v} | {c:.3f}s | {ma:.4f}s | {co:.3f}s | {fb:.3f} -> {fa:.3f} | {u:.2f} |".format(
                a=r["arch"], s=r["shape"], m=r["mesh"], v=v,
                c=ro["t_comp_s"], ma=ro["t_mem_analytic_s"], co=ro["t_coll_s"],
                fb=fb, fa=ro["fraction_of_roofline_analytic"],
                u=ro["useful_flops_ratio"],
            )
        )
    return "\n".join(out)


def ising_table() -> str:
    out = [
        "| mesh | swap mode | coll payload/dev | coll wire/dev | by-op |",
        "|---|---|---|---|---|",
    ]
    import glob

    for path in sorted(glob.glob("results/dryrun/ising_paper--*.json")):
        r = json.load(open(path))
        out.append(
            "| {m} | {v} | {p:.0f} B | {w:.0f} B | {b} |".format(
                m=r["mesh"], v=r["variant"], p=r["coll_payload_bytes"],
                w=r["coll_wire_bytes"],
                b="; ".join(f"{k}={vv:.0f}B" for k, vv in r["coll_by_op"].items()),
            )
        )
    return "\n".join(out)


def main():
    rows = load()
    table = markdown_table([r for r in rows if r.get("variant") == "baseline"], "single")
    if os.path.exists("EXPERIMENTS.md"):
        with open("EXPERIMENTS.md") as f:
            text = f.read()
    else:
        text = SKELETON
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n\nReading of the baseline table)",
        "<!-- ROOFLINE_TABLE -->\n" + table,
        text,
        flags=re.S,
    )
    text = re.sub(
        r"<!-- PERF_TABLES -->.*?<!-- /PERF_TABLES -->",
        "<!-- PERF_TABLES -->\n### Variant measurements (all cells)\n\n"
        + perf_variant_table(rows)
        + "\n\n### Ising PT swap traffic (per interval, 1536 replicas × 300²)\n\n"
        + ising_table()
        + "\n<!-- /PERF_TABLES -->",
        text,
        flags=re.S,
    )
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
