"""Perf-trajectory regression gate: fresh BENCH_*.json vs committed baseline.

CI re-runs the benchmark suites at smoke sizes and compares the fresh
records against the baselines committed at the repo root (``BENCH_kernels
.json``, ``BENCH_swap.json``, ``BENCH_shard.json``).  Metrics fall into
tolerance classes by what produces them:

* **exact** — configuration echoes (device/replica counts, sizes, boolean
  structural facts like "the shard fits VMEM").  Any drift is a real
  behaviour change.
* **model** (rtol 1%) — analytic numbers (`hbm_bytes_per_cell_sweep`, VMEM
  working sets, traffic ratios).  These only move when the model moves.
* **measured** (rtol 50%) — deterministic-but-environment-coupled values:
  swap acceptance and round trips at fixed seeds, HLO-parsed collective
  bytes.  Wide tolerance absorbs jax/XLA version shifts while still
  catching order-of-magnitude regressions (a lattice-sized collective
  sneaking into the swap path blows straight through 50%).
* **advisory** — wall-clock (``seconds`` and *_per_sweep/_per_call/_per_sec
  rates).  Printed, never fatal: CI machines are not a timing lab.

A record present in the baseline but missing fresh is fatal (a benchmark
silently disappearing is itself a regression); fresh-only records are fine
(new coverage).  Exit 1 on any fatal drift.

    python -m benchmarks.check_regression --baseline-dir . \
        --fresh-dir /tmp/bench kernels swap shard
"""
from __future__ import annotations

import argparse
import json
import os
import sys

EXACT = {
    "n_devices", "n_replicas", "length", "sweeps", "n_sweeps", "r_blk",
    "fits_vmem", "lattice_independent", "shard_fits", "exceeds_single_chip",
    "rounds_per_launch",
    # serve: the compile-amortization contract — N same-shaped jobs must
    # share exactly one mega-step compile, so this equals the job count
    "n_jobs", "jobs_packed_per_compile",
    # obs: instrumentation is structural — the host loop emits a fixed span
    # count per chunk, and attaching telemetry must never force a recompile
    "timeline_events_per_chunk", "n_compiles_obs_off", "n_compiles_obs_on",
    # resilience: fault plans are seeded and retries deterministic, so the
    # recovery machinery's counts — and the bit-equal verdict itself — are
    # structural facts; only a recovery's wall-clock is advisory
    "faults_injected", "retries_to_success", "quarantined_buckets",
    "quarantined_jobs", "jobs_failed_typed", "checkpoint_fallback_depth",
    "bit_equal", "degraded_kernels",
}
MODEL = {
    "hbm_bytes_per_cell_sweep", "traffic_reduction_x", "vmem_bytes",
    "vmem_bytes_fused", "vmem_bytes_packed", "vmem_bytes_single_chip",
    "vmem_bytes_per_shard", "modeled_hbm_bytes_per_sweep",
    # the obs <5%-overhead contract as a normalized verdict: 1.0 while the
    # measured on/off wall ratio is within budget, the raw ratio (an
    # automatic >1% drift) the moment it breaches — deterministic when the
    # contract holds, fatal when it doesn't (the raw ratio itself rides
    # along as advisory `obs_overhead_raw`)
    "obs_overhead_ratio",
}
MEASURED = {
    "swap_acceptance", "round_trips", "collective_bytes_per_exchange",
    "payload_bytes_per_exchange", "wire_bytes_per_chunk",
    "collective_wire_bytes_per_chunk", "collective_count",
}
# everything else (us_per_sweep, trips_per_sec, overhead_pct, ...) is
# timing-derived: advisory only

MODEL_RTOL = 0.01
MEASURED_RTOL = 0.50
MEASURED_ATOL = 1e-9


def _load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r for r in payload.get("records", [])}


def _rel_drift(base: float, fresh: float) -> float:
    if base == fresh:
        return 0.0
    denom = max(abs(base), MEASURED_ATOL)
    return abs(fresh - base) / denom


def compare_group(group: str, baseline_dir: str, fresh_dir: str):
    """Yield (severity, message) rows; severity in {'fail', 'warn', 'ok'}."""
    fname = f"BENCH_{group}.json"
    base_path = os.path.join(baseline_dir, fname)
    fresh_path = os.path.join(fresh_dir, fname)
    if not os.path.exists(base_path):
        yield "fail", f"{group}: missing committed baseline {base_path}"
        return
    if not os.path.exists(fresh_path):
        yield "fail", f"{group}: missing fresh output {fresh_path}"
        return
    base = _load(base_path)
    fresh = _load(fresh_path)
    for name, brec in sorted(base.items()):
        frec = fresh.get(name)
        if frec is None:
            yield "fail", f"{group}/{name}: record missing from fresh run"
            continue
        bm = brec.get("metrics", {})
        fm = frec.get("metrics", {})
        for metric, bval in sorted(bm.items()):
            if metric not in fm:
                yield "fail", f"{group}/{name}.{metric}: metric disappeared"
                continue
            fval = fm[metric]
            # Non-numeric metrics never reach the drift arithmetic: strings
            # (e.g. a backend/layout tag a future bench carries) are compared
            # for identity only and warn — they are provenance, not perf —
            # and booleans are structural facts, so any boolean outside the
            # EXACT set is still classified exact rather than floor-divided
            # into the float tolerance classes.
            if isinstance(bval, str) or isinstance(fval, str):
                if bval != fval:
                    yield "warn", (
                        f"{group}/{name}.{metric}: string metric changed "
                        f"{bval!r} -> {fval!r} (skipped drift check)"
                    )
                continue
            if isinstance(bval, bool) or isinstance(fval, bool):
                if bval != fval:
                    yield "fail", (
                        f"{group}/{name}.{metric}: boolean metric changed "
                        f"{bval} -> {fval}"
                    )
                continue
            drift = _rel_drift(bval, fval)
            if metric in EXACT:
                if bval != fval:
                    yield "fail", (
                        f"{group}/{name}.{metric}: exact metric changed "
                        f"{bval} -> {fval}"
                    )
            elif metric in MODEL:
                if drift > MODEL_RTOL:
                    yield "fail", (
                        f"{group}/{name}.{metric}: model drift "
                        f"{bval} -> {fval} ({drift:.1%} > {MODEL_RTOL:.0%})"
                    )
            elif metric in MEASURED:
                if drift > MEASURED_RTOL:
                    yield "fail", (
                        f"{group}/{name}.{metric}: measured drift "
                        f"{bval} -> {fval} ({drift:.1%} > {MEASURED_RTOL:.0%})"
                    )
            elif drift > 1.0:
                yield "warn", (
                    f"{group}/{name}.{metric}: timing moved "
                    f"{bval:.4g} -> {fval:.4g} (advisory)"
                )
        bsec, fsec = brec.get("seconds", 0.0), frec.get("seconds", 0.0)
        if bsec > 0 and _rel_drift(bsec, fsec) > 1.0:
            yield "warn", (
                f"{group}/{name}: wall-clock {bsec * 1e6:.0f}us -> "
                f"{fsec * 1e6:.0f}us (advisory)"
            )
    yield "ok", (
        f"{group}: {len(base)} baseline records checked "
        f"({len(set(fresh) - set(base))} fresh-only)"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("groups", nargs="+",
                    help="bench group names, e.g. kernels swap shard")
    ap.add_argument("--baseline-dir", default=".",
                    help="where committed BENCH_<group>.json baselines live")
    ap.add_argument("--fresh-dir", required=True,
                    help="where the fresh run wrote its BENCH_<group>.json")
    args = ap.parse_args(argv)
    failures = 0
    for group in args.groups:
        for severity, msg in compare_group(
            group, args.baseline_dir, args.fresh_dir
        ):
            print(f"[{severity.upper()}] {msg}")
            if severity == "fail":
                failures += 1
    if failures:
        print(f"{failures} regression(s) vs committed baselines", file=sys.stderr)
        return 1
    print("perf trajectory OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
