"""Serve-layer load benchmark: packed scheduler vs one-Session-per-job.

The serving claim (DESIGN.md §Serve): for N same-shaped tenant jobs, the
`repro.serve.Scheduler` packs all N along the mega-step's ensemble axis and
compiles **once**, while the naive path — a fresh `Session` per job — pays
the compile N times and serializes the sweeps.  This suite submits an
open-loop burst of N seed-variant Ising jobs and records both paths:

* ``jobs_per_sec`` (packed and naive) and the packed/naive ``speedup_x`` —
  wall-clock, so advisory in `benchmarks.check_regression`'s class scheme
  (the repo's timing tolerance class: printed, never fatal);
* per-job completion ``latency_p50_s`` / ``latency_p99_s`` from submission
  to `JobResult` delivery (advisory, same class);
* ``jobs_packed_per_compile`` — N jobs / mega-step compiles
  (`Engine.n_compiles`).  This is the *structural* compile-amortization
  contract and is checked EXACT: the whole burst must land in one bucket on
  one executable, so the value equals N.  Any drop means the packing broke.

Rows land in ``BENCH_serve.json``; CI runs this at smoke size and gates on
the committed baseline.  ``--assert-speedup X`` makes the packed/naive ratio
a hard failure locally (not used in CI — timing there is advisory).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.api.session import Session
from repro.api.spec import (
    EngineSpec,
    LadderSpec,
    PhaseSpec,
    RunSpec,
    ScheduleSpec,
    SystemSpec,
)
from repro.serve import Scheduler

GROUP = "serve"


def make_spec(seed: int, length: int, r: int, sweeps: int,
              swap_interval: int, chunk_intervals: int) -> RunSpec:
    burn = max(swap_interval, (sweeps // 4) // swap_interval * swap_interval)
    measure = max(swap_interval, (sweeps - burn) // swap_interval * swap_interval)
    return RunSpec(
        system=SystemSpec("ising", {"length": length}),
        ladder=LadderSpec(kind="geometric", n_replicas=r, t_min=1.5, t_max=4.5),
        engine=EngineSpec(
            swap_interval=swap_interval, chunk_intervals=chunk_intervals
        ),
        schedule=ScheduleSpec(phases=(
            PhaseSpec("burn", burn),
            PhaseSpec("measure", measure, reset_stats=True),
        )),
        observables=("absmag",),
        seed=seed,
    )


def run_packed(specs, quantum_chunks: int):
    """All jobs through one scheduler; per-job latency from the step loop."""
    sched = Scheduler(quantum_chunks=quantum_chunks)
    t0 = time.perf_counter()
    handles = [sched.submit(s) for s in specs]
    finish: dict[str, float] = {}
    while not sched.idle():
        sched.step()
        now = time.perf_counter()
        for job in handles:
            if job.done() and job.id not in finish:
                finish[job.id] = now
    wall = time.perf_counter() - t0
    for job in handles:
        job.result(timeout=0)  # raise if anything failed
    latencies = np.asarray([finish[j.id] - t0 for j in handles])
    return wall, latencies, sched.stats()


def run_naive(specs):
    """The baseline the scheduler replaces: a fresh Session per job,
    executed back-to-back (every job pays its own mega-step compile)."""
    t0 = time.perf_counter()
    latencies = []
    compiles = 0
    for spec in specs:
        session = Session(spec)
        session.run()
        compiles += session.engine.n_compiles
        latencies.append(time.perf_counter() - t0)
    wall = time.perf_counter() - t0
    return wall, np.asarray(latencies), compiles


def run(n_jobs: int = 8, length: int = 8, r: int = 4, sweeps: int = 320,
        swap_interval: int = 8, chunk_intervals: int = 5,
        quantum_chunks: int = 2, out_dir=None, assert_speedup: float = 0.0):
    specs = [
        make_spec(seed, length, r, sweeps, swap_interval, chunk_intervals)
        for seed in range(n_jobs)
    ]
    # schedule sweeps divide into whole chunks so the packed engine needs no
    # remainder executable — the one-compile contract below is exact
    total = specs[0].schedule.total_sweeps

    packed_wall, packed_lat, stats = run_packed(specs, quantum_chunks)
    naive_wall, naive_lat, naive_compiles = run_naive(specs)

    packed_rate = n_jobs / packed_wall
    naive_rate = n_jobs / naive_wall
    speedup = packed_rate / naive_rate
    per_compile = n_jobs / stats["n_compiles"]
    assert stats["n_compiles"] == 1, (
        f"packing broke: {n_jobs} same-shaped jobs cost "
        f"{stats['n_compiles']} mega-step compiles (expected 1)"
    )
    emit(
        "serve_packed", packed_wall,
        f"jobs={n_jobs};sweeps={total};jobs_per_s={packed_rate:.2f}"
        f";compiles={stats['n_compiles']};p99={packed_lat.max():.3f}s",
        group=GROUP,
        metrics={
            "n_jobs": n_jobs,
            "sweeps": total,
            "jobs_packed_per_compile": per_compile,
            "jobs_per_sec": packed_rate,
            "latency_p50_s": float(np.percentile(packed_lat, 50)),
            "latency_p99_s": float(np.percentile(packed_lat, 99)),
            "n_quanta": float(stats["n_quanta"]),
        },
    )
    emit(
        "serve_naive", naive_wall,
        f"jobs={n_jobs};sweeps={total};jobs_per_s={naive_rate:.2f}"
        f";compiles={naive_compiles}",
        group=GROUP,
        metrics={
            "n_jobs": n_jobs,
            "sweeps": total,
            "jobs_per_sec": naive_rate,
            "latency_p50_s": float(np.percentile(naive_lat, 50)),
            "latency_p99_s": float(np.percentile(naive_lat, 99)),
            "compiles_naive": float(naive_compiles),
        },
    )
    emit(
        "serve_speedup", 0.0,
        f"packed_vs_naive={speedup:.2f}x;jobs={n_jobs}",
        group=GROUP,
        metrics={"n_jobs": n_jobs, "speedup_x": speedup},
    )
    if assert_speedup > 0:
        assert speedup >= assert_speedup, (
            f"packed/naive speedup {speedup:.2f}x < required {assert_speedup}x"
        )
    path = write_bench_json(GROUP, out_dir)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--length", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--sweeps", type=int, default=320)
    ap.add_argument("--quantum-chunks", type=int, default=2)
    ap.add_argument("--assert-speedup", type=float, default=0.0,
                    help="fail unless packed/naive >= this ratio (local use)")
    ap.add_argument("--out-dir", default=None,
                    help="where BENCH_serve.json lands (default: $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(n_jobs=args.jobs, length=args.length, r=args.replicas,
        sweeps=args.sweeps, quantum_chunks=args.quantum_chunks,
        out_dir=args.out_dir, assert_speedup=args.assert_speedup)
