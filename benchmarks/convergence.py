"""Paper Fig. 3a/3b analogues: magnetization vs temperature (phase
transition) and iterations-to-converge vs lattice size (quadratic scaling).

Fig. 3a is a declarative `repro.api.RunSpec` (burn + measure schedule) run
purely on the engine's streaming statistics; Fig. 3b needs the time *series*
and uses the engine's opt-in per-chunk trace streaming, re-entering one
spec-compiled engine across seeds.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, time_call
from repro.api import (
    EngineSpec, LadderSpec, PhaseSpec, RunSpec, ScheduleSpec, Session,
    SystemSpec,
)
from repro.core import diagnostics


def fig3a(r: int = 16, length: int = 16, sweeps: int = 3000):
    interval = 10
    # engine runs advance whole intervals: round the budget so any `sweeps`
    # argument works and the burn/measure split stays interval-aligned
    n_int = max(2, round(sweeps / interval))
    sweeps = n_int * interval
    burn = (n_int // 2) * interval
    spec = RunSpec(
        system=SystemSpec("ising", {"length": length}),
        ladder=LadderSpec(kind="linear", n_replicas=r, t_min=1.0, t_max=4.0),
        engine=EngineSpec(swap_interval=interval, chunk_intervals=50, donate=False),
        schedule=ScheduleSpec(phases=(
            PhaseSpec(name="burn", n_sweeps=burn),
            # the streaming analogue of trace-and-discard-half: zero the
            # O(R) accumulators, then measure (same estimator, O(R) memory)
            PhaseSpec(name="measure", n_sweeps=sweeps - burn, reset_stats=True),
        )),
        observables=("absmag",),
    )
    session = Session(spec)
    temps = spec.ladder.build()
    st = session.init_state()
    t = time_call(lambda s: session.engine.run(s, sweeps)[0].pt.energy, st, iters=1)
    m = session.run().phases["measure"].summary["mean_absmag"]
    rows = ";".join(f"T{temps[i]:.2f}={m[i]*100:.0f}%" for i in range(0, r, 3))
    emit("fig3a_magnetization", t, rows + f";Tc~2.27_observed={'yes' if m[0]>0.8>m[-1] else 'no'}")


def fig3b(sizes=(8, 12, 16, 24), seeds=3, max_sweeps: int = 6000):
    """Iterations until the cold chain saturates |m|, vs lattice size.

    Recording granularity = swap_interval sweeps; a short interval and a
    tight threshold keep the detector above the measurement floor (larger
    lattices need orders more sweeps — the paper's Fig. 3b scaling)."""
    iters = []
    for L in sizes:
        # one spec-compiled Session per lattice size: its engine's mega-step
        # is identical across seeds (only the PRNG key changes), so seeds
        # share the compiled-executable cache
        r = 8
        spec = RunSpec(
            system=SystemSpec("ising", {"length": L}),
            ladder=LadderSpec(kind="linear", n_replicas=r, t_min=1.0, t_max=3.0),
            engine=EngineSpec(swap_interval=2, chunk_intervals=250,
                              record_trace=True),
            schedule=ScheduleSpec(phases=(
                PhaseSpec(name="run", n_sweeps=max_sweeps),
            )),
            observables=("absmag",),
        )
        session = Session(spec)
        temps = spec.ladder.build()
        per_seed = []
        for seed in range(seeds):
            st = session.engine.init(jax.random.key(seed), temps)
            _, res = session.engine.run(st, max_sweeps)
            am = res.trace["absmag"][:, 0]  # cold rung
            it = diagnostics.iterations_to_converge(am, threshold=0.98, window=4)
            per_seed.append(it * spec.engine.swap_interval if it >= 0 else max_sweeps)
        iters.append(float(np.median(per_seed)))
    sizes_a = np.asarray(sizes, float)
    its = np.asarray(iters, float)
    # fit sweeps ~ L^alpha; the PAPER counts single-spin MH iterations and
    # one checkerboard sweep = L^2 of those, so the paper-units exponent is
    # alpha + 2 (paper Fig. 3b reports ~quadratic growth).
    mask = its < max_sweeps
    alpha = float(np.polyfit(np.log(sizes_a[mask]), np.log(its[mask] + 1), 1)[0]) if mask.sum() > 1 else float("nan")
    detail = ";".join(f"L{int(l)}={int(i)}" for l, i in zip(sizes, iters))
    emit(
        "fig3b_convergence_vs_L", its.sum() / 1e6,
        f"{detail};sweep_exponent={alpha:.2f};paper_iteration_exponent={alpha+2:.2f}",
    )


def run():
    fig3a()
    fig3b()
