"""Multi-device PT scaling: shard_map mega-step over a replica mesh.

Weak and strong scaling of the sharded engine (DESIGN.md §Distributed) on a
simulated host-device mesh: wall-clock per sweep and measured collective
payload bytes per exchange (`repro.hlo.collectives`) vs device count, plus
the capacity headline — a replica ladder whose fused-kernel VMEM working set
exceeds a single chip's 16 MB budget running end-to-end once sharded, each
shard comfortably inside budget.

CPU wall-clock is not TPU wall-clock, but the *structure* carries: the
collective bytes are exact (parsed from the compiled HLO, O(R) scalar rows
per exchange), and the VMEM working-set model is the same one the tile
sweep and the kernel tests use.  Rows land in ``BENCH_shard.json``
(`benchmarks.common.write_bench_json`) — the perf-trajectory record
`benchmarks/check_regression.py` gates CI against.

Run with simulated devices (the flag must precede jax import; the
``--devices`` preamble below handles it):

    python -m benchmarks.shard_scaling --devices 8
"""
from __future__ import annotations

import os as _os
import sys as _sys

if __name__ == "__main__" and "--devices" in _sys.argv:
    # must land before jax is imported — the flag is read at backend init
    _n = _sys.argv[_sys.argv.index("--devices") + 1]
    _os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_n}"
    )

import numpy as np

import jax

from benchmarks.common import emit, time_call, write_bench_json
from repro.core import ising, ladder
from repro.core.distributed import MeshSpec
from repro.engine import Engine, EngineConfig
from repro.hlo.collectives import parse_collectives
from repro.kernels.ising_sweep import vmem_working_set_bytes_fused

GROUP = "shard"
VMEM_BYTES = 16 * 2**20


def _make_engine(r: int, length: int, n_dev: int, *, fused: bool = False):
    cfg = EngineConfig(
        n_replicas=r,
        swap_interval=5,
        chunk_intervals=4,
        donate=False,  # timing loop re-runs the same state
        mesh=None if n_dev == 1 else MeshSpec(ensemble=1, replica=n_dev),
    )
    params = {"length": length}
    if fused:
        params.update(use_fused=True, n_sweeps_fused=5)
    system = ising.IsingSystem(**params)
    eng = Engine(system, cfg)
    state = eng.init(jax.random.key(7), np.asarray(ladder.paper_ladder(r)))
    return eng, state


def _measure(name: str, r: int, length: int, n_dev: int, sweeps: int):
    eng, state = _make_engine(r, length, n_dev)
    t = time_call(lambda st: eng.run(st, sweeps)[0].pt.energy, state, iters=3)
    chunk = eng.config.chunk_intervals
    st = parse_collectives(eng._compiled(state, chunk).as_text())
    bytes_per_exchange = st.payload_bytes / chunk
    emit(
        name, t,
        f"devices={n_dev};R={r};L={length};sweeps={sweeps}"
        f";us_per_sweep={t / sweeps * 1e6:.1f}"
        f";coll_B_per_exchange={bytes_per_exchange:.0f}",
        group=GROUP,
        metrics={
            "n_devices": n_dev, "n_replicas": r, "length": length,
            "sweeps": sweeps, "us_per_sweep": t / sweeps * 1e6,
            "collective_bytes_per_exchange": bytes_per_exchange,
            "collective_wire_bytes_per_chunk": st.wire_bytes,
        },
    )


def _device_counts():
    n = jax.device_count()
    return [d for d in (1, 2, 4, 8) if d <= n]


def run_weak(r_per_device: int = 8, length: int = 16, sweeps: int = 100):
    """Weak scaling: R grows with the mesh, shard size held fixed."""
    for d in _device_counts():
        _measure(f"weak_d{d}", r_per_device * d, length, d, sweeps)


def run_strong(r: int = 16, length: int = 16, sweeps: int = 100):
    """Strong scaling: fixed ladder spread over more devices."""
    for d in _device_counts():
        if r % d:
            continue
        _measure(f"strong_d{d}", r, length, d, sweeps)


def run_capacity(length: int = 128, r: int = 64, sweeps: int = 10):
    """A ladder too big for one chip's VMEM runs end-to-end sharded.

    The fused-kernel working set for the whole ladder exceeds the 16 MB
    single-chip budget; split over the replica mesh each shard fits.  The
    run itself uses the default per-sweep path (this container has no real
    TPU), but the budget numbers are the same static model the tile sweep
    and kernel tests use, and the sharded mega-step is the real engine.
    """
    n_dev = jax.device_count()
    if n_dev < 2:
        emit("capacity_skipped", 0.0, "needs >=2 devices", group=GROUP)
        return
    ws_single = vmem_working_set_bytes_fused(r, length)
    ws_shard = vmem_working_set_bytes_fused(r // n_dev, length)
    if ws_single <= VMEM_BYTES:
        emit(
            "capacity_skipped", 0.0,
            f"R={r},L={length} fits one chip ({ws_single}B); raise sizes",
            group=GROUP,
        )
        return
    eng, state = _make_engine(r, length, n_dev)
    t = time_call(lambda st: eng.run(st, sweeps)[0].pt.energy, state,
                  warmup=1, iters=1)
    emit(
        "capacity_beyond_vmem", t,
        f"devices={n_dev};R={r};L={length};vmem_single={ws_single}"
        f";vmem_shard={ws_shard};budget={VMEM_BYTES}",
        group=GROUP,
        metrics={
            "n_devices": n_dev, "n_replicas": r, "length": length,
            "vmem_bytes_single_chip": ws_single,
            "vmem_bytes_per_shard": ws_shard,
            "exceeds_single_chip": float(ws_single > VMEM_BYTES),
            "shard_fits": float(ws_shard <= VMEM_BYTES),
        },
    )


def run(r_per_device: int = 8, length: int = 16, sweeps: int = 100,
        out_dir=None):
    run_weak(r_per_device=r_per_device, length=length, sweeps=sweeps)
    run_strong(r=2 * r_per_device, length=length, sweeps=sweeps)
    run_capacity()
    path = write_bench_json(GROUP, out_dir)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=0,
                    help="simulate N host devices (sets "
                         "--xla_force_host_platform_device_count before "
                         "jax is imported)")
    ap.add_argument("--r-per-device", type=int, default=8)
    ap.add_argument("--length", type=int, default=16)
    ap.add_argument("--sweeps", type=int, default=100)
    ap.add_argument("--out-dir", default=None,
                    help="where BENCH_shard.json lands (default: "
                         "$BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(r_per_device=args.r_per_device, length=args.length,
        sweeps=args.sweeps, out_dir=args.out_dir)
