"""System-zoo throughput: one row per PT-sampleable system (DESIGN.md §8).

Times one engine mega-step per system at benchmark scale (larger than the
validation-zoo instances, smaller than the paper's L=300 runs) and reports
per-sweep cost plus the system-specific derived figure:

  zoo_ising      checkerboard Pallas path (the paper's workload, reference row)
  zoo_potts      q=3 Potts through the Pallas replica-tile kernel
  zoo_ea         ±J Edwards-Anderson (pure-XLA disordered checkerboard)
  zoo_hp         HP lattice protein (sequential-move chain, generic vmap path)
  zoo_gaussian   1-D mixture (lower bound on driver overhead per sweep)

Run: PYTHONPATH=src python -m benchmarks.run --only zoo
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, time_call
from repro.core import gaussian, hp, ising, ladder, potts, spin_glass
from repro.engine import Engine, EngineConfig


def _bench(name: str, system, temps, sweeps: int, derived: str):
    r = len(temps)
    cfg = EngineConfig(
        n_replicas=r,
        swap_interval=sweeps,
        chunk_intervals=1,
        donate=False,  # timing loop re-runs the same state
    )
    eng = Engine(system, cfg)
    state = eng.init(jax.random.key(0), np.asarray(temps))
    t = time_call(lambda st: eng.run(st, sweeps)[0].pt.energy, state, iters=3)
    emit(f"zoo_{name}", t, f"sweeps={sweeps};R={r};us_per_sweep={t*1e6/sweeps:.1f};{derived}")


def run(r: int = 16, length: int = 32, sweeps: int = 50):
    temps = tuple(float(t) for t in ladder.paper_ladder(r))
    _bench(
        "ising",
        ising.IsingSystem(length=length, use_pallas=True),
        temps,
        sweeps,
        f"L={length};pallas=1",
    )
    _bench(
        "potts",
        potts.PottsSystem(shape=(length, length), q=3, use_pallas=True),
        temps,
        sweeps,
        f"L={length};q=3;pallas=1",
    )
    _bench(
        "ea",
        spin_glass.EASpinGlass(shape=(length, length)),
        temps,
        sweeps,
        f"L={length};xla_fallback=1",
    )
    _bench(
        "hp",
        hp.HPChain(sequence="HPHPPHHPHHPHPHHPPHPH"),
        temps,
        sweeps,
        "N=20;moveset=end+corner",
    )
    _bench(
        "gaussian",
        gaussian.GaussianMixture(),
        temps,
        sweeps,
        "modes=2",
    )
