"""System-zoo throughput: one row per PT-sampleable system (DESIGN.md §8).

Times one engine mega-step per system at benchmark scale (larger than the
validation-zoo instances, smaller than the paper's L=300 runs) and reports
per-sweep cost plus the system-specific derived figure:

  zoo_ising      checkerboard Pallas path (the paper's workload, reference row)
  zoo_potts      q=3 Potts through the Pallas replica-tile kernel
  zoo_ea         ±J Edwards-Anderson (pure-XLA disordered checkerboard)
  zoo_hp         HP lattice protein (sequential-move chain, generic vmap path)
  zoo_gaussian   1-D mixture (lower bound on driver overhead per sweep)

Each row is a declarative `repro.api.RunSpec` (every system nameable through
the constructor registry); `Session` compiles the spec and the timing loop
re-enters its engine.

Run: PYTHONPATH=src python -m benchmarks.run --only zoo
"""
from __future__ import annotations

from benchmarks.common import emit, time_call
from repro.api import (
    EngineSpec, LadderSpec, PhaseSpec, RunSpec, ScheduleSpec, Session,
    SystemSpec,
)


def _bench(name: str, system_spec: SystemSpec, r: int, sweeps: int, derived: str):
    spec = RunSpec(
        system=system_spec,
        ladder=LadderSpec(kind="paper", n_replicas=r),
        engine=EngineSpec(
            swap_interval=sweeps,
            chunk_intervals=1,
            donate=False,  # timing loop re-runs the same state
        ),
        schedule=ScheduleSpec(phases=(PhaseSpec(name="bench", n_sweeps=sweeps),)),
    )
    session = Session(spec)
    state = session.init_state()
    t = time_call(
        lambda st: session.engine.run(st, sweeps)[0].pt.energy, state, iters=3
    )
    emit(f"zoo_{name}", t, f"sweeps={sweeps};R={r};us_per_sweep={t*1e6/sweeps:.1f};{derived}")


def run(r: int = 16, length: int = 32, sweeps: int = 50):
    _bench(
        "ising",
        SystemSpec("ising", {"length": length, "use_pallas": True}),
        r,
        sweeps,
        f"L={length};pallas=1",
    )
    _bench(
        "potts",
        SystemSpec("potts", {"shape": (length, length), "q": 3, "use_pallas": True}),
        r,
        sweeps,
        f"L={length};q=3;pallas=1",
    )
    _bench(
        "ea",
        SystemSpec("ea_spin_glass", {"shape": (length, length)}),
        r,
        sweeps,
        f"L={length};xla_fallback=1",
    )
    _bench(
        "hp",
        SystemSpec("hp_protein", {"sequence": "HPHPPHHPHHPHPHHPPHPH"}),
        r,
        sweeps,
        "N=20;moveset=end+corner",
    )
    _bench(
        "gaussian",
        SystemSpec("gaussian", {}),
        r,
        sweeps,
        "modes=2",
    )
