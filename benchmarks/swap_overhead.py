"""Paper Fig. 7 analogue: swap-interval effect on runtime.

The paper's observation: swap cost is negligible at any interval because the
Ising system is glassy (low swap acceptance) and the swap itself is cheap
relative to an interval of sweeps.  We reproduce both the runtime comparison
and the acceptance-rate observation, and additionally compare the faithful
``state`` swap mode against the optimized ``temp`` mode (DESIGN.md §2).

Runs through the chunked engine; the acceptance column comes from the O(R)
online swap counters (`repro.engine.stats`) — no trace is materialized.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, time_call
from repro.core import ising, ladder
from repro.engine import Engine, EngineConfig


def run(r: int = 64, length: int = 32, sweeps: int = 1000):
    system = ising.IsingSystem(length=length)
    temps = np.asarray(ladder.paper_ladder(r))

    base_time = None
    for interval in (0, 10, 100, 1000):
        # Engine runs advance whole intervals; round the sweep budget to the
        # nearest interval multiple (at least one interval) so any `sweeps`
        # argument works, and report per-sweep-normalized overhead.
        n = sweeps if interval == 0 else interval * max(1, round(sweeps / interval))
        for mode in ("temp", "state") if interval else (("temp",)):
            cfg = EngineConfig(
                n_replicas=r,
                swap_interval=interval,
                swap_mode=mode,
                measure_interval=sweeps,
                chunk_intervals=32,
                donate=False,  # timing loop re-runs the same state
            )
            eng = Engine(system, cfg)
            state = eng.init(jax.random.key(1), temps)
            t = time_call(lambda st: eng.run(st, n)[0].pt.energy, state, iters=3)
            per_sweep = t / n
            if interval == 0:
                base_time = per_sweep
                emit(f"fig7_noswap", t, f"sweeps={n};R={r}")
                continue
            # acceptance from the streaming counters (one O(R) readback)
            _, res = eng.run(state, n)
            acc = float(np.mean(res.summary["swap_acceptance"]))
            emit(
                f"fig7_interval{interval}_{mode}", t,
                f"sweeps={n};overhead={100*(per_sweep-base_time)/base_time:.1f}%"
                f";swap_acc={acc:.3f}",
            )
