"""Paper Fig. 7 analogue: swap-interval effect on runtime.

The paper's observation: swap cost is negligible at any interval because the
Ising system is glassy (low swap acceptance) and the swap itself is cheap
relative to an interval of sweeps.  We reproduce both the runtime comparison
and the acceptance-rate observation, and additionally compare the faithful
``state`` swap mode against the optimized ``temp`` mode (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, time_call
from repro.core import diagnostics, ising, ladder, pt


def run(r: int = 64, length: int = 32, sweeps: int = 1000):
    system = ising.IsingSystem(length=length)
    temps = tuple(float(t) for t in ladder.paper_ladder(r))

    base_time = None
    for interval in (0, 10, 100, 1000):
        for mode in ("temp", "state") if interval else (("temp",)):
            cfg = pt.PTConfig(
                n_replicas=r, temps=temps, swap_interval=interval, swap_mode=mode
            )
            state = pt.init(system, cfg, jax.random.key(1))
            fn = jax.jit(lambda st: pt.run(system, cfg, st, sweeps)[0].energy)
            t = time_call(fn, state, iters=3)
            if interval == 0:
                base_time = t
                emit(f"fig7_noswap", t, f"sweeps={sweeps};R={r}")
                continue
            # acceptance rate for the derived column
            _, trace = pt.run(system, cfg, pt.init(system, cfg, jax.random.key(1)), sweeps)
            acc = float(np.mean(diagnostics.swap_acceptance_rate(trace)))
            emit(
                f"fig7_interval{interval}_{mode}", t,
                f"overhead={100*(t-base_time)/base_time:.1f}%;swap_acc={acc:.3f}",
            )
