"""Paper Fig. 7 analogue: swap-interval effect on runtime + exchange strategies.

The paper's observation: swap cost is negligible at any interval because the
Ising system is glassy (low swap acceptance) and the swap itself is cheap
relative to an interval of sweeps.  We reproduce both the runtime comparison
and the acceptance-rate observation, compare the faithful ``state`` swap
mode against the optimized ``temp`` mode (DESIGN.md §2), and benchmark the
pluggable exchange strategies (DESIGN.md §Exchange): per-strategy wall-clock
vs *round-trip rate* — round trips per second is the accuracy-per-FLOP
currency exchange strategies compete on, and exactly what the raw
swap-overhead numbers can't show.

Runs through the chunked engine; acceptance and round-trip columns come from
the O(R) online counters (`repro.engine.stats`) — no trace is materialized.
Rows land in ``BENCH_swap.json`` via `benchmarks.common.write_bench_json`
(the perf-trajectory record CI uploads on every PR).

With ``--devices N`` (or >=2 devices already visible) the suite also
compiles the *sharded* mega-step per exchange strategy and reports measured
collective payload bytes per exchange from the compiled HLO
(`repro.hlo.collectives`), asserting temp-mode DEO/SEO swap traffic is O(R)
— independent of the lattice size, no (L, L) block on the wire.
"""
from __future__ import annotations

import os as _os
import sys as _sys

if __name__ == "__main__" and "--devices" in _sys.argv:
    # must land before jax is imported — the flag is read at backend init
    _n = _sys.argv[_sys.argv.index("--devices") + 1]
    _os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={_n}"
    )

import numpy as np

import jax

from benchmarks.common import emit, time_call, write_bench_json
from repro.core import ising, ladder
from repro.engine import Engine, EngineConfig
from repro.exchange import available_strategies

GROUP = "swap"


def run_intervals(r: int = 64, length: int = 32, sweeps: int = 1000):
    """Fig. 7: per-sweep overhead of the swap phase vs swap interval."""
    system = ising.IsingSystem(length=length)
    temps = np.asarray(ladder.paper_ladder(r))

    base_time = None
    for interval in (0, 10, 100, 1000):
        # Engine runs advance whole intervals; round the sweep budget to the
        # nearest interval multiple (at least one interval) so any `sweeps`
        # argument works, and report per-sweep-normalized overhead.
        n = sweeps if interval == 0 else interval * max(1, round(sweeps / interval))
        for mode in ("temp", "state") if interval else (("temp",)):
            cfg = EngineConfig(
                n_replicas=r,
                swap_interval=interval,
                swap_mode=mode,
                measure_interval=sweeps,
                chunk_intervals=32,
                donate=False,  # timing loop re-runs the same state
            )
            eng = Engine(system, cfg)
            state = eng.init(jax.random.key(1), temps)
            t = time_call(lambda st: eng.run(st, n)[0].pt.energy, state, iters=3)
            per_sweep = t / n
            if interval == 0:
                base_time = per_sweep
                emit(
                    "fig7_noswap", t, f"sweeps={n};R={r}", group=GROUP,
                    metrics={"sweeps": n, "n_replicas": r,
                             "us_per_sweep": per_sweep * 1e6},
                )
                continue
            # acceptance from the streaming counters (one O(R) readback)
            _, res = eng.run(state, n)
            acc = float(np.mean(res.summary["swap_acceptance"]))
            overhead = 100 * (per_sweep - base_time) / base_time
            emit(
                f"fig7_interval{interval}_{mode}", t,
                f"sweeps={n};overhead={overhead:.1f}%;swap_acc={acc:.3f}",
                group=GROUP,
                metrics={"sweeps": n, "overhead_pct": overhead,
                         "swap_acceptance": acc,
                         "us_per_sweep": per_sweep * 1e6},
            )


def run_strategies(r: int = 16, length: int = 16, sweeps: int = 4000):
    """Per-strategy round-trip rate vs wall-clock (DESIGN.md §Exchange).

    Aggressive swap cadence (interval 2) on a ladder spanning the Ising
    critical region, so replicas actually travel: the comparison is *round
    trips per second* — wall-clock alone would call every strategy a tie.
    """
    system = ising.IsingSystem(length=length)
    temps = np.asarray(ladder.geometric_ladder(r, 1.5, 4.5))
    interval = 2
    sweeps = interval * max(1, round(sweeps / interval))
    for name in available_strategies():
        cfg = EngineConfig(
            n_replicas=r,
            swap_interval=interval,
            chunk_intervals=64,
            donate=False,
            exchange=name,
        )
        eng = Engine(system, cfg)
        state = eng.init(jax.random.key(2), temps)
        t = time_call(lambda st: eng.run(st, sweeps)[0].pt.energy, state, iters=3)
        _, res = eng.run(state, sweeps)
        trips = float(np.asarray(res.summary["round_trips"]).sum())
        acc = float(np.mean(res.summary["swap_acceptance"]))
        rate = trips / t if t > 0 else 0.0
        emit(
            f"strategy_{name}", t,
            f"sweeps={sweeps};round_trips={trips:.0f};trips_per_s={rate:.1f}"
            f";swap_acc={acc:.3f}",
            group=GROUP,
            metrics={"sweeps": sweeps, "n_replicas": r, "round_trips": trips,
                     "trips_per_sec": rate, "swap_acceptance": acc},
        )


def run_collectives(r: int = 8, length: int = 8, devices: int = 0):
    """Measured collective payload per exchange on the sharded mega-step.

    Compiles the shard_map chunk for every exchange strategy on a (1, D)
    replica mesh and parses the compiled HLO for collective payload bytes
    (`repro.hlo.collectives.parse_collectives`).  The O(R) claim is checked
    structurally: the payload must be *identical* when the lattice side
    doubles — only O(R) energy/rung rows may cross the interconnect, never
    an (L, L) lattice block.  Temp-mode DEO/SEO assert on this; every
    strategy reports it.
    """
    from repro.core.distributed import MeshSpec
    from repro.hlo.collectives import parse_collectives

    n_dev = devices or jax.device_count()
    n_dev = min(n_dev, jax.device_count())
    if n_dev < 2:
        emit(
            "collectives_skipped", 0.0,
            f"need >=2 devices (have {jax.device_count()}); rerun with "
            "--devices N (sets --xla_force_host_platform_device_count)",
            group=GROUP,
        )
        return
    r = max(r, n_dev) // n_dev * n_dev  # replica axis must divide evenly
    interval, chunk = 4, 3

    def stats_for(name: str, side: int):
        cfg = EngineConfig(
            n_replicas=r, swap_interval=interval, chunk_intervals=chunk,
            donate=False, exchange=name,
            mesh=MeshSpec(ensemble=1, replica=n_dev),
        )
        eng = Engine(ising.IsingSystem(length=side), cfg)
        state = eng.init(jax.random.key(3), np.asarray(ladder.paper_ladder(r)))
        return parse_collectives(eng._compiled(state, chunk).as_text())

    for name in available_strategies():
        st = stats_for(name, length)
        st2 = stats_for(name, 2 * length)
        per_exchange = st.payload_bytes / chunk
        l_independent = st.payload_bytes == st2.payload_bytes
        if name in ("deo", "seo"):
            assert l_independent, (
                f"{name}: collective payload grew with the lattice "
                f"({st.payload_bytes:.0f} -> {st2.payload_bytes:.0f} B/chunk)"
                " — a lattice-sized block is crossing the interconnect"
            )
        ops = ",".join(f"{k}:{v:.0f}" for k, v in sorted(st.by_op.items()))
        emit(
            f"collectives_{name}", 0.0,
            f"devices={n_dev};R={r};B_per_exchange={per_exchange:.0f}"
            f";ops={ops};L_independent={l_independent}",
            group=GROUP,
            metrics={
                "n_devices": n_dev, "n_replicas": r,
                "payload_bytes_per_exchange": per_exchange,
                "wire_bytes_per_chunk": st.wire_bytes,
                "collective_count": float(st.count),
                "lattice_independent": float(l_independent),
            },
        )


def run(r: int = 64, length: int = 32, sweeps: int = 1000, out_dir=None,
        devices: int = 0):
    run_intervals(r=r, length=length, sweeps=sweeps)
    # strategy rows scale off the same knobs so the CI smoke run stays tiny
    run_strategies(r=max(4, r // 4), length=min(length, 16), sweeps=4 * sweeps)
    run_collectives(r=min(r, 8), length=min(length, 8), devices=devices)
    path = write_bench_json(GROUP, out_dir)
    print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--replicas", type=int, default=64)
    ap.add_argument("--length", type=int, default=32)
    ap.add_argument("--sweeps", type=int, default=1000)
    ap.add_argument("--devices", type=int, default=0,
                    help="simulate N host devices for the sharded-collective "
                         "rows (sets --xla_force_host_platform_device_count "
                         "before jax is imported)")
    ap.add_argument("--out-dir", default=None,
                    help="where BENCH_swap.json lands (default: $BENCH_OUT_DIR or .)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(r=args.replicas, length=args.length, sweeps=args.sweeps,
        out_dir=args.out_dir, devices=args.devices)
