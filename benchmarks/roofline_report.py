"""Render the §Roofline table from results/dryrun/*.json (and emit summary
CSV rows for benchmarks.run).

The PT-kernel traffic section is fed by the same analytic model the fused
kernels and their ≥5× traffic assertions use —
`repro.hlo.traffic.hbm_bytes_per_cell_sweep` — so the report can never
drift from the numbers the tests actually gate on.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.hlo.traffic import hbm_bytes_per_cell_sweep

COLS = (
    "t_comp_s", "t_mem_s", "t_mem_analytic_s", "t_coll_s",
    "dominant", "dominant_analytic", "fraction_of_roofline",
    "fraction_of_roofline_analytic", "useful_flops_ratio", "mfu_bound",
)


def load(res_dir: str = "results/dryrun", variant: str | None = None):
    rows = []
    for path in sorted(glob.glob(os.path.join(res_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if variant is not None and r.get("variant") != variant:
            continue
        rows.append(r)
    return rows


def markdown_table(rows, mesh="single") -> str:
    out = [
        "| arch | shape | variant | T_comp | T_mem^hlo | T_mem^an | T_coll | dom(hlo/an) | frac | frac_an | useful | MFU_bound |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if "skipped" in r:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r.get('variant','')} | — | — | — | — | skipped: {r['skipped']} | | | |"
            )
            continue
        if "roofline" not in r:  # other schemas (e.g. ising PT records)
            continue
        ro = r["roofline"]
        out.append(
            "| {arch} | {shape} | {var} | {c:.3f}s | {m:.3f}s | {ma:.3f}s | {co:.3f}s | {d}/{da} | {f:.3f} | {fa:.3f} | {u:.2f} | {mfu:.3f} |".format(
                arch=r["arch"], shape=r["shape"], var=r.get("variant", ""),
                c=ro["t_comp_s"], m=ro["t_mem_s"], ma=ro["t_mem_analytic_s"],
                co=ro["t_coll_s"], d=ro["dominant"][:4], da=ro["dominant_analytic"][:4],
                f=ro["fraction_of_roofline"], fa=ro["fraction_of_roofline_analytic"],
                u=ro["useful_flops_ratio"], mfu=ro["mfu_bound"],
            )
        )
    return "\n".join(out)


# (system, per-cell uniform-plane bytes): one f32 plane per colour for Ising,
# proposal + acceptance planes for Potts — same constants the kernels'
# per-system wrappers pass when they delegate to the shared model.
_KERNEL_SYSTEMS = (("ising", 8.0), ("potts", 16.0))
_FUSE_SWEEPS = (1, 4, 16, 64)


def kernel_traffic_rows():
    """Modeled HBM traffic rows for the fused PT sweep kernels.

    One row per (system, sweeps-per-interval) from the shared model —
    these are the exact values `tests/test_kernels.py` asserts ≥5× on.
    """
    rows = []
    for system, plane_bytes in _KERNEL_SYSTEMS:
        unfused = hbm_bytes_per_cell_sweep(
            fused=False, uniform_plane_bytes=plane_bytes
        )
        for s in _FUSE_SWEEPS:
            fused = hbm_bytes_per_cell_sweep(
                fused=True, sweeps_per_interval=s,
                uniform_plane_bytes=plane_bytes,
            )
            rows.append({
                "system": system, "sweeps_per_interval": s,
                "unfused_bytes_per_cell_sweep": unfused,
                "fused_bytes_per_cell_sweep": fused,
                "traffic_reduction_x": unfused / fused,
            })
    return rows


def kernel_traffic_markdown(rows) -> str:
    out = [
        "## Fused PT sweep kernels (modeled, `repro.hlo.traffic`)",
        "",
        "| system | sweeps/interval | unfused B/cell/sweep | fused B/cell/sweep | traffic x |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            "| {sys} | {s} | {u:.1f} | {f:.3f} | {x:.0f}x |".format(
                sys=r["system"], s=r["sweeps_per_interval"],
                u=r["unfused_bytes_per_cell_sweep"],
                f=r["fused_bytes_per_cell_sweep"],
                x=r["traffic_reduction_x"],
            )
        )
    return "\n".join(out)


def run(res_dir: str = "results/dryrun"):
    os.makedirs("results", exist_ok=True)
    krows = kernel_traffic_rows()
    with open(os.path.join("results", "roofline_kernels.md"), "w") as f:
        f.write(kernel_traffic_markdown(krows) + "\n")
    for r in krows:
        emit(
            f"roofline_kernel_{r['system']}_s{r['sweeps_per_interval']}",
            0.0,
            f"unfused={r['unfused_bytes_per_cell_sweep']:.1f}B"
            f";fused={r['fused_bytes_per_cell_sweep']:.3f}B"
            f";x{r['traffic_reduction_x']:.0f}",
        )
    rows = load(res_dir)
    if not rows:
        emit("roofline_report", 0.0, "no dryrun results found")
        return
    for mesh in ("single", "multi"):
        md = markdown_table(rows, mesh)
        path = os.path.join("results", f"roofline_{mesh}.md")
        with open(path, "w") as f:
            f.write(md + "\n")
    done = [r for r in rows if "roofline" in r and r["mesh"] == "single"]
    skipped = [r for r in rows if "skipped" in r and r["mesh"] == "single"]
    if done:
        worst = min(done, key=lambda r: r["roofline"]["fraction_of_roofline_analytic"])
        emit(
            "roofline_summary", 0.0,
            f"cells={len(done)};skipped={len(skipped)};"
            f"worst={worst['arch']}/{worst['shape']}"
            f"@{worst['roofline']['fraction_of_roofline_analytic']:.3f}",
        )
        for r in done:
            ro = r["roofline"]
            emit(
                f"roofline_{r['arch']}_{r['shape']}_{r.get('variant','baseline')}",
                ro["bound_time_s"],
                f"dom={ro['dominant_analytic']};frac={ro['fraction_of_roofline_analytic']:.3f};useful={ro['useful_flops_ratio']:.2f}",
            )
