"""Paper Figs. 4/5 analogue: replica-level parallelization speed-up.

The paper measures OpenMP/CUDA thread scaling.  On this CPU host the
equivalent comparison is *sequential per-replica execution* (the paper's
1-thread baseline: one replica stepped at a time) vs the framework's
*vectorized replica batch* (all replicas advance in one fused program — the
paper's all-threads case; on TPU this is also what shards across the mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.core import ising, ladder, pt


def run(sweeps: int = 50, length: int = 32):
    system = ising.IsingSystem(length=length)

    for r in (16, 64, 256):
        temps = tuple(float(t) for t in ladder.paper_ladder(r))
        cfg = pt.PTConfig(n_replicas=r, temps=temps, swap_interval=0)
        state = pt.init(system, cfg, jax.random.key(0))

        # vectorized: all replicas in one program (swaps off, as in the paper)
        vec = jax.jit(lambda st: pt.run(system, cfg, st, sweeps)[0].energy)
        t_vec = time_call(vec, state)

        # sequential: replicas advanced one-by-one (paper's serial baseline)
        cfg1 = pt.PTConfig(n_replicas=1, temps=(1.0,), swap_interval=0)
        st1 = pt.init(system, cfg1, jax.random.key(0))
        one = jax.jit(lambda st: pt.run(system, cfg1, st, sweeps)[0].energy)

        def seq(st):
            out = None
            for _ in range(r):
                out = one(st)
            return out

        t_seq = time_call(seq, st1)
        emit(
            f"fig45_speedup_R{r}", t_vec,
            f"seq_us={t_seq*1e6:.0f};speedup={t_seq / t_vec:.1f}x;sweeps={sweeps}",
        )
