"""Paper Figs. 4/5 analogue: replica-level parallelization speed-up.

The paper measures OpenMP/CUDA thread scaling.  On this CPU host the
equivalent comparison is *sequential per-replica execution* (the paper's
1-thread baseline: one replica stepped at a time) vs the engine's
*vectorized replica batch* (all replicas advance in one compiled mega-step —
the paper's all-threads case; on TPU this is also what shards across the
mesh).  Both paths now run through `repro.engine.Engine` (DESIGN.md §1): the
chunked AOT driver with streaming O(R) statistics.

Extra rows beyond the paper:

* ``engine_ensemble_CxR`` — the many-chain axis: C independent chains of R
  replicas in one launch, per-chain cost (throughput scaling knob);
* ``engine_stream_mem`` — device bytes held by the streaming statistics vs
  the O(intervals x R) trace the seed driver would materialize for a
  10k-sweep run (the engine's memory win).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_call, write_bench_json
from repro.core import ising, ladder
from repro.engine import Engine, EngineConfig

GROUP = "speedup"


def _engine(system, r: int, sweeps: int, n_chains: int = 1) -> Engine:
    cfg = EngineConfig(
        n_replicas=r,
        swap_interval=0,  # swaps off, as in the paper's speed-up figures
        measure_interval=sweeps,
        chunk_intervals=1,
        n_chains=n_chains,
        track_stats=True,
        donate=False,  # timing loops re-run the same state
    )
    return Engine(system, cfg)


def run(sweeps: int = 50, length: int = 32, out_dir=None):
    system = ising.IsingSystem(length=length)

    for r in (16, 64, 256):
        temps = np.asarray(ladder.paper_ladder(r))
        eng = _engine(system, r, sweeps)
        state = eng.init(jax.random.key(0), temps)

        # vectorized: all replicas in one compiled mega-step
        vec = lambda st: eng.run(st, sweeps)[0].pt.energy
        t_vec = time_call(vec, state)

        # sequential: replicas advanced one-by-one (paper's serial baseline)
        eng1 = _engine(system, 1, sweeps)
        st1 = eng1.init(jax.random.key(0), np.asarray([1.0]))
        one = lambda st: eng1.run(st, sweeps)[0].pt.energy

        def seq(st):
            out = None
            for _ in range(r):
                out = one(st)
            return out

        t_seq = time_call(seq, st1)
        emit(
            f"fig45_speedup_R{r}", t_vec,
            f"seq_us={t_seq*1e6:.0f};speedup={t_seq / t_vec:.1f}x;sweeps={sweeps}",
            group=GROUP,
            metrics={"seq_seconds": t_seq, "speedup": t_seq / t_vec,
                     "sweeps": sweeps, "n_replicas": r},
        )

    # ensemble axis: many chains per launch (per-chain cost should stay flat
    # until the hardware saturates — the Karimi-style throughput knob)
    r = 16
    temps = np.asarray(ladder.paper_ladder(r))
    for c in (1, 4, 16):
        eng = _engine(system, r, sweeps, n_chains=c)
        state = eng.init(jax.random.key(0), temps)
        t = time_call(lambda st: eng.run(st, sweeps)[0].pt.energy, state)
        emit(
            f"engine_ensemble_C{c}xR{r}", t,
            f"per_chain_us={t/c*1e6:.0f};sweeps={sweeps}",
            group=GROUP,
            metrics={"per_chain_seconds": t / c, "n_chains": c,
                     "sweeps": sweeps, "n_replicas": r},
        )

    # streaming-stats memory vs the seed's full trace, 10k-sweep run
    n_sweeps, interval = 10_000, 100
    eng = _engine(system, 64, interval)
    state = eng.init(jax.random.key(0), np.asarray(ladder.paper_ladder(64)))
    stats_bytes = sum(
        x.nbytes for x in jax.tree_util.tree_leaves(state.stats)
    )
    # per interval per rung: energy f32 + swap_prob f32 + swap_accept bool +
    # swap_attempt bool = 10 bytes
    trace_bytes = (n_sweeps // interval) * 64 * 10
    emit(
        "engine_stream_mem", 0.0,
        f"stats_bytes={stats_bytes};trace_bytes_10k={trace_bytes};"
        f"ratio={trace_bytes/max(stats_bytes,1):.0f}x",
        group=GROUP,
        metrics={"stats_bytes": stats_bytes, "trace_bytes_10k": trace_bytes},
    )
    path = write_bench_json(GROUP, out_dir)
    print(f"# wrote {path}", flush=True)
