# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure (DESIGN.md §8):

  fig3a/fig3b   convergence.py      magnetization & iterations-vs-size
  fig4/fig5     speedup.py          replica-parallel speed-up
  fig6          tile_sweep.py       block-size + sweeps-per-launch tile sweep
  fig7          swap_overhead.py    swap-interval cost + acceptance
  zoo           systems_bench.py    per-system sweep throughput (system zoo)
  ptlm          ptlm_bench.py       paper technique on the LM pool
  serve         serve_load.py       multi-tenant packed scheduler vs naive
                                    one-Session-per-job (jobs/sec, latency,
                                    jobs-packed-per-compile)
  roofline      roofline_report.py  §Roofline tables from the dry-run JSONs
  shard         shard_scaling.py    multi-device weak/strong scaling +
                                    collective bytes (invoke the module
                                    directly with --devices N for a
                                    simulated multi-device mesh)
  obs           obs_overhead.py     telemetry overhead: obs-on vs obs-off
                                    wall ratio (<5% contract) + per-chunk
                                    timeline event count
  faults        fault_recovery.py   injected-fault recovery/quarantine/
                                    degradation (deterministic counts EXACT,
                                    recovery wall-clock advisory)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig7,...]
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset")
    args = ap.parse_args()

    from benchmarks import convergence, fault_recovery, obs_overhead
    from benchmarks import ptlm_bench, roofline_report, serve_load
    from benchmarks import shard_scaling, speedup, swap_overhead
    from benchmarks import systems_bench, tile_sweep

    suites = {
        "fig3": convergence.run,
        "fig45": speedup.run,
        "fig6": tile_sweep.run,
        "fig7": swap_overhead.run,
        "zoo": systems_bench.run,
        "ptlm": ptlm_bench.run,
        "roofline": roofline_report.run,
        "shard": shard_scaling.run,
        "serve": serve_load.run,
        "obs": obs_overhead.run,
        "faults": fault_recovery.run,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:
            traceback.print_exc()
            failed.append(name)
            print(f"{name}_FAILED,0,{type(e).__name__}")
        print(f"# suite {name} finished in {time.time()-t0:.1f}s", flush=True)
    if failed:
        sys.exit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
