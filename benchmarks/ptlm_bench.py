"""PT-LM sampling benchmark (the paper's technique on the LM pool):
single-chain MH vs parallel tempering on sequence NLL."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, time_call
from repro.configs import get_config
from repro.core import ladder, pt
from repro.core.ptlm import LMSystem
from repro.models import model as model_lib


def run(r: int = 8, seq_len: int = 24, steps: int = 60):
    cfg = get_config("gemma_2b", reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    system = LMSystem(cfg=cfg, seq_len=seq_len).bind(params)
    temps = tuple(float(t) for t in ladder.geometric_ladder(r, 1.0, 8.0))
    ptc = pt.PTConfig(n_replicas=r, temps=temps, swap_interval=5, swap_mode="temp")
    st = pt.init(system, ptc, jax.random.key(1))
    e0 = float(np.asarray(st.energy)[np.argsort(np.asarray(st.rung))][0])
    fn = jax.jit(lambda s: pt.run(system, ptc, s, steps))
    t = time_call(lambda s: fn(s)[0].energy, st, iters=1)
    st2, trace = fn(st)
    e_cold = float(np.asarray(trace["energy"])[-1, 0])
    emit(
        "ptlm_sampling", t / steps,
        f"steps={steps};R={r};cold_nll {e0:.1f}->{e_cold:.1f};improved={'yes' if e_cold < e0 else 'no'}",
    )
