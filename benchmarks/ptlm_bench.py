"""PT-LM sampling benchmark (the paper's technique on the LM pool):
single-chain MH vs parallel tempering on sequence NLL.

Runs through the chunked streaming engine (`repro.engine.Engine`) — the LM
system binds live model params, so it is driven at the Engine layer rather
than through a serializable `repro.api.RunSpec`.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import emit, time_call
from repro.configs import get_config
from repro.core import ladder
from repro.core.ptlm import LMSystem
from repro.engine import Engine, EngineConfig
from repro.models import model as model_lib


def run(r: int = 8, seq_len: int = 24, steps: int = 60):
    cfg = get_config("gemma_2b", reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    system = LMSystem(cfg=cfg, seq_len=seq_len).bind(params)
    temps = np.asarray(ladder.geometric_ladder(r, 1.0, 8.0), np.float64)
    eng = Engine(system, EngineConfig(
        n_replicas=r, swap_interval=5, swap_mode="temp", chunk_intervals=12,
        record_trace=True, donate=False,  # timing loop re-runs the same state
    ))
    st = eng.init(jax.random.key(1), temps)
    e0 = float(np.asarray(st.pt.energy)[np.argsort(np.asarray(st.pt.rung))][0])
    t = time_call(lambda s: eng.run(s, steps)[0].pt.energy, st, iters=1)
    _, res = eng.run(st, steps)
    e_cold = float(res.trace["energy"][-1, 0])
    emit(
        "ptlm_sampling", t / steps,
        f"steps={steps};R={r};cold_nll {e0:.1f}->{e_cold:.1f};improved={'yes' if e_cold < e0 else 'no'}",
    )
