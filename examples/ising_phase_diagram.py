"""Reproduce the paper's Fig. 3a: |magnetization| vs temperature across the
2-D Ising phase transition, via PT sampling (CSV output).

A declarative `RunSpec` with the **ensemble axis**: two independent chains
`(C, R, L, L)` advance in one compiled program and their online statistics
are pooled (`repro.engine.stats.combine_chains`) — half the sweeps per chain
for the same sample count, and an error bar for free.

    python examples/ising_phase_diagram.py > phase.csv
"""
import sys

import numpy as np

from repro.api import (
    EngineSpec, LadderSpec, PhaseSpec, RunSpec, ScheduleSpec, Session,
    SystemSpec,
)
from repro.engine import combine_chains

T_C = 2.0 / np.log(1.0 + np.sqrt(2.0))  # Onsager: ~2.269


def main():
    r, length, chains, sweeps = 24, 24, 2, 2000
    spec = RunSpec(
        system=SystemSpec("ising", {"length": length}),
        ladder=LadderSpec(kind="linear", n_replicas=r, t_min=1.0, t_max=4.0),
        engine=EngineSpec(swap_interval=10, chunk_intervals=50, n_chains=chains),
        schedule=ScheduleSpec(phases=(
            PhaseSpec(name="burn", n_sweeps=sweeps // 2),
            PhaseSpec(name="measure", n_sweeps=sweeps - sweeps // 2,
                      reset_stats=True),
        )),
        observables=("absmag", "energy_per_site"),
        seed=7,
    )
    temps = spec.ladder.build()
    result = Session(spec).run()
    pooled = combine_chains(result.state.stats)  # merge the ensemble axis (Chan)
    per_chain = np.asarray(result.state.stats.mean["absmag"])  # (C, R)
    spread = (per_chain.max(axis=0) - per_chain.min(axis=0)) / 2.0
    print("temperature,abs_magnetization_pct,energy_per_spin,chain_spread_pct")
    for i, T in enumerate(temps):
        print(f"{T:.3f},{100*pooled['mean_absmag'][i]:.1f},"
              f"{pooled['mean_energy_per_site'][i]:.4f},{100*spread[i]:.1f}")
    print(f"# exact T_c = {T_C:.4f}; observed transition between the rungs "
          f"where |m| crosses 50%", file=sys.stderr)


if __name__ == "__main__":
    main()
