"""Reproduce the paper's Fig. 3a: |magnetization| vs temperature across the
2-D Ising phase transition, via PT sampling (CSV output).

Runs through the streaming engine with the **ensemble axis**: two independent
chains `(C, R, L, L)` advance in one compiled program and their online
statistics are pooled (`repro.engine.stats.combine_chains`) — half the sweeps
per chain for the same sample count, and an error bar for free.

    PYTHONPATH=src python examples/ising_phase_diagram.py > phase.csv
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ising, ladder
from repro.engine import Engine, EngineConfig, combine_chains

T_C = 2.0 / np.log(1.0 + np.sqrt(2.0))  # Onsager: ~2.269


def main():
    R, L, C, sweeps = 24, 24, 2, 2000
    system = ising.IsingSystem(length=L)
    temps = np.asarray(ladder.linear_ladder(R, 1.0, 4.0))
    cfg = EngineConfig(n_replicas=R, swap_interval=10, chunk_intervals=50, n_chains=C)
    obs = {"am": lambda s: jnp.abs(ising.magnetization(s)),
           "e": lambda s: system.energy(s) / (L * L)}
    eng = Engine(system, cfg, observables=obs)
    st = eng.init(jax.random.key(7), temps)
    st, _ = eng.run(st, sweeps // 2)  # burn-in
    st = eng.reset_stats(st)
    st, _ = eng.run(st, sweeps - sweeps // 2)
    pooled = combine_chains(st.stats)  # merge the ensemble axis (Chan)
    per_chain = np.asarray(st.stats.mean["am"])  # (C, R)
    spread = (per_chain.max(axis=0) - per_chain.min(axis=0)) / 2.0
    print("temperature,abs_magnetization_pct,energy_per_spin,chain_spread_pct")
    for i, T in enumerate(temps):
        print(f"{T:.3f},{100*pooled['mean_am'][i]:.1f},"
              f"{pooled['mean_e'][i]:.4f},{100*spread[i]:.1f}")
    print(f"# exact T_c = {T_C:.4f}; observed transition between the rungs "
          f"where |m| crosses 50%", file=sys.stderr)


if __name__ == "__main__":
    main()
