"""Reproduce the paper's Fig. 3a: |magnetization| vs temperature across the
2-D Ising phase transition, via PT sampling (CSV output).

    PYTHONPATH=src python examples/ising_phase_diagram.py > phase.csv
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diagnostics, ising, ladder, pt

T_C = 2.0 / np.log(1.0 + np.sqrt(2.0))  # Onsager: ~2.269


def main():
    R, L, sweeps = 24, 24, 4000
    system = ising.IsingSystem(length=L)
    temps = tuple(float(t) for t in ladder.linear_ladder(R, 1.0, 4.0))
    cfg = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=10)
    obs = {"am": lambda s: jnp.abs(ising.magnetization(s)),
           "e": lambda s: system.energy(s) / (L * L)}
    st = pt.init(system, cfg, jax.random.key(7))
    _, trace = pt.run(system, cfg, st, sweeps, observables=obs)
    m = diagnostics.grand_mean_by_rung(trace, "am")
    e = diagnostics.grand_mean_by_rung(trace, "e")
    print("temperature,abs_magnetization_pct,energy_per_spin")
    for T, mm, ee in zip(temps, m, e):
        print(f"{T:.3f},{100*mm:.1f},{ee:.4f}")
    print(f"# exact T_c = {T_C:.4f}; observed transition between the rungs "
          f"where |m| crosses 50%", file=sys.stderr)


if __name__ == "__main__":
    main()
