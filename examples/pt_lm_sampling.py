"""Parallel Tempering over LM sequences — the paper's technique as a
first-class feature of the LM stack (DESIGN.md §5).

Replicas hold token sequences; energy = sequence NLL; hot rungs explore token
space, cold rungs sharpen toward high-likelihood sequences, and PT swaps move
good continuations down the ladder.

    PYTHONPATH=src python examples/pt_lm_sampling.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ladder, pt
from repro.core.ptlm import LMSystem
from repro.models import model as model_lib


def main():
    R, seq_len, steps = 8, 24, 150
    cfg = get_config("qwen3_32b", reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    system = LMSystem(cfg=cfg, seq_len=seq_len).bind(params)

    temps = tuple(float(t) for t in ladder.geometric_ladder(R, 1.0, 10.0))
    ptc = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=5, swap_mode="temp")
    state = pt.init(system, ptc, jax.random.key(1))
    e_init = np.asarray(state.energy)[np.argsort(np.asarray(state.rung))]

    state, trace = pt.run(system, ptc, state, steps)
    e = np.asarray(trace["energy"])
    acc = np.asarray(trace["swap_prob"])

    print(f"PT-LM: {R} replicas x {steps} MH steps over {seq_len}-token sequences")
    print(f"cold-rung NLL: {e_init[0]:8.2f} -> {e[-1, 0]:8.2f}")
    print(f"hot-rung  NLL: {e_init[-1]:8.2f} -> {e[-1, -1]:8.2f}")
    print(f"mean swap prob: {acc[acc > 0].mean():.3f}")
    assert e[-1, 0] < e_init[0], "cold chain should find higher-likelihood sequences"
    print("cold chain improved: OK")


if __name__ == "__main__":
    main()
