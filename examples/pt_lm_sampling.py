"""Parallel Tempering over LM sequences — the paper's technique as a
first-class feature of the LM stack (DESIGN.md §5).

Replicas hold token sequences; energy = sequence NLL; hot rungs explore token
space, cold rungs sharpen toward high-likelihood sequences, and PT swaps move
good continuations down the ladder.

Runs through the chunked streaming engine (`repro.engine.Engine`) with the
opt-in per-chunk trace.  The LM system binds live model params (not
JSON-able), so it is driven at the Engine layer rather than through a
serializable `repro.api.RunSpec`.

    python examples/pt_lm_sampling.py    (pip install -e ., or PYTHONPATH=src)
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import ladder
from repro.core.ptlm import LMSystem
from repro.engine import Engine, EngineConfig
from repro.models import model as model_lib


def main():
    R, seq_len, steps = 8, 24, 150
    cfg = get_config("qwen3_32b", reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    system = LMSystem(cfg=cfg, seq_len=seq_len).bind(params)

    temps = np.asarray(ladder.geometric_ladder(R, 1.0, 10.0), np.float64)
    eng = Engine(system, EngineConfig(
        n_replicas=R, swap_interval=5, swap_mode="temp", chunk_intervals=10,
        record_trace=True,
    ))
    state = eng.init(jax.random.key(1), temps)
    e_init = np.asarray(state.pt.energy)[np.argsort(np.asarray(state.pt.rung))]

    state, res = eng.run(state, steps)
    e = res.trace["energy"]
    acc = res.trace["swap_prob"]

    print(f"PT-LM: {R} replicas x {steps} MH steps over {seq_len}-token sequences")
    print(f"cold-rung NLL: {e_init[0]:8.2f} -> {e[-1, 0]:8.2f}")
    print(f"hot-rung  NLL: {e_init[-1]:8.2f} -> {e[-1, -1]:8.2f}")
    print(f"mean swap prob: {acc[acc > 0].mean():.3f}")
    assert e[-1, 0] < e_init[0], "cold chain should find higher-likelihood sequences"
    print("cold chain improved: OK")


if __name__ == "__main__":
    main()
