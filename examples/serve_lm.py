"""Batched autoregressive serving with a KV cache (decode path used by the
decode_32k / long_500k dry-run cells), on a reduced config.

    python examples/serve_lm.py [--arch rwkv6_7b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    state = model_lib.init_decode_state(cfg, args.batch, max_seq=args.tokens + 8)

    ctx = None
    if cfg.family == "vlm":
        ctx = jax.random.normal(jax.random.key(2), (args.batch, cfg.img_tokens, cfg.d_model))
    if cfg.family == "encdec":
        from repro.models import whisper
        frames = jax.random.normal(jax.random.key(2), (args.batch, cfg.enc_seq, cfg.d_model))
        ctx = whisper.encode(params, cfg, frames)

    @jax.jit
    def step(state, token, pos, key):
        logits, state = model_lib.decode_step(params, cfg, state, token, pos, ctx=ctx)
        nxt = jax.random.categorical(key, logits / 0.8, axis=-1)
        return state, nxt[:, None]

    token = jnp.ones((args.batch, 1), jnp.int32)
    seqs = [token]
    t0 = time.time()
    for pos in range(args.tokens):
        state, token = step(state, token, pos, jax.random.key(100 + pos))
        seqs.append(token)
    jax.block_until_ready(token)
    dt = time.time() - t0
    out = np.concatenate([np.asarray(s) for s in seqs], axis=1)
    print(f"arch={args.arch} batch={args.batch}: generated {args.tokens} tokens "
          f"in {dt:.2f}s ({args.batch*args.tokens/dt:.1f} tok/s incl compile)")
    print("sample token ids:", out[0][:16].tolist())
    assert np.isfinite(out).all()


if __name__ == "__main__":
    main()
