"""System zoo tour: PT-sample every registered system against ground truth.

Runs the chunked streaming engine (adaptive ladder on, 2-chain ensemble) on
each tier-1 entry of `repro.core.systems.REGISTRY` — the 4x4 Ising model,
the bimodal Gaussian mixture, the 4x4 ±J Edwards-Anderson spin glass and a
10-monomer HP lattice protein — and prints the engine's per-rung estimates
next to the exact enumeration / quadrature answers with batch-means error
bars (`repro.validate`).  This is the conformance suite as a demo: the same
harness `tests/test_conformance.py` gates on, driven by the declarative
`RunSpec` each zoo entry compiles to (``python -m repro validate <system>``
is this script for one system).

    python examples/system_zoo.py [--all]

``--all`` includes the `slow`-tier entries (4x4 q=3 Potts: its exact
reference enumerates 3^16 configurations, ~20 s).
"""
import sys

import numpy as np

from repro.core import systems
from repro.validate import run_conformance


def main():
    include_slow = "--all" in sys.argv[1:]
    for name, entry in sorted(systems.REGISTRY.items()):
        if entry.slow and not include_slow:
            print(f"== {name}: skipped (slow exact reference; rerun with --all)")
            continue
        report = run_conformance(entry, seed=0)
        series = ", ".join(k for k in report.means if k != "energy")
        print(f"\n== {name}  (ladder retuned {report.n_retunes}x during burn-in; "
              f"{report.n_batches} batch means; observables: energy, {series})")
        print("   T        <E> engine   <E> exact    |z|   " + "  ".join(
            f"<{k}> eng  <{k}> exact" for k in report.means if k != "energy"))
        for r, t in enumerate(report.temps):
            row = (f"   {t:6.3f}  {report.means['energy'][r]:10.4f}  "
                   f"{report.exact['energy'][r]:10.4f}  {abs(report.z['energy'][r]):5.2f}")
            for k in report.means:
                if k == "energy":
                    continue
                row += f"   {report.means[k][r]:8.4f}  {report.exact[k][r]:8.4f}"
            print(row)
        worst_series, worst_z = report.worst()
        print(f"   worst |z| = {worst_z:.2f} ({worst_series}); "
              f"conformance gate is |z| <= 4")


if __name__ == "__main__":
    main()
