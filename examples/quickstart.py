"""Quickstart: sample a 2-D Ising model with Metropolis-Hastings + Parallel
Tempering — the paper's core experiment at laptop scale.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diagnostics, ising, ladder, pt


def main():
    R, L, sweeps = 16, 32, 2000
    system = ising.IsingSystem(length=L, j=1.0, b=0.0)  # paper's J=1, B=0
    temps = tuple(float(t) for t in ladder.paper_ladder(R))  # T_i = 1 + 3i/R
    cfg = pt.PTConfig(
        n_replicas=R, temps=temps, swap_interval=100,  # paper's interval family
        criterion="logistic",  # paper's P_swap (Coluzza & Frenkel)
        swap_mode="temp",  # O(1)-bytes optimized swaps (state mode also available)
    )
    print(f"PT: {R} replicas, {L}x{L} lattice, {sweeps} sweeps, "
          f"T in [{temps[0]:.2f}, {temps[-1]:.2f}]")

    state = pt.init(system, cfg, jax.random.key(0))
    obs = {"absmag": lambda s: jnp.abs(ising.magnetization(s))}
    state, trace = pt.run(system, cfg, state, sweeps, observables=obs)

    m = diagnostics.grand_mean_by_rung(trace, "absmag")
    acc = diagnostics.swap_acceptance_rate(trace)
    print("\n T      |m|    (phase transition at T_c ~ 2.27)")
    for T, mm in zip(temps, m):
        bar = "#" * int(mm * 40)
        print(f" {T:4.2f}  {mm:5.3f}  {bar}")
    print(f"\nmean swap acceptance: {np.mean(acc):.3f} "
          f"(glassy system -> low, as the paper observes)")
    print(f"cold-chain energy: {float(np.asarray(state.energy)[np.argsort(np.asarray(state.rung))][0]):.1f} "
          f"(ground state = {-2 * L * L})")


if __name__ == "__main__":
    main()
