"""Quickstart: sample a 2-D Ising model with Metropolis-Hastings + Parallel
Tempering — the paper's core experiment at laptop scale, through the chunked
streaming engine (`repro.engine`): one AOT-compiled mega-step re-used for the
whole run, O(R) online statistics instead of a full trace, and an in-loop
adaptive temperature ladder.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ising, ladder
from repro.engine import AdaptConfig, Engine, EngineConfig


def main():
    R, L, sweeps = 16, 32, 2000
    system = ising.IsingSystem(length=L, j=1.0, b=0.0)  # paper's J=1, B=0
    temps = np.asarray(ladder.paper_ladder(R))  # T_i = 1 + 3i/R
    cfg = EngineConfig(
        n_replicas=R,
        swap_interval=100,  # paper's interval family
        criterion="logistic",  # paper's P_swap (Coluzza & Frenkel)
        swap_mode="temp",  # O(1)-bytes optimized swaps (state mode also available)
        chunk_intervals=5,  # one compiled mega-step = 5 intervals
    )
    print(f"PT: {R} replicas, {L}x{L} lattice, {sweeps} sweeps, "
          f"T in [{temps[0]:.2f}, {temps[-1]:.2f}]")

    eng = Engine(
        system, cfg,
        observables={"absmag": lambda s: jnp.abs(ising.magnetization(s))},
        adapt=AdaptConfig(target=0.25, min_attempts_per_pair=2),
    )
    state = eng.init(jax.random.key(0), temps)
    # burn-in (the adaptive ladder also settles here), then freeze the
    # ladder, reset the O(R) accumulators and measure — every sample in the
    # report is drawn at the printed temperatures; no trace ever materializes
    state, burn = eng.run(state, sweeps // 2)
    eng.adapt = None
    state = eng.reset_stats(state)
    state, res = eng.run(state, sweeps // 2)

    m = res.summary["mean_absmag"]
    acc = res.summary["swap_acceptance"]
    final_temps = 1.0 / np.asarray(state.betas)
    print("\n T      |m|    (phase transition at T_c ~ 2.27)")
    for T, mm in zip(final_temps, m):
        bar = "#" * int(mm * 40)
        print(f" {T:4.2f}  {mm:5.3f}  {bar}")
    print(f"\nmean swap acceptance: {np.mean(acc):.3f} "
          f"(glassy system -> low, as the paper observes; "
          f"ladder retuned {len(burn.ladder_history) - 1}x during burn-in)")
    phases = (sweeps // 2) // cfg.swap_interval
    print(f"round trips (cold->hot->cold): {int(res.summary['round_trips'].sum())} "
          f"(each needs >= 2(R-1) = {2 * (R - 1)} swap phases; "
          f"this window has {phases} — expect 0 at demo scale)")
    energy = np.asarray(state.pt.energy)[np.argsort(np.asarray(state.pt.rung))]
    print(f"cold-chain energy: {energy[0]:.1f} (ground state = {-2 * L * L})")


if __name__ == "__main__":
    main()
