"""Quickstart: sample a 2-D Ising model with Metropolis-Hastings + Parallel
Tempering — the paper's core experiment at laptop scale, described as a
10-line declarative `RunSpec` and executed by `repro.api.Session` (the same
spec runs identically via ``spec.to_json()`` + ``python -m repro run``).

    python examples/quickstart.py        (pip install -e ., or PYTHONPATH=src)
"""
import numpy as np

from repro.api import (
    AdaptSpec, EngineSpec, LadderSpec, RunSpec, Session, SystemSpec,
    simple_schedule,
)

R, L, SWEEPS = 16, 32, 2000

SPEC = RunSpec(
    system=SystemSpec("ising", {"length": L, "j": 1.0, "b": 0.0}),  # paper's J=1, B=0
    ladder=LadderSpec(kind="paper", n_replicas=R),  # T_i = 1 + 3i/R
    engine=EngineSpec(swap_interval=100,  # paper's interval family
                      criterion="logistic",  # paper's P_swap (Coluzza & Frenkel)
                      swap_mode="temp",  # O(1)-bytes optimized swaps
                      chunk_intervals=5),  # one compiled mega-step = 5 intervals
    adapt=AdaptSpec(target=0.25, min_attempts_per_pair=2),
    # burn-in (the adaptive ladder also settles here), then freeze the
    # ladder, reset the O(R) accumulators and measure — every sample in the
    # report is drawn at the printed temperatures; no trace ever materializes
    schedule=simple_schedule(burn_sweeps=SWEEPS // 2, measure_sweeps=SWEEPS // 2),
    observables=("absmag",),
    seed=0,
)


def main():
    temps0 = SPEC.ladder.build()
    print(f"PT: {R} replicas, {L}x{L} lattice, {SWEEPS} sweeps, "
          f"T in [{temps0[0]:.2f}, {temps0[-1]:.2f}]")
    result = Session(SPEC).run()

    burn, res = result.phases["burn"], result.phases["measure"]
    m = res.summary["mean_absmag"]
    acc = res.summary["swap_acceptance"]
    final_temps = 1.0 / np.asarray(result.state.betas)
    print("\n T      |m|    (phase transition at T_c ~ 2.27)")
    for T, mm in zip(final_temps, m):
        bar = "#" * int(mm * 40)
        print(f" {T:4.2f}  {mm:5.3f}  {bar}")
    print(f"\nmean swap acceptance: {np.mean(acc):.3f} "
          f"(glassy system -> low, as the paper observes; "
          f"ladder retuned {len(burn.ladder_history) - 1}x during burn-in)")
    phases = (SWEEPS // 2) // SPEC.engine.swap_interval
    print(f"round trips (cold->hot->cold): {int(res.summary['round_trips'].sum())} "
          f"(each needs >= 2(R-1) = {2 * (R - 1)} swap phases; "
          f"this window has {phases} — expect 0 at demo scale)")
    print(f"cold-chain energy: {result.final_energies()[0]:.1f} "
          f"(ground state = {-2 * L * L})")


if __name__ == "__main__":
    main()
