"""End-to-end training driver: train a small LM for a few hundred steps on
the deterministic synthetic pipeline, with checkpoint/restart fault-tolerance
demonstrated mid-run.

    python examples/train_lm.py [--steps 200] [--arch gemma_2b]

The default is a reduced config sized for this CPU container; on a TPU mesh
the same driver scales via repro.launch (--arch <id> full configs).
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.synthetic import SyntheticLM
from repro.train import optimizer as opt_lib
from repro.train.train_step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8)
    opt_cfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    state = init_state(cfg, jax.random.key(0))
    start = 0
    restored = mgr.restore_latest(state)
    if restored is not None:
        state, meta = restored
        start = meta["step"]
        print(f"[restart] resumed from checkpoint at step {start}")

    losses = []
    t0 = time.time()
    for step, batch in data.batches(start):
        if step >= args.steps:
            break
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % 20 == 0:
            rate = (step + 1 - start) / (time.time() - t0)
            print(f"step {step+1:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {rate:.2f} it/s")
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, meta={"loss": losses[-1]}, blocking=False)
    mgr.wait()

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'LEARNING OK' if last < first - 0.2 else 'no improvement?'})")
    print(f"checkpoints kept: {mgr.steps()}")


if __name__ == "__main__":
    main()
