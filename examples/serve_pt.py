"""PT-as-a-service demo: heterogeneous tenants through one scheduler.

Twelve tenant jobs — seed variants of three *different* systems (2-D Ising,
3-state Potts, bimodal Gaussian mixture) — are submitted to one
`repro.serve.Scheduler`.  The scheduler buckets them by shape signature:
the four seed variants of each system pack into ONE compiled mega-step
along the engine's ensemble axis (3 shapes -> 3 compiles for 12 jobs), and
the round-robin host loop time-slices the three buckets so no tenant
starves while another shape runs.

Every tenant's results are bit-equal to running its spec alone — packing
changes throughput, never results (pinned by ``tests/test_serve.py``).

    python examples/serve_pt.py        (pip install -e ., or PYTHONPATH=src)

For the LM-decode analogue of serving (token streams, not PT jobs), see
``examples/serve_lm.py``; the CLI front door for this scheduler is
``python -m repro serve SPEC.json --jobs N``.
"""
import dataclasses

import numpy as np

from repro.api import (
    EngineSpec, LadderSpec, PhaseSpec, RunSpec, ScheduleSpec, SystemSpec,
)
from repro.serve import Scheduler

SEEDS = range(4)

# Three tenant shapes.  Same schedule/ladder sizes by coincidence — what
# matters is that the *signature* (system + params + ladder values + engine
# + schedule) differs, so each system gets its own bucket and executable.
SCHEDULE = ScheduleSpec(phases=(
    PhaseSpec("burn", 400),
    PhaseSpec("measure", 800, reset_stats=True),
))
ENGINE = EngineSpec(swap_interval=10, chunk_intervals=10)

TENANTS = {
    "ising": RunSpec(
        system=SystemSpec("ising", {"length": 16}),
        ladder=LadderSpec(kind="paper", n_replicas=8, t_min=1.0, t_max=4.0),
        engine=ENGINE, schedule=SCHEDULE, observables=("absmag",),
    ),
    "potts": RunSpec(
        system=SystemSpec("potts", {"shape": (12, 12), "q": 3}),
        ladder=LadderSpec(kind="geometric", n_replicas=8, t_min=0.7, t_max=2.0),
        engine=ENGINE, schedule=SCHEDULE, observables=("pmag",),
    ),
    "gaussian": RunSpec(
        system=SystemSpec("gaussian", {"mus": (-4.0, 4.0), "step_size": 0.5}),
        ladder=LadderSpec(kind="geometric", n_replicas=8, t_min=1.0, t_max=8.0),
        engine=ENGINE, schedule=SCHEDULE, observables=("x",),
    ),
}
OBSERVABLE = {"ising": "mean_absmag", "potts": "mean_pmag", "gaussian": "mean_x"}


def main():
    sched = Scheduler(quantum_chunks=1)  # 1 chunk = 100 sweeps per time-slice
    progress = {}

    def on_update(job, update):
        progress[job.id] = f"{update.sweeps_done}/{update.total_sweeps}"

    handles = {
        f"{name}-s{seed}": sched.submit(
            dataclasses.replace(spec, seed=seed),
            on_update=on_update,
            job_id=f"{name}-s{seed}",
        )
        for name, spec in TENANTS.items()
        for seed in SEEDS
    }
    print(f"submitted {len(handles)} jobs across {len(TENANTS)} shapes")
    sched.run_until_idle()

    stats = sched.stats()
    print(
        f"\n{stats['n_jobs']} jobs -> {stats['n_engines']} packed engines, "
        f"{stats['n_compiles']} mega-step compiles, "
        f"{stats['n_quanta']} round-robin quanta\n"
    )
    print(" job           cold-rung observable   final E(T_min)")
    for name, spec in TENANTS.items():
        for seed in SEEDS:
            job_id = f"{name}-s{seed}"
            res = handles[job_id].result()
            obs = res.phases["measure"][OBSERVABLE[name]]
            print(
                f" {job_id:<13} {OBSERVABLE[name]}[0] = {obs[0]: .4f}   "
                f"{np.asarray(res.final_energy)[0]: .2f}"
            )
    assert stats["n_compiles"] == len(TENANTS), "one compile per shape"


if __name__ == "__main__":
    main()
