"""The replica-exchange strategy protocol and registry (DESIGN.md §Exchange).

The swap *phase* of a PT interval decomposes into three policy decisions:

1. **propose_pairs** — which rungs attempt to exchange this iteration
   (an involution over rung indices; ``partner[i] = i`` means unpaired);
2. **accept** — accept/reject each proposed pair (shared acceptance core,
   `repro.core.swap.accept_pairs`: logistic or Metropolis on ``Δβ·ΔE``);
3. **estimator_weights** — optionally, per-rung weights over the *virtual*
   outcomes of the swap, so rejected exchanges still inform the estimator
   (waste recycling, Coluzza & Frenkel cond-mat/0503245 — paper ref [13]).

Strategies are small frozen dataclasses: hashable (so they ride inside the
jit-static `repro.engine.driver.StepSpec`), serializable by name + params
(`repro.api.ExchangeSpec`), and fully traceable — every method is pure JAX,
so each strategy runs *inside* the compiled mega-step with zero host
round-trips per swap iteration.

Register new strategies with `register_strategy`; `make_strategy` resolves
the names the spec layer and the CLI use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core import swap as swap_lib

__all__ = [
    "ExchangeStrategy",
    "STRATEGIES",
    "register_strategy",
    "make_strategy",
    "available_strategies",
    "strategy_help",
]


@dataclasses.dataclass(frozen=True)
class ExchangeStrategy:
    """Base replica-exchange strategy (the deterministic even/odd default).

    Subclasses override `propose_pairs` (and, for waste-recycling schemes,
    `estimator_weights` + ``n_virtual``).  `accept` is shared: one uniform
    per rung, one decision per proposed pair — identical acceptance math for
    every pairing policy, which is what makes the strategies interchangeable
    inside the engine's swap phase.

    Attributes (class-level):
      name: registry key (`repro.api.ExchangeSpec.strategy` namespace).
      n_virtual: number of virtual outcomes each rung contributes to the
        estimator record.  1 = record the realized post-swap state only
        (the classical estimator); 2 = record both virtual outcomes of the
        pair with `estimator_weights` (waste recycling).  Static, so the
        record shape — and therefore the compiled mega-step — is fixed.
    """

    name = "deo"
    n_virtual = 1

    def propose_pairs(self, key: jax.Array, phase: jax.Array, n: int) -> jnp.ndarray:
        """(R,) partner involution for this swap iteration.

        Args:
          key: the iteration's swap PRNG key (shared with `accept`; proposal
            randomness must fold a distinct salt off it).
          phase: the running swap-iteration counter (traced; drives the
            even/odd alternation for deterministic schedules).
          n: number of rungs (static).
        """
        return swap_lib.pair_partners(n, phase)

    def accept(
        self,
        key: jax.Array,
        partner: jnp.ndarray,
        betas: jnp.ndarray,
        energies: jnp.ndarray,
        criterion: str = "logistic",
    ):
        """Shared acceptance core — see `repro.core.swap.accept_pairs`."""
        return swap_lib.accept_pairs(key, partner, betas, energies, criterion=criterion)

    def estimator_weights(
        self, partner: jnp.ndarray, prob_pair: jnp.ndarray
    ) -> jnp.ndarray | None:
        """(n_virtual, R) estimator weights over virtual outcomes, or None.

        ``None`` (the default) means the classical estimator: record the
        realized post-swap configuration with weight 1.  Waste-recycling
        strategies return per-rung weights over the ``n_virtual`` outcomes
        (row ``v=0`` = keep, ``v=1`` = exchange with ``partner``); each
        rung's weights must sum to 1.

        Args:
          partner: this iteration's (R,) pairing involution.
          prob_pair: (R,) acceptance probability at the lower member of each
            pair, 0 elsewhere (the `accept` diagnostic).
        """
        return None


# -- registry -------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Registered:
    build: Callable[..., ExchangeStrategy]
    help: str


STRATEGIES: dict[str, _Registered] = {}


def register_strategy(
    name: str, build: Callable[..., ExchangeStrategy], help: str
) -> None:
    if name in STRATEGIES:
        raise ValueError(f"exchange strategy {name!r} already registered")
    STRATEGIES[name] = _Registered(build=build, help=help)


def available_strategies() -> list[str]:
    return sorted(STRATEGIES)


def strategy_help(name: str) -> str:
    return STRATEGIES[name].help


def make_strategy(
    name: str | ExchangeStrategy | None, params: Mapping[str, Any] | None = None
) -> ExchangeStrategy:
    """Resolve a strategy name (+ JSON-able params) to a strategy instance.

    ``None`` resolves to the default (``deo``, the paper's scheme); an
    already-built `ExchangeStrategy` passes through so engine-level callers
    can hand instances around.
    """
    if name is None:
        name = "deo"
    if isinstance(name, ExchangeStrategy):
        return name
    if name not in STRATEGIES:
        raise ValueError(
            f"unknown exchange strategy {name!r}; "
            f"allowed: {available_strategies()}"
        )
    return STRATEGIES[name].build(**dict(params or {}))
