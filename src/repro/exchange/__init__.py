"""Pluggable replica-exchange strategies (DESIGN.md §Exchange).

The swap phase of the PT mega-step delegates *which rungs exchange* and *how
the estimator uses the attempt* to an `ExchangeStrategy` — a tiny frozen
dataclass resolved by name through `make_strategy`:

    from repro.exchange import make_strategy
    strategy = make_strategy("vmpt")           # or "deo" / "seo" / "windowed"
    cfg = EngineConfig(n_replicas=8, exchange=strategy)

``deo`` is the default and is bit-equal to the pre-strategy swap path; the
others trade proposal structure for mixing (see `repro.exchange.strategies`
and the README strategy table).  `repro.api.ExchangeSpec` is the
serializable form.
"""
from repro.exchange.base import (
    STRATEGIES,
    ExchangeStrategy,
    available_strategies,
    make_strategy,
    register_strategy,
    strategy_help,
)
from repro.exchange.strategies import DEO, SEO, VMPT, Windowed

__all__ = [
    "DEO",
    "SEO",
    "STRATEGIES",
    "VMPT",
    "Windowed",
    "ExchangeStrategy",
    "available_strategies",
    "make_strategy",
    "register_strategy",
    "strategy_help",
]
