"""The in-tree replica-exchange strategies (DESIGN.md §Exchange).

* `DEO` — **d**eterministic **e**ven/**o**dd: the paper's §3 scheme and the
  engine default.  Pairing alternates ``(0,1),(2,3),…`` / ``(1,2),(3,4),…``
  with the swap-iteration counter, which gives ballistic (O(R)) rather than
  diffusive (O(R²)) index flow on well-tuned ladders [Okabe et al.; Syed et
  al. 2019 analyze exactly this DEO/SEO gap].  Bit-equal to the pre-strategy
  swap path.
* `SEO` — **s**tochastic even/odd: the phase is *drawn from the PRNG stream*
  each swap iteration instead of alternating.  The classical randomized
  scheme; kept as the reference point the DEO literature compares against.
* `Windowed` — all-pairs exchange within rung windows: rungs are tiled into
  windows of ``window`` rungs (the grid shifts by ``window // 2`` on odd
  iterations so state can traverse the whole ladder) and each window draws a
  uniform random perfect matching of its members — so *non-adjacent* rungs
  can exchange directly, which helps when a mid-ladder bottleneck starves
  neighbour-only schemes.
* `VMPT` — virtual-move parallel tempering (Coluzza & Frenkel,
  cond-mat/0503245 — paper ref [13]): DEO pairing for the chain itself, but
  the *estimator* records both virtual outcomes of every attempted exchange,
  weighted by the acceptance probability (waste recycling / Rao-
  Blackwellization).  The chain law is identical to DEO; the per-rung
  Welford accumulators consume the weighted record through the engine's
  estimator-weight channel (`repro.engine.stats`).

All proposal randomness folds distinct salts off the iteration's swap key,
so the acceptance uniforms (drawn from the unfolded key, exactly as the
pre-strategy path did) stay on a disjoint stream.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import swap as swap_lib
from repro.exchange.base import ExchangeStrategy, register_strategy

__all__ = ["DEO", "SEO", "Windowed", "VMPT"]

# fold_in salts for proposal randomness (disjoint from the acceptance
# uniforms, which use the swap key itself)
_SEO_SALT = 0x5E0
_WINDOW_SALT = 0x71D0


@dataclasses.dataclass(frozen=True)
class DEO(ExchangeStrategy):
    """Deterministic even/odd neighbour pairing (paper §3; the default)."""

    name = "deo"


@dataclasses.dataclass(frozen=True)
class SEO(ExchangeStrategy):
    """Stochastic even/odd: the pairing phase is a per-iteration coin flip."""

    name = "seo"

    def propose_pairs(self, key, phase, n):
        coin = jax.random.randint(
            jax.random.fold_in(key, _SEO_SALT), (), 0, 2, dtype=jnp.int32
        )
        return swap_lib.pair_partners(n, coin)


@dataclasses.dataclass(frozen=True)
class Windowed(ExchangeStrategy):
    """Random perfect matching within (alternately shifted) rung windows.

    The ladder is tiled into contiguous windows of ``window`` rungs; on odd
    iterations the grid shifts by ``window // 2`` (a truncated window at the
    cold end takes up the slack — windows never wrap the cold/hot boundary)
    so state can traverse the whole ladder.  Every window pairs its members
    by a uniformly random permutation taken two at a time, which proposes
    *any* of the ``C(w, 2)`` in-window pairs with equal probability — a
    symmetric, state-independent proposal, so the shared acceptance core
    applies unchanged.

    Note on acceptance-mode ladder adaptation: attempt/accept counters are
    credited to the *lower rung of the pair* whatever its span, so the
    per-gap acceptance the Kofke feedback reads is only approximate under
    this strategy; prefer ``AdaptConfig(mode="flow")``, which consumes the
    pairing-agnostic round-trip flow instead.
    """

    name = "windowed"
    window: int = 4

    def __post_init__(self):
        if self.window < 2:
            raise ValueError(f"window must be >= 2, got {self.window}")

    def _matching(self, key, n, w, off):
        """Involution for one (static) grid offset: windows [0, w-off),
        [w-off, 2w-off), … — the first window is truncated, none wrap."""
        partner = jnp.arange(n, dtype=jnp.int32)
        starts = [0] + list(range(w - off if off else w, n, w))
        for b, start in enumerate(starts):
            size = min(w, n - start) if start else min(w - off, n)
            if size < 2:
                continue
            perm = jax.random.permutation(
                jax.random.fold_in(key, _WINDOW_SALT + 4096 * off + b), size
            )
            members = (start + perm).astype(jnp.int32)
            n_pairs = size // 2
            a = members[0 : 2 * n_pairs : 2]
            c = members[1 : 2 * n_pairs : 2]
            partner = partner.at[a].set(c).at[c].set(a)
        return partner

    def propose_pairs(self, key, phase, n):
        w = min(self.window, n)
        # the offset is binary (0 / w//2), so build both static tilings and
        # select by the traced phase parity
        aligned = self._matching(key, n, w, 0)
        shifted = self._matching(key, n, w, w // 2)
        return jnp.where(jnp.asarray(phase, jnp.int32) % 2 == 0, aligned, shifted)


@dataclasses.dataclass(frozen=True)
class VMPT(ExchangeStrategy):
    """Virtual-move PT: DEO dynamics + waste-recycled estimator weights."""

    name = "vmpt"
    n_virtual = 2

    def estimator_weights(self, partner, prob_pair):
        n = partner.shape[0]
        idx = jnp.arange(n, dtype=jnp.int32)
        lower = jnp.minimum(idx, partner)
        # both members of a pair see the pair's acceptance probability;
        # unpaired rungs keep their configuration with certainty
        p = jnp.where(partner != idx, prob_pair[lower], 0.0)
        return jnp.stack([1.0 - p, p])


register_strategy(
    "deo", DEO,
    "deterministic even/odd neighbour pairing (paper §3; default, "
    "ballistic index flow)",
)
register_strategy(
    "seo", SEO,
    "stochastic even/odd: pairing phase drawn from the PRNG per iteration "
    "(diffusive reference scheme)",
)
register_strategy(
    "windowed", Windowed,
    "random perfect matching within alternately-shifted rung windows "
    "(non-adjacent exchanges; params: window)",
)
register_strategy(
    "vmpt", VMPT,
    "virtual-move PT: DEO dynamics + waste-recycled estimator weights "
    "over every attempted exchange (Coluzza & Frenkel)",
)
