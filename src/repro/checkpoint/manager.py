"""Fault-tolerant checkpointing: atomic writes, retention, async save,
corruption fallback.

Layout:  <dir>/step_<N>/arrays.npz + meta.json   (+ .tmp staging dirs)
         <dir>/spec.json — the declarative run description (`save_spec`)

* **atomic**: written to `step_N.tmp/` then `os.replace`d — a crash mid-save
  never corrupts the latest checkpoint;
* **fault tolerant restore**: `restore_latest` walks checkpoints newest-first
  and falls back past unreadable/incomplete ones;
* **async**: `save(..., blocking=False)` hands the (host-synced) arrays to a
  writer thread so the train loop overlaps I/O with compute — the next save
  joins the previous writer first (bounded queue of 1);
* **multi-host layout**: each process writes `arrays_p<proc>.npz`; restore
  reads the local process' file (single-process here, but the layout is the
  production one);
* **concurrent multi-job use**: staging directories carry a unique token
  (``step_N.<token>.tmp``) and the final rename is serialized through a
  per-directory in-process lock, so several managers in one process (the
  `repro.serve` scheduler runs one per bucket) never clobber each other's
  step dirs even when they target the same directory and step.  `child`
  derives a manager rooted in a per-job subdirectory.

PT states, train states, engine states and data-cursor metadata all go
through the same pytree path-flattening, so any registered dataclass
(PTState, TrainState, `repro.engine.EngineState` — including its dict-keyed
online-stats leaves) round-trips.  Typed PRNG-key leaves are stored as their
`key_data` words and re-wrapped with the template's key impl on restore, so
a resumed engine run continues the *same* random streams mid-run.
"""
from __future__ import annotations

import itertools
import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

# In-process serialization of the final tmp -> step_N swap, per directory.
# Two managers pointed at the same directory stage into *unique* tmp dirs,
# but the replace-over-existing dance (rmtree + os.replace) is not atomic —
# without the lock an interleaving can rmtree the dir the other manager just
# renamed into place, or make os.replace fail on a re-materialized target.
_DIR_LOCKS: dict[str, threading.Lock] = {}
_DIR_LOCKS_GUARD = threading.Lock()
_TMP_COUNTER = itertools.count()


def _dir_lock(directory: str) -> threading.Lock:
    key = os.path.realpath(directory)
    with _DIR_LOCKS_GUARD:
        return _DIR_LOCKS.setdefault(key, threading.Lock())


def _is_prng_key(leaf) -> bool:
    dtype = getattr(leaf, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(dtype, jax.dtypes.prng_key)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        if _is_prng_key(leaf):
            leaf = jax.random.key_data(leaf)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(tree_like, arrays: dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, like in leaves_p:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if _is_prng_key(like):
            want = tuple(jax.random.key_data(like).shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: key-data shape {arr.shape} != {want}")
            out.append(
                jax.random.wrap_key_data(
                    jax.numpy.asarray(arr), impl=jax.random.key_impl(like)
                )
            )
            continue
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {like.shape}")
        out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree_like), out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.proc = process_index
        os.makedirs(directory, exist_ok=True)
        self._writer: threading.Thread | None = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _staging_dir(self, step: int) -> str:
        # unique per save: pid + a process-wide counter, so concurrent
        # managers (same process or not) never write into one staging dir
        token = f"{os.getpid()}-{next(_TMP_COUNTER)}"
        return f"{self._step_dir(step)}.{token}.tmp"

    def child(self, name: str) -> "CheckpointManager":
        """A manager rooted in the subdirectory ``name`` (same retention).

        The multi-job layout: the serve scheduler gives every bucket/job its
        own subdirectory so concurrent runs keep disjoint step namespaces.
        """
        return CheckpointManager(
            os.path.join(self.dir, name), keep=self.keep,
            process_index=self.proc,
        )

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    # -- run description --------------------------------------------------------
    def save_spec(self, spec: Any):
        """Persist the declarative run description next to the checkpoints.

        ``spec`` is a JSON string or a JSON-able dict (typically
        `repro.api.RunSpec.to_json()`); with it, a run resumes from
        ``(spec, latest checkpoint)`` alone — no Python driver state needed
        (`repro.api.Session.from_checkpoint`).  Written atomically.
        """
        text = spec if isinstance(spec, str) else json.dumps(spec, indent=2)
        json.loads(text)  # fail fast on non-JSON input
        token = f"{os.getpid()}-{next(_TMP_COUNTER)}"
        tmp = os.path.join(self.dir, f"spec.json.{token}.tmp")
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, os.path.join(self.dir, "spec.json"))

    def load_spec(self) -> dict | None:
        """The saved run description as a dict, or None if never saved."""
        path = os.path.join(self.dir, "spec.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, meta: dict | None = None, blocking: bool = True):
        """Checkpoint `tree` at `step`.  Device->host transfer happens here
        (synchronously — the arrays are then immutable); file I/O can be
        deferred to the writer thread."""
        arrays = _flatten(jax.tree_util.tree_map(lambda x: x, tree))
        meta = dict(meta or {}, step=step, time=time.time())
        self.wait()  # bound async queue at depth 1

        def write():
            tmp = self._staging_dir(step)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"arrays_p{self.proc}.npz"), **arrays)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = self._step_dir(step)
            # the write-then-rename swap: staged files are complete before
            # the step dir ever exists, and the swap itself (plus retention
            # GC) is serialized per directory so concurrent managers leave
            # every step dir either absent or whole
            with _dir_lock(self.dir):
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()

        if blocking:
            write()
        else:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def restore(self, step: int, tree_like: Any):
        d = self._step_dir(step)
        with np.load(os.path.join(d, f"arrays_p{self.proc}.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return _unflatten(tree_like, arrays), meta

    def restore_latest(self, tree_like: Any):
        """Newest-first restore with corruption fallback (fault tolerance)."""
        self.wait()
        errors = []
        for step in reversed(self.steps()):
            try:
                return self.restore(step, tree_like)
            except Exception as e:  # corrupted/incomplete -> try older
                errors.append((step, repr(e)))
        if errors:
            raise RuntimeError(f"no restorable checkpoint; tried {errors}")
        return None
