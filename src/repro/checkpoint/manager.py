"""Fault-tolerant checkpointing: atomic writes, retention, async save,
corruption fallback.

Layout:  <dir>/step_<N>/arrays.npz + meta.json   (+ .tmp staging dirs)
         <dir>/spec.json — the declarative run description (`save_spec`)

* **atomic**: written to `step_N.tmp/` then `os.replace`d — a crash mid-save
  never corrupts the latest checkpoint;
* **integrity**: every staged file's sha256 + byte count lands in the step's
  ``meta.json`` (``integrity``), verified on restore — a flipped byte or a
  torn write that still got renamed raises the typed `CheckpointCorrupt`
  instead of unflattening garbage;
* **fault tolerant restore**: `restore_latest` walks checkpoints newest-first
  and falls back past unreadable/incomplete ones (the generations skipped are
  reported in ``last_restore_fallback``); retention GC counts only *readable*
  steps toward ``keep``, so a zero-byte or half-written newest step can never
  push the last intact generation out of retention;
* **async**: `save(..., blocking=False)` hands the (host-synced) arrays to a
  writer thread so the train loop overlaps I/O with compute — the next save
  joins the previous writer first (bounded queue of 1);
* **multi-host layout**: each process writes `arrays_p<proc>.npz`; restore
  reads the local process' file (single-process here, but the layout is the
  production one);
* **concurrent multi-job use**: staging directories carry a unique token
  (``step_N.<token>.tmp``) and the final rename is serialized through a
  per-directory in-process lock, so several managers in one process (the
  `repro.serve` scheduler runs one per bucket) never clobber each other's
  step dirs even when they target the same directory and step.  `child`
  derives a manager rooted in a per-job subdirectory.

PT states, train states, engine states and data-cursor metadata all go
through the same pytree path-flattening, so any registered dataclass
(PTState, TrainState, `repro.engine.EngineState` — including its dict-keyed
online-stats leaves) round-trips.  Typed PRNG-key leaves are stored as their
`key_data` words and re-wrapped with the template's key impl on restore, so
a resumed engine run continues the *same* random streams mid-run.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A step's bytes do not match its recorded integrity digest."""

# In-process serialization of the final tmp -> step_N swap, per directory.
# Two managers pointed at the same directory stage into *unique* tmp dirs,
# but the replace-over-existing dance (rmtree + os.replace) is not atomic —
# without the lock an interleaving can rmtree the dir the other manager just
# renamed into place, or make os.replace fail on a re-materialized target.
_DIR_LOCKS: dict[str, threading.Lock] = {}
_DIR_LOCKS_GUARD = threading.Lock()
_TMP_COUNTER = itertools.count()


def _dir_lock(directory: str) -> threading.Lock:
    key = os.path.realpath(directory)
    with _DIR_LOCKS_GUARD:
        return _DIR_LOCKS.setdefault(key, threading.Lock())


def _is_prng_key(leaf) -> bool:
    dtype = getattr(leaf, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(dtype, jax.dtypes.prng_key)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        if _is_prng_key(leaf):
            leaf = jax.random.key_data(leaf)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(tree_like, arrays: dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, like in leaves_p:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if _is_prng_key(like):
            want = tuple(jax.random.key_data(like).shape)
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: key-data shape {arr.shape} != {want}")
            out.append(
                jax.random.wrap_key_data(
                    jax.numpy.asarray(arr), impl=jax.random.key_impl(like)
                )
            )
            continue
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {like.shape}")
        out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(tree_like), out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, process_index: int = 0,
                 faults=None):
        self.dir = directory
        self.keep = keep
        self.proc = process_index
        os.makedirs(directory, exist_ok=True)
        self._writer: threading.Thread | None = None
        # fault-injection handle (repro.resilience.FaultPlan) — None in
        # production; every site below is a single `is None` test when off
        self._faults = faults
        # generations skipped by the newest-first walk of the last
        # `restore_latest` call (0 = the newest step was intact)
        self.last_restore_fallback = 0

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _staging_dir(self, step: int) -> str:
        # unique per save: pid + a process-wide counter, so concurrent
        # managers (same process or not) never write into one staging dir
        token = f"{os.getpid()}-{next(_TMP_COUNTER)}"
        return f"{self._step_dir(step)}.{token}.tmp"

    def child(self, name: str) -> "CheckpointManager":
        """A manager rooted in the subdirectory ``name`` (same retention).

        The multi-job layout: the serve scheduler gives every bucket/job its
        own subdirectory so concurrent runs keep disjoint step namespaces.
        """
        return CheckpointManager(
            os.path.join(self.dir, name), keep=self.keep,
            process_index=self.proc, faults=self._faults,
        )

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    # -- integrity ---------------------------------------------------------------
    def _arrays_name(self) -> str:
        return f"arrays_p{self.proc}.npz"

    @staticmethod
    def _sha256(path: str) -> str:
        h = hashlib.sha256()
        with open(path, "rb") as f:
            for block in iter(lambda: f.read(1 << 20), b""):
                h.update(block)
        return h.hexdigest()

    def step_readable(self, step: int) -> bool:
        """Cheap readability check: the meta parses and every file recorded
        in its ``integrity`` manifest exists with the recorded byte count
        (pre-digest steps: the arrays file merely exists and is non-empty).
        Full digests are verified on `restore`, not here — this runs inside
        retention GC on every save."""
        d = self._step_dir(step)
        try:
            with open(os.path.join(d, "meta.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError):
            return False
        integrity = meta.get("integrity")
        if integrity is None:
            try:
                return os.path.getsize(
                    os.path.join(d, self._arrays_name())) > 0
            except OSError:
                return False
        for fname, rec in integrity.items():
            try:
                if os.path.getsize(os.path.join(d, fname)) != rec["bytes"]:
                    return False
            except (OSError, KeyError, TypeError):
                return False
        return True

    def readable_steps(self) -> list[int]:
        """`steps()` filtered to the ones that pass `step_readable`."""
        return [s for s in self.steps() if self.step_readable(s)]

    def _verify(self, step: int) -> None:
        """Full content-digest check; raises `CheckpointCorrupt`."""
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        for fname, rec in meta.get("integrity", {}).items():
            path = os.path.join(d, fname)
            size = os.path.getsize(path)
            if size != rec["bytes"]:
                raise CheckpointCorrupt(
                    f"{path}: {size} bytes on disk, manifest says "
                    f"{rec['bytes']} (torn write)"
                )
            digest = self._sha256(path)
            if digest != rec["sha256"]:
                raise CheckpointCorrupt(
                    f"{path}: content digest {digest[:12]}… != manifest "
                    f"{rec['sha256'][:12]}… (corrupt bytes)"
                )

    # -- run description --------------------------------------------------------
    def save_spec(self, spec: Any):
        """Persist the declarative run description next to the checkpoints.

        ``spec`` is a JSON string or a JSON-able dict (typically
        `repro.api.RunSpec.to_json()`); with it, a run resumes from
        ``(spec, latest checkpoint)`` alone — no Python driver state needed
        (`repro.api.Session.from_checkpoint`).  Written atomically.
        """
        text = spec if isinstance(spec, str) else json.dumps(spec, indent=2)
        json.loads(text)  # fail fast on non-JSON input
        token = f"{os.getpid()}-{next(_TMP_COUNTER)}"
        tmp = os.path.join(self.dir, f"spec.json.{token}.tmp")
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, os.path.join(self.dir, "spec.json"))

    def load_spec(self) -> dict | None:
        """The saved run description as a dict, or None if never saved."""
        path = os.path.join(self.dir, "spec.json")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, meta: dict | None = None, blocking: bool = True):
        """Checkpoint `tree` at `step`.  Device->host transfer happens here
        (synchronously — the arrays are then immutable); file I/O can be
        deferred to the writer thread."""
        arrays = _flatten(jax.tree_util.tree_map(lambda x: x, tree))
        meta = dict(meta or {}, step=step, time=time.time())
        self.wait()  # bound async queue at depth 1

        def write():
            tmp = self._staging_dir(step)
            os.makedirs(tmp, exist_ok=True)
            arrays_name = self._arrays_name()
            arrays_path = os.path.join(tmp, arrays_name)
            np.savez(arrays_path, **arrays)
            # content digest of the staged bytes BEFORE any injected
            # corruption below — that is the point: a torn/flipped file no
            # longer matches its manifest, so restore detects it
            meta["integrity"] = {
                arrays_name: {
                    "sha256": self._sha256(arrays_path),
                    "bytes": os.path.getsize(arrays_path),
                }
            }
            if self._faults is not None:
                if self._faults.check("checkpoint.write.torn") is not None:
                    size = os.path.getsize(arrays_path)
                    with open(arrays_path, "r+b") as f:
                        f.truncate(size // 2)
                if self._faults.check("checkpoint.write.corrupt") is not None:
                    size = os.path.getsize(arrays_path)
                    with open(arrays_path, "r+b") as f:
                        f.seek(size // 2)
                        byte = f.read(1)
                        f.seek(size // 2)
                        f.write(bytes([byte[0] ^ 0xFF]))
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if self._faults is not None and self._faults.check(
                "checkpoint.write.crash_before_rename"
            ) is not None:
                from repro.resilience.faults import InjectedCrash

                raise InjectedCrash(
                    f"killed before renaming {tmp} (staging dir left behind)"
                )
            final = self._step_dir(step)
            # the write-then-rename swap: staged files are complete before
            # the step dir ever exists, and the swap itself (plus retention
            # GC) is serialized per directory so concurrent managers leave
            # every step dir either absent or whole
            with _dir_lock(self.dir):
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                self._gc()
            if self._faults is not None and self._faults.check(
                "checkpoint.write.crash_after_rename"
            ) is not None:
                from repro.resilience.faults import InjectedCrash

                raise InjectedCrash(
                    f"killed after renaming {final} (step dir is whole)"
                )

        if blocking:
            write()
        else:
            self._writer = threading.Thread(target=write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def _gc(self):
        if not self.keep:
            return
        steps = self.steps()
        # retention counts READABLE generations only: a zero-byte or
        # half-written step dir from a killed process must never push the
        # last intact generation out of the keep window.  Unreadable dirs
        # older than the protected set are garbage and are pruned with the
        # rest (with no readable step at all, fall back to raw numbering so
        # the directory still cannot grow without bound).
        readable = [s for s in steps if self.step_readable(s)]
        protect = set(readable[-self.keep:] if readable else steps[-self.keep:])
        for s in steps:
            if s not in protect:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def restore(self, step: int, tree_like: Any, verify: bool = True):
        d = self._step_dir(step)
        if verify:
            # digest check before touching the arrays: a flipped byte in a
            # compressed member can otherwise unflatten into silently wrong
            # state instead of an exception
            self._verify(step)
        with np.load(os.path.join(d, f"arrays_p{self.proc}.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return _unflatten(tree_like, arrays), meta

    def restore_latest(self, tree_like: Any):
        """Newest-first restore with corruption fallback (fault tolerance).

        Torn/truncated/corrupt generations are skipped (their count lands
        in ``last_restore_fallback`` — the recovery-depth telemetry); with
        no restorable step but recorded failures, raises so the caller
        never silently restarts from scratch on a wholly corrupt directory.
        """
        self.wait()
        errors = []
        self.last_restore_fallback = 0
        for step in reversed(self.steps()):
            try:
                out = self.restore(step, tree_like)
                self.last_restore_fallback = len(errors)
                return out
            except Exception as e:  # corrupted/incomplete -> try older
                errors.append((step, repr(e)))
        self.last_restore_fallback = len(errors)
        if errors:
            raise RuntimeError(f"no restorable checkpoint; tried {errors}")
        return None
