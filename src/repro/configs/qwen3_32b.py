"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8, head_dim=128)
d_ff=25600 vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab=151936,
        act="silu",
        qk_norm=True,
        rope_theta=1_000_000.0,
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, attn_chunk=0, logit_chunk=16, remat=False,
    )
