"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8, head_dim=128)
d_ff=9216 vocab=256000 — pruned nemotron (squared-ReLU MLP, no gating).
[arXiv:2407.14679; hf]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab=256_000,
        act="relu2",  # nemotron-family squared ReLU
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, attn_chunk=0, logit_chunk=16, remat=False,
    )
