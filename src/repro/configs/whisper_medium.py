"""whisper-medium [audio enc-dec]: 24+24L d_model=1024 16H (MHA kv=16,
head_dim=64) d_ff=4096 vocab=51865 — conv frontend is a stub:
input_specs() provides precomputed frame embeddings (B, 1500, 1024).
[arXiv:2212.04356; unverified]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="encdec",
        n_layers=24,  # decoder
        enc_layers=24,
        enc_seq=1500,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab=51865,
        act="gelu",
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, enc_layers=2, enc_seq=16, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, attn_chunk=0,
        logit_chunk=16, remat=False,
    )
