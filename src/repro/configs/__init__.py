"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_config(name, reduced=True)`` returns the same-family reduced config
used by CPU smoke tests (the full configs are exercised only via the
dry-run's ShapeDtypeStructs — no allocation).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen3_32b",
    "gemma_2b",
    "minitron_4b",
    "stablelm_3b",
    "qwen3_moe_235b",
    "mixtral_8x22b",
    "recurrentgemma_9b",
    "rwkv6_7b",
    "whisper_medium",
    "llama32_vision_11b",
]

# accept dashed external ids too (CLI convenience)
ALIASES = {
    "qwen3-32b": "qwen3_32b",
    "gemma-2b": "gemma_2b",
    "minitron-4b": "minitron_4b",
    "stablelm-3b": "stablelm_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "mixtral-8x22b": "mixtral_8x22b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-medium": "whisper_medium",
    "llama-3.2-vision-11b": "llama32_vision_11b",
}


def get_config(name: str, reduced: bool = False):
    mod_name = ALIASES.get(name, name)
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced() if reduced else mod.config()
