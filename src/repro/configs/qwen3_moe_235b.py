"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4, head_dim=128)
expert d_ff=1536 vocab=151936, MoE 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B family; hf]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,  # per-expert
        vocab=151936,
        act="silu",
        qk_norm=True,
        rope_theta=1_000_000.0,
        n_experts=128,
        top_k=8,
        capacity_factor=1.25,
        renorm_gates=True,
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=512, n_experts=8, top_k=2, attn_chunk=0,
        logit_chunk=16, remat=False,
    )
