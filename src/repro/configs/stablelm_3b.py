"""stablelm-3b [dense]: 32L d_model=2560 32H (MHA kv=32, head_dim=80)
d_ff=6912 vocab=50304.  [hf:stabilityai/stablelm-2-1_6b family; unverified]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab=50304,
        act="silu",
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, attn_chunk=0, logit_chunk=16, remat=False,
    )
