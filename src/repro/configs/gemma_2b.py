"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1, head_dim=256)
d_ff=16384 vocab=256000 — GeGLU, tied embeddings, sqrt(d) embed scale.
[arXiv:2403.08295; hf]"""
import dataclasses
import math

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab=256_000,
        act="geglu",
        tie_embeddings=True,
        embed_scale=math.sqrt(2048.0),
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, embed_scale=8.0, attn_chunk=0, logit_chunk=16,
        remat=False,
    )
