"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8, head_dim=128)
d_ff=14336 vocab=128256 — gated cross-attention image layers every 5th layer
(8 of 40); vision tower is a stub: input_specs() provides precomputed patch
embeddings (B, 1601, 4096).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=128256,
        act="silu",
        rope_theta=500_000.0,
        cross_attn_every=5,
        img_tokens=1601,
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, img_tokens=8, attn_chunk=0, logit_chunk=16,
        remat=False,
    )
