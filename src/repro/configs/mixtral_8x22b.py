"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8, head_dim=128)
expert d_ff=16384 vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,  # per-expert
        vocab=32768,
        act="silu",
        n_experts=8,
        top_k=2,
        capacity_factor=1.25,
        renorm_gates=True,
        swa_window=4096,  # SWA => sub-quadratic: long_500k runs for this arch
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=512, n_experts=4, top_k=2, swa_window=16,
        attn_chunk=0, logit_chunk=16, remat=False,
    )
