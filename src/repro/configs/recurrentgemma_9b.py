"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1, head_dim=256)
d_ff=12288 vocab=256000 — RG-LRU + local attention, pattern
(rglru, rglru, local-attn) i.e. 1 attention per 2 recurrent layers;
38 = 12x3 + 2 recurrent tail.  [arXiv:2402.19427; unverified]"""
import dataclasses
import math

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab=256_000,
        act="geglu",
        pattern=("rglru", "rglru", "attn_local"),
        lru_width=4096,
        conv1d_width=4,
        local_window=2048,
        tie_embeddings=True,
        embed_scale=math.sqrt(4096.0),
        attn_chunk=2048,
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=5,  # 1 group + 2-layer tail: exercises both code paths
        d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
        vocab=512, lru_width=64, local_window=16, embed_scale=8.0,
        attn_chunk=0, logit_chunk=16, remat=False,
    )
