"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free, 64 heads x 64 dims)
d_ff=14336 vocab=65536 — "Finch", data-dependent decay linear recurrence.
[arXiv:2404.05892; hf]"""
import dataclasses

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="rwkv",
        n_layers=32,
        d_model=4096,
        n_heads=64,  # d_model / 64 (fixed RWKV head dim)
        n_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab=65536,
        act="relu2",  # channel-mix squared ReLU
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        config(),
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=64,
        d_ff=256, vocab=512, logit_chunk=16, remat=False,
    )
