"""Job lifecycle for PT-as-a-service (DESIGN.md §Serve).

A `Job` is one tenant's `RunSpec` submitted to the `repro.serve.Scheduler`.
Its lifecycle is

    PENDING ──► RUNNING ◄──► PREEMPTED ──► DONE
                   │                        ▲
                   └──────► FAILED          └─ (bucket schedule complete)

* PENDING    — queued, not yet sealed into a packed bucket;
* RUNNING    — its bucket currently holds the scheduler quantum;
* PREEMPTED  — its bucket was time-sliced out between quanta (the packed
  engine state stays resident / checkpointed; the job resumes bit-equal);
* DONE       — the bucket finished the schedule; `Job.result()` returns;
* FAILED     — this job's stream callback raised, or its chains went
  non-finite.  The *bucket* keeps running: failure is isolated to the
  tenant (its chain slots keep simulating as dead lanes until the bucket
  completes, since the compiled mega-step shape cannot shrink mid-run).

Each job owns an isolated PRNG stream: chain ``c`` of job with seed ``s``
runs on exactly the key stream a solo ``Session`` run of the same spec
would use (``jax.random.key(s)``, plus ``fold_in(·, c)`` for an ensemble
spec) — packing is invisible to the tenant's randomness.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from collections import deque
from typing import Any, Callable

import numpy as np

__all__ = [
    "JobState",
    "JobUpdate",
    "JobResult",
    "JobFailedError",
    "QueueFull",
    "SchedulerStopped",
    "Job",
    "JobQueue",
]


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    PREEMPTED = "preempted"
    DONE = "done"
    FAILED = "failed"


class JobFailedError(RuntimeError):
    """Raised by `Job.result` when the job ended FAILED."""


class SchedulerStopped(RuntimeError):
    """The scheduler shut down before this PENDING job was ever sealed into
    a bucket — `Scheduler.shutdown` drains such jobs into FAILED with this
    error instead of leaving their `Job.result` callers blocked forever."""


class QueueFull(RuntimeError):
    """`Scheduler.submit` backpressure: the intake queue is at its bounded
    depth (``queue_depth``) and the caller asked not to block."""


@dataclasses.dataclass
class JobUpdate:
    """One streamed observation: this tenant's slice of a compiled chunk.

    Attributes:
      sweeps_done: schedule sweeps completed so far (per chain).
      total_sweeps: the spec's full schedule budget.
      phase: name of the schedule phase the chunk ran in.
      energy: per-rung energies, cold->hot — ``(R,)`` for an ``n_chains=1``
        spec, ``(C, R)`` otherwise.  Bit-equal to what a solo run's
        ``ChunkInfo.state`` would show at the same sweep.
      trace: this chunk's per-interval trace slice (only when the spec sets
        ``record_trace=True``), same shapes a solo run streams.
    """

    sweeps_done: int
    total_sweeps: int
    phase: str
    energy: np.ndarray
    trace: dict[str, np.ndarray] | None = None


@dataclasses.dataclass
class JobResult:
    """Final per-tenant outcome, extracted from the bucket's ensemble slice.

    ``phases`` maps phase name -> the `repro.engine.stats.summarize` dict of
    that phase's accumulators, sliced to this job's chains (phases completed
    before a scheduler restart are absent — the same contract as
    `Session.from_checkpoint`).
    """

    job_id: str
    spec: Any  # RunSpec
    phases: dict[str, dict[str, np.ndarray]]
    final_energy: np.ndarray  # (R,) or (C, R), rung order cold->hot
    n_sweeps: int

    def manifest(self) -> dict:
        """JSON-able result manifest (what ``repro serve`` writes per job)."""
        phases = {}
        for name, summary in self.phases.items():
            phases[name] = {
                k: np.asarray(v, np.float64).tolist() for k, v in summary.items()
            }
        return {
            "job": self.job_id,
            "spec": self.spec.to_dict(),
            "n_sweeps": int(self.n_sweeps),
            "phases": phases,
            "final_energy": np.asarray(self.final_energy, np.float64).tolist(),
        }


class Job:
    """Client-side handle for one submitted `RunSpec`.

    ``on_update`` (optional) is called as ``on_update(job, update)`` after
    every compiled chunk of the job's bucket — the tenant's view of the
    Session callback pipeline, restricted to its own ensemble slice.  An
    exception raised by the callback FAILs this job only; the bucket and its
    other tenants continue (pinned by ``tests/test_serve.py``).
    """

    def __init__(
        self,
        job_id: str,
        spec,
        on_update: Callable[["Job", JobUpdate], Any] | None = None,
    ):
        self.id = job_id
        self.spec = spec
        self.on_update = on_update
        self.state = JobState.PENDING
        self.error: BaseException | None = None
        self.last_update: JobUpdate | None = None
        self.n_updates = 0
        # monotonic submit timestamp, stamped by Scheduler.submit — feeds
        # the wakeup-latency and time-in-queue histograms (None for jobs
        # restored from a checkpoint, which were never in this queue)
        self.submitted_at: float | None = None
        self._result: JobResult | None = None
        self._finished = threading.Event()

    @property
    def seed(self) -> int:
        return self.spec.seed

    @property
    def n_chains(self) -> int:
        return self.spec.engine.n_chains

    @property
    def total_sweeps(self) -> int:
        return self.spec.schedule.total_sweeps

    def done(self) -> bool:
        return self._finished.is_set()

    def result(self, timeout: float | None = None) -> JobResult:
        """Block until the job finishes; raise `JobFailedError` on FAILED."""
        if not self._finished.wait(timeout):
            raise TimeoutError(
                f"job {self.id} still {self.state.value} after {timeout}s"
            )
        if self.state is JobState.FAILED:
            raise JobFailedError(f"job {self.id} failed: {self.error!r}") \
                from self.error
        assert self._result is not None
        return self._result

    # -- transitions (driven by the scheduler/bucket, not the client) ----------
    def _notify(self, update: JobUpdate) -> None:
        self.last_update = update
        self.n_updates += 1
        if self.on_update is not None:
            self.on_update(self, update)

    def _deliver(self, result: JobResult) -> None:
        self._result = result
        self.state = JobState.DONE
        self._finished.set()

    def _fail(self, error: BaseException) -> None:
        self.error = error
        self.state = JobState.FAILED
        self._finished.set()

    def __repr__(self):
        return f"Job({self.id!r}, {self.state.value}, seed={self.seed})"


class JobQueue:
    """Thread-safe FIFO intake between `submit()` callers and the host loop.

    ``maxsize`` bounds the depth (0 = unbounded): at capacity, `put` either
    raises `QueueFull` immediately or — with ``block=True`` — waits for the
    host loop to drain space, raising `QueueFull` only on timeout.  The
    bound is backpressure against a producer outrunning the service, not a
    fairness mechanism (buckets already round-robin).
    """

    def __init__(self, maxsize: int = 0):
        self.maxsize = maxsize
        self._items: deque[Job] = deque()
        self._cond = threading.Condition()

    def put(self, job: Job, block: bool = False,
            timeout: float | None = None) -> None:
        with self._cond:
            if self.maxsize:
                if not block and len(self._items) >= self.maxsize:
                    raise QueueFull(
                        f"intake queue at bounded depth {self.maxsize}"
                    )
                if block:
                    ok = self._cond.wait_for(
                        lambda: len(self._items) < self.maxsize, timeout
                    )
                    if not ok:
                        raise QueueFull(
                            f"intake queue still at depth {self.maxsize} "
                            f"after {timeout}s"
                        )
            self._items.append(job)
            self._cond.notify_all()

    def poke(self) -> None:
        """Wake every `wait` caller without enqueueing (stop signalling)."""
        with self._cond:
            self._cond.notify_all()

    def drain(self) -> list[Job]:
        """Remove and return every queued job (possibly empty)."""
        with self._cond:
            items = list(self._items)
            self._items.clear()
            # free capacity: wake any producer blocked in put(block=True)
            self._cond.notify_all()
        return items

    def peek(self) -> list[Job]:
        """A snapshot of the queued jobs without removing them."""
        with self._cond:
            return list(self._items)

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the queue is non-empty (True) or timeout (False)."""
        with self._cond:
            if self._items:
                return True
            self._cond.wait(timeout)
            return bool(self._items)

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)
