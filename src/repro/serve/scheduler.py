"""The multi-tenant PT scheduler: intake, packing, time-slicing, resume.

One `Scheduler` owns a `JobQueue`, a cache of packed engines, and a
round-robin deque of live `PackedRun` buckets.  The host loop:

1. **intake** — drain the queue; servability-check each spec
   (`check_servable` — a bad spec FAILs its job at submit time, it never
   poisons a bucket) and stage it under its `shape_signature`;
2. **seal** — once a signature's pack window closes, snapshot the staged
   jobs into a `PackedRun`.  The packed engine is cached by
   ``(signature, total chains)``, so bucket generation N+1 of the same shape
   reuses generation N's compiled executables — the "exactly one compile for
   N jobs" contract `benchmarks/serve_load.py` measures;
3. **time-slice** — pop the head bucket, run one quantum
   (``quantum_chunks`` compiled chunks), checkpoint it, and rotate it to the
   tail (strict FIFO requeue == round-robin: with B live buckets every
   bucket runs every B quanta — no starvation, pinned by
   ``tests/test_serve.py``).

Preemption rides the PR 3 checkpoint machinery: each bucket owns a
`CheckpointManager` subdirectory (``<root>/<signature>-<seq>/``) holding a
``serve.json`` composition manifest plus ordinary engine step dirs, and
`Scheduler.from_checkpoint` rebuilds every unfinished bucket bit-equal after
a process restart.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time
from collections import deque
from typing import Any, Callable

from repro.api.spec import RunSpec
from repro.checkpoint.manager import CheckpointManager
from repro.engine import Engine
from repro.serve.bucket import (
    MANIFEST_NAME,
    PackedRun,
    check_servable,
    shape_signature,
)
from repro.serve.job import Job, JobQueue, JobResult, JobState, JobUpdate

__all__ = ["Scheduler"]


@dataclasses.dataclass
class _Staged:
    """Jobs of one signature waiting for their pack window to close."""

    template: RunSpec
    jobs: list
    since: float  # monotonic time of first arrival


class Scheduler:
    """PT-as-a-service: submit `RunSpec`s, receive per-tenant `JobResult`s.

    Args:
      checkpoint_dir: root directory for per-bucket checkpoint subdirs;
        None disables preemption persistence (buckets stay memory-resident).
      quantum_chunks: compiled chunks per time-slice — the fairness quantum.
      pack_window: seconds a new signature's first job waits for bucket-mates
        before sealing.  0 seals as soon as the loop observes the jobs, which
        still packs everything submitted before the loop runs (the
        batch-submission pattern of `run_until_idle`).
      checkpoint_every_quanta: bucket-checkpoint cadence (0 = only at seal
        and finish).
      keep: checkpoint retention per bucket.

    Use either synchronously (``submit(...)`` then ``run_until_idle()``) or
    as a service (``start()`` spawns the host loop thread; ``submit`` is
    thread-safe; ``shutdown()`` stops it).
    """

    def __init__(
        self,
        checkpoint_dir: str | None = None,
        quantum_chunks: int = 1,
        pack_window: float = 0.0,
        checkpoint_every_quanta: int = 0,
        keep: int = 2,
    ):
        if quantum_chunks < 1:
            raise ValueError("quantum_chunks must be >= 1")
        self.queue = JobQueue()
        self.quantum_chunks = quantum_chunks
        self.pack_window = pack_window
        self.checkpoint_every_quanta = checkpoint_every_quanta
        self.keep = keep
        self._root = None
        if checkpoint_dir is not None:
            self._root = CheckpointManager(str(checkpoint_dir), keep=keep)
        self._staged: dict[str, _Staged] = {}
        self._buckets: deque[PackedRun] = deque()
        # (signature, packed width) -> Engine: the compile-amortization cache
        self._engines: dict[tuple[str, int], Engine] = {}
        self._job_seq = itertools.count()
        self._bucket_seq = itertools.count()
        self._quanta_run: dict[int, int] = {}  # id(bucket) -> quanta count
        self.quantum_log: list[str] = []  # signature per quantum (fairness)
        self.jobs: dict[str, Job] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- client API --------------------------------------------------------------
    def submit(
        self,
        spec: RunSpec,
        on_update: Callable[[Job, JobUpdate], Any] | None = None,
        job_id: str | None = None,
    ) -> Job:
        """Enqueue one tenant run; returns immediately with its handle."""
        if job_id is None:
            job_id = f"job-{next(self._job_seq):04d}"
        if job_id in self.jobs:
            raise ValueError(f"duplicate job id {job_id!r}")
        job = Job(job_id, spec, on_update=on_update)
        self.jobs[job_id] = job
        self.queue.put(job)
        return job

    def result(self, job: Job | str, timeout: float | None = None) -> JobResult:
        """Block for one job's result (`Job.result`); accepts id or handle."""
        if isinstance(job, str):
            job = self.jobs[job]
        return job.result(timeout)

    # -- intake / packing --------------------------------------------------------
    def _intake(self) -> None:
        now = time.monotonic()
        for job in self.queue.drain():
            try:
                check_servable(job.spec)
            except ValueError as err:
                job._fail(err)
                continue
            digest, _ = shape_signature(job.spec)
            staged = self._staged.get(digest)
            if staged is None:
                staged = self._staged[digest] = _Staged(
                    template=job.spec, jobs=[], since=now
                )
            staged.jobs.append(job)

    def _seal(self, force: bool = False) -> None:
        now = time.monotonic()
        for digest in list(self._staged):
            staged = self._staged[digest]
            if not force and now - staged.since < self.pack_window:
                continue
            del self._staged[digest]
            self._buckets.append(self._make_bucket(digest, staged))

    def _engine_for(self, digest: str, template: RunSpec, width: int) -> Engine:
        key = (digest, width)
        engine = self._engines.get(key)
        if engine is None:
            system = template.system.build()
            config = dataclasses.replace(
                template.engine.build(
                    template.ladder.n_replicas,
                    exchange=template.exchange.build(),
                ),
                n_chains=width,
            )
            engine = Engine(
                system,
                config,
                observables=template.system.observables(
                    system, template.observables
                ),
            )
            self._engines[key] = engine
        return engine

    def _bucket_manager(self, name: str):
        return None if self._root is None else self._root.child(name)

    def _make_bucket(self, digest: str, staged: _Staged) -> PackedRun:
        width = sum(j.n_chains for j in staged.jobs)
        engine = self._engine_for(digest, staged.template, width)
        name = f"{digest}-{next(self._bucket_seq):04d}"
        bucket = PackedRun(
            digest, staged.template, staged.jobs, engine,
            manager=self._bucket_manager(name),
        )
        bucket.write_manifest()
        return bucket

    # -- the host loop -----------------------------------------------------------
    def step(self) -> bool:
        """One scheduler step: intake, seal, run one quantum.  True if any
        bucket advanced."""
        self._intake()
        self._seal(force=self.pack_window <= 0)
        if not self._buckets:
            return False
        bucket = self._buckets.popleft()
        for job in bucket.live_jobs():
            job.state = JobState.RUNNING
        self.quantum_log.append(bucket.digest)
        finished = bucket.run_quantum(self.quantum_chunks)
        n = self._quanta_run.get(id(bucket), 0) + 1
        self._quanta_run[id(bucket)] = n
        if finished:
            self._quanta_run.pop(id(bucket), None)
            bucket.checkpoint()  # final state: restart delivers instantly
        else:
            if self.checkpoint_every_quanta and (
                n % self.checkpoint_every_quanta == 0
            ):
                bucket.checkpoint()
            for job in bucket.live_jobs():
                job.state = JobState.PREEMPTED
            self._buckets.append(bucket)
        return True

    def idle(self) -> bool:
        return not (self._buckets or self._staged or len(self.queue))

    def run_until_idle(self, max_quanta: int | None = None) -> None:
        """Drive the loop synchronously until every submitted job resolves."""
        quanta = 0
        while not self.idle():
            if not self.step():
                continue
            quanta += 1
            if max_quanta is not None and quanta >= max_quanta:
                return

    def start(self) -> None:
        """Run the host loop on a background thread (service mode)."""
        if self._thread is not None:
            raise RuntimeError("scheduler already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if not self.step() and self.idle():
                    # nothing live: sleep until a submission (or stop poke)
                    self.queue.wait(timeout=0.05)

        self._thread = threading.Thread(
            target=loop, name="repro-serve", daemon=True
        )
        self._thread.start()

    def shutdown(self, wait: bool = True) -> None:
        """Stop the host loop.  With ``wait``, drain all live work first."""
        if self._thread is None:
            return
        if wait:
            while not self.idle():
                time.sleep(0.01)
        self._stop.set()
        self._thread.join()
        self._thread = None

    # -- introspection -----------------------------------------------------------
    def stats(self) -> dict:
        """Service counters (the serve benchmark's instrumentation source)."""
        return {
            "n_jobs": len(self.jobs),
            "n_buckets_live": len(self._buckets),
            "n_engines": len(self._engines),
            "n_compiles": sum(e.n_compiles for e in self._engines.values()),
            "n_quanta": len(self.quantum_log),
            "states": {
                s.value: sum(1 for j in self.jobs.values() if j.state is s)
                for s in JobState
            },
        }

    # -- restart -----------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, checkpoint_dir: str, **kwargs) -> "Scheduler":
        """Rebuild a scheduler from its checkpoint root after a restart.

        Every subdirectory holding a ``serve.json`` manifest becomes a
        restored bucket: jobs are re-registered (fresh handles — client
        callbacks do not survive a process), engines are rebuilt and the
        newest packed state restored bit-equal.  Buckets whose checkpointed
        sweep counter already covers the schedule deliver their results
        immediately; the rest re-enter the round-robin where they left off.
        Phase summaries recorded before the restart are not replayed — a
        restored `JobResult.phases` only holds phases that *ended* after the
        restore point (the `Session.from_checkpoint` contract).
        """
        sched = cls(checkpoint_dir=checkpoint_dir, **kwargs)
        root = sched._root.dir
        for name in sorted(os.listdir(root)):
            manifest_path = os.path.join(root, name, MANIFEST_NAME)
            if not os.path.isfile(manifest_path):
                continue
            with open(manifest_path) as f:
                manifest = json.load(f)
            digest = manifest["signature"]
            template = RunSpec.from_dict(manifest["template"])
            jobs = []
            for entry in manifest["jobs"]:
                job = Job(entry["id"], RunSpec.from_dict(entry["spec"]))
                job.state = JobState.PREEMPTED
                sched.jobs[job.id] = job
                jobs.append(job)
            width = sum(j.n_chains for j in jobs)
            bucket = PackedRun.restore(
                digest, template, jobs,
                sched._engine_for(digest, template, width),
                sched._root.child(name),
            )
            # keep the bucket-name sequence ahead of restored dirs
            try:
                seq = int(name.rsplit("-", 1)[1])
                sched._bucket_seq = itertools.count(seq + 1)
            except (IndexError, ValueError):
                pass
            if bucket.finished:
                continue
            sched._buckets.append(bucket)
        return sched
