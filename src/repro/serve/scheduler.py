"""The multi-tenant PT scheduler: intake, packing, time-slicing, resume.

One `Scheduler` owns a `JobQueue`, a cache of packed engines, and a
round-robin deque of live `PackedRun` buckets.  The host loop:

1. **intake** — drain the queue; servability-check each spec
   (`check_servable` — a bad spec FAILs its job at submit time, it never
   poisons a bucket) and stage it under its `shape_signature`;
2. **seal** — once a signature's pack window closes, snapshot the staged
   jobs into a `PackedRun`.  The packed engine is cached by
   ``(signature, total chains)``, so bucket generation N+1 of the same shape
   reuses generation N's compiled executables — the "exactly one compile for
   N jobs" contract `benchmarks/serve_load.py` measures;
3. **time-slice** — pop the head bucket, run one quantum
   (``quantum_chunks`` compiled chunks), checkpoint it, and rotate it to the
   tail (strict FIFO requeue == round-robin: with B live buckets every
   bucket runs every B quanta — no starvation, pinned by
   ``tests/test_serve.py``).

Preemption rides the PR 3 checkpoint machinery: each bucket owns a
`CheckpointManager` subdirectory (``<root>/<signature>-<seq>/``) holding a
``serve.json`` composition manifest plus ordinary engine step dirs, and
`Scheduler.from_checkpoint` rebuilds every unfinished bucket bit-equal after
a process restart.

Every quantum runs under a `repro.resilience.Supervisor` (DESIGN.md
§Resilience): a transient failure — a launch raise, a torn checkpoint, a
compile error, a watchdog-caught stall — recovers the bucket from its last
intact checkpoint and retries with backoff; ``max_attempts`` consecutive
failures quarantine the bucket (its jobs FAIL with `BucketQuarantined`, a
``quarantine.json`` manifest lands next to its checkpoints) while every
other bucket keeps serving.  ``queue_depth`` bounds the intake queue
(`QueueFull` backpressure) and `shutdown` drains still-PENDING jobs into
FAILED with `SchedulerStopped` instead of leaving `Job.result` callers
blocked forever.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import threading
import time
import warnings
from collections import deque
from typing import Any, Callable

from repro.api.spec import RunSpec
from repro.checkpoint.manager import CheckpointManager
from repro.engine import Engine
from repro.resilience import RetryPolicy, Supervisor
from repro.serve.bucket import (
    MANIFEST_NAME,
    PackedRun,
    check_servable,
    shape_signature,
)
from repro.serve.job import (
    Job,
    JobQueue,
    JobResult,
    JobState,
    JobUpdate,
    SchedulerStopped,
)

__all__ = ["Scheduler"]


@dataclasses.dataclass
class _Staged:
    """Jobs of one signature waiting for their pack window to close."""

    template: RunSpec
    jobs: list
    since: float  # monotonic time of first arrival


class Scheduler:
    """PT-as-a-service: submit `RunSpec`s, receive per-tenant `JobResult`s.

    Args:
      checkpoint_dir: root directory for per-bucket checkpoint subdirs;
        None disables preemption persistence (buckets stay memory-resident).
      quantum_chunks: compiled chunks per time-slice — the fairness quantum.
      pack_window: seconds a new signature's first job waits for bucket-mates
        before sealing.  0 seals as soon as the loop observes the jobs, which
        still packs everything submitted before the loop runs (the
        batch-submission pattern of `run_until_idle`).
      checkpoint_every_quanta: bucket-checkpoint cadence (0 = only at seal
        and finish).
      keep: checkpoint retention per bucket.
      obs: an optional `repro.obs.Observability` — when given, its timeline
        gains per-bucket quantum lanes and job-lifecycle flow arrows
        (PENDING -> RUNNING -> DONE), and every packed engine is attached to
        it (engine spans land in the same trace).  Metrics are *always*
        recorded into `Scheduler.metrics()`'s registry, obs or not — the
        quantum loop is coarse enough (whole compiled chunks) that the cost
        is noise.
      metrics_every: write the Prometheus exposition every N quanta (0 = on
        demand only) to ``metrics_path``.
      metrics_path: destination for the periodic exposition.
      max_attempts: supervised retry budget per quantum — a bucket failing
        this many consecutive attempts is quarantined (``repro serve
        --max-attempts``).
      retry_backoff_s: base of the exponential retry backoff.
      watchdog_s: wall-clock budget per quantum and per first compile (0 =
        no watchdog threads; ``repro serve --watchdog-s``).
      queue_depth: bound on the intake queue (0 = unbounded; ``repro serve
        --queue-depth``) — at capacity `submit` raises `QueueFull` (or
        blocks, with ``submit(..., block=True)``).
      faults: an optional `repro.resilience.FaultPlan` threaded through
        every engine, checkpoint manager and bucket this scheduler builds
        (chaos testing; None in production — zero-cost-off).

    Use either synchronously (``submit(...)`` then ``run_until_idle()``) or
    as a service (``start()`` spawns the host loop thread; ``submit`` is
    thread-safe; ``shutdown()`` stops it).
    """

    def __init__(
        self,
        checkpoint_dir: str | None = None,
        quantum_chunks: int = 1,
        pack_window: float = 0.0,
        checkpoint_every_quanta: int = 0,
        keep: int = 2,
        obs=None,
        metrics_every: int = 0,
        metrics_path: str | None = None,
        max_attempts: int = 3,
        retry_backoff_s: float = 0.05,
        watchdog_s: float = 0.0,
        queue_depth: int = 0,
        faults=None,
    ):
        if quantum_chunks < 1:
            raise ValueError("quantum_chunks must be >= 1")
        self.queue = JobQueue(maxsize=queue_depth)
        self.quantum_chunks = quantum_chunks
        self.pack_window = pack_window
        self.checkpoint_every_quanta = checkpoint_every_quanta
        self.keep = keep
        self._faults = faults
        self._supervisor = Supervisor(
            policy=RetryPolicy(
                max_attempts=max_attempts, base_delay_s=retry_backoff_s
            ),
            watchdog_s=watchdog_s,
            compile_watchdog_s=watchdog_s,
        )
        self._root = None
        if checkpoint_dir is not None:
            self._root = CheckpointManager(
                str(checkpoint_dir), keep=keep, faults=faults
            )
        self._staged: dict[str, _Staged] = {}
        self._buckets: deque[PackedRun] = deque()
        # (signature, packed width) -> Engine: the compile-amortization cache
        self._engines: dict[tuple[str, int], Engine] = {}
        self._job_seq = itertools.count()
        self._bucket_seq = itertools.count()
        self._quanta_run: dict[int, int] = {}  # id(bucket) -> quanta count
        self.quantum_log: list[str] = []  # signature per quantum (fairness)
        self.jobs: dict[str, Job] = {}
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        # idle handshake for shutdown(wait=True): the loop notifies after
        # any step that may have drained the last work, so shutdown blocks
        # on a condition instead of polling time.sleep(0.01)
        self._idle_cond = threading.Condition()
        # -- telemetry (repro.obs) --------------------------------------------
        from repro.obs import MetricsRegistry, NULL

        self._obs = obs
        self._timeline = obs.timeline if obs is not None else NULL
        self.metrics_every = metrics_every
        self.metrics_path = metrics_path
        m = obs.metrics if obs is not None else MetricsRegistry()
        self._registry = m
        self._m_queue_depth = m.gauge(
            "serve_queue_depth", "jobs submitted but not yet staged")
        self._m_buckets_live = m.gauge(
            "serve_buckets_live", "sealed buckets in the round-robin")
        self._m_wakeup = m.histogram(
            "serve_wakeup_latency_seconds",
            "submit-to-intake latency (idle-loop responsiveness)")
        self._m_time_in_queue = m.histogram(
            "serve_time_in_queue_seconds",
            "submit-to-seal latency (pack window + loop occupancy)")
        self._m_quantum = m.histogram(
            "serve_quantum_seconds", "wall time per scheduler quantum")
        self._m_quanta = m.counter(
            "serve_quanta_total", "quanta executed")
        self._m_idle_wakeups = m.counter(
            "serve_idle_wakeups_total",
            "loop wakeups that found no work to advance")
        self._m_occupancy = m.gauge(
            "serve_bucket_occupancy", "live jobs packed per bucket",
            labels=("bucket",))
        self._m_packed_per_compile = m.gauge(
            "serve_jobs_packed_per_compile",
            "jobs amortized per mega-step compile")
        self._m_job_sweeps = m.gauge(
            "serve_job_sweeps", "per-tenant sweeps completed", labels=("job",))
        # -- resilience counters (DESIGN.md §Resilience) ------------------------
        self._m_faults = m.counter(
            "pt_fault_injected", "injected faults fired, by site",
            labels=("site",))
        self._m_retries = m.counter(
            "pt_retries", "supervised quantum retries (bucket recoveries)")
        self._m_quarantined = m.counter(
            "pt_quarantined", "buckets quarantined after exhausting retries")
        self._m_degraded = m.counter(
            "pt_degraded_kernel",
            "fused/Pallas compile failures degraded to the per-sweep path")
        if faults is not None and faults.on_fire is None:
            faults.on_fire = lambda f: self._m_faults.labels(f.site).inc()

    # -- client API --------------------------------------------------------------
    def submit(
        self,
        spec: RunSpec,
        on_update: Callable[[Job, JobUpdate], Any] | None = None,
        job_id: str | None = None,
        block: bool = False,
        timeout: float | None = None,
    ) -> Job:
        """Enqueue one tenant run; returns immediately with its handle.

        With a bounded ``queue_depth``, a full queue raises `QueueFull` —
        or, with ``block=True``, waits up to ``timeout`` seconds for the
        host loop to drain space.  A rejected submission registers nothing.
        """
        if job_id is None:
            job_id = f"job-{next(self._job_seq):04d}"
        if job_id in self.jobs:
            raise ValueError(f"duplicate job id {job_id!r}")
        job = Job(job_id, spec, on_update=on_update)
        job.submitted_at = time.monotonic()
        # enqueue BEFORE registering: a QueueFull rejection must leave no
        # half-registered handle behind
        self.queue.put(job, block=block, timeout=timeout)
        self.jobs[job_id] = job
        self._m_queue_depth.set(len(self.queue))
        self._timeline.flow_start("job:" + job_id, job_id, track="intake",
                                  seed=job.seed)
        return job

    def result(self, job: Job | str, timeout: float | None = None) -> JobResult:
        """Block for one job's result (`Job.result`); accepts id or handle."""
        if isinstance(job, str):
            job = self.jobs[job]
        return job.result(timeout)

    # -- intake / packing --------------------------------------------------------
    def _intake(self) -> None:
        now = time.monotonic()
        drained = self.queue.drain()
        if drained:
            self._m_queue_depth.set(len(self.queue))
        for job in drained:
            if job.submitted_at is not None:
                self._m_wakeup.observe(now - job.submitted_at)
            try:
                check_servable(job.spec)
            except ValueError as err:
                job._fail(err)
                self._timeline.flow_end("job:" + job.id, job.id,
                                        track="intake", state="failed")
                continue
            digest, _ = shape_signature(job.spec)
            staged = self._staged.get(digest)
            if staged is None:
                staged = self._staged[digest] = _Staged(
                    template=job.spec, jobs=[], since=now
                )
            staged.jobs.append(job)

    def _seal(self, force: bool = False) -> None:
        now = time.monotonic()
        for digest in list(self._staged):
            staged = self._staged[digest]
            if not force and now - staged.since < self.pack_window:
                continue
            del self._staged[digest]
            self._buckets.append(self._make_bucket(digest, staged))

    def _engine_for(self, digest: str, template: RunSpec, width: int) -> Engine:
        key = (digest, width)
        engine = self._engines.get(key)
        if engine is None:
            system = template.system.build()
            config = dataclasses.replace(
                template.engine.build(
                    template.ladder.n_replicas,
                    exchange=template.exchange.build(),
                ),
                n_chains=width,
            )
            engine = Engine(
                system,
                config,
                observables=template.system.observables(
                    system, template.observables
                ),
                # packed engines share the scheduler's telemetry bundle, so
                # engine spans (compile, chunk, device_wait) land on the
                # same trace as the quantum lanes
                obs=self._obs,
                faults=self._faults,
                # obs-on engines count degradations themselves (into the
                # same registry); the hook covers the obs-off path only —
                # both would double-count
                on_degrade=(
                    self._m_degraded.inc if self._obs is None else None
                ),
            )
            self._engines[key] = engine
        return engine

    def _bucket_manager(self, name: str):
        return None if self._root is None else self._root.child(name)

    def _make_bucket(self, digest: str, staged: _Staged) -> PackedRun:
        width = sum(j.n_chains for j in staged.jobs)
        engine = self._engine_for(digest, staged.template, width)
        name = f"{digest}-{next(self._bucket_seq):04d}"
        bucket = PackedRun(
            digest, staged.template, staged.jobs, engine,
            manager=self._bucket_manager(name),
            faults=self._faults, name=name,
        )
        bucket.write_manifest()
        now = time.monotonic()
        lane = f"bucket:{digest[:8]}"
        self._m_occupancy.labels(name).set(len(staged.jobs))
        for job in staged.jobs:
            if job.submitted_at is not None:
                self._m_time_in_queue.observe(now - job.submitted_at)
            self._timeline.flow_step("job:" + job.id, job.id, track=lane,
                                     bucket=name)
        self._timeline.instant("seal", cat="serve", track=lane,
                               bucket=name, jobs=len(staged.jobs))
        return bucket

    def _checkpoint_bucket(self, bucket) -> None:
        """Best-effort bucket checkpoint: a failed save (e.g. an injected
        crash at a write seam) is non-fatal — the state is still live in
        memory, the on-disk generations stay intact (atomic rename), and
        the next cadence simply retries."""
        try:
            bucket.checkpoint()
        except Exception as err:
            warnings.warn(
                f"checkpoint save for bucket {bucket.name} failed "
                f"({err!r}); continuing from the in-memory state",
                RuntimeWarning,
            )

    # -- the host loop -----------------------------------------------------------
    def step(self) -> bool:
        """One scheduler step: intake, seal, run one quantum.  True if any
        bucket advanced."""
        self._intake()
        self._seal(force=self.pack_window <= 0)
        self._m_buckets_live.set(len(self._buckets))
        if not self._buckets:
            return False
        bucket = self._buckets.popleft()
        for job in bucket.live_jobs():
            job.state = JobState.RUNNING
        self.quantum_log.append(bucket.digest)
        lane = f"bucket:{bucket.digest[:8]}"
        t0 = time.perf_counter()
        out = self._supervisor.run(bucket, self.quantum_chunks)
        if out.bucket is not bucket:
            # a recovered generation replaced the instance we passed in —
            # move the quantum bookkeeping over with it
            self._quanta_run[id(out.bucket)] = self._quanta_run.pop(
                id(bucket), 0
            )
            bucket = out.bucket
        finished = out.finished
        dt = time.perf_counter() - t0
        self._m_quantum.observe(dt)
        self._m_quanta.inc()
        self._timeline.complete(
            "quantum", t0, dt, cat="serve", track=lane,
            args={"jobs": len(bucket.jobs), "finished": finished,
                  "retries": out.retries, "quarantined": out.quarantined},
        )
        if out.retries:
            self._m_retries.inc(out.retries)
        for rec in out.recoveries:
            self._timeline.complete(
                "recovery", rec["t0"], rec["seconds"], cat="serve",
                track=lane,
                args={"error": rec["error"], "sweep": rec["sweep"],
                      "fallback_depth": rec["fallback_depth"]},
            )
        n = self._quanta_run.get(id(bucket), 0) + 1
        self._quanta_run[id(bucket)] = n
        for job in bucket.jobs:
            if job.last_update is not None:
                self._m_job_sweeps.labels(job.id).set(
                    job.last_update.sweeps_done
                )
        if out.quarantined:
            self._m_quarantined.inc()
            self._quanta_run.pop(id(bucket), None)
            # no final checkpoint: the on-disk generations stay the last
            # *intact* pre-fault states (quarantine.json records the rest)
            for job in bucket.jobs:
                self._timeline.flow_end("job:" + job.id, job.id, track=lane,
                                        state=job.state.value)
        elif finished:
            self._quanta_run.pop(id(bucket), None)
            # final state: restart delivers instantly
            self._checkpoint_bucket(bucket)
            for job in bucket.jobs:
                self._timeline.flow_end("job:" + job.id, job.id, track=lane,
                                        state=job.state.value)
        else:
            if self.checkpoint_every_quanta and (
                n % self.checkpoint_every_quanta == 0
            ):
                self._checkpoint_bucket(bucket)
            for job in bucket.live_jobs():
                job.state = JobState.PREEMPTED
            self._buckets.append(bucket)
        n_compiles = sum(e.n_compiles for e in self._engines.values())
        if n_compiles:
            self._m_packed_per_compile.set(len(self.jobs) / n_compiles)
        if (
            self.metrics_every
            and self.metrics_path
            and len(self.quantum_log) % self.metrics_every == 0
        ):
            self.write_metrics(self.metrics_path)
        return True

    def idle(self) -> bool:
        return not (self._buckets or self._staged or len(self.queue))

    def run_until_idle(self, max_quanta: int | None = None) -> None:
        """Drive the loop synchronously until every submitted job resolves."""
        quanta = 0
        while not self.idle():
            if not self.step():
                continue
            quanta += 1
            if max_quanta is not None and quanta >= max_quanta:
                return

    def start(self) -> None:
        """Run the host loop on a background thread (service mode)."""
        if self._thread is not None:
            raise RuntimeError("scheduler already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                advanced = self.step()
                if not advanced and self.idle():
                    # possibly the last work just drained: let a blocked
                    # shutdown(wait=True) re-check before we sleep
                    with self._idle_cond:
                        self._idle_cond.notify_all()
                    self._m_idle_wakeups.inc()
                    # nothing live: block until a submission or a stop poke
                    # (both notify the queue condition — no sleep polling)
                    self.queue.wait(timeout=1.0)

        self._thread = threading.Thread(
            target=loop, name="repro-serve", daemon=True
        )
        self._thread.start()

    def shutdown(self, wait: bool = True) -> None:
        """Stop the host loop.  With ``wait``, drain all live work first.

        The drain blocks on the loop's idle notification (condition
        variable), not a sleep poll; the timeout is only a safety net
        against a notify landing between our predicate check and the wait.

        With ``wait=False`` (or work submitted after the drain), jobs still
        PENDING — queued or staged but never sealed — FAIL with a typed
        `SchedulerStopped` instead of leaving their `Job.result` callers
        blocked forever.
        """
        if self._thread is not None:
            if wait:
                with self._idle_cond:
                    while not self.idle():
                        self._idle_cond.wait(timeout=0.5)
            self._stop.set()
            self.queue.poke()  # wake the loop out of its queue wait promptly
            self._thread.join()
            self._thread = None
        self._drain_pending()

    def _drain_pending(self) -> None:
        """FAIL every never-sealed PENDING job (queued or staged)."""
        stopped = [job for job in self.queue.drain()]
        for staged in self._staged.values():
            stopped.extend(staged.jobs)
        self._staged.clear()
        self._m_queue_depth.set(0)
        for job in stopped:
            if job.done():
                continue
            job._fail(SchedulerStopped(
                f"scheduler shut down before job {job.id} was scheduled"
            ))
            self._timeline.flow_end("job:" + job.id, job.id, track="intake",
                                    state="failed")

    # -- introspection -----------------------------------------------------------
    def metrics(self) -> dict:
        """Snapshot of the service metrics registry (`repro.obs.metrics`).

        Always live — queue depth, quantum latency histograms, bucket
        occupancy, jobs-packed-per-compile, per-tenant sweep progress —
        whether or not an `Observability` bundle was attached.  Render with
        `repro.obs.to_prometheus` / `to_json`.
        """
        return self._registry.snapshot()

    def write_metrics(self, path: str) -> str:
        """Write the Prometheus text exposition to ``path`` (atomic)."""
        from repro.obs import write_prometheus

        return write_prometheus(self._registry, path)

    def stats(self) -> dict:
        """Service counters (the serve benchmark's instrumentation source)."""
        return {
            "n_jobs": len(self.jobs),
            "n_buckets_live": len(self._buckets),
            "n_engines": len(self._engines),
            "n_compiles": sum(e.n_compiles for e in self._engines.values()),
            "n_quanta": len(self.quantum_log),
            "states": {
                s.value: sum(1 for j in self.jobs.values() if j.state is s)
                for s in JobState
            },
            "resilience": dict(self._supervisor.totals),
            "faults_fired": (
                0 if self._faults is None else self._faults.fired()
            ),
        }

    # -- restart -----------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, checkpoint_dir: str, **kwargs) -> "Scheduler":
        """Rebuild a scheduler from its checkpoint root after a restart.

        Every subdirectory holding a ``serve.json`` manifest becomes a
        restored bucket: jobs are re-registered (fresh handles — client
        callbacks do not survive a process), engines are rebuilt and the
        newest packed state restored bit-equal.  Buckets whose checkpointed
        sweep counter already covers the schedule deliver their results
        immediately; the rest re-enter the round-robin where they left off.
        Phase summaries recorded before the restart are not replayed — a
        restored `JobResult.phases` only holds phases that *ended* after the
        restore point (the `Session.from_checkpoint` contract).
        """
        sched = cls(checkpoint_dir=checkpoint_dir, **kwargs)
        root = sched._root.dir
        for name in sorted(os.listdir(root)):
            manifest_path = os.path.join(root, name, MANIFEST_NAME)
            if not os.path.isfile(manifest_path):
                continue
            try:
                with open(manifest_path) as f:
                    manifest = json.load(f)
                digest = manifest["signature"]
                template = RunSpec.from_dict(manifest["template"])
                entries = manifest["jobs"]
            except Exception as err:
                # one poisoned bucket dir must not take down the whole
                # restart — every other bucket still resumes bit-equal
                warnings.warn(
                    f"skipping unreadable bucket manifest {manifest_path}: "
                    f"{err!r}",
                    RuntimeWarning,
                )
                continue
            jobs = []
            for entry in entries:
                job = Job(entry["id"], RunSpec.from_dict(entry["spec"]))
                job.state = JobState.PREEMPTED
                sched.jobs[job.id] = job
                jobs.append(job)
            width = sum(j.n_chains for j in jobs)
            bucket = PackedRun.restore(
                digest, template, jobs,
                sched._engine_for(digest, template, width),
                sched._root.child(name),
                faults=sched._faults, name=name,
            )
            # keep the bucket-name sequence ahead of restored dirs
            try:
                seq = int(name.rsplit("-", 1)[1])
                sched._bucket_seq = itertools.count(seq + 1)
            except (IndexError, ValueError):
                pass
            if bucket.finished:
                continue
            sched._buckets.append(bucket)
        return sched
