"""Shape-bucketed job packing: N same-shaped tenants, one compiled mega-step.

The serving layer's core economics (DESIGN.md §Serve): compiling the PT
mega-step costs seconds while running a chunk costs milliseconds, so jobs
whose specs share every *shape-relevant* field are packed along the engine's
existing ensemble axis and advanced by a single executable.  The pieces:

* `shape_signature` — the bucket key: the spec's `to_dict()` minus ``seed``,
  canonically serialized and hashed.  Everything except the seed is
  shape-relevant: system params and ``L`` fix lattice shapes, the ladder
  fixes the *shared* ``(R,)`` betas row (`EngineState.betas` has no ensemble
  axis, so two jobs on different ladders can never share a mega-step),
  engine/exchange knobs and the phase schedule fix the compiled program.
* `check_servable` — the packing preconditions, rejected loudly at submit
  time: no adaptive phases (`repro.engine.adapt` pools swap counters over
  the whole ensemble and retunes the shared ladder — one tenant's feedback
  would perturb every other tenant's trajectory) and no explicit device mesh
  (the scheduler owns placement).
* `PackedRun` — one live bucket: the packed `EngineState`, the job -> chain
  slot map, the schedule cursor, per-job observable streaming and failure
  isolation, and checkpoint save/restore for preemption.

**Isolation contract** (pinned by ``tests/test_serve.py``): chain slot ``c``
of a packed job runs on exactly the key a solo run would use —
``jax.random.key(seed)`` for an ``n_chains=1`` spec, ``fold_in(·, c)`` for an
ensemble spec (`Engine.init_ensemble`) — and the vmapped mega-step applies
the same per-chain program, so every tenant's energies, states and online
statistics are bit-equal to running its spec alone.  Packing changes
throughput, never results.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import stats as stats_lib
from repro.serve.job import Job, JobResult, JobUpdate

__all__ = [
    "shape_signature",
    "check_servable",
    "PackedRun",
    "MANIFEST_NAME",
]

MANIFEST_NAME = "serve.json"


def shape_signature(spec) -> tuple[str, dict]:
    """Bucket key for a `RunSpec`: ``(digest, sans_seed_dict)``.

    Two specs pack into one mega-step iff their digests match.  The seed is
    the *only* field excluded — it selects the PRNG stream, which is
    per-chain data, not program shape.
    """
    d = spec.to_dict()
    d.pop("seed", None)
    payload = json.dumps(d, sort_keys=True, separators=(",", ":"))
    return hashlib.sha1(payload.encode()).hexdigest()[:12], d


def check_servable(spec) -> None:
    """Raise ValueError if the spec cannot run under the packing contract."""
    for phase in spec.schedule.phases:
        if phase.adapt:
            raise ValueError(
                f"phase {phase.name!r} sets adapt=True: adaptive ladders "
                "pool swap counters across the whole ensemble and retune "
                "the shared betas row, so one tenant's feedback would "
                "perturb its bucket-mates' trajectories.  Adapt offline "
                "(a solo Session run), then serve the tuned custom ladder."
            )
    if spec.engine.mesh is not None:
        raise ValueError(
            "spec.engine.mesh is set: the serve scheduler owns device "
            "placement; submit specs with mesh=None"
        )


class PackedRun:
    """One live bucket: same-signature jobs packed along the ensemble axis.

    Chain-slot layout is submission order — job ``i`` owns the contiguous
    block ``[offset_i, offset_i + n_chains_i)``.  The engine is built by the
    scheduler with ``n_chains == sum(n_chains_i)`` and is *shared across
    bucket generations* of the same ``(signature, width)``, so the mega-step
    compiles once per shape, not once per bucket.
    """

    def __init__(self, digest: str, template, jobs: Sequence[Job],
                 engine, manager=None, faults=None, name: str | None = None):
        if not jobs:
            raise ValueError("a bucket needs at least one job")
        self.digest = digest
        self.template = template  # any member spec (sans-seed identical)
        self.jobs = list(jobs)
        self.engine = engine
        self.manager = manager  # per-bucket CheckpointManager (or None)
        # fault-injection handle (repro.resilience.FaultPlan; None = off)
        self.faults = faults
        # stable identity across recovery generations (the Supervisor's
        # retry bookkeeping and the quarantine manifest key on this)
        self.name = name if name is not None else digest
        # set by Supervisor watchdog expiry: the host loop observes this at
        # the next chunk boundary and stops without notifying any tenant
        self._abandoned = False
        # restore_latest fallback depth of the generation this bucket was
        # recovered/restored from (recovery telemetry)
        self.restore_fallback_depth = 0
        self.temps = template.ladder.build()
        self._slices: list[tuple[int, int]] = []
        off = 0
        for j in self.jobs:
            self._slices.append((off, j.n_chains))
            off += j.n_chains
        self.n_chains = off
        if engine.config.n_chains != self.n_chains:
            raise ValueError(
                f"engine packs {engine.config.n_chains} chains but the "
                f"bucket holds {self.n_chains}"
            )
        self.total_sweeps = template.schedule.total_sweeps
        self.sweeps_done = 0
        self.state = None
        self.finished = False
        self._failed: set[str] = set()
        # job.id -> {phase name -> summarize() dict}; phases completed before
        # a scheduler restart are absent (same contract as Session resume)
        self._phase_summaries: dict[str, dict[str, dict]] = {}
        self._current_phase = None
        self._base_sweeps = 0

    # -- construction ----------------------------------------------------------
    def chain_keys(self) -> list[jax.Array]:
        """Per-slot PRNG keys, exactly as each job's solo run derives them."""
        keys = []
        for j in self.jobs:
            base = jax.random.key(j.seed)
            if j.n_chains == 1:
                keys.append(base)
            else:
                for c in range(j.n_chains):
                    keys.append(jax.random.fold_in(base, jnp.uint32(c)))
        return keys

    def init(self) -> None:
        self.state = self.engine.init_ensemble(self.chain_keys(), self.temps)

    def write_manifest(self) -> None:
        """Persist the bucket composition next to its checkpoints (atomic).

        ``serve.json`` + the newest step dir is everything
        `PackedRun.restore` / `Scheduler.from_checkpoint` needs to resume
        the bucket after a process restart.
        """
        if self.manager is None:
            return
        payload = {
            "signature": self.digest,
            "template": self.template.to_dict(),
            "jobs": [{"id": j.id, "spec": j.spec.to_dict()} for j in self.jobs],
        }
        path = os.path.join(self.manager.dir, MANIFEST_NAME)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def restore(cls, digest: str, template, jobs: Sequence[Job],
                engine, manager, faults=None,
                name: str | None = None) -> "PackedRun":
        """Rebuild a bucket from its checkpoint directory.

        Restores the newest *intact* packed `EngineState` (bit-equal resume
        — PR 3's checkpoint contract; corrupt generations are skipped by
        `CheckpointManager.restore_latest` and their count recorded in
        ``restore_fallback_depth``) and relocates the schedule cursor from
        the state's own sweep counter.  With no restorable step the bucket
        simply starts fresh on its next quantum.
        """
        run = cls(digest, template, jobs, engine, manager=manager,
                  faults=faults, name=name)
        out = engine.restore(manager)
        if out is not None:
            state, meta = out
            run.state = state
            run.restore_fallback_depth = getattr(
                manager, "last_restore_fallback", 0
            )
            if "temps" in meta:
                # authoritative f64 ladder (f32 betas aren't exactly invertible)
                engine._temps = np.asarray(meta["temps"], np.float64)
            run.sweeps_done = int(np.asarray(state.pt.t).reshape(-1)[0])
            if run.sweeps_done >= run.total_sweeps:
                # schedule already complete at checkpoint time: deliver now
                run._finalize()
        return run

    # -- supervised recovery ----------------------------------------------------
    def abandon(self) -> None:
        """Cooperative cancellation (Supervisor watchdog expiry): the host
        loop stops at the next chunk boundary, silently — no tenant update,
        stream callback, or result is delivered by an abandoned attempt."""
        self._abandoned = True

    def ensure_compiled(self) -> None:
        """Warm exactly the executable the next quantum would compile first
        (so a Supervisor compile-watchdog can budget it separately).  The
        chunk length is derived the same way `Engine.run` derives it — a
        different length would compile an executable the run never uses and
        break the one-compile-per-shape contract."""
        if self.finished:
            return
        if self.state is None:
            self.init()
        phase, _, end = self._locate(self.sweeps_done)
        spi = self.engine.config.spec.sweeps_per_interval
        n_intervals = (end - self.sweeps_done) // spi
        this = min(self.engine.config.chunk_intervals, n_intervals)
        if this > 0:
            self.engine._compiled(self.state, this)

    def recover(self) -> "PackedRun":
        """A fresh generation of this bucket, replayed from the last intact
        checkpoint (or from scratch with no manager / no intact step).

        Bit-equality: preemption and chunk boundaries are invisible to the
        PRNG stream, so the replayed trajectory is identical to the
        fault-free one; summaries of phases that *ended* at or before the
        restore point were computed from the same (uncorrupted) trajectory
        pre-fault and are carried over, so the recovered bucket's final
        `JobResult`s carry every phase — bit-equal to a never-faulted run.
        """
        fresh: "PackedRun"
        try:
            fresh = PackedRun.restore(
                self.digest, self.template, self.jobs, self.engine,
                self.manager, faults=self.faults, name=self.name,
            ) if self.manager is not None else PackedRun(
                self.digest, self.template, self.jobs, self.engine,
                manager=self.manager, faults=self.faults, name=self.name,
            )
        except Exception:
            # a wholly corrupt checkpoint dir: last resort is a clean replay
            # from sweep 0 (still bit-equal — the stream is deterministic)
            fresh = PackedRun(
                self.digest, self.template, self.jobs, self.engine,
                manager=self.manager, faults=self.faults, name=self.name,
            )
            fresh.restore_fallback_depth = len(
                self.manager.steps()) if self.manager is not None else 0
        fresh._failed = set(self._failed)
        for jid, phases in self._phase_summaries.items():
            for pname, summary in phases.items():
                if self._phase_end(pname) <= fresh.sweeps_done:
                    fresh._phase_summaries.setdefault(jid, {})[pname] = summary
        return fresh

    def _phase_end(self, name: str) -> int:
        start = 0
        for phase in self.template.schedule.phases:
            end = start + phase.n_sweeps
            if phase.name == name:
                return end
            start = end
        raise ValueError(f"unknown phase {name!r}")

    def checkpoint(self) -> None:
        if self.manager is None or self.state is None:
            return
        meta = {"temps": [float(t) for t in self.temps]}
        self.manager.save(self.sweeps_done, self.state, meta=meta)

    # -- schedule bookkeeping ---------------------------------------------------
    def _locate(self, sweep: int):
        """The phase containing ``sweep`` and its [start, end) window."""
        start = 0
        for phase in self.template.schedule.phases:
            end = start + phase.n_sweeps
            if sweep < end:
                return phase, start, end
            start = end
        raise ValueError(f"sweep {sweep} beyond the schedule ({start})")

    def live_jobs(self) -> list[Job]:
        return [j for j in self.jobs if j.id not in self._failed]

    # -- execution --------------------------------------------------------------
    def run_quantum(self, max_chunks: int = 1) -> bool:
        """Advance the bucket by at most ``max_chunks`` compiled chunks.

        The scheduler's time-slice: the engine host loop is entered with the
        current phase's remaining budget and stopped through the ``on_chunk``
        hook once the quantum is spent, so preemption cost is bounded by one
        chunk.  Quanta never split a compiled chunk and chunk boundaries are
        invisible to the PRNG stream (keys derive from the state's sweep
        counter), so any preemption pattern yields bit-identical results.
        Returns True when the whole schedule is done (results delivered).
        """
        if self.finished:
            return True
        if self.state is None:
            self.init()
        spent = [0]

        def hook(info):
            if self._abandoned:
                # watchdog expiry: stop at this chunk boundary with no
                # tenant-visible side effects — the recovered generation
                # replays these sweeps bit-equal
                return True
            self._stream(info)
            spent[0] += 1
            return spent[0] >= max_chunks

        while not self._abandoned and self.sweeps_done < self.total_sweeps:
            phase, start, end = self._locate(self.sweeps_done)
            self._current_phase = phase
            if phase.reset_stats and self.sweeps_done == start:
                # entering the phase fresh (also holds when resuming from a
                # checkpoint cut exactly at the boundary — the uninterrupted
                # loop resets at the same point); a mid-phase resume keeps
                # the checkpointed accumulators, as Session.run does
                self.state = self.engine.reset_stats(self.state)
            self._base_sweeps = self.sweeps_done
            self.state, result = self.engine.run(
                self.state,
                end - self.sweeps_done,
                on_chunk=hook,
                keep_trace=False,
            )
            self.sweeps_done += result.n_sweeps
            if self.sweeps_done == end and not self._abandoned:
                self._record_phase(phase)
            if spent[0] >= max_chunks and self.sweeps_done < self.total_sweeps:
                break
        self._current_phase = None
        if self._abandoned:
            return False
        if self.sweeps_done >= self.total_sweeps and not self.finished:
            self._finalize()
        return self.finished

    # -- per-tenant views -------------------------------------------------------
    def _ensemble(self, arr: np.ndarray) -> np.ndarray:
        """Normalize a state/trace leaf to a leading chain axis."""
        return arr[None] if self.n_chains == 1 else arr

    def _job_energy(self, energy: np.ndarray, rung: np.ndarray,
                    index: int) -> np.ndarray:
        """Job ``index``'s rung-ordered (cold->hot) energies: (R,) or (C,R)."""
        off, width = self._slices[index]
        e = self._ensemble(energy)[off:off + width]
        r = self._ensemble(rung)[off:off + width]
        out = np.take_along_axis(e, np.argsort(r, axis=1), axis=1)
        return out[0] if self.jobs[index].n_chains == 1 else out

    def _job_trace(self, trace, index: int):
        if trace is None:
            return None
        off, width = self._slices[index]
        solo = self.jobs[index].n_chains == 1
        out = {}
        for k, v in trace.items():
            block = self._ensemble(v)[off:off + width]
            out[k] = block[0] if solo else block
        return out

    def _stream(self, info) -> None:
        """Fan one compiled chunk out to every live tenant's callback.

        A callback exception — or a non-finite energy in the job's own chain
        block — FAILs that job alone; its slots keep simulating as dead lanes
        (the compiled shape cannot shrink mid-run) and every other tenant is
        untouched.
        """
        energy = np.asarray(info.state.pt.energy)
        rung = np.asarray(info.state.pt.rung)
        phase = self._current_phase.name if self._current_phase else ""
        for i, job in enumerate(self.jobs):
            if job.id in self._failed:
                continue
            try:
                if self.faults is not None:
                    # models a tenant callback raising (the failure is
                    # isolated to that job, like any callback exception)
                    self.faults.fire("serve.callback")
                e = self._job_energy(energy, rung, i)
                if not np.all(np.isfinite(e)):
                    raise FloatingPointError(
                        f"non-finite energy in job {job.id} at sweep "
                        f"{self._base_sweeps + info.sweeps_done}"
                    )
                job._notify(JobUpdate(
                    sweeps_done=self._base_sweeps + info.sweeps_done,
                    total_sweeps=self.total_sweeps,
                    phase=phase,
                    energy=e,
                    trace=self._job_trace(info.trace, i),
                ))
            except BaseException as err:  # isolate: never take down the bucket
                self._failed.add(job.id)
                job._fail(err)

    # -- results ----------------------------------------------------------------
    def _job_stats(self, index: int):
        off, width = self._slices[index]
        stats = self.state.stats
        if self.n_chains == 1:
            return stats  # single-slot bucket: leaves are already (R,)
        if self.jobs[index].n_chains == 1:
            return stats_lib.chain_slice(stats, off)
        return stats_lib.chain_block(stats, off, off + width)

    def _record_phase(self, phase) -> None:
        for i, job in enumerate(self.jobs):
            if job.id in self._failed:
                continue
            summary = stats_lib.summarize(self._job_stats(i))
            self._phase_summaries.setdefault(job.id, {})[phase.name] = {
                k: np.asarray(v).copy() for k, v in summary.items()
            }

    def _finalize(self) -> None:
        energy = np.asarray(self.state.pt.energy)
        rung = np.asarray(self.state.pt.rung)
        for i, job in enumerate(self.jobs):
            if job.id in self._failed:
                continue
            job._deliver(JobResult(
                job_id=job.id,
                spec=job.spec,
                phases=self._phase_summaries.get(job.id, {}),
                final_energy=self._job_energy(energy, rung, i),
                n_sweeps=self.sweeps_done,
            ))
        self.finished = True

    def __repr__(self):
        return (
            f"PackedRun({self.digest}, jobs={len(self.jobs)}, "
            f"chains={self.n_chains}, sweep={self.sweeps_done}/"
            f"{self.total_sweeps})"
        )
