"""PT-as-a-service: multi-tenant async scheduling with shape-bucketed
job packing (DESIGN.md §Serve).

Many small PT runs share one accelerator by packing same-shaped `RunSpec`s
along the engine's existing ensemble axis — N tenants, one compiled
mega-step — while a round-robin host loop time-slices the live buckets in
chunk-sized quanta:

* `repro.serve.job`       — `Job` lifecycle, streamed `JobUpdate`s, the
  thread-safe intake `JobQueue`;
* `repro.serve.bucket`    — `shape_signature` bucketing, the `check_servable`
  packing preconditions, and `PackedRun` (per-tenant PRNG isolation,
  streaming, failure isolation, checkpointed preemption);
* `repro.serve.scheduler` — the `Scheduler`: ``submit()`` / ``result()``
  client API, pack-window sealing, the compile-amortizing engine cache, and
  `Scheduler.from_checkpoint` restart.

The isolation contract: a packed job's observables are bit-equal to running
its spec alone — packing changes throughput, never results.

    >>> from dataclasses import replace
    >>> from repro.serve import Scheduler
    >>> sched = Scheduler()
    >>> handles = [sched.submit(replace(spec, seed=s)) for s in range(8)]
    >>> sched.run_until_idle()
    >>> results = [h.result() for h in handles]

CLI front door: ``python -m repro serve --spec spec.json --jobs 8``.
"""
from repro.resilience.supervisor import BucketQuarantined
from repro.serve.bucket import PackedRun, check_servable, shape_signature
from repro.serve.job import (
    Job,
    JobFailedError,
    JobQueue,
    JobResult,
    JobState,
    JobUpdate,
    QueueFull,
    SchedulerStopped,
)
from repro.serve.scheduler import Scheduler

__all__ = [
    "BucketQuarantined",
    "Job",
    "JobFailedError",
    "JobQueue",
    "JobResult",
    "JobState",
    "JobUpdate",
    "PackedRun",
    "QueueFull",
    "Scheduler",
    "SchedulerStopped",
    "check_servable",
    "shape_signature",
]
