"""Pallas TPU kernel: RWKV-6 ("Finch") linear-attention recurrence.

The assigned ``rwkv6-7b`` architecture is attention-free; its hot-spot is the
per-head recurrence ``S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t`` with
data-dependent decay.  A naive `lax.scan` keeps the (dk × dv) state in HBM
between steps; this kernel keeps it in a **VMEM scratch that persists across
the sequential time-chunk grid dimension**, so HBM sees only the streaming
r/k/v/w inputs and the output — the TPU analogue of the CUDA "state in
registers/SMEM" linear-attention kernels.

Grid: ``(BH, T/chunk)`` — the second (minor) dimension is sequential on TPU,
so the scratch carries the state from chunk to chunk for a fixed batch*head
slab; on a new slab (first chunk) the state is re-initialized from the
``initial_state`` input (zeros for training, the cache for decode).

VMEM per step ≈ chunk·(3·dk + dv)·4 + dk·dv·4 bytes: for dk=dv=64,
chunk=128 that's ≈ 150 KB — tiny; many slabs can be multi-buffered.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref, s_scr):
    chunk_idx = pl.program_id(1)

    @pl.when(chunk_idx == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    ct = r_ref.shape[1]
    s = s_scr[...]

    def body(t, s):
        rt = r_ref[0, t]  # (dk,)
        kt = k_ref[0, t]
        vt = v_ref[0, t]  # (dv,)
        wt = w_ref[0, t]
        bonus = jnp.sum(rt * u_ref[0] * kt)  # scalar
        out = jnp.dot(rt, s) + bonus * vt  # (dv,)
        o_ref[0, t] = out.astype(o_ref.dtype)
        return wt[:, None] * s + kt[:, None] * vt[None, :]

    s = jax.lax.fori_loop(0, ct, body, s)
    s_scr[...] = s
    # Final state is only meaningful after the last chunk; writing every chunk
    # keeps the dataflow simple (last write wins).
    sout_ref[0] = s


def wkv6_pallas(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    initial_state: jnp.ndarray | None = None,
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    """pallas_call wrapper. Contract: `repro.kernels.ref.wkv6`.

    Args:
      r, k, w: (BH, T, dk) f32; v: (BH, T, dv) f32; u: (BH, dk) f32.
      initial_state: (BH, dk, dv) f32 or None (zeros).
      chunk: time-chunk size; T must be a multiple (ops.py pads).
    """
    bh, t, dk = r.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    if initial_state is None:
        initial_state = jnp.zeros((bh, dk, dv), jnp.float32)
    grid = (bh, t // chunk)
    return pl.pallas_call(
        _wkv6_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dk), lambda i, j: (i, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, initial_state)
