"""Pallas TPU kernel: checkerboard sweep for the q-state Potts model.

Same tile strategy as `repro.kernels.ising_sweep` (DESIGN.md §2/§6): one grid
step holds a block of ``r_blk`` replicas with their full (H, W) lattices
resident in VMEM, both colour half-sweeps run back-to-back in-kernel (one HBM
round-trip of the colour block per sweep), colours are int8 in HBM and widened
to int32 only inside VMEM.  The proposal randoms ride alongside the
acceptance randoms as kernel inputs, so the CPU `interpret=True` path is
bit-exact with `ref.potts_sweep`.

Like the Ising kernel, the variants share the tile strategy (DESIGN.md §6):
``potts_sweep_pallas`` (one sweep per launch, uniforms as an input stream —
bit-exact vs `ref.potts_sweep`), ``potts_sweep_fused_pallas`` (one swap
*interval* per launch: all ``n_sweeps`` sweeps with the colour block
VMEM-resident, the four uniform planes per sweep generated in-kernel by the
counter PRNG `repro.kernels.prng` at ``(key, sweep, replica, 2*colour +
(proposal|accept))``, ΔE/acceptance accumulated in-kernel), and
``potts_round_fused_pallas`` (one launch = whole PT round(s): sweeps plus
the temp-mode DEO/SEO exchange via `repro.kernels.exchange`, swap uniforms
from the counter PRNG's swap stream).  Modeled HBM traffic drops from
34 B/cell/sweep (int8 in/out + 16 B of uniforms written externally + 16 B
read back) to 2 B/cell/*launch* plus O(R) scalars
(`hbm_bytes_per_cell_sweep`).

The fused variants take ``pack_bits``: a Potts colour does not compress to
one bit, so "packing" here keeps the lattice in its dense **int8 lanes**
through the whole update (proposal, trial, equality comparisons) instead of
widening to int32 — 4× denser working state, valid for ``q ≤ 64`` (the
int8 intermediate ``s + d`` peaks at ``2q − 2``).  Comparisons and selects
on int8 produce the same booleans, so the trajectory is bitwise identical
(pinned by tests).

VMEM working set per grid step ≈ r_blk · H · W · (2 int8 in/out + 4·4 u-f32 +
2·4 i32 working copies + 4 de-f32) = 30·r_blk·H·W bytes — roughly 2.3× the
Ising kernel's (the extra uniform plane pays for the colour proposal), still
inside a v5e core's 16 MB for the paper's L=300 at r_blk=4 (~10.8 MB;
`vmem_working_set_bytes`).  The fused variant swaps the 16 B/cell uniforms
block for one in-flight plane of PRNG draws (8 B bits+f32), totalling
22 B/cell (`vmem_working_set_bytes_fused`) — r_blk=4 at L=300 stays well
inside budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import exchange as _kx
from repro.kernels import prng


def _roll1(x: jnp.ndarray, shift: int, axis: int) -> jnp.ndarray:
    """±1 circular shift via slice+concat (lowers on both Mosaic and CPU)."""
    n = x.shape[axis]
    if shift == 1:
        a = jax.lax.slice_in_dim(x, n - 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, n - 1, axis=axis)
    else:  # shift == -1
        a = jax.lax.slice_in_dim(x, 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
    return jnp.concatenate([a, b], axis=axis)


def _accept_prob(de, beta, rule):
    """Mirror of `ref.accept_prob` (kept local: kernel code is self-contained)."""
    if rule == "metropolis":
        return jnp.exp(-beta * de)
    if rule == "glauber":
        return jax.nn.sigmoid(-beta * de)
    raise ValueError(rule)


def _potts_sweep_kernel(
    states_ref, u_ref, beta_ref, out_ref, de_ref, nacc_ref, *, q, j, rule
):
    """One full checkerboard sweep over an (r_blk, H, W) block."""
    s = states_ref[...].astype(jnp.int32)  # widen in VMEM only
    h, w = s.shape[-2], s.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    parity = (ii + jj) % 2
    beta = beta_ref[...].astype(jnp.float32)[:, None, None]

    de_total = jnp.zeros(s.shape[0], jnp.float32)
    n_acc = jnp.zeros(s.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll: two half-sweeps, one HBM round-trip
        d = 1 + jnp.floor(u_ref[:, color, 0] * (q - 1)).astype(jnp.int32)
        trial = jax.lax.rem(s + d, q)
        de = jnp.zeros(s.shape, jnp.float32)
        for axis, shift in ((1, 1), (1, -1), (2, 1), (2, -1)):
            nbr = _roll1(s, shift, axis)
            de = de + j * (
                (s == nbr).astype(jnp.float32) - (trial == nbr).astype(jnp.float32)
            )
        accept = (u_ref[:, color, 1] < _accept_prob(de, beta, rule)) & (
            parity == color
        )
        s = jnp.where(accept, trial, s)
        de_total = de_total + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
        n_acc = n_acc + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))

    out_ref[...] = s.astype(jnp.int8)
    de_ref[...] = de_total
    nacc_ref[...] = n_acc


def potts_sweep_pallas(
    states: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    q: int,
    j: float = 1.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    interpret: bool = True,
):
    """pallas_call wrapper. See `repro.kernels.ref.potts_sweep` for semantics.

    Args:
      states: (R, H, W) int8 in {0..q-1}; R must be a multiple of ``r_blk``
        (ops.py pads).
      u: (R, 2, 2, H, W) f32 uniforms (colour x (proposal, accept)).
      betas: (R,) f32.
      q: number of colours (static).
      r_blk: replicas per grid step (the Fig.-6 "block size" analogue).
      interpret: True on CPU (bit-exact vs the oracle); False on real TPU.
    """
    r, h, w = states.shape
    assert r % r_blk == 0, (r, r_blk)
    grid = (r // r_blk,)
    kernel = functools.partial(_potts_sweep_kernel, q=q, j=j, rule=rule)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk, 2, 2, h, w), lambda i: (i, 0, 0, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, h, w), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(states, u, betas)


def _parity(h: int, w: int) -> jnp.ndarray:
    """(h, w) checkerboard colour map from 2-D iotas (Mosaic-safe)."""
    ii = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    return (ii + jj) % 2


def _potts_sweep_body(s, beta, parity, w0, w1, *, q, j, rule, packed):
    """One checkerboard Potts sweep on the in-VMEM colour block.

    Shared by the interval-fused and whole-round kernels.  ``packed`` keeps
    the update in int8 lanes (multispin-style dense storage, q ≤ 64)
    instead of the int32 working copy; equality comparisons and the accept
    select produce identical booleans either way, so the two modes are
    bitwise-identical — only the VMEM working set differs.
    Returns ``(s', delta_e (r,), n_accepted (r,))``.
    """
    h, w = parity.shape
    beta3 = beta[:, None, None]
    ds = jnp.zeros(s.shape[0], jnp.float32)
    na = jnp.zeros(s.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll, exactly as the per-sweep kernel
        u_prop = prng.plane_uniforms(w0, w1, 2 * color + 0, h, w)
        u_acc = prng.plane_uniforms(w0, w1, 2 * color + 1, h, w)
        if packed:
            # int8 lanes throughout: s + d peaks at 2q-2 <= 126 for q <= 64
            d = 1 + jnp.floor(u_prop * (q - 1)).astype(jnp.int8)
            trial = jax.lax.rem((s + d).astype(jnp.int8), jnp.int8(q))
        else:
            d = 1 + jnp.floor(u_prop * (q - 1)).astype(jnp.int32)
            trial = jax.lax.rem(s + d, q)
        de = jnp.zeros(s.shape, jnp.float32)
        for axis, shift in ((1, 1), (1, -1), (2, 1), (2, -1)):
            nbr = _roll1(s, shift, axis)
            de = de + j * (
                (s == nbr).astype(jnp.float32)
                - (trial == nbr).astype(jnp.float32)
            )
        accept = (u_acc < _accept_prob(de, beta3, rule)) & (parity == color)
        s = jnp.where(accept, trial, s)
        ds = ds + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
        na = na + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))
    return s, ds, na


def _potts_sweep_fused_kernel(
    states_ref, beta_ref, kw_ref, t0_ref, off_ref, out_ref, de_ref, nacc_ref,
    *, n_sweeps, r_blk, q, j, rule, pack_bits,
):
    """``n_sweeps`` checkerboard Potts sweeps over an (r_blk, H, W) block.

    Same interval-fusion scheme as `_ising_sweep_fused_kernel`: the colour
    block stays VMEM-resident, per-sweep uniforms come from the counter PRNG
    (plane ``2*colour + (0 proposal | 1 accept)``) keyed on the *global*
    replica counter (block offset + ``off_ref`` under replica-axis sharding),
    and ΔE/acceptance accumulate in the per-sweep oracle's association order
    (bit-equal f32).  ``pack_bits`` keeps the lattice in int8 lanes instead
    of widening to int32 (same trajectory bitwise, q ≤ 64).
    """
    s = states_ref[...] if pack_bits else states_ref[...].astype(jnp.int32)
    h, w = s.shape[-2], s.shape[-1]
    parity = _parity(h, w)
    beta = beta_ref[...].astype(jnp.float32)
    sk0, sk1 = prng.stream_key(kw_ref[...])
    rep = (
        jax.lax.broadcasted_iota(jnp.uint32, (r_blk,), 0)
        + (pl.program_id(0) * r_blk).astype(jnp.uint32)
        + off_ref[0]
    )
    t0 = t0_ref[0]

    def sweep(i, carry):
        s, de_total, n_acc = carry
        w0, w1 = prng.sweep_key(sk0, sk1, t0 + i.astype(jnp.uint32), rep)
        s, ds, na = _potts_sweep_body(
            s, beta, parity, w0, w1, q=q, j=j, rule=rule, packed=pack_bits
        )
        return s, de_total + ds, n_acc + na

    s, de_total, n_acc = jax.lax.fori_loop(
        0, n_sweeps, sweep,
        (s, jnp.zeros(r_blk, jnp.float32), jnp.zeros(r_blk, jnp.int32)),
    )
    out_ref[...] = s.astype(jnp.int8)
    de_ref[...] = de_total
    nacc_ref[...] = n_acc


def potts_sweep_fused_pallas(
    states: jnp.ndarray,
    key_words: jnp.ndarray,
    t0: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    q: int,
    replica_offset: jnp.ndarray | None = None,
    j: float = 1.0,
    rule: str = "metropolis",
    r_blk: int = 4,
    pack_bits: bool = False,
    interpret: bool = True,
):
    """Interval-fused pallas_call wrapper (see module docstring).

    Args:
      states: (R, H, W) int8 in {0..q-1}; R a multiple of ``r_blk``
        (ops.py pads).
      key_words: (2,) uint32 run-key words (`prng.key_words`).
      t0: (1,) uint32 global sweep counter at interval entry.
      betas: (R,) f32;  n_sweeps / q: static.
      replica_offset: (1,) uint32 global index of local slot 0 (sharded
        replica axis); default 0 keeps single-device streams unchanged.
      pack_bits: dense int8-lane storage in VMEM (bitwise-identical; q ≤ 64).

    Returns ``(states', delta_e, n_accepted)`` summed over the interval.
    """
    r, h, w = states.shape
    assert r % r_blk == 0, (r, r_blk)
    if pack_bits and q > 64:
        raise ValueError(f"pack_bits needs q <= 64 (int8 lanes), got q={q}")
    if replica_offset is None:
        replica_offset = jnp.zeros((1,), jnp.uint32)
    grid = (r // r_blk,)
    kernel = functools.partial(
        _potts_sweep_fused_kernel,
        n_sweeps=n_sweeps, r_blk=r_blk, q=q, j=j, rule=rule,
        pack_bits=pack_bits,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, h, w), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(states, betas, key_words, t0, replica_offset)


def _potts_round_fused_kernel(
    states_ref, beta_ref, kw_ref, t0_ref, ph0_ref, rung_ref, energy_ref,
    out_ref, rung_out_ref, energy_out_ref, nacc_ref, acc_ref, prob_ref,
    att_ref,
    *, n_sweeps, n_rounds, r, q, j, rule, criterion, pairing, pack_bits,
):
    """``n_rounds`` full PT rounds (sweeps + temp-mode exchange) per launch.

    Potts analogue of `_ising_round_fused_kernel` (see that docstring):
    whole ladder in one grid step, per-slot sweep temperature one-hot
    gathered from the rung-ordered ladder each round, exchange via the
    shared `exchange.exchange_step` on the in-VMEM energy row at the global
    swap-phase counter.
    """
    s = states_ref[...] if pack_bits else states_ref[...].astype(jnp.int32)
    h, w = s.shape[-2], s.shape[-1]
    parity = _parity(h, w)
    betas_rung = beta_ref[...].astype(jnp.float32)
    kw = kw_ref[...]
    sk0, sk1 = prng.stream_key(kw)
    rep = jax.lax.broadcasted_iota(jnp.uint32, (r,), 0)
    t0 = t0_ref[0]
    ph0 = ph0_ref[0]
    rung = rung_ref[...]
    energy = energy_ref[...]
    nacc_total = jnp.zeros(r, jnp.int32)

    for k in range(n_rounds):  # static unroll: one exchange per round
        beta_slot = _kx.onehot_gather(betas_rung, rung)
        t_base = t0 + jnp.uint32(k * n_sweeps)

        def sweep(i, c, _beta=beta_slot, _t=t_base):
            s, de_total, n_acc = c
            w0, w1 = prng.sweep_key(sk0, sk1, _t + i.astype(jnp.uint32), rep)
            s, ds, na = _potts_sweep_body(
                s, _beta, parity, w0, w1, q=q, j=j, rule=rule,
                packed=pack_bits,
            )
            return s, de_total + ds, n_acc + na

        s, de_total, na = jax.lax.fori_loop(
            0, n_sweeps, sweep,
            (s, jnp.zeros(r, jnp.float32), jnp.zeros(r, jnp.int32)),
        )
        # Same accumulation order as the driver: interval ΔE summed in the
        # sweep loop, then one f32 add onto the running per-slot energy.
        energy = energy + de_total
        nacc_total = nacc_total + na
        rung, acc, prob, att, _ = _kx.exchange_step(
            rung, energy, betas_rung, ph0 + jnp.int32(k), kw,
            pairing=pairing, criterion=criterion,
        )
        acc_ref[k, :] = acc.astype(jnp.int32)
        prob_ref[k, :] = prob
        att_ref[k, :] = att.astype(jnp.int32)

    out_ref[...] = s.astype(jnp.int8)
    rung_out_ref[...] = rung
    energy_out_ref[...] = energy
    nacc_ref[...] = nacc_total


def potts_round_fused_pallas(
    states: jnp.ndarray,
    key_words: jnp.ndarray,
    t0: jnp.ndarray,
    phase0: jnp.ndarray,
    rung: jnp.ndarray,
    energy: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    q: int,
    n_rounds: int = 1,
    j: float = 1.0,
    rule: str = "metropolis",
    criterion: str = "logistic",
    pairing: str = "deo",
    pack_bits: bool = False,
    interpret: bool = True,
):
    """Whole-PT-round pallas_call wrapper (Potts).

    Same contract as `ising_sweep.ising_round_fused_pallas`: whole ladder,
    single grid step, returns ``(states', rung', energy', n_accepted,
    accept, prob, attempt)`` with diagnostics shaped (n_rounds, R)
    (accept/attempt as int32 0/1).
    """
    r, h, w = states.shape
    if pack_bits and q > 64:
        raise ValueError(f"pack_bits needs q <= 64 (int8 lanes), got q={q}")
    kernel = functools.partial(
        _potts_round_fused_kernel,
        n_sweeps=n_sweeps, n_rounds=n_rounds, r=r, q=q, j=j, rule=rule,
        criterion=criterion, pairing=pairing, pack_bits=pack_bits,
    )
    row = pl.BlockSpec((r,), lambda i: (0,))
    diag = pl.BlockSpec((n_rounds, r), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(1,),  # the exchange couples all replicas: one grid step
        in_specs=[
            pl.BlockSpec((r, h, w), lambda i: (0, 0, 0)),
            row,
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            row,
            row,
        ],
        out_specs=[
            pl.BlockSpec((r, h, w), lambda i: (0, 0, 0)),
            row,
            row,
            row,
            diag,
            diag,
            diag,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, h, w), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((n_rounds, r), jnp.int32),
            jax.ShapeDtypeStruct((n_rounds, r), jnp.float32),
            jax.ShapeDtypeStruct((n_rounds, r), jnp.int32),
        ],
        interpret=interpret,
    )(states, betas, key_words, t0, phase0, rung, energy)


def vmem_working_set_bytes(r_blk: int, height: int, width: int) -> int:
    """Static VMEM budget model (bytes per grid step; see module docstring)."""
    cells = r_blk * height * width
    states_in = cells  # int8
    uniforms = cells * 4 * 4  # (2 colours) x (prop, acc) f32
    widened = cells * 4  # i32 working copy
    trial = cells * 4  # i32 proposal lattice
    de = cells * 4  # f32 per-site energy delta
    out = cells
    return states_in + uniforms + widened + trial + de + out


def vmem_working_set_bytes_fused(r_blk: int, height: int, width: int) -> int:
    """VMEM budget of the interval-fused Potts kernel (bytes per grid step).

    The 16 B/cell uniforms input block is replaced by one in-flight plane of
    counter-PRNG draws (4 B uint32 bits + 4 B f32) plus O(r_blk) key state —
    22 B/cell total vs the per-sweep kernel's 30.
    """
    cells = r_blk * height * width
    states_in = cells  # int8
    bits = cells * 4  # uint32 PRNG draw, active plane
    uniforms = cells * 4  # f32 uniforms, active plane
    widened = cells * 4  # i32 working copy
    trial = cells * 4  # i32 proposal lattice
    de = cells * 4  # f32 per-site energy delta
    out = cells
    rng_state = 4 * 4 * r_blk  # stream/sweep key words + replica counters
    return states_in + bits + uniforms + widened + trial + de + out + rng_state


def vmem_working_set_bytes_packed(r_blk: int, height: int, width: int) -> int:
    """VMEM budget of the fused Potts kernel with int8-lane packing.

    The i32 working copy and i32 trial lattice (4 B/cell each) stay int8
    (1 B each): 22 → 16 B/cell.
    """
    cells = r_blk * height * width
    states_in = cells  # int8
    bits = cells * 4  # uint32 PRNG draw, active plane
    uniforms = cells * 4  # f32 uniforms, active plane
    working = cells  # int8 lanes (replaces i32 working copy)
    trial = cells  # int8 proposal lattice (replaces i32)
    de = cells * 4  # f32 per-site energy delta
    out = cells
    rng_state = 4 * 4 * r_blk
    return states_in + bits + uniforms + working + trial + de + out + rng_state


def hbm_bytes_per_cell_sweep(
    *, fused: bool, sweeps_per_interval: int = 1, rounds_per_launch: int = 1
) -> float:
    """Modeled HBM bytes per cell per sweep (O(R) scalars excluded).

    Per-sweep path: int8 in+out (2 B) + 16 B/cell of uniforms written by the
    external generator + 16 B read back = 34 B/cell/sweep.  Fused: the
    colour block crosses HBM once each way per launch (2 B/cell amortized
    over ``sweeps_per_interval × rounds_per_launch``); randoms never exist
    in HBM.

    Delegates to `repro.hlo.traffic.hbm_bytes_per_cell_sweep` — the shared
    model the roofline report and traffic assertions also consume.
    """
    from repro.hlo.traffic import hbm_bytes_per_cell_sweep as model

    return model(
        fused=fused, sweeps_per_interval=sweeps_per_interval,
        rounds_per_launch=rounds_per_launch,
        state_bytes=2.0, uniform_plane_bytes=16.0,
    )
