"""Kernel-shared replica-exchange core for the whole-round fused kernels.

The whole-PT-round kernels (`ising_sweep.ising_round_fused_pallas`,
`potts_sweep.potts_round_fused_pallas`) fold the DEO/SEO swap decision and
the slot↔rung permutation into the interval launch: the ladder's O(R) energy
row is already accumulated in VMEM, so the exchange costs O(R²) elementwise
ops instead of a kernel exit + host-side `jax.random` draw per swap.

One function — `exchange_step` — is the single source of truth for that
decision.  The *same jnp ops* run in three places:

* inside the Pallas round-kernel bodies (Mosaic or ``interpret=True``);
* in the pure-JAX ``use_pallas=False`` reference path (`ops.*_round_fused`);
* in the sharded driver (`engine.driver.make_sharded_interval_step`), where
  each device recomputes the full-ladder decision redundantly from the
  all-gathered O(R) energy/rung rows (PR 6 contract) — the replica axis
  cannot be sharded *through* an exchange, so the multi-device analogue of
  the round kernel is per-shard fused sweeps + this function on gathered
  rows, bit-equal to the single-device launch.

That sharing is what makes interpret-mode bit-equality against the
`repro.exchange` DEO/SEO strategy + `core.swap.accept_pairs` oracle (fed the
same counter-stream uniforms) hold by construction, and it is why everything
here is written Mosaic-friendly: 1-D `broadcasted_iota` instead of
``arange``, one-hot broadcast-compare-sum instead of gather/argsort (an
arbitrary slot→rung permutation has no static gather pattern Mosaic can
lower; at O(R²) on R scalars the one-hot form is noise next to the O(R·L²)
sweeps).

Scope: temp-mode DEO/SEO only.  State-mode swaps move O(R·L²) lattice bytes
(exactly what fusion exists to avoid), `windowed` builds its random matching
with a host-side sequential loop, and VMPT needs pre-swap virtual-outcome
records the kernel does not emit — all three keep the PR 4 strategy path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import swap as swap_lib
from repro.kernels import prng

__all__ = [
    "pair_partners",
    "onehot_gather",
    "rung_energies",
    "decide",
    "exchange_step",
]

PAIRINGS = ("deo", "seo")


def _iota(n: int) -> jnp.ndarray:
    # broadcasted_iota lowers on Mosaic where 1-D `arange`/`iota` does not
    return jax.lax.broadcasted_iota(jnp.int32, (n,), 0)


def pair_partners(n: int, phase) -> jnp.ndarray:
    """Mosaic-safe mirror of `core.swap.pair_partners` (same values)."""
    idx = _iota(n)
    ph = jnp.asarray(phase, jnp.int32) % 2
    even = idx ^ 1
    odd = jnp.where(idx == 0, 0, ((idx - 1) ^ 1) + 1)
    partner = jnp.where(ph == 0, even, odd)
    return jnp.where(partner >= n, idx, partner).astype(jnp.int32)


def onehot_gather(values: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """``values[idx]`` as a one-hot broadcast-compare-sum (Mosaic-safe).

    Exactly one term of each sum is nonzero, so the result is bitwise the
    gathered value (a ``-0.0`` entry surfaces as ``+0.0`` — value-equal,
    and impossible for the betas/energies this module gathers).
    """
    n = values.shape[0]
    eq = idx[:, None] == _iota(n)[None, :]
    zero = jnp.zeros((), values.dtype)
    return jnp.sum(jnp.where(eq, values[None, :], zero), axis=1)


def rung_energies(rung: jnp.ndarray, energy: jnp.ndarray) -> jnp.ndarray:
    """(R,) energy row in *rung* order from per-slot energies.

    The inversion-free form of ``energy[argsort(rung)]``: ``e_rung[r] =
    Σ_i energy[i]·[rung[i] == r]`` — argsort does not lower in a kernel
    body, the one-hot sum does.
    """
    n = rung.shape[0]
    eq = _iota(n)[:, None] == rung[None, :]
    return jnp.sum(jnp.where(eq, energy[None, :], 0.0), axis=1)


def decide(partner, betas, e_rung, u, criterion):
    """`core.swap.accept_pairs` with externally supplied uniforms.

    Same decision structure (one uniform per rung, decided at the lower
    member, broadcast to both) and the shared `swap_probability`, so the
    outputs are bit-equal to ``accept_pairs(..., uniforms=u)`` — the oracle
    the round kernels are pinned against.  Returns ``(perm, accept_at_lower,
    prob_at_lower, attempt_at_lower)`` in `accept_pairs`' conventions.
    """
    n = partner.shape[0]
    idx = _iota(n)
    lower = jnp.minimum(idx, partner)
    is_lower = (partner != idx) & (idx == lower)
    p = swap_lib.swap_probability(
        betas, onehot_gather(betas, partner),
        e_rung, onehot_gather(e_rung, partner), criterion=criterion,
    )
    accept_at_lower = (u < p) & is_lower
    pair_accept = (
        onehot_gather(accept_at_lower.astype(jnp.int32), lower) > 0
    ) & (partner != idx)
    perm = jnp.where(pair_accept, partner, idx)
    prob_at_lower = jnp.where(is_lower, p, 0.0)
    return perm, accept_at_lower, prob_at_lower, is_lower


def exchange_step(
    rung: jnp.ndarray,
    energy: jnp.ndarray,
    betas: jnp.ndarray,
    phase,
    key_words: jnp.ndarray,
    *,
    pairing: str,
    criterion: str,
):
    """One temp-mode exchange from the counter stream (kernel/driver shared).

    Args:
      rung: (R,) int32 slot→rung map.
      energy: (R,) f32 per-*slot* energies.
      betas: (R,) f32 inverse temperatures in *rung* order (cold→hot).
      phase: traced int — the global swap-iteration counter (keys the draw;
        `prng.swap_uniforms`).
      key_words: (2,) uint32 run-key words (`prng.key_words`).
      pairing: "deo" (alternating even/odd by phase parity) or "seo"
        (even/odd drawn from the counter stream's phase coin).
      criterion: "logistic" | "metropolis".

    Returns ``(new_rung, accept, prob, attempt, e_rung)``: the post-swap
    slot→rung map plus `accept_pairs`-convention lower-rung diagnostics and
    the pre-swap rung-ordered energy row.
    """
    if pairing not in PAIRINGS:
        raise ValueError(
            f"in-kernel exchange supports pairings {PAIRINGS}, got {pairing!r}"
        )
    n = rung.shape[0]
    e_rung = rung_energies(rung, energy)
    u = prng.swap_uniforms(key_words, phase, n)
    if pairing == "deo":
        partner = pair_partners(n, phase)
    else:
        partner = pair_partners(n, prng.seo_coin(key_words, phase))
    perm, accept, prob, attempt = decide(partner, betas, e_rung, u, criterion)
    # temp mode: slot i holding rung r now holds perm[r]; states stay put.
    new_rung = onehot_gather(perm, rung)
    return new_rung, accept, prob, attempt, e_rung
