"""Pallas TPU kernel: checkerboard Metropolis sweep for the 2-D Ising model.

This is the paper's compute hot-spot (the per-iteration MH update, §3)
re-thought for the TPU memory hierarchy (DESIGN.md §2/§6):

* one grid step processes a **block of replicas** with their full (L, L)
  lattices resident in VMEM — the analogue of the paper's "replicas per CUDA
  block" question (Fig. 6); the block size `r_blk` is the tuning knob swept by
  ``benchmarks/tile_sweep.py``;
* both colour half-sweeps run back-to-back in-kernel, so each sweep costs one
  HBM round-trip of the spin block instead of two;
* spins are int8 in HBM (8× denser than the f32 math dtype) and are widened
  to f32 only inside VMEM.

Two kernels share that tile strategy (DESIGN.md §6):

* ``ising_sweep_pallas`` — **one sweep per launch**; the random uniforms are
  a kernel *input* stream ``(R, 2, L, L)`` f32, so the CPU
  ``interpret=True`` path is bit-exact with `ref.ising_sweep`.  Modeled HBM
  traffic: int8 spins in+out (2 B/cell) plus the externally generated
  uniforms stream (8 B/cell written by the generator + 8 B/cell read back) =
  **18 B/cell/sweep** (`hbm_bytes_per_cell_sweep`).
* ``ising_sweep_fused_pallas`` — **one swap interval per launch**: all
  ``n_sweeps`` sweeps run with the spin block VMEM-resident and the uniforms
  generated *in-kernel* by the counter PRNG (`repro.kernels.prng`, threefry
  from ``(key, sweep, replica, colour)``), accumulating per-replica
  ΔE/acceptance in-kernel.  The spin block crosses HBM once each way per
  *interval*, cutting modeled traffic to **2 B/cell/interval** plus O(R)
  scalars — the paper's single-launch device residency (its 986× CUDA
  recipe) applied to the TPU memory hierarchy.  The stream is deterministic
  pure-uint32 arithmetic, so interpret mode is bit-exact with repeated
  `ref.ising_sweep` application fed `prng.ising_sweep_uniforms`.

VMEM working set per grid step (bytes; pinned by tests/test_kernels.py and
checked by the tile sweep):

* per-sweep: r_blk · L² · (2 int8 in/out + 2·4 u-f32 + 4 f32 widened +
  4 f32 neighbour-sum) = 18·r_blk·L²; L=300, r_blk=8 ≈ 12.4 MiB — just
  inside a v5e core's 16 MB (`vmem_working_set_bytes`);
* fused: the uniforms input stream is replaced by one in-flight colour plane
  of PRNG draws (4 B bits + 4 B f32) plus O(r_blk) key/counter state —
  same 18 B/cell total (`vmem_working_set_bytes_fused`), the win is HBM
  traffic, not VMEM footprint.

On hardware, the trailing lattice dim should be padded to a multiple of 128
lanes for full VPU utilization (the wrapper in ops.py reports alignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng


def _roll1(x: jnp.ndarray, shift: int, axis: int) -> jnp.ndarray:
    """±1 circular shift via slice+concat (lowers on both Mosaic and CPU)."""
    n = x.shape[axis]
    if shift == 1:
        a = jax.lax.slice_in_dim(x, n - 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, n - 1, axis=axis)
    else:  # shift == -1
        a = jax.lax.slice_in_dim(x, 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
    return jnp.concatenate([a, b], axis=axis)


def _accept_prob(de, beta, rule):
    """Mirror of `ref.accept_prob` (kept local: kernel code is self-contained)."""
    if rule == "metropolis":
        return jnp.exp(-beta * de)
    if rule == "glauber":
        return jax.nn.sigmoid(-beta * de)
    raise ValueError(rule)


def _ising_sweep_kernel(
    spins_ref, u_ref, beta_ref, out_ref, de_ref, nacc_ref, *, j, b, rule
):
    """One full checkerboard sweep over an (r_blk, L, L) block."""
    s = spins_ref[...].astype(jnp.float32)  # widen in VMEM only
    l = s.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    parity = (ii + jj) % 2
    beta = beta_ref[...].astype(jnp.float32)[:, None, None]

    de_total = jnp.zeros(s.shape[0], jnp.float32)
    n_acc = jnp.zeros(s.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll: two half-sweeps, one HBM round-trip
        nbr = (
            _roll1(s, 1, 1) + _roll1(s, -1, 1) + _roll1(s, 1, 2) + _roll1(s, -1, 2)
        )
        de = 2.0 * s * (j * nbr - b)
        accept = (u_ref[:, color] < _accept_prob(de, beta, rule)) & (parity == color)
        s = jnp.where(accept, -s, s)
        de_total = de_total + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
        n_acc = n_acc + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))

    out_ref[...] = s.astype(jnp.int8)
    de_ref[...] = de_total
    nacc_ref[...] = n_acc


def ising_sweep_pallas(
    spins: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    interpret: bool = True,
):
    """pallas_call wrapper. See `repro.kernels.ref.ising_sweep` for semantics.

    Args:
      spins: (R, L, L) int8; R must be a multiple of ``r_blk`` (ops.py pads).
      u: (R, 2, L, L) f32 uniforms; betas: (R,) f32.
      r_blk: replicas per grid step (the Fig.-6 "block size" analogue).
      interpret: True on CPU (bit-exact vs the oracle); False on real TPU.
    """
    r, l, _ = spins.shape
    assert r % r_blk == 0, (r, r_blk)
    grid = (r // r_blk,)
    kernel = functools.partial(_ising_sweep_kernel, j=j, b=b, rule=rule)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk, 2, l, l), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, l, l), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(spins, u, betas)


def _ising_sweep_fused_kernel(
    spins_ref, beta_ref, kw_ref, t0_ref, off_ref, out_ref, de_ref, nacc_ref,
    *, n_sweeps, r_blk, j, b, rule,
):
    """``n_sweeps`` checkerboard sweeps over an (r_blk, L, L) block.

    The spin block stays VMEM-resident across the whole interval; each
    sweep's uniforms come from the counter PRNG at ``(t0 + sweep, replica,
    colour)``.  The replica counter is *global*: block offset plus
    ``off_ref`` (the device's first global slot when the replica axis is
    sharded), so a device computing slots [off, off+r_local) draws exactly
    the streams the single-device launch would.  ΔE/acceptance accumulate
    per replica with the *same association order* as per-sweep oracle
    application (per-colour within a sweep, then per-sweep), so the f32
    totals are bit-equal too.
    """
    s = spins_ref[...].astype(jnp.float32)  # widen in VMEM only
    l = s.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    parity = (ii + jj) % 2
    beta = beta_ref[...].astype(jnp.float32)[:, None, None]
    sk0, sk1 = prng.stream_key(kw_ref[...])
    rep = (
        jax.lax.broadcasted_iota(jnp.uint32, (r_blk,), 0)
        + (pl.program_id(0) * r_blk).astype(jnp.uint32)
        + off_ref[0]
    )
    t0 = t0_ref[0]

    def sweep(i, carry):
        s, de_total, n_acc = carry
        w0, w1 = prng.sweep_key(sk0, sk1, t0 + i.astype(jnp.uint32), rep)
        ds = jnp.zeros(r_blk, jnp.float32)
        na = jnp.zeros(r_blk, jnp.int32)
        for color in (0, 1):  # static unroll, exactly as the per-sweep kernel
            u = prng.plane_uniforms(w0, w1, color, l, l)
            nbr = (
                _roll1(s, 1, 1) + _roll1(s, -1, 1)
                + _roll1(s, 1, 2) + _roll1(s, -1, 2)
            )
            de = 2.0 * s * (j * nbr - b)
            accept = (u < _accept_prob(de, beta, rule)) & (parity == color)
            s = jnp.where(accept, -s, s)
            ds = ds + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
            na = na + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))
        return s, de_total + ds, n_acc + na

    s, de_total, n_acc = jax.lax.fori_loop(
        0, n_sweeps, sweep,
        (s, jnp.zeros(r_blk, jnp.float32), jnp.zeros(r_blk, jnp.int32)),
    )
    out_ref[...] = s.astype(jnp.int8)
    de_ref[...] = de_total
    nacc_ref[...] = n_acc


def ising_sweep_fused_pallas(
    spins: jnp.ndarray,
    key_words: jnp.ndarray,
    t0: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    replica_offset: jnp.ndarray | None = None,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    interpret: bool = True,
):
    """Interval-fused pallas_call wrapper (see module docstring).

    Args:
      spins: (R, L, L) int8; R must be a multiple of ``r_blk`` (ops.py pads).
      key_words: (2,) uint32 run-key words (`prng.key_words`).
      t0: (1,) uint32 global sweep counter at interval entry.
      betas: (R,) f32.
      n_sweeps: sweeps fused into this launch (static).
      replica_offset: (1,) uint32 global index of local slot 0 (sharded
        replica axis); default 0 keeps single-device streams unchanged.
      r_blk: replicas per grid step (the Fig.-6 "block size" analogue).
      interpret: True on CPU; False on real TPU.

    Returns ``(spins', delta_e, n_accepted)`` with ΔE/acceptance summed over
    the whole interval.
    """
    r, l, _ = spins.shape
    assert r % r_blk == 0, (r, r_blk)
    if replica_offset is None:
        replica_offset = jnp.zeros((1,), jnp.uint32)
    grid = (r // r_blk,)
    kernel = functools.partial(
        _ising_sweep_fused_kernel,
        n_sweeps=n_sweeps, r_blk=r_blk, j=j, b=b, rule=rule,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, l, l), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(spins, betas, key_words, t0, replica_offset)


def vmem_working_set_bytes(r_blk: int, length: int) -> int:
    """Static VMEM budget model used by the tile sweep (bytes per grid step)."""
    spins_in = r_blk * length * length  # int8
    uniforms = r_blk * 2 * length * length * 4
    widened = r_blk * length * length * 4  # f32 working copy
    nbr = r_blk * length * length * 4  # neighbour-sum temporary
    out = r_blk * length * length
    return spins_in + uniforms + widened + nbr + out


def vmem_working_set_bytes_fused(r_blk: int, length: int) -> int:
    """VMEM budget of the interval-fused kernel (bytes per grid step).

    The per-sweep kernel's 8 B/cell uniforms *input block* is replaced by one
    in-flight colour plane of counter-PRNG draws (4 B uint32 bits + 4 B f32
    uniforms) plus O(r_blk) key/counter scalars — the total stays 18 B/cell;
    fusing wins HBM traffic (`hbm_bytes_per_cell_sweep`), not VMEM footprint.
    """
    cells = r_blk * length * length
    spins_in = cells  # int8
    bits = cells * 4  # uint32 PRNG draw, active colour
    uniforms = cells * 4  # f32 uniforms, active colour
    widened = cells * 4  # f32 working copy
    nbr = cells * 4  # neighbour-sum temporary
    out = cells
    rng_state = 4 * 4 * r_blk  # stream/sweep key words + replica counters
    return spins_in + bits + uniforms + widened + nbr + out + rng_state


def hbm_bytes_per_cell_sweep(
    *, fused: bool, sweeps_per_interval: int = 1
) -> float:
    """Modeled HBM bytes per lattice cell per sweep (O(R) scalars excluded).

    Per-sweep path: int8 spins in+out (2 B) **plus the uniforms stream** —
    8 B/cell written by the external generator and 8 B/cell read back by the
    kernel — 18 B/cell/sweep.  Fused path: the spin block crosses HBM once
    each way per *interval*, so 2 B/cell amortized over
    ``sweeps_per_interval`` sweeps; the randoms never exist in HBM.

    Delegates to `repro.hlo.traffic.hbm_bytes_per_cell_sweep` — the shared
    model the roofline report and traffic assertions also consume.
    """
    from repro.hlo.traffic import hbm_bytes_per_cell_sweep as model

    return model(
        fused=fused, sweeps_per_interval=sweeps_per_interval,
        state_bytes=2.0, uniform_plane_bytes=8.0,
    )
