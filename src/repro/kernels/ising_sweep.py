"""Pallas TPU kernel: checkerboard Metropolis sweep for the 2-D Ising model.

This is the paper's compute hot-spot (the per-iteration MH update, §3)
re-thought for the TPU memory hierarchy (DESIGN.md §2/§6):

* one grid step processes a **block of replicas** with their full (L, L)
  lattices resident in VMEM — the analogue of the paper's "replicas per CUDA
  block" question (Fig. 6); the block size `r_blk` is the tuning knob swept by
  ``benchmarks/tile_sweep.py``;
* both colour half-sweeps run back-to-back in-kernel, so each sweep costs one
  HBM round-trip of the spin block instead of two;
* spins are int8 in HBM (8× denser than the f32 math dtype) and are widened
  to f32 only inside VMEM;
* random uniforms are **kernel inputs** so the CPU `interpret=True` path is
  bit-exact with `ref.ising_sweep` (on hardware, `pltpu.prng_random_bits`
  in-kernel would remove that HBM stream — recorded as follow-up work).

VMEM working set per grid step ≈ r_blk · L² · (2 int8 in/out + 2·4 u-f32 +
4 f32 widened + 4 f32 neighbour-sum) = 18·r_blk·L² bytes; for the paper's
L=300 and r_blk=8 that's ≈ 12.4 MiB — just inside a v5e core's 16 MB of VMEM
(`vmem_working_set_bytes`, pinned by tests/test_kernels.py and checked by the
tile sweep).

On hardware, the trailing lattice dim should be padded to a multiple of 128
lanes for full VPU utilization (the wrapper in ops.py reports alignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _roll1(x: jnp.ndarray, shift: int, axis: int) -> jnp.ndarray:
    """±1 circular shift via slice+concat (lowers on both Mosaic and CPU)."""
    n = x.shape[axis]
    if shift == 1:
        a = jax.lax.slice_in_dim(x, n - 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, n - 1, axis=axis)
    else:  # shift == -1
        a = jax.lax.slice_in_dim(x, 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
    return jnp.concatenate([a, b], axis=axis)


def _accept_prob(de, beta, rule):
    """Mirror of `ref.accept_prob` (kept local: kernel code is self-contained)."""
    if rule == "metropolis":
        return jnp.exp(-beta * de)
    if rule == "glauber":
        return jax.nn.sigmoid(-beta * de)
    raise ValueError(rule)


def _ising_sweep_kernel(
    spins_ref, u_ref, beta_ref, out_ref, de_ref, nacc_ref, *, j, b, rule
):
    """One full checkerboard sweep over an (r_blk, L, L) block."""
    s = spins_ref[...].astype(jnp.float32)  # widen in VMEM only
    l = s.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    parity = (ii + jj) % 2
    beta = beta_ref[...].astype(jnp.float32)[:, None, None]

    de_total = jnp.zeros(s.shape[0], jnp.float32)
    n_acc = jnp.zeros(s.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll: two half-sweeps, one HBM round-trip
        nbr = (
            _roll1(s, 1, 1) + _roll1(s, -1, 1) + _roll1(s, 1, 2) + _roll1(s, -1, 2)
        )
        de = 2.0 * s * (j * nbr - b)
        accept = (u_ref[:, color] < _accept_prob(de, beta, rule)) & (parity == color)
        s = jnp.where(accept, -s, s)
        de_total = de_total + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
        n_acc = n_acc + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))

    out_ref[...] = s.astype(jnp.int8)
    de_ref[...] = de_total
    nacc_ref[...] = n_acc


def ising_sweep_pallas(
    spins: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    interpret: bool = True,
):
    """pallas_call wrapper. See `repro.kernels.ref.ising_sweep` for semantics.

    Args:
      spins: (R, L, L) int8; R must be a multiple of ``r_blk`` (ops.py pads).
      u: (R, 2, L, L) f32 uniforms; betas: (R,) f32.
      r_blk: replicas per grid step (the Fig.-6 "block size" analogue).
      interpret: True on CPU (bit-exact vs the oracle); False on real TPU.
    """
    r, l, _ = spins.shape
    assert r % r_blk == 0, (r, r_blk)
    grid = (r // r_blk,)
    kernel = functools.partial(_ising_sweep_kernel, j=j, b=b, rule=rule)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk, 2, l, l), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, l, l), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(spins, u, betas)


def vmem_working_set_bytes(r_blk: int, length: int) -> int:
    """Static VMEM budget model used by the tile sweep (bytes per grid step)."""
    spins_in = r_blk * length * length  # int8
    uniforms = r_blk * 2 * length * length * 4
    widened = r_blk * length * length * 4  # f32 working copy
    nbr = r_blk * length * length * 4  # neighbour-sum temporary
    out = r_blk * length * length
    return spins_in + uniforms + widened + nbr + out
