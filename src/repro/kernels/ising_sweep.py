"""Pallas TPU kernel: checkerboard Metropolis sweep for the 2-D Ising model.

This is the paper's compute hot-spot (the per-iteration MH update, §3)
re-thought for the TPU memory hierarchy (DESIGN.md §2/§6):

* one grid step processes a **block of replicas** with their full (L, L)
  lattices resident in VMEM — the analogue of the paper's "replicas per CUDA
  block" question (Fig. 6); the block size `r_blk` is the tuning knob swept by
  ``benchmarks/tile_sweep.py``;
* both colour half-sweeps run back-to-back in-kernel, so each sweep costs one
  HBM round-trip of the spin block instead of two;
* spins are int8 in HBM (8× denser than the f32 math dtype) and are widened
  to f32 only inside VMEM.

Three kernels share that tile strategy (DESIGN.md §6):

* ``ising_sweep_pallas`` — **one sweep per launch**; the random uniforms are
  a kernel *input* stream ``(R, 2, L, L)`` f32, so the CPU
  ``interpret=True`` path is bit-exact with `ref.ising_sweep`.  Modeled HBM
  traffic: int8 spins in+out (2 B/cell) plus the externally generated
  uniforms stream (8 B/cell written by the generator + 8 B/cell read back) =
  **18 B/cell/sweep** (`hbm_bytes_per_cell_sweep`).
* ``ising_sweep_fused_pallas`` — **one swap interval per launch**: all
  ``n_sweeps`` sweeps run with the spin block VMEM-resident and the uniforms
  generated *in-kernel* by the counter PRNG (`repro.kernels.prng`, threefry
  from ``(key, sweep, replica, colour)``), accumulating per-replica
  ΔE/acceptance in-kernel.  The spin block crosses HBM once each way per
  *interval*, cutting modeled traffic to **2 B/cell/interval** plus O(R)
  scalars — the paper's single-launch device residency (its 986× CUDA
  recipe) applied to the TPU memory hierarchy.  The stream is deterministic
  pure-uint32 arithmetic, so interpret mode is bit-exact with repeated
  `ref.ising_sweep` application fed `prng.ising_sweep_uniforms`.
* ``ising_round_fused_pallas`` — **one launch = whole PT round(s)**: sweeps
  *plus* the temp-mode DEO/SEO exchange, with the swap uniforms drawn from
  the counter PRNG's swap stream (`prng.swap_uniforms`) and the slot↔rung
  permutation applied in-kernel (`repro.kernels.exchange`).  Eliminates the
  per-swap kernel exit + host round-trip entirely; with ``n_rounds > 1``
  the spin block stays VMEM-resident across multiple exchanges.

All fused variants take ``pack_bits``: bit-plane **multispin packing** of
the replica axis (Weigel, arXiv:1004.0023) — spins live as 1 bit per
replica in uint32 words, neighbour counts come from a bitwise full-adder
tree, and ΔE is table-selected per replica; bitwise-identical trajectories
to the unpacked path (pinned by tests).

VMEM working set per grid step (bytes; pinned by tests/test_kernels.py and
checked by the tile sweep):

* per-sweep: r_blk · L² · (2 int8 in/out + 2·4 u-f32 + 4 f32 widened +
  4 f32 neighbour-sum) = 18·r_blk·L²; L=300, r_blk=8 ≈ 12.4 MiB — just
  inside a v5e core's 16 MB (`vmem_working_set_bytes`);
* fused: the uniforms input stream is replaced by one in-flight colour plane
  of PRNG draws (4 B bits + 4 B f32) plus O(r_blk) key/counter state —
  same 18 B/cell total (`vmem_working_set_bytes_fused`), the win is HBM
  traffic, not VMEM footprint.

On hardware, the trailing lattice dim should be padded to a multiple of 128
lanes for full VPU utilization (the wrapper in ops.py reports alignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import exchange as _kx
from repro.kernels import prng


def _roll1(x: jnp.ndarray, shift: int, axis: int) -> jnp.ndarray:
    """±1 circular shift via slice+concat (lowers on both Mosaic and CPU)."""
    n = x.shape[axis]
    if shift == 1:
        a = jax.lax.slice_in_dim(x, n - 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, n - 1, axis=axis)
    else:  # shift == -1
        a = jax.lax.slice_in_dim(x, 1, n, axis=axis)
        b = jax.lax.slice_in_dim(x, 0, 1, axis=axis)
    return jnp.concatenate([a, b], axis=axis)


def _accept_prob(de, beta, rule):
    """Mirror of `ref.accept_prob` (kept local: kernel code is self-contained)."""
    if rule == "metropolis":
        return jnp.exp(-beta * de)
    if rule == "glauber":
        return jax.nn.sigmoid(-beta * de)
    raise ValueError(rule)


def _ising_sweep_kernel(
    spins_ref, u_ref, beta_ref, out_ref, de_ref, nacc_ref, *, j, b, rule
):
    """One full checkerboard sweep over an (r_blk, L, L) block."""
    s = spins_ref[...].astype(jnp.float32)  # widen in VMEM only
    l = s.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    parity = (ii + jj) % 2
    beta = beta_ref[...].astype(jnp.float32)[:, None, None]

    de_total = jnp.zeros(s.shape[0], jnp.float32)
    n_acc = jnp.zeros(s.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll: two half-sweeps, one HBM round-trip
        nbr = (
            _roll1(s, 1, 1) + _roll1(s, -1, 1) + _roll1(s, 1, 2) + _roll1(s, -1, 2)
        )
        de = 2.0 * s * (j * nbr - b)
        accept = (u_ref[:, color] < _accept_prob(de, beta, rule)) & (parity == color)
        s = jnp.where(accept, -s, s)
        de_total = de_total + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
        n_acc = n_acc + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))

    out_ref[...] = s.astype(jnp.int8)
    de_ref[...] = de_total
    nacc_ref[...] = n_acc


def ising_sweep_pallas(
    spins: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    interpret: bool = True,
):
    """pallas_call wrapper. See `repro.kernels.ref.ising_sweep` for semantics.

    Args:
      spins: (R, L, L) int8; R must be a multiple of ``r_blk`` (ops.py pads).
      u: (R, 2, L, L) f32 uniforms; betas: (R,) f32.
      r_blk: replicas per grid step (the Fig.-6 "block size" analogue).
      interpret: True on CPU (bit-exact vs the oracle); False on real TPU.
    """
    r, l, _ = spins.shape
    assert r % r_blk == 0, (r, r_blk)
    grid = (r // r_blk,)
    kernel = functools.partial(_ising_sweep_kernel, j=j, b=b, rule=rule)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk, 2, l, l), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, l, l), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(spins, u, betas)


def _parity(l: int) -> jnp.ndarray:
    """(l, l) checkerboard colour map from 2-D iotas (Mosaic-safe)."""
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    return (ii + jj) % 2


def _ising_sweep_body(s, beta, parity, w0, w1, *, j, b, rule):
    """One checkerboard sweep (two half-sweeps) on a widened f32 spin block.

    Shared by the interval-fused and whole-round kernels; the op sequence is
    byte-for-byte the per-sweep kernel's, which is what keeps every fused
    variant bit-exact against repeated `ref.ising_sweep` application.
    Returns ``(s', delta_e (r,), n_accepted (r,))``.
    """
    l = parity.shape[-1]
    beta3 = beta[:, None, None]
    ds = jnp.zeros(s.shape[0], jnp.float32)
    na = jnp.zeros(s.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll, exactly as the per-sweep kernel
        u = prng.plane_uniforms(w0, w1, color, l, l)
        nbr = (
            _roll1(s, 1, 1) + _roll1(s, -1, 1)
            + _roll1(s, 1, 2) + _roll1(s, -1, 2)
        )
        de = 2.0 * s * (j * nbr - b)
        accept = (u < _accept_prob(de, beta3, rule)) & (parity == color)
        s = jnp.where(accept, -s, s)
        ds = ds + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
        na = na + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))
    return s, ds, na


# -- bit-plane multispin packing (Weigel, arXiv:1004.0023 §multi-spin) ---------
#
# An Ising spin is one bit; storing a replica block as f32 planes spends 32×
# the state bytes and runs the neighbour reduction on r_blk separate f32
# planes.  Packing the *replica axis* into uint32 bit-plane words (spin k of
# word w = replica 32w+k; up=1) lets one logical op update 32 replicas'
# worth of lattice at once: the 4-neighbour up-count (0..4) comes from a
# bitwise full-adder tree over the 4 rolled word planes, and ΔE is selected
# per replica from the 10 possible values (s ∈ {−1,+1} × count ∈ 0..4) by
# nested `where`s on the count's 3 bit-planes.  The table entries are built
# with the *same f32 op sequence* as the unpacked ``2.0 * s * (j*nbr - b)``
# and the accept/ΔE planes are restacked to (r, l, l) before the *same* sum
# reductions, so the packed path is bit-equal to the unpacked one — pinned
# by tests/test_kernels.py.


def _pack_spins(s: jnp.ndarray):
    """(r, l, l) ±1 f32 → tuple of ⌈r/32⌉ (l, l) uint32 bit-plane words."""
    r = s.shape[0]
    words = []
    for w in range((r + 31) // 32):
        acc = jnp.zeros(s.shape[1:], jnp.uint32)
        for k in range(min(32, r - 32 * w)):
            bit = (s[32 * w + k] > 0).astype(jnp.uint32)
            acc = acc | (bit << jnp.uint32(k))
        words.append(acc)
    return tuple(words)


def _unpack_spins(words, r: int) -> jnp.ndarray:
    """Inverse of `_pack_spins`: bit-plane words → (r, l, l) ±1 f32."""
    planes = []
    for i in range(r):
        bit = (words[i // 32] >> jnp.uint32(i % 32)) & jnp.uint32(1)
        planes.append(2.0 * bit.astype(jnp.float32) - 1.0)
    return jnp.stack(planes)


def _majority(a, b, c):
    return (a & b) | (a & c) | (b & c)


def _sel_cnt(n0, n1, n2, vals):
    """Select ``vals[cnt]`` from the count's bit-planes (cnt = n0+2·n1+4·n2).

    cnt ∈ 0..4, so n2 set implies n0 = n1 = 0; two nested `where` levels
    cover all five values without a gather.
    """
    lo = jnp.where(n0 > 0, vals[1], vals[0])
    mid = jnp.where(n0 > 0, vals[3], vals[2])
    x = jnp.where(n1 > 0, mid, lo)
    return jnp.where(n2 > 0, vals[4], x)


def _ising_de_tables(j, b):
    """ΔE(s, count) lookup rows, one per spin sign, f32-op-identical.

    Entry ``cnt`` is ``2.0 * s * (j * nbr - b)`` with ``nbr = 2·cnt − 4``,
    evaluated with the same jnp f32 op order as the unpacked body so the
    selected values match it bitwise.
    """
    rows = {}
    for sv in (-1.0, 1.0):
        s = jnp.float32(sv)
        rows[sv] = [
            2.0 * s * (j * jnp.float32(2 * cnt - 4) - b) for cnt in range(5)
        ]
    return rows[-1.0], rows[1.0]


def _ising_sweep_body_packed(words, beta, parity, w0, w1, *, j, b, rule):
    """`_ising_sweep_body` on bit-plane-packed spins (same pytree protocol).

    ``words`` is the `_pack_spins` tuple; r is recovered from the per-replica
    sweep-key shape.  The uniforms draw, acceptance comparison, and ΔE /
    acceptance reductions reuse the exact unpacked expressions on restacked
    (r, l, l) planes — only the spin storage and neighbour count differ.
    """
    r = w0.shape[0]
    neg_tab, pos_tab = _ising_de_tables(j, b)
    one = jnp.uint32(1)
    ds = jnp.zeros(r, jnp.float32)
    na = jnp.zeros(r, jnp.int32)
    for color in (0, 1):
        u = prng.plane_uniforms(w0, w1, color, parity.shape[-1], parity.shape[-1])
        new_words = []
        de_planes = []
        acc_planes = []
        for wi, word in enumerate(words):
            # 4-neighbour up-count via a bitwise full adder on rolled planes:
            # count bit-planes (n0, n1, n2) hold cnt = n0 + 2·n1 + 4·n2.
            up = _roll1(word, 1, 0)
            dn = _roll1(word, -1, 0)
            lf = _roll1(word, 1, 1)
            rt = _roll1(word, -1, 1)
            s0, c0 = up ^ dn, up & dn
            s1, c1 = lf ^ rt, lf & rt
            n0 = s0 ^ s1
            c2 = s0 & s1
            n1 = c0 ^ c1 ^ c2
            n2 = _majority(c0, c1, c2)
            flips = jnp.zeros_like(word)
            for k in range(min(32, r - 32 * wi)):
                i = 32 * wi + k
                kk = jnp.uint32(k)
                sbit = (word >> kk) & one
                b0 = (n0 >> kk) & one
                b1 = (n1 >> kk) & one
                b2 = (n2 >> kk) & one
                de = jnp.where(
                    sbit > 0,
                    _sel_cnt(b0, b1, b2, pos_tab),
                    _sel_cnt(b0, b1, b2, neg_tab),
                )
                accept = (u[i] < _accept_prob(de, beta[i], rule)) & (
                    parity == color
                )
                flips = flips | (accept.astype(jnp.uint32) << kk)
                de_planes.append(de)
                acc_planes.append(accept)
            new_words.append(word ^ flips)
        words = tuple(new_words)
        de = jnp.stack(de_planes)
        accept = jnp.stack(acc_planes)
        ds = ds + jnp.sum(jnp.where(accept, de, 0.0), axis=(1, 2))
        na = na + jnp.sum(accept.astype(jnp.int32), axis=(1, 2))
    return words, ds, na


def _ising_sweep_fused_kernel(
    spins_ref, beta_ref, kw_ref, t0_ref, off_ref, out_ref, de_ref, nacc_ref,
    *, n_sweeps, r_blk, j, b, rule, pack_bits,
):
    """``n_sweeps`` checkerboard sweeps over an (r_blk, L, L) block.

    The spin block stays VMEM-resident across the whole interval; each
    sweep's uniforms come from the counter PRNG at ``(t0 + sweep, replica,
    colour)``.  The replica counter is *global*: block offset plus
    ``off_ref`` (the device's first global slot when the replica axis is
    sharded), so a device computing slots [off, off+r_local) draws exactly
    the streams the single-device launch would.  ΔE/acceptance accumulate
    per replica with the *same association order* as per-sweep oracle
    application (per-colour within a sweep, then per-sweep), so the f32
    totals are bit-equal too.  With ``pack_bits`` the in-VMEM spin storage
    is bit-plane packed along the replica axis (multispin coding); the
    trajectory is unchanged bitwise.
    """
    s = spins_ref[...].astype(jnp.float32)  # widen in VMEM only
    l = s.shape[-1]
    parity = _parity(l)
    beta = beta_ref[...].astype(jnp.float32)
    sk0, sk1 = prng.stream_key(kw_ref[...])
    rep = (
        jax.lax.broadcasted_iota(jnp.uint32, (r_blk,), 0)
        + (pl.program_id(0) * r_blk).astype(jnp.uint32)
        + off_ref[0]
    )
    t0 = t0_ref[0]
    body = _ising_sweep_body_packed if pack_bits else _ising_sweep_body
    carry0 = _pack_spins(s) if pack_bits else s

    def sweep(i, carry):
        s, de_total, n_acc = carry
        w0, w1 = prng.sweep_key(sk0, sk1, t0 + i.astype(jnp.uint32), rep)
        s, ds, na = body(s, beta, parity, w0, w1, j=j, b=b, rule=rule)
        return s, de_total + ds, n_acc + na

    s, de_total, n_acc = jax.lax.fori_loop(
        0, n_sweeps, sweep,
        (carry0, jnp.zeros(r_blk, jnp.float32), jnp.zeros(r_blk, jnp.int32)),
    )
    if pack_bits:
        s = _unpack_spins(s, r_blk)
    out_ref[...] = s.astype(jnp.int8)
    de_ref[...] = de_total
    nacc_ref[...] = n_acc


def ising_sweep_fused_pallas(
    spins: jnp.ndarray,
    key_words: jnp.ndarray,
    t0: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    replica_offset: jnp.ndarray | None = None,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    pack_bits: bool = False,
    interpret: bool = True,
):
    """Interval-fused pallas_call wrapper (see module docstring).

    Args:
      spins: (R, L, L) int8; R must be a multiple of ``r_blk`` (ops.py pads).
      key_words: (2,) uint32 run-key words (`prng.key_words`).
      t0: (1,) uint32 global sweep counter at interval entry.
      betas: (R,) f32.
      n_sweeps: sweeps fused into this launch (static).
      replica_offset: (1,) uint32 global index of local slot 0 (sharded
        replica axis); default 0 keeps single-device streams unchanged.
      r_blk: replicas per grid step (the Fig.-6 "block size" analogue).
      pack_bits: bit-plane-pack the replica axis inside the kernel
        (multispin coding); bitwise-identical trajectory, denser VMEM.
      interpret: True on CPU; False on real TPU.

    Returns ``(spins', delta_e, n_accepted)`` with ΔE/acceptance summed over
    the whole interval.
    """
    r, l, _ = spins.shape
    assert r % r_blk == 0, (r, r_blk)
    if replica_offset is None:
        replica_offset = jnp.zeros((1,), jnp.uint32)
    grid = (r // r_blk,)
    kernel = functools.partial(
        _ising_sweep_fused_kernel,
        n_sweeps=n_sweeps, r_blk=r_blk, j=j, b=b, rule=rule,
        pack_bits=pack_bits,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((r_blk, l, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
            pl.BlockSpec((r_blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, l, l), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
        ],
        interpret=interpret,
    )(spins, betas, key_words, t0, replica_offset)


def _ising_round_fused_kernel(
    spins_ref, beta_ref, kw_ref, t0_ref, ph0_ref, rung_ref, energy_ref,
    out_ref, rung_out_ref, energy_out_ref, nacc_ref, acc_ref, prob_ref,
    att_ref,
    *, n_sweeps, n_rounds, r, j, b, rule, criterion, pairing, pack_bits,
):
    """``n_rounds`` full PT rounds — sweeps *and* exchange — in one launch.

    Each round is ``n_sweeps`` checkerboard sweeps (the shared
    `_ising_sweep_body`, at each slot's current rung temperature) followed by
    one temp-mode DEO/SEO exchange (`exchange.exchange_step`) on the
    in-VMEM energy row, drawn from the counter PRNG's swap stream at the
    global swap-phase counter.  The exchange couples every replica, so the
    whole ladder is one grid step (``grid=(1,)``; no r_blk tiling, no
    padding) — exactly the regime whole-round fusion targets: R·L² small
    enough that per-swap kernel exits, not compute, dominate.

    ``beta_ref`` is the (R,) rung-ordered ladder; the per-slot sweep
    temperature is its one-hot gather at the slot's rung, bitwise the
    ``betas[rung]`` the interval-fused driver path feeds the sweep kernel.
    Diagnostics (`accept/prob/attempt` in `core.swap.accept_pairs`
    conventions) are written per round; int32 stands in for bool on the
    accept/attempt planes (kernel outputs stay in Mosaic-friendly dtypes).
    """
    s = spins_ref[...].astype(jnp.float32)
    l = s.shape[-1]
    parity = _parity(l)
    betas_rung = beta_ref[...].astype(jnp.float32)
    kw = kw_ref[...]
    sk0, sk1 = prng.stream_key(kw)
    rep = jax.lax.broadcasted_iota(jnp.uint32, (r,), 0)
    t0 = t0_ref[0]
    ph0 = ph0_ref[0]
    rung = rung_ref[...]
    energy = energy_ref[...]
    body = _ising_sweep_body_packed if pack_bits else _ising_sweep_body
    carry = _pack_spins(s) if pack_bits else s
    nacc_total = jnp.zeros(r, jnp.int32)

    for k in range(n_rounds):  # static unroll: one exchange per round
        beta_slot = _kx.onehot_gather(betas_rung, rung.astype(jnp.int32))
        t_base = t0 + jnp.uint32(k * n_sweeps)

        def sweep(i, c, _beta=beta_slot, _t=t_base):
            s, de_total, n_acc = c
            w0, w1 = prng.sweep_key(sk0, sk1, _t + i.astype(jnp.uint32), rep)
            s, ds, na = body(s, _beta, parity, w0, w1, j=j, b=b, rule=rule)
            return s, de_total + ds, n_acc + na

        carry, de_total, na = jax.lax.fori_loop(
            0, n_sweeps, sweep,
            (carry, jnp.zeros(r, jnp.float32), jnp.zeros(r, jnp.int32)),
        )
        # Same accumulation order as the driver: interval ΔE summed in the
        # sweep loop, then one f32 add onto the running per-slot energy.
        energy = energy + de_total
        nacc_total = nacc_total + na
        rung, acc, prob, att, _ = _kx.exchange_step(
            rung, energy, betas_rung, ph0 + jnp.int32(k), kw,
            pairing=pairing, criterion=criterion,
        )
        acc_ref[k, :] = acc.astype(jnp.int32)
        prob_ref[k, :] = prob
        att_ref[k, :] = att.astype(jnp.int32)

    if pack_bits:
        carry = _unpack_spins(carry, r)
    out_ref[...] = carry.astype(jnp.int8)
    rung_out_ref[...] = rung
    energy_out_ref[...] = energy
    nacc_ref[...] = nacc_total


def ising_round_fused_pallas(
    spins: jnp.ndarray,
    key_words: jnp.ndarray,
    t0: jnp.ndarray,
    phase0: jnp.ndarray,
    rung: jnp.ndarray,
    energy: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    n_rounds: int = 1,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    criterion: str = "logistic",
    pairing: str = "deo",
    pack_bits: bool = False,
    interpret: bool = True,
):
    """Whole-PT-round pallas_call wrapper: one launch = ``n_rounds`` rounds.

    Args:
      spins: (R, L, L) int8 (whole ladder; no r_blk padding — the exchange
        couples all replicas, so the launch is a single grid step).
      key_words: (2,) uint32 run-key words (`prng.key_words`).
      t0: (1,) uint32 global sweep counter at entry.
      phase0: (1,) int32 global swap-phase counter at entry.
      rung: (R,) int32 slot→rung map; energy: (R,) f32 per-slot energies.
      betas: (R,) f32 inverse temperatures in rung order (cold→hot).
      n_sweeps: sweeps per round (the swap interval, static).
      n_rounds: PT rounds fused into this launch (static).
      pairing: "deo" | "seo"; criterion: "logistic" | "metropolis".
      pack_bits: bit-plane multispin storage in VMEM (bitwise-identical).
      interpret: True on CPU; False on real TPU.

    Returns ``(spins', rung', energy', n_accepted, accept, prob, attempt)``
    with the three diagnostic rows shaped (n_rounds, R) (accept/attempt as
    int32 0/1).
    """
    r, l, _ = spins.shape
    kernel = functools.partial(
        _ising_round_fused_kernel,
        n_sweeps=n_sweeps, n_rounds=n_rounds, r=r, j=j, b=b, rule=rule,
        criterion=criterion, pairing=pairing, pack_bits=pack_bits,
    )
    row = pl.BlockSpec((r,), lambda i: (0,))
    diag = pl.BlockSpec((n_rounds, r), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(1,),  # the exchange couples all replicas: one grid step
        in_specs=[
            pl.BlockSpec((r, l, l), lambda i: (0, 0, 0)),
            row,
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            row,
            row,
        ],
        out_specs=[
            pl.BlockSpec((r, l, l), lambda i: (0, 0, 0)),
            row,
            row,
            row,
            diag,
            diag,
            diag,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, l, l), jnp.int8),
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((n_rounds, r), jnp.int32),
            jax.ShapeDtypeStruct((n_rounds, r), jnp.float32),
            jax.ShapeDtypeStruct((n_rounds, r), jnp.int32),
        ],
        interpret=interpret,
    )(spins, betas, key_words, t0, phase0, rung, energy)


def vmem_working_set_bytes(r_blk: int, length: int) -> int:
    """Static VMEM budget model used by the tile sweep (bytes per grid step)."""
    spins_in = r_blk * length * length  # int8
    uniforms = r_blk * 2 * length * length * 4
    widened = r_blk * length * length * 4  # f32 working copy
    nbr = r_blk * length * length * 4  # neighbour-sum temporary
    out = r_blk * length * length
    return spins_in + uniforms + widened + nbr + out


def vmem_working_set_bytes_fused(r_blk: int, length: int) -> int:
    """VMEM budget of the interval-fused kernel (bytes per grid step).

    The per-sweep kernel's 8 B/cell uniforms *input block* is replaced by one
    in-flight colour plane of counter-PRNG draws (4 B uint32 bits + 4 B f32
    uniforms) plus O(r_blk) key/counter scalars — the total stays 18 B/cell;
    fusing wins HBM traffic (`hbm_bytes_per_cell_sweep`), not VMEM footprint.
    """
    cells = r_blk * length * length
    spins_in = cells  # int8
    bits = cells * 4  # uint32 PRNG draw, active colour
    uniforms = cells * 4  # f32 uniforms, active colour
    widened = cells * 4  # f32 working copy
    nbr = cells * 4  # neighbour-sum temporary
    out = cells
    rng_state = 4 * 4 * r_blk  # stream/sweep key words + replica counters
    return spins_in + bits + uniforms + widened + nbr + out + rng_state


def vmem_working_set_bytes_packed(r_blk: int, length: int) -> int:
    """VMEM budget of the fused kernel with bit-plane multispin packing.

    The f32 widened carry (4 B/cell) and the f32 neighbour-sum plane
    (4 B/cell) are replaced by ⌈r_blk/32⌉ uint32 bit-plane words plus the
    full-adder count planes (rolled plane + 3 count bit-planes, all uint32)
    and per-replica selected-ΔE / accept planes (4 + 1 B/cell).  Net:
    18 → 15 + 20·⌈r_blk/32⌉·L²/cells B/cell (17.5 at r_blk=8, 15.6 at 32) —
    a modest VMEM saving; the real packing win is the neighbour reduction
    running on uint32 words (32 replica lanes per logical op) instead of
    r_blk separate f32 planes.
    """
    cells = r_blk * length * length
    plane = length * length
    n_words = -(-r_blk // 32)
    spins_in = cells  # int8 in
    packed = 4 * n_words * plane  # bit-plane spin carry (replaces f32 widened)
    adder = 4 * 4 * n_words * plane  # rolled plane + 3 count bit-planes
    bits = cells * 4  # uint32 PRNG draw, active colour
    uniforms = cells * 4  # f32 uniforms, active colour
    de_sel = cells * 4  # selected-ΔE planes (replaces f32 neighbour sum)
    accept = cells  # accept planes (bool)
    out = cells  # int8 out
    rng_state = 4 * 4 * r_blk
    return (
        spins_in + packed + adder + bits + uniforms + de_sel + accept + out
        + rng_state
    )


def hbm_bytes_per_cell_sweep(
    *, fused: bool, sweeps_per_interval: int = 1, rounds_per_launch: int = 1
) -> float:
    """Modeled HBM bytes per lattice cell per sweep (O(R) scalars excluded).

    Per-sweep path: int8 spins in+out (2 B) **plus the uniforms stream** —
    8 B/cell written by the external generator and 8 B/cell read back by the
    kernel — 18 B/cell/sweep.  Fused path: the spin block crosses HBM once
    each way per *launch*, so 2 B/cell amortized over ``sweeps_per_interval
    × rounds_per_launch`` sweeps (the whole-round kernels fold the exchange
    in too, so multi-round launches never touch HBM between rounds); the
    randoms never exist in HBM.

    Delegates to `repro.hlo.traffic.hbm_bytes_per_cell_sweep` — the shared
    model the roofline report and traffic assertions also consume.
    """
    from repro.hlo.traffic import hbm_bytes_per_cell_sweep as model

    return model(
        fused=fused, sweeps_per_interval=sweeps_per_interval,
        rounds_per_launch=rounds_per_launch,
        state_bytes=2.0, uniform_plane_bytes=8.0,
    )
