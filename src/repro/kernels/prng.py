"""Counter-based in-kernel PRNG for the interval-fused sweep kernels.

The per-sweep kernels take their uniforms as an externally generated
``(R, colours, ..., H, W)`` f32 *input stream* — 8 bytes of pure
random-number HBM traffic per cell per colour against 1-byte int8 spins.
Fusing a whole swap interval into one kernel (DESIGN.md §6) only pays off if
the randoms are generated *inside* VMEM, so this module provides the
established GPU-Ising recipe (Weigel, arXiv:1004.0023): a **counter-based**
generator — Threefry-2x32-20 (Salmon et al., SC'11), the same cipher behind
``jax.random`` — evaluated at a deterministic counter derived from

    (run key, sweep counter t, replica index, plane)

where *plane* enumerates the per-sweep random lattices a system consumes
(Ising: one per colour half-sweep; Potts: (proposal, accept) per colour).

Why counter-based and not ``pltpu.prng_random_bits``: the hardware PRNG is
stateful and backend-specific, so a CPU oracle could never reproduce its
stream.  Threefry is pure uint32 arithmetic — the *same jnp ops* run inside
the Pallas kernel body (Mosaic or ``interpret=True``) and in the pure-JAX
reference below, which is what keeps the fused kernels bit-exact against
``ref.ising_sweep`` / ``ref.potts_sweep`` fed this module's stream
(tests/test_kernels.py pins it).

Stream derivation (all uint32)::

    stream key  = threefry(key_words, (DOMAIN, DOMAIN))     # once per run
    sweep key   = threefry(stream key, (t, replica))        # per sweep x replica
    lattice bits= threefry(sweep key, (plane, i*W + j))     # per site

The DOMAIN constant separates this stream from every ``jax.random`` fold-in
derivation of the same run key (the engine's swap phase draws
``fold_in(key, 2t+1)`` uniforms from the *same* root key; without domain
separation the (t=0, replica=odd) sweep keys would collide with swap keys).

Uniforms are the top 24 bits scaled by 2^-24 — exact in f32, in [0, 1), and
never 1.0, matching the half-open contract of the acceptance comparisons.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "DOMAIN",
    "SWAP_DOMAIN",
    "threefry2x32",
    "key_words",
    "stream_key",
    "sweep_key",
    "plane_uniforms",
    "ising_sweep_uniforms",
    "potts_sweep_uniforms",
    "swap_stream_key",
    "swap_key",
    "swap_uniforms",
    "seo_coin",
]

# Domain-separation constant for the fused-sweep stream (arbitrary, fixed
# forever: changing it changes every fused trajectory).
DOMAIN = 0x46555345  # ascii "FUSE"
# Domain-separation constant for the in-kernel *exchange* stream of the
# whole-round fused kernels.  The round kernel draws its per-rung swap
# uniforms from (run key, swap-phase counter, rung) inside the launch; this
# constant keeps those draws disjoint from both the sweep stream above and
# every `jax.random` fold-in of the same root key.  Like DOMAIN: arbitrary,
# fixed forever.
SWAP_DOMAIN = 0x53574150  # ascii "SWAP"

_KS_PARITY = 0x1BD11BDA  # Threefry key-schedule constant
# Threefry-2x32 rotation schedule: groups of four rounds alternate between
# these two rotation quadruples; 20 rounds = 5 groups.
_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))


def _rotl(x: jnp.ndarray, d: int) -> jnp.ndarray:
    return (x << jnp.uint32(d)) | (x >> jnp.uint32(32 - d))


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32-20 block cipher: key (k0,k1), counter (x0,x1) -> 2 words.

    All inputs are (broadcastable) uint32 arrays; uint32 addition wraps
    mod 2^32 by definition, which is exactly the cipher's arithmetic.  This
    is the reference implementation for both the pure-JAX stream functions
    below and the Pallas kernel bodies — one function, one stream.
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(x0, jnp.uint32)
    x1 = jnp.asarray(x1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(_KS_PARITY))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for group in range(5):
        for d in _ROTATIONS[group % 2]:
            x0 = x0 + x1
            x1 = _rotl(x1, d) ^ x0
        inject = group + 1
        x0 = x0 + ks[inject % 3]
        x1 = x1 + ks[(inject + 1) % 3] + jnp.uint32(inject)
    return x0, x1


def key_words(key: jax.Array) -> jnp.ndarray:
    """(2,) uint32 key words from a typed JAX PRNG key (or raw uint32 data).

    Threefry keys are two words; wider key data (e.g. the rbg impl) is
    folded down by XOR so every bit of the original key still matters.
    """
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    else:
        data = key
    data = jnp.asarray(data, jnp.uint32).reshape(-1)
    k0 = data[0]
    k1 = data[1] if data.shape[0] > 1 else jnp.uint32(0)
    for i in range(2, data.shape[0]):
        k0, k1 = (k0 ^ data[i], k1) if i % 2 == 0 else (k0, k1 ^ data[i])
    return jnp.stack([k0, k1])


def stream_key(words: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Domain-separated root of the fused-sweep stream (two uint32 scalars)."""
    return threefry2x32(words[0], words[1], DOMAIN, DOMAIN)


def sweep_key(s0, s1, t, replica):
    """Per-(sweep, replica) subkey; ``t``/``replica`` broadcast elementwise."""
    return threefry2x32(s0, s1, t, replica)


def plane_uniforms(w0, w1, plane: int, h: int, w: int) -> jnp.ndarray:
    """(..., h, w) f32 uniforms in [0,1) for one random lattice ("plane").

    ``w0``/``w1`` are per-replica sweep-key words shaped (...,) — typically
    (R,); the site counter is the linear index ``i*w + j`` so the stream is
    layout-independent (padding W for TPU lanes would not change values at
    real sites).
    """
    ii = jax.lax.broadcasted_iota(jnp.uint32, (h, w), 0)
    jj = jax.lax.broadcasted_iota(jnp.uint32, (h, w), 1)
    site = ii * jnp.uint32(w) + jj
    b0, _ = threefry2x32(
        w0[..., None, None], w1[..., None, None], jnp.uint32(plane), site
    )
    return (b0 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


# -- pure-JAX per-sweep stream (the oracle's view of the kernel stream) --------


def ising_sweep_uniforms(words, t, replica_ids, length: int) -> jnp.ndarray:
    """(R, 2, L, L) f32 — the Ising sweep-``t`` uniforms of the fused stream.

    Feeding this to `ref.ising_sweep` for t = t0..t0+S-1 reproduces
    ``ising_sweep_fused`` over S sweeps bit-for-bit (spins and counters).
    """
    s0, s1 = stream_key(words)
    w0, w1 = sweep_key(s0, s1, jnp.uint32(t), jnp.asarray(replica_ids, jnp.uint32))
    return jnp.stack(
        [plane_uniforms(w0, w1, c, length, length) for c in (0, 1)], axis=1
    )


# -- counter-based exchange stream (the in-kernel swap draw) -------------------
#
# Derivation mirrors the sweep stream, keyed on the swap-*phase* counter
# (one increment per exchange attempt) instead of the sweep counter:
#
#     swap stream key = threefry(key_words, (SWAP_DOMAIN, SWAP_DOMAIN))
#     swap step key   = threefry(swap stream key, (phase, 0))
#     rung uniforms   = threefry(swap step key, (0, rung))     # plane 0
#     SEO phase coin  = threefry(swap step key, (1, 0)) & 1    # plane 1
#
# Keying on `phase` (not t) makes the stream invariant to how sweeps are
# grouped into launches: round k of a multi-round launch draws exactly what
# k successive single-round launches would.  The stream deliberately differs
# from the engine's `fold_in(key, 2t+1)` swap draw — like the fused sweep
# stream, whole-round fusion is gated *statistically* (conformance), with
# bit-equality pinned against this stream's own pure-JAX oracle.


def swap_stream_key(words: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Domain-separated root of the in-kernel exchange stream."""
    return threefry2x32(words[0], words[1], SWAP_DOMAIN, SWAP_DOMAIN)


def swap_key(s0, s1, phase):
    """Per-swap-iteration subkey; ``phase`` is the global swap counter."""
    return threefry2x32(s0, s1, jnp.asarray(phase, jnp.uint32), jnp.uint32(0))


def swap_uniforms(words: jnp.ndarray, phase, n: int) -> jnp.ndarray:
    """(n,) f32 in [0,1): one acceptance uniform per rung for swap ``phase``.

    Same top-24-bit scaling as `plane_uniforms`; the counter is the rung
    index, so the draw at rung r is independent of R (ladder growth never
    perturbs existing rungs' streams).
    """
    s0, s1 = swap_stream_key(words)
    w0, w1 = swap_key(s0, s1, phase)
    rung = jax.lax.broadcasted_iota(jnp.uint32, (n,), 0)
    b0, _ = threefry2x32(w0, w1, jnp.uint32(0), rung)
    return (b0 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def seo_coin(words: jnp.ndarray, phase) -> jnp.ndarray:
    """Scalar int32 in {0, 1}: the SEO even/odd pairing coin for ``phase``."""
    s0, s1 = swap_stream_key(words)
    w0, w1 = swap_key(s0, s1, phase)
    b0, _ = threefry2x32(w0, w1, jnp.uint32(1), jnp.uint32(0))
    return (b0 & jnp.uint32(1)).astype(jnp.int32)


def potts_sweep_uniforms(words, t, replica_ids, h: int, w: int) -> jnp.ndarray:
    """(R, 2, 2, H, W) f32 — the Potts sweep-``t`` uniforms (colour x
    (proposal, accept)); plane index is ``2*colour + which``."""
    s0, s1 = stream_key(words)
    w0, w1 = sweep_key(s0, s1, jnp.uint32(t), jnp.asarray(replica_ids, jnp.uint32))
    return jnp.stack(
        [
            jnp.stack(
                [plane_uniforms(w0, w1, 2 * c + p, h, w) for p in (0, 1)], axis=1
            )
            for c in (0, 1)
        ],
        axis=1,
    )
