"""Pure-jnp oracles for the Pallas kernels.

These are the *numerical contracts*: each kernel in this package must match
its oracle bit-for-bit in f32 (tests/test_kernels_*.py sweep shapes/dtypes).
They are also used as the production XLA fallback paths (e.g. lattices too
large for VMEM-resident tiles, or CPU execution).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accept_prob(de: jnp.ndarray, beta, rule: str) -> jnp.ndarray:
    """Per-site acceptance probability.

    * ``metropolis`` — ``min(1, e^{-beta*dE})`` (paper Eq. 1).  NOTE: at
      dE <= 0 this accepts deterministically; simultaneous (checkerboard)
      deterministic flips can create absorbing 2-cycles on tiny/stripe-
      symmetric lattices (observed on 2x2 — see tests/test_ising.py).
    * ``glauber`` — heat-bath ``1/(1 + e^{beta*dE})``: strictly in (0,1), so
      the simultaneous update stays aperiodic; same stationary law.
    """
    if rule == "metropolis":
        return jnp.exp(-beta * de)  # u in [0,1) < e^0 handles dE<=0
    if rule == "glauber":
        return jax.nn.sigmoid(-beta * de)
    raise ValueError(f"unknown acceptance rule {rule!r}")


def ising_sweep(
    spins: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    j: float,
    b: float,
    rule: str = "metropolis",
):
    """One full checkerboard Metropolis sweep, batched over replicas.

    Args:
      spins: (R, L, L) int8 in {-1, +1}.
      u: (R, 2, L, L) float32 uniforms in [0, 1) — one lattice of randoms per
        colour half-sweep.  Randoms are *inputs* (not generated in-kernel) so
        the Pallas kernel and this oracle are bit-exact on CPU (DESIGN.md §6).
      betas: (R,) float32 inverse temperatures.
      rule: per-site acceptance rule (see `accept_prob`).

    Returns:
      (new_spins (R,L,L) int8, delta_e (R,) f32, n_accepted (R,) i32).
    """
    L = spins.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    parity = (ii + jj) % 2
    beta = betas.astype(jnp.float32)[:, None, None]

    s = spins.astype(jnp.float32)
    de_total = jnp.zeros(spins.shape[0], jnp.float32)
    n_acc = jnp.zeros(spins.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll, exactly as the kernel does
        nbr = (
            jnp.roll(s, 1, axis=-2)
            + jnp.roll(s, -1, axis=-2)
            + jnp.roll(s, 1, axis=-1)
            + jnp.roll(s, -1, axis=-1)
        )
        de = 2.0 * s * (j * nbr - b)
        accept = (u[:, color] < accept_prob(de, beta, rule)) & (parity == color)
        s = jnp.where(accept, -s, s)
        de_total = de_total + jnp.sum(jnp.where(accept, de, 0.0), axis=(-2, -1))
        n_acc = n_acc + jnp.sum(accept.astype(jnp.int32), axis=(-2, -1))
    return s.astype(jnp.int8), de_total, n_acc


def potts_sweep(
    states: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    q: int,
    j: float,
    rule: str = "metropolis",
):
    """One full checkerboard sweep of the q-state Potts model, replica-batched.

    The proposal at each site is a uniformly random *different* colour,
    ``s' = (s + d) mod q`` with ``d = 1 + floor(u_prop * (q-1))`` — symmetric,
    so plain MH acceptance applies.  Same two-colour scheme as the Ising
    sweep: sites of one parity share no bonds (PBC needs even dims), so the
    whole colour class updates simultaneously.

    Args:
      states: (R, H, W) int8 colours in {0..q-1}.
      u: (R, 2, 2, H, W) float32 uniforms in [0, 1) — axis 1 is the colour
        half-sweep, axis 2 is (proposal draw, acceptance draw).  Randoms are
        inputs so the Pallas kernel and this oracle are bit-exact on CPU
        (DESIGN.md §6).
      betas: (R,) float32 inverse temperatures.
      q: number of colours (static).
      j: coupling; E = -j * sum_<xy> delta(s_x, s_y), each bond once.
      rule: per-site acceptance rule (see `accept_prob`).

    Returns:
      (new_states (R,H,W) int8, delta_e (R,) f32, n_accepted (R,) i32).
    """
    h, w = states.shape[-2], states.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
    parity = (ii + jj) % 2
    beta = betas.astype(jnp.float32)[:, None, None]

    s = states.astype(jnp.int32)
    de_total = jnp.zeros(states.shape[0], jnp.float32)
    n_acc = jnp.zeros(states.shape[0], jnp.int32)
    for color in (0, 1):  # static unroll, exactly as the kernel does
        d = 1 + jnp.floor(u[:, color, 0] * (q - 1)).astype(jnp.int32)
        trial = jax.lax.rem(s + d, q)
        de = jnp.zeros(s.shape, jnp.float32)
        for axis, shift in ((-2, 1), (-2, -1), (-1, 1), (-1, -1)):
            nbr = jnp.roll(s, shift, axis=axis)
            de = de + j * (
                (s == nbr).astype(jnp.float32) - (trial == nbr).astype(jnp.float32)
            )
        accept = (u[:, color, 1] < accept_prob(de, beta, rule)) & (parity == color)
        s = jnp.where(accept, trial, s)
        de_total = de_total + jnp.sum(jnp.where(accept, de, 0.0), axis=(-2, -1))
        n_acc = n_acc + jnp.sum(accept.astype(jnp.int32), axis=(-2, -1))
    return s.astype(jnp.int8), de_total, n_acc


def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    initial_state: jnp.ndarray | None = None,
):
    """RWKV-6 ("Finch") recurrence, one batch*head slab at a time.

    Per head, with state ``S`` of shape (dk, dv)::

        o_t = r_t @ S_{t-1}  +  (r_t · (u ⊙ k_t)) v_t
        S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t

    ``w_t`` is the *data-dependent* decay in (0, 1) — the defining RWKV-6
    feature [arXiv:2404.05892].

    Args:
      r, k, w: (BH, T, dk) float32 (w already exp(-exp(...))-activated).
      v: (BH, T, dv) float32.
      u: (BH, dk) float32 "bonus" for the current token.
      initial_state: optional (BH, dk, dv) f32 (decode); zeros otherwise.

    Returns (o (BH, T, dv) f32, final_state (BH, dk, dv) f32).
    """
    bh, t, dk = r.shape
    dv = v.shape[-1]
    s0 = (
        jnp.zeros((bh, dk, dv), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, inputs):
        rt, kt, vt, wt, ut = inputs  # (bh,dk),(bh,dk),(bh,dv),(bh,dk),(bh,dk)
        bonus = jnp.sum(rt * ut * kt, axis=-1, keepdims=True)  # (bh, 1)
        out = jnp.einsum("bk,bkv->bv", rt, s) + bonus * vt
        s = wt[:, :, None] * s + kt[:, :, None] * vt[:, None, :]
        return s, out

    xs = (
        r.transpose(1, 0, 2),
        k.transpose(1, 0, 2),
        v.transpose(1, 0, 2),
        w.transpose(1, 0, 2),
        jnp.broadcast_to(u[None], (t, bh, dk)),
    )
    s_final, o = jax.lax.scan(step, s0, xs)
    return o.transpose(1, 0, 2), s_final
