"""Public jit'd wrappers around the Pallas kernels.

Handles padding to kernel-friendly shapes, dispatch between the Pallas path
and the pure-jnp oracle (`ref.py`), and platform detection (interpret=True
everywhere except real TPUs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ising_sweep as _ising
from repro.kernels import potts_sweep as _potts
from repro.kernels import ref as _ref
from repro.kernels import wkv6 as _wkv6


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("j", "b", "rule", "r_blk", "use_pallas"))
def ising_sweep(
    spins: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    use_pallas: bool = True,
):
    """Checkerboard sweep; see `ref.ising_sweep` for the contract.

    Pads the replica axis to a multiple of ``r_blk`` (padded replicas run at
    beta=0 on junk lattices and are dropped — grid shape stays static).
    """
    if not use_pallas:
        return _ref.ising_sweep(spins, u, betas, j=j, b=b, rule=rule)
    r = spins.shape[0]
    pad = (-r) % r_blk
    if pad:
        spins = jnp.concatenate([spins, spins[:pad]], axis=0)
        u = jnp.concatenate([u, u[:pad]], axis=0)
        betas = jnp.concatenate([betas, jnp.zeros((pad,), betas.dtype)], axis=0)
    out, de, nacc = _ising.ising_sweep_pallas(
        spins, u, betas, j=j, b=b, rule=rule, r_blk=min(r_blk, spins.shape[0]),
        interpret=not _on_tpu(),
    )
    return out[:r], de[:r], nacc[:r]


@partial(jax.jit, static_argnames=("q", "j", "rule", "r_blk", "use_pallas"))
def potts_sweep(
    states: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    q: int,
    j: float = 1.0,
    rule: str = "metropolis",
    r_blk: int = 4,
    use_pallas: bool = True,
):
    """Checkerboard Potts sweep; see `ref.potts_sweep` for the contract.

    Pads the replica axis to a multiple of ``r_blk`` exactly like
    `ising_sweep` (padded replicas run at beta=0 on junk lattices and are
    dropped — grid shape stays static).  The default ``r_blk=4`` is the
    documented v5e-VMEM-safe block for the paper's L=300 lattice (the Potts
    working set is ~2.3x Ising's per cell; `potts_sweep.vmem_working_set_bytes`).
    """
    if not use_pallas:
        return _ref.potts_sweep(states, u, betas, q=q, j=j, rule=rule)
    r = states.shape[0]
    pad = (-r) % r_blk
    if pad:
        states = jnp.concatenate([states, states[:pad]], axis=0)
        u = jnp.concatenate([u, u[:pad]], axis=0)
        betas = jnp.concatenate([betas, jnp.zeros((pad,), betas.dtype)], axis=0)
    out, de, nacc = _potts.potts_sweep_pallas(
        states, u, betas, q=q, j=j, rule=rule,
        r_blk=min(r_blk, states.shape[0]), interpret=not _on_tpu(),
    )
    return out[:r], de[:r], nacc[:r]


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    initial_state: jnp.ndarray | None = None,
    *,
    chunk: int = 64,
    use_pallas: bool = True,
):
    """RWKV-6 recurrence; see `ref.wkv6` for the contract.

    Pads T to a multiple of ``chunk`` with w=1, k=0 steps (state-neutral).
    """
    if not use_pallas:
        return _ref.wkv6(r, k, v, w, u, initial_state)
    bh, t, dk = r.shape
    pad = (-t) % chunk
    if pad:
        zk = jnp.zeros((bh, pad, dk), r.dtype)
        zv = jnp.zeros((bh, pad, v.shape[-1]), v.dtype)
        r = jnp.concatenate([r, zk], axis=1)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zv], axis=1)
        w = jnp.concatenate([w, jnp.ones((bh, pad, dk), w.dtype)], axis=1)
    o, s = _wkv6.wkv6_pallas(
        r, k, v, w, u, initial_state, chunk=chunk, interpret=not _on_tpu()
    )
    return o[:, :t], s
