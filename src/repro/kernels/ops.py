"""Public jit'd wrappers around the Pallas kernels.

Handles padding to kernel-friendly shapes, dispatch between the Pallas path
and the pure-jnp oracle (`ref.py`), and platform detection (interpret=True
everywhere except real TPUs).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import exchange as _kx
from repro.kernels import ising_sweep as _ising
from repro.kernels import potts_sweep as _potts
from repro.kernels import prng as _prng
from repro.kernels import ref as _ref
from repro.kernels import wkv6 as _wkv6


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_replicas(arrays, betas, r_blk: int):
    """Pad the replica axis of every array to a multiple of ``r_blk``.

    Pad rows *tile* the real replicas (``row i -> row i % R``) so any pad
    count — including ``pad > R``, e.g. R=3 at r_blk=8 — yields consistent
    shapes (``spins[:pad]`` silently under-padded there, leaving betas one
    length and spins another).  Padded rows are *copies of real lattices*
    running at beta=0 (infinite temperature) and are dropped by the caller;
    the grid shape stays static and real rows are untouched.
    """
    r = betas.shape[0]
    pad = (-r) % r_blk
    if not pad:
        return arrays, betas, r
    idx = jnp.arange(pad) % r
    arrays = [jnp.concatenate([a, a[idx]], axis=0) for a in arrays]
    betas = jnp.concatenate([betas, jnp.zeros((pad,), betas.dtype)], axis=0)
    return arrays, betas, r


@partial(jax.jit, static_argnames=("j", "b", "rule", "r_blk", "use_pallas"))
def ising_sweep(
    spins: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    use_pallas: bool = True,
):
    """Checkerboard sweep; see `ref.ising_sweep` for the contract.

    Pads the replica axis to a multiple of ``r_blk`` (pad rows tile the real
    lattices at beta=0 and are dropped — grid shape stays static).
    """
    if not use_pallas:
        return _ref.ising_sweep(spins, u, betas, j=j, b=b, rule=rule)
    (spins, u), betas, r = _pad_replicas([spins, u], betas, r_blk)
    out, de, nacc = _ising.ising_sweep_pallas(
        spins, u, betas, j=j, b=b, rule=rule, r_blk=r_blk,
        interpret=not _on_tpu(),
    )
    return out[:r], de[:r], nacc[:r]


@partial(jax.jit, static_argnames=("q", "j", "rule", "r_blk", "use_pallas"))
def potts_sweep(
    states: jnp.ndarray,
    u: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    q: int,
    j: float = 1.0,
    rule: str = "metropolis",
    r_blk: int = 4,
    use_pallas: bool = True,
):
    """Checkerboard Potts sweep; see `ref.potts_sweep` for the contract.

    Pads the replica axis to a multiple of ``r_blk`` exactly like
    `ising_sweep` (pad rows tile the real lattices at beta=0 and are
    dropped — grid shape stays static).  The default ``r_blk=4`` is the
    documented v5e-VMEM-safe block for the paper's L=300 lattice (the Potts
    working set is ~2.3x Ising's per cell; `potts_sweep.vmem_working_set_bytes`).
    """
    if not use_pallas:
        return _ref.potts_sweep(states, u, betas, q=q, j=j, rule=rule)
    (states, u), betas, r = _pad_replicas([states, u], betas, r_blk)
    out, de, nacc = _potts.potts_sweep_pallas(
        states, u, betas, q=q, j=j, rule=rule,
        r_blk=r_blk, interpret=not _on_tpu(),
    )
    return out[:r], de[:r], nacc[:r]


def _fused_prelude(key, t):
    """Normalize the fused-kernel PRNG inputs: key words + (1,) u32 counter."""
    words = _prng.key_words(key)
    t0 = jnp.asarray(t).astype(jnp.uint32).reshape(1)
    return words, t0


@partial(
    jax.jit,
    static_argnames=(
        "n_sweeps", "j", "b", "rule", "r_blk", "pack_bits", "use_pallas"
    ),
)
def ising_sweep_fused(
    spins: jnp.ndarray,
    key: jnp.ndarray,
    t: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    replica_offset=0,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    r_blk: int = 8,
    pack_bits: bool = False,
    use_pallas: bool = True,
):
    """Interval-fused checkerboard sweeps: ``n_sweeps`` sweeps, one launch.

    ``key`` is a typed JAX PRNG key (or raw uint32 key data) and ``t`` the
    global sweep counter at interval entry; uniforms come from the counter
    PRNG (`repro.kernels.prng`) so the ``use_pallas=False`` pure-JAX path —
    ``n_sweeps`` applications of `ref.ising_sweep` fed
    `prng.ising_sweep_uniforms` — is bit-exact with the kernel in interpret
    mode.  Replica padding follows `ising_sweep` (pad rows tile the real
    lattices at beta=0, dropped on return); real replicas keep counter
    indices ``offset..offset+R-1``
    so the stream is padding-invariant.  ``replica_offset`` (traced uint32
    scalar, default 0) is the global index of local replica 0 when the
    replica axis is sharded across devices: a device holding slots
    ``[off, off+R_local)`` reproduces exactly the single-device streams.
    ``pack_bits`` selects bit-plane multispin storage inside the kernel
    (`ising_sweep.vmem_working_set_bytes_packed`); the trajectory is
    bitwise-identical, so the reference path is packing-oblivious.
    """
    words, t0 = _fused_prelude(key, t)
    off = jnp.asarray(replica_offset).astype(jnp.uint32).reshape(-1)[:1]
    r, length = spins.shape[0], spins.shape[-1]
    if not use_pallas:
        rep = off[0] + jnp.arange(r, dtype=jnp.uint32)

        def sweep(i, carry):
            s, de, na = carry
            u = _prng.ising_sweep_uniforms(
                words, t0[0] + jnp.uint32(i), rep, length
            )
            s, d, n = _ref.ising_sweep(s, u, betas, j=j, b=b, rule=rule)
            return s, de + d, na + n

        return jax.lax.fori_loop(
            0, n_sweeps, sweep,
            (spins, jnp.zeros((r,), jnp.float32), jnp.zeros((r,), jnp.int32)),
        )
    (spins,), padded_betas, r = _pad_replicas([spins], betas, r_blk)
    out, de, nacc = _ising.ising_sweep_fused_pallas(
        spins, words, t0, padded_betas, n_sweeps=n_sweeps,
        replica_offset=off, j=j, b=b,
        rule=rule, r_blk=r_blk, pack_bits=pack_bits, interpret=not _on_tpu(),
    )
    return out[:r], de[:r], nacc[:r]


@partial(
    jax.jit,
    static_argnames=(
        "n_sweeps", "q", "j", "rule", "r_blk", "pack_bits", "use_pallas"
    ),
)
def potts_sweep_fused(
    states: jnp.ndarray,
    key: jnp.ndarray,
    t: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    q: int,
    replica_offset=0,
    j: float = 1.0,
    rule: str = "metropolis",
    r_blk: int = 4,
    pack_bits: bool = False,
    use_pallas: bool = True,
):
    """Interval-fused Potts sweeps; see `ising_sweep_fused` for the contract
    (including the sharded-replica ``replica_offset`` counter convention).

    The ``use_pallas=False`` path applies `ref.potts_sweep` ``n_sweeps``
    times on `prng.potts_sweep_uniforms` — bit-exact with the fused kernel
    in interpret mode.
    """
    words, t0 = _fused_prelude(key, t)
    off = jnp.asarray(replica_offset).astype(jnp.uint32).reshape(-1)[:1]
    r = states.shape[0]
    h, w = states.shape[-2], states.shape[-1]
    if not use_pallas:
        rep = off[0] + jnp.arange(r, dtype=jnp.uint32)

        def sweep(i, carry):
            s, de, na = carry
            u = _prng.potts_sweep_uniforms(
                words, t0[0] + jnp.uint32(i), rep, h, w
            )
            s, d, n = _ref.potts_sweep(s, u, betas, q=q, j=j, rule=rule)
            return s, de + d, na + n

        return jax.lax.fori_loop(
            0, n_sweeps, sweep,
            (states, jnp.zeros((r,), jnp.float32), jnp.zeros((r,), jnp.int32)),
        )
    (states,), padded_betas, r = _pad_replicas([states], betas, r_blk)
    out, de, nacc = _potts.potts_sweep_fused_pallas(
        states, words, t0, padded_betas, n_sweeps=n_sweeps, q=q,
        replica_offset=off, j=j,
        rule=rule, r_blk=r_blk, pack_bits=pack_bits, interpret=not _on_tpu(),
    )
    return out[:r], de[:r], nacc[:r]


def _round_prelude(key, t, phase, rung, energy):
    """Normalize the round-kernel inputs (words, t0, ph0, rung, energy)."""
    words, t0 = _fused_prelude(key, t)
    ph0 = jnp.asarray(phase).astype(jnp.int32).reshape(1)
    rung = jnp.asarray(rung, jnp.int32)
    energy = jnp.asarray(energy, jnp.float32)
    return words, t0, ph0, rung, energy


@partial(
    jax.jit,
    static_argnames=(
        "n_sweeps", "n_rounds", "j", "b", "rule", "criterion", "pairing",
        "pack_bits", "use_pallas",
    ),
)
def ising_round_fused(
    spins: jnp.ndarray,
    key: jnp.ndarray,
    t: jnp.ndarray,
    phase: jnp.ndarray,
    rung: jnp.ndarray,
    energy: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    n_rounds: int = 1,
    j: float = 1.0,
    b: float = 0.0,
    rule: str = "metropolis",
    criterion: str = "logistic",
    pairing: str = "deo",
    pack_bits: bool = False,
    use_pallas: bool = True,
):
    """Whole-PT-round launch: ``n_rounds`` × (``n_sweeps`` sweeps + exchange).

    The in-kernel exchange is temp-mode DEO/SEO with uniforms from the
    counter PRNG's swap stream (`prng.swap_uniforms` at the global swap
    ``phase``); ``rung``/``energy`` are the per-slot rung map and energies,
    ``betas`` the rung-ordered ladder.  The ``use_pallas=False`` pure-JAX
    reference composes `ising_sweep_fused` (reference mode) with the shared
    `exchange.exchange_step` per round — bit-exact with the kernel in
    interpret mode (tests/test_fused_round.py pins it).  Keying the swap
    stream on ``phase`` makes the trajectory invariant to ``n_rounds``
    launch grouping: K rounds in one launch ≡ K single-round launches.

    Returns ``(spins', rung', energy', n_accepted, accept, prob, attempt)``;
    diagnostics are (n_rounds, R) in `core.swap.accept_pairs` conventions
    (accept/attempt bool).
    """
    words, t0, ph0, rung, energy = _round_prelude(key, t, phase, rung, energy)
    r = spins.shape[0]
    if not use_pallas:
        na_total = jnp.zeros((r,), jnp.int32)
        acc_rows, prob_rows, att_rows = [], [], []
        for k in range(n_rounds):
            beta_slot = _kx.onehot_gather(betas, rung)
            spins, de, na = ising_sweep_fused(
                spins, key, t0[0] + jnp.uint32(k * n_sweeps), beta_slot,
                n_sweeps=n_sweeps, j=j, b=b, rule=rule, use_pallas=False,
            )
            energy = energy + de
            na_total = na_total + na
            rung, acc, prob, att, _ = _kx.exchange_step(
                rung, energy, betas, ph0[0] + jnp.int32(k), words,
                pairing=pairing, criterion=criterion,
            )
            acc_rows.append(acc)
            prob_rows.append(prob)
            att_rows.append(att)
        return (
            spins, rung, energy, na_total,
            jnp.stack(acc_rows), jnp.stack(prob_rows), jnp.stack(att_rows),
        )
    out, rung, energy, nacc, acc, prob, att = _ising.ising_round_fused_pallas(
        spins, words, t0, ph0, rung, energy, betas,
        n_sweeps=n_sweeps, n_rounds=n_rounds, j=j, b=b, rule=rule,
        criterion=criterion, pairing=pairing, pack_bits=pack_bits,
        interpret=not _on_tpu(),
    )
    return out, rung, energy, nacc, acc.astype(bool), prob, att.astype(bool)


@partial(
    jax.jit,
    static_argnames=(
        "n_sweeps", "n_rounds", "q", "j", "rule", "criterion", "pairing",
        "pack_bits", "use_pallas",
    ),
)
def potts_round_fused(
    states: jnp.ndarray,
    key: jnp.ndarray,
    t: jnp.ndarray,
    phase: jnp.ndarray,
    rung: jnp.ndarray,
    energy: jnp.ndarray,
    betas: jnp.ndarray,
    *,
    n_sweeps: int,
    q: int,
    n_rounds: int = 1,
    j: float = 1.0,
    rule: str = "metropolis",
    criterion: str = "logistic",
    pairing: str = "deo",
    pack_bits: bool = False,
    use_pallas: bool = True,
):
    """Whole-PT-round Potts launch; see `ising_round_fused` for the contract."""
    words, t0, ph0, rung, energy = _round_prelude(key, t, phase, rung, energy)
    r = states.shape[0]
    if not use_pallas:
        na_total = jnp.zeros((r,), jnp.int32)
        acc_rows, prob_rows, att_rows = [], [], []
        for k in range(n_rounds):
            beta_slot = _kx.onehot_gather(betas, rung)
            states, de, na = potts_sweep_fused(
                states, key, t0[0] + jnp.uint32(k * n_sweeps), beta_slot,
                n_sweeps=n_sweeps, q=q, j=j, rule=rule, use_pallas=False,
            )
            energy = energy + de
            na_total = na_total + na
            rung, acc, prob, att, _ = _kx.exchange_step(
                rung, energy, betas, ph0[0] + jnp.int32(k), words,
                pairing=pairing, criterion=criterion,
            )
            acc_rows.append(acc)
            prob_rows.append(prob)
            att_rows.append(att)
        return (
            states, rung, energy, na_total,
            jnp.stack(acc_rows), jnp.stack(prob_rows), jnp.stack(att_rows),
        )
    out, rung, energy, nacc, acc, prob, att = _potts.potts_round_fused_pallas(
        states, words, t0, ph0, rung, energy, betas,
        n_sweeps=n_sweeps, q=q, n_rounds=n_rounds, j=j, rule=rule,
        criterion=criterion, pairing=pairing, pack_bits=pack_bits,
        interpret=not _on_tpu(),
    )
    return out, rung, energy, nacc, acc.astype(bool), prob, att.astype(bool)


@partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def wkv6(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    initial_state: jnp.ndarray | None = None,
    *,
    chunk: int = 64,
    use_pallas: bool = True,
):
    """RWKV-6 recurrence; see `ref.wkv6` for the contract.

    Pads T to a multiple of ``chunk`` with w=1, k=0 steps (state-neutral).
    """
    if not use_pallas:
        return _ref.wkv6(r, k, v, w, u, initial_state)
    bh, t, dk = r.shape
    pad = (-t) % chunk
    if pad:
        zk = jnp.zeros((bh, pad, dk), r.dtype)
        zv = jnp.zeros((bh, pad, v.shape[-1]), v.dtype)
        r = jnp.concatenate([r, zk], axis=1)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zv], axis=1)
        w = jnp.concatenate([w, jnp.ones((bh, pad, dk), w.dtype)], axis=1)
    o, s = _wkv6.wkv6_pallas(
        r, k, v, w, u, initial_state, chunk=chunk, interpret=not _on_tpu()
    )
    return o[:, :t], s
