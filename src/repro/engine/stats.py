"""Device-side online statistics for streaming PT runs (DESIGN.md §1).

The seed driver recorded a full per-interval trace — O(intervals x R) device
memory, fetched to the host for post-hoc analysis (`repro.core.diagnostics`).
At "run as long as the hardware allows" scale that trace dominates memory, so
the engine keeps O(R) *online* accumulators on device instead and updates them
inside the compiled mega-step:

* **Welford moments** per rung (cold->hot order) for the energy and every
  registered observable — numerically stable mean/variance with a single pass.
  Records may carry an **estimator-weight channel** (``rec["est_weight"]``,
  shape ``(V, R)`` with the series values stacked ``(V, R)``): each of the
  ``V`` virtual outcomes updates the accumulator with its weight (West's
  weighted Welford).  This is how virtual-move PT (`repro.exchange.VMPT`)
  waste-recycles rejected exchanges — both outcomes of every attempted swap
  reach the estimator, weighted by the acceptance probability;
* **swap counters** per adjacent rung pair — attempts and acceptances at the
  lower rung of each pair (the same convention as
  `diagnostics.swap_acceptance_rate`), which feed the in-loop ladder
  adaptation (`repro.engine.adapt`);
* **round-trip / flow tracking** per replica slot: a replica is labelled "up"
  when it last touched the coldest rung and "down" when it last touched the
  hottest; a round trip completes when a "down" replica returns to rung 0.
  ``up_visits / labeled_visits`` per rung is the Katzgraber et al. flow
  fraction f(T) used to judge ladder quality.  (Only meaningful in ``temp``
  swap mode — in ``state`` mode rungs are pinned to slots.)

All update math runs under `jit`/`vmap`; the summaries are host-side numpy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "OnlineStats",
    "init_stats",
    "update_stats",
    "summarize",
    "combine_chains",
    "chain_slice",
    "chain_block",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OnlineStats:
    """O(R) accumulator pytree carried through the engine's scan.

    Leaves are shaped ``(R,)`` for a single chain or ``(C, R)`` with the
    ensemble axis; ``mean``/``m2`` are dicts keyed by series name ("energy"
    plus observable names), in rung order (cold->hot).
    """

    n_records: jax.Array  # i32 scalar (per chain) — records accumulated
    weight_sum: jax.Array  # (R,) f32 — total estimator weight per rung
    mean: Any  # dict[str, (R,) f32] running (weighted) mean per rung
    m2: Any  # dict[str, (R,) f32] running sum of squared deviations
    swap_attempts: jax.Array  # (R,) f32 — attempts with rung r as lower member
    swap_accepts: jax.Array  # (R,) f32 — acceptances, same convention
    direction: jax.Array  # (R,) i8 per slot: +1 up (to hot), -1 down, 0 unlabelled
    round_trips: jax.Array  # (R,) i32 per slot — completed 0 -> R-1 -> 0 cycles
    up_visits: jax.Array  # (R,) f32 — records where rung r was held "up"
    labeled_visits: jax.Array  # (R,) f32 — records where rung r was labelled


def init_stats(
    n_replicas: int, names: Sequence[str], n_chains: int = 0
) -> OnlineStats:
    """Zeroed accumulators; ``n_chains=0`` means no ensemble axis."""
    shape = (n_replicas,) if n_chains == 0 else (n_chains, n_replicas)
    scalar = () if n_chains == 0 else (n_chains,)
    f = lambda: jnp.zeros(shape, jnp.float32)
    return OnlineStats(
        n_records=jnp.zeros(scalar, jnp.int32),
        weight_sum=f(),
        mean={k: f() for k in names},
        m2={k: f() for k in names},
        swap_attempts=f(),
        swap_accepts=f(),
        direction=jnp.zeros(shape, jnp.int8),
        round_trips=jnp.zeros(shape, jnp.int32),
        up_visits=f(),
        labeled_visits=f(),
    )


def update_stats(stats: OnlineStats, rec, rung: jax.Array) -> OnlineStats:
    """Fold one per-interval record into the accumulators (device-side).

    Args:
      stats: accumulators with un-batched ``(R,)`` leaves (the engine `vmap`s
        this function over the chain axis).
      rec: the interval record — per-rung series named in ``stats.mean`` plus
        ``swap_accept``/``swap_attempt`` at the lower rung of attempted pairs.
        When the record carries ``est_weight`` (shape ``(V, R)``), the series
        are stacked virtual outcomes ``(V, R)`` and each outcome updates the
        accumulators with its weight (the VMPT waste-recycling channel).
      rung: (R,) slot -> rung map after the interval (for flow tracking).
    """
    n = stats.n_records + 1
    mean, m2 = {}, {}
    w_rec = rec.get("est_weight")
    if w_rec is None:
        # Unweighted fast path — kept textually identical to the
        # pre-weight-channel update so classical runs stay bit-equal.
        cnt = n.astype(jnp.float32)
        for k in stats.mean:
            x = rec[k].astype(jnp.float32)
            d = x - stats.mean[k]
            m = stats.mean[k] + d / cnt
            mean[k] = m
            m2[k] = stats.m2[k] + d * (x - m)
        weight_sum = stats.weight_sum + 1.0
    else:
        # West's weighted Welford, one update per virtual outcome.  All
        # series share the record's weights; per-rung weights may be zero
        # (unpaired rungs), which must leave the accumulators untouched.
        for k in stats.mean:
            m_k, m2_k = stats.mean[k], stats.m2[k]
            w_run = stats.weight_sum
            for v in range(w_rec.shape[0]):
                w = w_rec[v].astype(jnp.float32)
                x = rec[k][v].astype(jnp.float32)
                w_new = w_run + w
                d = x - m_k
                frac = jnp.where(w_new > 0, w / jnp.maximum(w_new, 1e-30), 0.0)
                m_k = m_k + d * frac
                m2_k = m2_k + w * d * (x - m_k)
                w_run = w_new
            mean[k], m2[k] = m_k, m2_k
        weight_sum = stats.weight_sum + w_rec.sum(axis=0).astype(jnp.float32)

    # Attempts come from the structural pairing mask, not `prob > 0`: the
    # acceptance probability can underflow to exactly 0 in f32 for badly
    # spaced pairs, and those must still count as (rejected) attempts or the
    # adaptive ladder would never see them.
    attempt = rec["swap_attempt"].astype(jnp.float32)
    accept = rec["swap_accept"].astype(jnp.float32)

    r = stats.direction.shape[-1]
    at_bottom = rung == 0
    at_top = rung == r - 1
    completed = at_bottom & (stats.direction == -1)
    direction = jnp.where(
        at_bottom, jnp.int8(1), jnp.where(at_top, jnp.int8(-1), stats.direction)
    )
    up = (direction == 1).astype(jnp.float32)
    labeled = (direction != 0).astype(jnp.float32)
    return OnlineStats(
        n_records=n,
        weight_sum=weight_sum,
        mean=mean,
        m2=m2,
        swap_attempts=stats.swap_attempts + attempt,
        swap_accepts=stats.swap_accepts + accept,
        direction=direction,
        round_trips=stats.round_trips + completed.astype(jnp.int32),
        up_visits=stats.up_visits.at[rung].add(up),
        labeled_visits=stats.labeled_visits.at[rung].add(labeled),
    )


# -- ensemble-slice extraction -------------------------------------------------
#
# The serving layer (repro.serve) packs many tenants' chains along the
# ensemble axis of ONE OnlineStats pytree; each tenant must read back exactly
# the accumulators a solo run of its spec would have produced.  These
# helpers carve a chain (or a contiguous block of chains) back out with the
# leaf shapes the solo run would carry, so `summarize` on the slice is
# bit-equal to the solo summary.


def _map_leaves(stats: OnlineStats, fn) -> OnlineStats:
    kw = {
        f.name: jax.tree_util.tree_map(fn, getattr(stats, f.name))
        for f in dataclasses.fields(OnlineStats)
    }
    return OnlineStats(**kw)


def chain_slice(stats: OnlineStats, index: int) -> OnlineStats:
    """Chain ``index`` of an ensemble accumulator, as un-batched ``(R,)``
    leaves — the shape a solo ``n_chains=1`` run carries."""
    return _map_leaves(stats, lambda x: x[index])


def chain_block(stats: OnlineStats, start: int, stop: int) -> OnlineStats:
    """Chains ``[start, stop)`` of an ensemble accumulator, keeping the
    ensemble axis — the shape a solo ``n_chains=stop-start`` run carries."""
    return _map_leaves(stats, lambda x: x[start:stop])


# -- host-side summaries -------------------------------------------------------


def _assemble(n, wsum, means, m2s, attempts, accepts, round_trips, up, labeled):
    """Shared summary assembly for the per-chain and chain-pooled views."""
    out: dict[str, np.ndarray] = {"n_records": n}
    # Per-rung weight totals drive the variance denominator; for classical
    # (unweighted) runs wsum == n at every rung, so this is the familiar
    # n - 1.  VMPT weights sum to 1 per record, so the same identity holds.
    # Guard explicitly at wsum <= 1 (zero/one records: variance undefined,
    # report m2 as-is) instead of max(wsum-1, 1), which also silently clamped
    # every fractional pooled weight in (1, 2) — early-run VMPT — inflating
    # the denominator and underestimating the variance there.
    denom = np.where(wsum > 1.0, wsum - 1.0, 1.0)
    for k in means:
        out[f"mean_{k}"] = means[k]
        out[f"var_{k}"] = m2s[k] / denom
    att, acc = attempts[..., :-1], accepts[..., :-1]
    out["swap_attempts"] = att
    out["swap_acceptance"] = np.where(att > 0, acc / np.maximum(att, 1.0), 0.0)
    out["round_trips"] = round_trips
    out["flow_up"] = np.where(labeled > 0, up / np.maximum(labeled, 1.0), 0.0)
    return out


def summarize(stats: OnlineStats) -> dict[str, np.ndarray]:
    """Host-side summary of the accumulators (works for (R,) and (C, R)).

    Returns ``mean_<k>``/``var_<k>`` per series (sample variance),
    ``swap_acceptance`` per adjacent pair (shape (..., R-1)), ``round_trips``
    per slot, and ``flow_up`` — the fraction of labelled visits at each rung
    that were travelling cold->hot.
    """
    f64 = lambda x: np.asarray(x, np.float64)
    return _assemble(
        f64(stats.n_records),
        f64(stats.weight_sum),
        {k: f64(v) for k, v in stats.mean.items()},
        {k: f64(v) for k, v in stats.m2.items()},
        f64(stats.swap_attempts),
        f64(stats.swap_accepts),
        np.asarray(stats.round_trips, np.int64),
        f64(stats.up_visits),
        f64(stats.labeled_visits),
    )


def combine_chains(stats: OnlineStats) -> dict[str, np.ndarray]:
    """Merge the ensemble axis into one grand summary (host-side).

    Welford states merge by Chan's parallel algorithm: counts add, means
    combine weighted, and ``m2`` gains the between-chain spread term.  Swap
    and round-trip counters simply sum (chains are independent simulations of
    the same ladder).
    """
    n_c = np.asarray(stats.n_records, np.float64)  # (C,)
    if n_c.ndim == 0:
        return summarize(stats)
    n = n_c.sum()
    ws_c = np.asarray(stats.weight_sum, np.float64)  # (C, R)
    ws = ws_c.sum(axis=0)  # (R,)
    # Per-rung chain weights must sum to exactly 1 over chains wherever any
    # weight exists: normalizing by max(ws, 1) made them sum to ws < 1 when a
    # rung's pooled estimator weight was below 1 (VMPT early in a run, where
    # per-record weights are fractional), biasing the grand mean toward zero.
    # Normalize by the true total with an explicit zero guard instead.
    w = np.divide(
        ws_c, ws, out=np.zeros_like(ws_c), where=ws > 0
    )  # (C, R) per-rung chain weights
    means, m2s = {}, {}
    for k in stats.mean:
        cm = np.asarray(stats.mean[k], np.float64)  # (C, R)
        grand = (w * cm).sum(axis=0)
        means[k] = grand
        m2s[k] = np.asarray(stats.m2[k], np.float64).sum(axis=0) + (
            ws_c * (cm - grand) ** 2
        ).sum(axis=0)
    pool = lambda x, dt=np.float64: np.asarray(x, dt).sum(axis=0)
    return _assemble(
        np.asarray(n),
        ws,
        means,
        m2s,
        pool(stats.swap_attempts),
        pool(stats.swap_accepts),
        pool(stats.round_trips, np.int64),
        pool(stats.up_visits),
        pool(stats.labeled_visits),
    )
