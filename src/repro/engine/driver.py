"""Chunked streaming PT driver: AOT mega-steps, online stats, ensembles.

The seed driver (`repro.core.pt._run_jit`) compiled one XLA program per
``n_sweeps`` value and materialized the whole O(intervals x R) trace on
device.  This engine keeps the paper's device-residency insight but
restructures the execution for unbounded runs (DESIGN.md §1):

* **chunked driver** — one "mega-step" (``chunk_intervals`` intervals of
  sweeps + swap phase + stats update) is AOT-lowered once with donated state
  buffers and called from a host loop.  Compile cost is O(1) in run length
  (at most two executables: the steady chunk and a remainder chunk) and the
  state is updated in place on device;
* **streaming statistics** — `repro.engine.stats` accumulators ride inside
  the scan, so a 10k-sweep run carries O(R) diagnostic state instead of an
  O(intervals x R) trace.  The full trace remains available as an opt-in
  (``record_trace=True``) and is streamed to host per chunk, bounding device
  memory by O(chunk_intervals x R);
* **in-loop adaptation** — betas are a *traced* engine input (a leaf of
  `EngineState`, not a static config field), so `repro.engine.adapt` can
  retune the ladder between chunks with zero recompiles;
* **ensemble axis** — the mega-step `vmap`s over ``n_chains`` independent
  chains ``(C, R, ...)``; chain ``c`` draws its PRNG stream from
  ``fold_in(key, c)`` so its results are invariant to the ensemble size;
* **explicit multi-device placement** — `EngineConfig.mesh`
  (`repro.core.distributed.MeshSpec`) runs the mega-step through an explicit
  `shard_map` over a named (``chains`` x ``replicas``) device mesh instead
  of GSPMD constraint hints.  Each device advances its local replica block
  with zero communication (fused kernels run per-shard with global-slot
  counter streams via ``replica_offset``); the exchange step is
  device-resident — only the O(R) energy/rung rows are all-gathered, the
  full-ladder swap decision is recomputed redundantly on every device from
  identical inputs, and temp-mode swaps move *no lattice state*.  That
  redundancy is what keeps the sharded mega-step bit-equal to the
  single-device path at identical seeds.

PRNG streams are identical to the seed driver (keys derive from the state's
global sweep counter), so a fixed-ladder chunked run is bit-equal to the
monolithic `repro.core.pt.run` — chunk boundaries are invisible to the chain.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed as dist_lib
from repro.core.distributed import CHAIN_AXIS, MeshSpec, REPLICA_AXIS
from repro.core.pt import PTState, init_replicas as pt_init_replicas
from repro.core.systems import System
from repro.engine import stats as stats_lib
from repro.engine.adapt import AdaptConfig, AdaptState, maybe_adapt
from repro.exchange import DEO, ExchangeStrategy, make_strategy
from repro.kernels import exchange as kernel_exchange
from repro.kernels import prng as kernel_prng

__all__ = [
    "StepSpec",
    "EngineConfig",
    "EngineState",
    "RunResult",
    "ChunkInfo",
    "AdaptInfo",
    "Engine",
    "make_interval_step",
    "make_sharded_interval_step",
]


# -- interval step: the shared physics core -----------------------------------
#
# This is the single source of truth for "one PT interval" — the monolithic
# compatibility path (`repro.core.pt.run`) and the chunked engine both build
# on it, which is what makes them bit-equal.


@dataclasses.dataclass(frozen=True)
class StepSpec:
    """Hashable static shape of one PT interval (jit-static).

    ``sweeps_per_interval`` sweeps, then one swap phase (if ``do_swap``)
    executed through ``exchange`` — the pluggable replica-exchange strategy
    (`repro.exchange`; the default `DEO` is the paper's even/odd scheme and
    is bit-equal to the pre-strategy swap path).
    """

    n_replicas: int
    sweeps_per_interval: int
    do_swap: bool = True
    criterion: str = "logistic"
    swap_mode: str = "temp"
    exchange: ExchangeStrategy = DEO()

    def __post_init__(self):
        if self.sweeps_per_interval < 1:
            raise ValueError("sweeps_per_interval must be >= 1")
        if self.swap_mode not in ("temp", "state"):
            raise ValueError(f"bad swap_mode {self.swap_mode!r}")
        if self.criterion not in ("logistic", "metropolis"):
            raise ValueError(
                f"unknown criterion {self.criterion!r}; "
                "allowed: ['logistic', 'metropolis']"
            )


def _batched_step(system: System):
    """System step batched over replicas (kernel fast-path if provided)."""
    fn = getattr(system, "batched_mcmc_step", None)
    if fn is not None:
        return fn
    return jax.vmap(system.mcmc_step)


def _batched_interval(system: System):
    """The fused whole-interval fast path, when selected by the system.

    Systems expose ``batched_mcmc_interval(key, t, states, betas, *,
    n_sweeps)`` — all ``sweeps_per_interval`` sweeps in one kernel launch
    with in-kernel counter-PRNG uniforms (`repro.kernels.prng`).  It is an
    *opt-in* (``use_fused=True``): the fused random stream cannot be
    bit-equal to the per-sweep `jax.random` stream, so the default path must
    stay bit-equal to pre-fused behaviour.  Systems without the method (or
    with fusion off) fall back to the per-sweep scan.
    """
    if not getattr(system, "use_fused", False):
        return None
    return getattr(system, "batched_mcmc_interval", None)


def _round_interval(system: System, spec: StepSpec):
    """The whole-round fused fast path, when selected by the system.

    Systems expose ``batched_mcmc_round(key, t, phase, states, rung, energy,
    betas, *, n_sweeps, criterion, pairing)`` — the interval's sweeps *plus*
    the temp-mode exchange in one kernel launch, with the swap uniforms drawn
    from the counter PRNG's swap stream (`repro.kernels.prng.swap_uniforms`)
    instead of the engine's ``fold_in(key, 2t+1)`` draw.  Opt-in via
    ``use_fused_round=True``; only the kernel-resident subset of the exchange
    zoo is supported — temp-mode DEO/SEO with swaps on (see
    `repro.kernels.exchange` for why the rest stays on the strategy path) —
    and an incompatible spec is a loud error, not a silent fallback.
    """
    if not getattr(system, "use_fused_round", False):
        return None
    fn = getattr(system, "batched_mcmc_round", None)
    if fn is None:
        return None
    pairing = getattr(spec.exchange, "name", None)
    supported = (
        spec.do_swap
        and spec.swap_mode == "temp"
        and pairing in kernel_exchange.PAIRINGS
        and spec.exchange.n_virtual == 1
    )
    if not supported:
        raise ValueError(
            "use_fused_round=True folds the exchange into the kernel and "
            "supports only temp-mode DEO/SEO with swaps on; got "
            f"do_swap={spec.do_swap}, swap_mode={spec.swap_mode!r}, "
            f"exchange={pairing!r} (n_virtual={spec.exchange.n_virtual})"
        )
    return fn


def _sweep_once(system, spec: StepSpec, betas, st: PTState, shard=None) -> PTState:
    """One parallel sweep of every replica at its current temperature."""
    r = spec.n_replicas
    # 2t/2t+1 split keeps sweep and swap key streams disjoint for any R.
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.fold_in(st.key, 2 * st.t), jnp.arange(r, dtype=jnp.uint32)
    )
    if shard is not None:
        # pin the per-replica key axis: the per-replica random lattices then
        # generate shard-local (otherwise the partitioner replicates the
        # whole PRNG stream — measured 16x redundant HBM traffic)
        keys = jax.lax.with_sharding_constraint(keys, shard)
    betas_slot = betas[st.rung]
    states, de, _ = _batched_step(system)(keys, st.states, betas_slot)
    return dataclasses.replace(
        st,
        states=states,
        energy=st.energy + de.astype(jnp.float32),
        t=st.t + 1,
    )


def _swap_decision(spec: StepSpec, betas, st: PTState):
    """Propose + accept this iteration's exchanges (no state mutation).

    Returns ``(partner, perm, diagnostics)`` — ``partner`` is the proposed
    pairing involution in rung space, ``perm`` the accepted rung permutation.
    """
    r = spec.n_replicas
    k_swap = jax.random.fold_in(st.key, 2 * st.t + 1)
    inv = jnp.argsort(st.rung)  # slot holding rung r
    e_rung = st.energy[inv]
    strat = spec.exchange
    partner = strat.propose_pairs(k_swap, st.phase, r)
    # Attempts are the structural pairing mask, NOT `prob > 0`: a badly
    # spaced pair can underflow sigmoid to exactly 0 in f32 and would
    # otherwise never register an attempt — starving the adaptive-ladder
    # feedback in precisely the case it exists to fix.
    perm, accept, prob, attempt = strat.accept(
        k_swap, partner, betas, e_rung, criterion=spec.criterion
    )
    diag = {"swap_accept": accept, "swap_prob": prob, "swap_attempt": attempt}
    return partner, perm, diag


def _apply_swap(spec: StepSpec, st: PTState, perm) -> PTState:
    """Apply an accepted rung permutation and advance the phase counter."""
    r = spec.n_replicas
    if spec.swap_mode == "temp":
        # Slot inv[r] now holds rung perm[r]; states stay in place.
        inv = jnp.argsort(st.rung)
        new_rung = jnp.zeros((r,), jnp.int32).at[inv].set(perm)
        st = dataclasses.replace(st, rung=new_rung)
    else:
        # Faithful mode: rung == slot identity; move the states themselves.
        states = jax.tree_util.tree_map(lambda x: jnp.take(x, perm, axis=0), st.states)
        st = dataclasses.replace(st, states=states, energy=st.energy[perm])
    return dataclasses.replace(st, phase=st.phase + 1)


def _swap_phase(spec: StepSpec, betas, st: PTState):
    """One parallel swap iteration; returns (state, diagnostics)."""
    _, perm, diag = _swap_decision(spec, betas, st)
    return _apply_swap(spec, st, perm), diag


def _observe(system, observables, st: PTState) -> Mapping[str, jax.Array]:
    """Per-rung diagnostics (rung order, cold->hot)."""
    inv = jnp.argsort(st.rung)
    out = {"energy": st.energy[inv]}
    for name, fn in (observables or {}).items():
        vals = jax.vmap(fn)(st.states)
        out[name] = vals[inv]
    return out


def make_interval_step(
    system: System,
    spec: StepSpec,
    observables: Mapping[str, Callable] | None = None,
    shard=None,
):
    """Build ``(PTState, betas) -> (PTState, record)`` for one interval.

    ``record`` holds per-rung arrays: ``energy``, each observable, and
    ``swap_accept``/``swap_prob`` at the lower rung of each attempted pair.
    With a waste-recycling exchange strategy (``spec.exchange.n_virtual >
    1``, e.g. `repro.exchange.VMPT`) the series are recorded *pre-swap* as
    stacked virtual outcomes ``(n_virtual, R)`` alongside an ``est_weight``
    channel — `repro.engine.stats.update_stats` folds them in with West's
    weighted Welford update.
    """
    observables = dict(observables or {})
    recycle = spec.do_swap and spec.exchange.n_virtual > 1
    fused = _batched_interval(system)
    fused_round = _round_interval(system, spec)

    def constrain(st):
        # keep the replica axis sharded through the loop — without this the
        # partitioner may replicate the whole simulation (measured: 256x
        # redundant compute on the production mesh; DESIGN.md §Perf)
        if shard is None:
            return st
        from repro.core.distributed import shard_state

        return shard_state(st, shard)

    def interval_step(st: PTState, betas):
        if fused_round is not None:
            # One launch for the whole PT round: the kernel owns the sweep
            # loop AND the temp-mode exchange (swap uniforms from the counter
            # PRNG's swap stream, keyed on st.phase), so nothing but the
            # post-round state crosses the launch boundary.
            states, rung, energy, _, acc, prob, att = fused_round(
                st.key, st.t, st.phase, st.states, st.rung, st.energy,
                betas, n_sweeps=spec.sweeps_per_interval,
                criterion=spec.criterion, pairing=spec.exchange.name,
            )
            st = constrain(dataclasses.replace(
                st,
                states=states,
                rung=rung,
                energy=energy,
                t=st.t + spec.sweeps_per_interval,
                phase=st.phase + 1,
            ))
            rec = dict(_observe(system, observables, st))
            # diag rows come back (n_rounds, R); the engine runs one round
            # per interval, so row 0 is the interval's swap diagnostics.
            rec.update({
                "swap_accept": acc[0],
                "swap_prob": prob[0],
                "swap_attempt": att[0],
            })
            return constrain(st), rec
        if fused is not None:
            # One launch for the whole interval: the kernel owns the sweep
            # loop (VMEM-resident states, in-kernel counter PRNG keyed on the
            # same (st.key, st.t) the per-sweep path derives from); the
            # driver just advances the incremental energy and the counter.
            states, de, _ = fused(
                st.key, st.t, st.states, betas[st.rung],
                n_sweeps=spec.sweeps_per_interval,
            )
            st = constrain(dataclasses.replace(
                st,
                states=states,
                energy=st.energy + de.astype(jnp.float32),
                t=st.t + spec.sweeps_per_interval,
            ))
        else:
            def sweep_body(s, _):
                return constrain(_sweep_once(system, spec, betas, s, shard)), None

            st, _ = jax.lax.scan(
                sweep_body, st, None, length=spec.sweeps_per_interval
            )
        if recycle:
            # Waste recycling: record BOTH virtual outcomes of every
            # attempted exchange (pre-swap values, rung order), weighted by
            # the acceptance probability, then apply the realized swap.
            # The chain law is untouched — only the estimator changes.
            partner, perm, swap_diag = _swap_decision(spec, betas, st)
            weights = spec.exchange.estimator_weights(
                partner, swap_diag["swap_prob"]
            )
            pre = _observe(system, observables, st)
            rec = {k: jnp.stack([v, v[partner]]) for k, v in pre.items()}
            rec["est_weight"] = weights
            st = _apply_swap(spec, st, perm)
        else:
            if spec.do_swap:
                st, swap_diag = _swap_phase(spec, betas, st)
            else:
                z = jnp.zeros((spec.n_replicas,))
                swap_diag = {
                    "swap_accept": z.astype(bool),
                    "swap_prob": z,
                    "swap_attempt": z.astype(bool),
                }
            rec = dict(_observe(system, observables, st))
        rec.update(swap_diag)
        return constrain(st), rec

    return interval_step


# -- sharded interval step: the shard_map per-device body ----------------------


def _observe_full(observables, st_local: PTState, full: PTState):
    """`_observe` on a device's full-row view of a sharded state.

    ``full`` carries the all-gathered (R,) energy/rung rows; per-replica
    observables are evaluated on the *local* lattice block and all-gathered
    as O(R) scalar rows — lattices never cross devices.
    """
    inv = jnp.argsort(full.rung)
    out = {"energy": full.energy[inv]}
    for name, fn in (observables or {}).items():
        vals = jax.lax.all_gather(
            jax.vmap(fn)(st_local.states), REPLICA_AXIS, tiled=True
        )
        out[name] = vals[inv]
    return out


def make_sharded_interval_step(
    system: System,
    spec: StepSpec,
    observables: Mapping[str, Callable] | None = None,
):
    """Per-device interval body for the `shard_map` mega-step.

    Semantics match `make_interval_step` exactly — same record contract,
    same PRNG streams — but expressed per replica shard:

    * **sweeps**: each device advances its contiguous slot block
      ``[off, off + R_local)`` with the *global* slot indices folded into the
      per-replica keys (and ``replica_offset`` into the fused kernels'
      counter PRNG), so local streams are bit-identical to the single-device
      launch;
    * **exchange (device-resident)**: one `all_gather` each of the (R,)
      energy and rung rows — O(R) scalars, the module docstring's
      O(R·L²) → O(R) reduction — then the full-ladder `_swap_decision` is
      recomputed *redundantly* on every device from identical inputs (same
      ``fold_in(key, 2t+1)`` swap key), and each device slices its block of
      the new rung assignment back out.  Temp-mode swaps therefore move no
      lattice state between devices.  DEO/SEO/windowed/VMPT all ride the
      same gathered row, differing only in how they consume it.

    Returns ``step(st_local, betas) -> (st_local, record, rung_full)`` where
    ``record`` holds full (R,) rung-ordered rows (replicated along the
    replica axis) and ``rung_full`` is the post-swap slot->rung map the
    redundant stats update keys on.
    """
    observables = dict(observables or {})
    recycle = spec.do_swap and spec.exchange.n_virtual > 1
    fused = _batched_interval(system)
    fused_round = _round_interval(system, spec)
    r = spec.n_replicas

    def gather(x):
        return jax.lax.all_gather(x, REPLICA_AXIS, tiled=True)

    def step(st: PTState, betas):
        r_local = st.energy.shape[0]
        start = jax.lax.axis_index(REPLICA_AXIS) * r_local
        offset = start.astype(jnp.uint32)
        if fused is not None:
            states, de, _ = fused(
                st.key, st.t, st.states, betas[st.rung],
                n_sweeps=spec.sweeps_per_interval, replica_offset=offset,
            )
            st = dataclasses.replace(
                st,
                states=states,
                energy=st.energy + de.astype(jnp.float32),
                t=st.t + spec.sweeps_per_interval,
            )
        else:
            def sweep_body(s, _):
                # global slot ids into fold_in: slot k's stream is invariant
                # to how the replica axis is carved up
                keys = jax.vmap(jax.random.fold_in, (None, 0))(
                    jax.random.fold_in(s.key, 2 * s.t),
                    offset + jnp.arange(r_local, dtype=jnp.uint32),
                )
                states, de, _ = _batched_step(system)(keys, s.states, betas[s.rung])
                return dataclasses.replace(
                    s,
                    states=states,
                    energy=s.energy + de.astype(jnp.float32),
                    t=s.t + 1,
                ), None

            st, _ = jax.lax.scan(
                sweep_body, st, None, length=spec.sweeps_per_interval
            )

        # device-resident exchange: gather the O(R) scalar rows, nothing else
        full = dataclasses.replace(
            st, energy=gather(st.energy), rung=gather(st.rung)
        )

        def pull_back(local: PTState, full_after: PTState) -> PTState:
            new_rung = jax.lax.dynamic_slice_in_dim(
                full_after.rung, start, r_local
            )
            local = dataclasses.replace(
                local, rung=new_rung, phase=full_after.phase
            )
            if spec.swap_mode == "state":
                # only reachable with a 1-way replica axis (Engine guards):
                # the full rows ARE the local rows, lattices moved locally
                local = dataclasses.replace(
                    local, states=full_after.states, energy=full_after.energy
                )
            return local

        if fused_round is not None:
            # The replica axis cannot be sharded *through* an exchange, so
            # the multi-device analogue of the whole-round kernel is the
            # per-shard fused sweeps above plus this device-resident exchange
            # on the gathered rows — drawn from the SAME counter-PRNG swap
            # stream the round kernel uses (`repro.kernels.exchange`), which
            # keeps a sharded ``use_fused_round`` run bit-equal to the
            # single-device whole-round launch at identical seeds.
            new_rung, acc, prob, att, _ = kernel_exchange.exchange_step(
                full.rung, full.energy, betas, st.phase,
                kernel_prng.key_words(st.key),
                pairing=spec.exchange.name, criterion=spec.criterion,
            )
            full = dataclasses.replace(
                full, rung=new_rung, phase=full.phase + 1
            )
            st = pull_back(st, full)
            rec = dict(_observe_full(observables, st, full))
            rec.update({
                "swap_accept": acc,
                "swap_prob": prob,
                "swap_attempt": att,
            })
            return st, rec, full.rung
        if recycle:
            partner, perm, swap_diag = _swap_decision(spec, betas, full)
            weights = spec.exchange.estimator_weights(
                partner, swap_diag["swap_prob"]
            )
            pre = _observe_full(observables, st, full)
            rec = {k: jnp.stack([v, v[partner]]) for k, v in pre.items()}
            rec["est_weight"] = weights
            full = _apply_swap(spec, full, perm)
            st = pull_back(st, full)
        else:
            if spec.do_swap:
                _, perm, swap_diag = _swap_decision(spec, betas, full)
                full = _apply_swap(spec, full, perm)
                st = pull_back(st, full)
            else:
                z = jnp.zeros((r,))
                swap_diag = {
                    "swap_accept": z.astype(bool),
                    "swap_prob": z,
                    "swap_attempt": z.astype(bool),
                }
            rec = dict(_observe_full(observables, st, full))
        rec.update(swap_diag)
        return st, rec, full.rung

    return step


# -- engine configuration and state -------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine configuration (ladder *values* live in `EngineState`).

    Attributes:
      n_replicas: |R| rungs per chain.
      swap_interval: sweeps between swap phases (0 disables swaps).
      criterion: "logistic" (paper) | "metropolis".
      swap_mode: "temp" (optimized) | "state" (faithful).
      chunk_intervals: intervals fused into one compiled mega-step — the
        device-memory bound for opt-in trace recording and the host-loop
        cadence for adaptation/checkpointing.
      n_chains: ensemble axis C — independent chains run per launch.
      record_trace: opt-in full per-interval trace, streamed to host each
        chunk (the seed's always-on behaviour).
      track_stats: update the O(R) online statistics inside the mega-step.
      measure_interval: record/stats cadence (sweeps) when swaps are off.
      donate: donate the state buffers to the mega-step (in-place device
        update).  Disable to re-run the same `EngineState` several times,
        e.g. benchmark timing loops.
      exchange: replica-exchange strategy — an `repro.exchange` strategy
        instance, a registered strategy name ("deo"/"seo"/"windowed"/
        "vmpt"), or None for the default `DEO` (the paper's scheme,
        bit-equal to the pre-strategy swap path).
      mesh: `repro.core.distributed.MeshSpec` (or its dict form) selecting
        the explicit shard_map mega-step over an (ensemble x replica) device
        mesh; None (default) keeps the single-device path.  Requires
        ``n_chains % mesh.ensemble == 0``, ``n_replicas % mesh.replica == 0``
        and — with ``mesh.replica > 1`` — ``swap_mode='temp'`` (state-mode
        swaps would move O(R·L²) lattice bytes per exchange).
    """

    n_replicas: int
    swap_interval: int = 100
    criterion: str = "logistic"
    swap_mode: str = "temp"
    chunk_intervals: int = 8
    n_chains: int = 1
    record_trace: bool = False
    track_stats: bool = True
    measure_interval: int = 100
    donate: bool = True
    exchange: Any = None
    mesh: Any = None

    def __post_init__(self):
        if self.chunk_intervals < 1:
            raise ValueError("chunk_intervals must be >= 1")
        if self.n_chains < 1:
            raise ValueError("n_chains must be >= 1")
        # resolve names eagerly so a bad strategy fails at config time, not
        # deep inside the first compiled chunk
        object.__setattr__(self, "exchange", make_strategy(self.exchange))
        # accept the MeshSpec's dict form (dataclasses.asdict round-trips
        # through api.spec flatten nested dataclasses into dicts)
        if isinstance(self.mesh, Mapping):
            object.__setattr__(self, "mesh", MeshSpec(**self.mesh))
        if self.mesh is not None:
            self.mesh.validate(self.n_replicas, self.n_chains)
            if self.mesh.replica > 1 and self.swap_mode != "temp":
                raise ValueError(
                    "swap_mode='state' exchanges O(R*L^2) lattice state and "
                    "is not supported across a sharded replica axis; use "
                    "swap_mode='temp' or mesh.replica=1"
                )

    @property
    def spec(self) -> StepSpec:
        interval = self.swap_interval if self.swap_interval > 0 else self.measure_interval
        return StepSpec(
            n_replicas=self.n_replicas,
            sweeps_per_interval=interval,
            do_swap=self.swap_interval > 0,
            criterion=self.criterion,
            swap_mode=self.swap_mode,
            exchange=self.exchange,
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    """Donated device-resident engine state (checkpointable pytree).

    ``pt`` leaves are ``(R, ...)`` for a single chain or ``(C, R, ...)`` with
    the ensemble axis; ``betas`` is the *shared* ladder ``(R,)`` — a traced
    input, so retuning it never recompiles the mega-step.
    """

    pt: Any  # PTState
    stats: Any  # stats_lib.OnlineStats
    betas: jax.Array  # (R,) f32, rung order cold->hot


@dataclasses.dataclass
class RunResult:
    """Host-side outcome of `Engine.run`.

    Attributes:
      summary: `stats.summarize` of the final accumulators (per chain when
        C > 1; see `stats.combine_chains` for the pooled view).  In an
        adaptive run the moment accumulators restart at every retune, so
        ``mean_*``/``var_*`` estimate the *final* ladder only — never a pool
        of samples drawn at different temperatures.
      trace: concatenated per-interval trace (numpy, interval axis first for
        C == 1, chain-first ``(C, T, R)`` otherwise) or None.
      ladder_history: (n_retunes + 1, R) temperatures, initial ladder first.
      n_sweeps: sweeps advanced by this call (per chain).  Less than the
        requested budget when an ``on_chunk`` hook stopped the run early.
      stopped_early: an ``on_chunk`` hook returned truthy — also set when
        the request landed on the final chunk (``n_sweeps`` then equals the
        full budget, but callers must still see the stop to skip later
        work).
    """

    summary: dict[str, np.ndarray]
    trace: dict[str, np.ndarray] | None
    ladder_history: np.ndarray
    n_sweeps: int
    stopped_early: bool = False


@dataclasses.dataclass
class ChunkInfo:
    """Payload handed to the ``on_chunk`` hook after each compiled chunk.

    Attributes:
      index: chunk ordinal within this `Engine.run` call (1-based).
      sweeps_done: sweeps advanced so far in this call (per chain).
      n_sweeps: the call's total sweep budget.
      state: the live `EngineState` after this chunk (device arrays).
      trace: this chunk's streamed per-interval trace (numpy) when
        ``record_trace`` is on, else None — the streaming hook point.
    """

    index: int
    sweeps_done: int
    n_sweeps: int
    state: EngineState
    trace: dict[str, np.ndarray] | None


@dataclasses.dataclass
class AdaptInfo:
    """Payload handed to the ``on_adapt`` hook after a ladder retune.

    Attributes:
      round: cumulative retune count for this engine (1-based).
      temps: the new ladder (R,), cold->hot.
      acceptance: the window feedback signal that drove the retune — per-pair
        acceptance (R-1,) in "acceptance" mode, per-rung flow fraction f(T)
        (R,) in "flow" mode.
      sweeps_done: sweeps advanced in this call when the retune fired.
    """

    round: int
    temps: np.ndarray
    acceptance: np.ndarray
    sweeps_done: int


# -- observability (obs-on runs only; see repro.obs) --------------------------


class _EngineObs:
    """Pre-resolved metric handles + timeline for an instrumented engine.

    Built once when an `repro.obs.Observability` is attached (``engine.obs =
    obs``); never constructed on the obs-off path, which is the structural
    zero-overhead contract: with ``obs=None`` the host loop performs exactly
    one ``is None`` test per site and allocates nothing.

    All series here derive from state the engine already holds on host —
    the O(R) pooled swap/flow counters, wall-clock timestamps, compile
    bookkeeping.  Nothing in this class touches device buffers beyond the
    `block_until_ready` the chunk span needs for an honest duration.
    """

    __slots__ = (
        "obs", "timeline", "compiles", "compile_seconds", "chunks", "sweeps",
        "chunk_seconds", "device_seconds", "host_seconds", "sweeps_per_sec",
        "swap_acc", "flow_up", "adapt_rounds", "checkpoints", "hbm_bytes",
        "degraded_kernel", "_last_counters",
    )

    def __init__(self, obs, system, config):
        self.obs = obs
        self.timeline = obs.timeline
        m = obs.metrics
        self.compiles = m.counter(
            "engine_compiles_total", "mega-step AOT compiles")
        self.compile_seconds = m.counter(
            "engine_compile_seconds_total", "wall seconds spent in AOT compile")
        self.chunks = m.counter(
            "engine_chunks_total", "compiled chunks executed")
        self.sweeps = m.counter(
            "engine_sweeps_total", "sweeps advanced (per chain)")
        self.chunk_seconds = m.histogram(
            "engine_chunk_seconds", "wall time per compiled chunk")
        self.device_seconds = m.counter(
            "engine_device_seconds_total",
            "wall seconds waiting on device inside chunks")
        self.host_seconds = m.counter(
            "engine_host_seconds_total",
            "host-side overhead between device launches (adapt, trace drain, "
            "checkpoint, callbacks)")
        self.sweeps_per_sec = m.gauge(
            "engine_sweeps_per_sec", "throughput of the last chunk")
        self.adapt_rounds = m.counter(
            "engine_adapt_rounds_total", "ladder retunes performed")
        self.checkpoints = m.counter(
            "engine_checkpoints_total", "engine-loop checkpoint saves")
        self.degraded_kernel = m.counter(
            "pt_degraded_kernel",
            "fused/Pallas compile failures degraded to the per-sweep path")
        # live per-rung diagnostics from the O(R) pooled counters the adapt
        # feedback already reads — label children resolved once, not per chunk
        acc = m.gauge("pt_swap_acceptance",
                      "live swap acceptance per rung pair", labels=("pair",))
        flow = m.gauge("pt_flow_up_fraction",
                       "live up-flow fraction f(k) per rung", labels=("rung",))
        self.swap_acc = [acc.labels(str(k)) for k in range(config.n_replicas - 1)]
        self.flow_up = [flow.labels(str(k)) for k in range(config.n_replicas)]
        # window deltas for the acceptance gauges: cumulative counters would
        # smear early-run transients over the whole series
        self._last_counters = None
        self.hbm_bytes = self._modeled_hbm_bytes(system, config)

    @staticmethod
    def _modeled_hbm_bytes(system, config) -> float | None:
        """Modeled HBM bytes per chunk launch (analytic sweep-kernel model).

        Best-effort: only lattice systems exposing ``length`` participate;
        anything else annotates nothing rather than a wrong number.
        """
        L = getattr(system, "length", None)
        if L is None:
            return None
        from repro.hlo.traffic import hbm_bytes_per_cell_sweep

        spi = config.spec.sweeps_per_interval
        per_cell = hbm_bytes_per_cell_sweep(
            fused=getattr(system, "use_fused", False),
            sweeps_per_interval=spi,
            rounds_per_launch=(
                config.chunk_intervals
                if getattr(system, "use_fused_round", False) else 1
            ),
            # Potts moves two random planes per sweep (proposal + accept)
            uniform_plane_bytes=16.0 if hasattr(system, "q") else 8.0,
        )
        cells = float(L) * float(L)
        sweeps = spi * config.chunk_intervals
        return per_cell * cells * sweeps * config.n_replicas * config.n_chains

    def record_chunk(self, state, *, intervals, spi, device_s, wall_s) -> None:
        """Per-chunk series: throughput, durations, live rung diagnostics."""
        sweeps = intervals * spi
        self.chunks.inc()
        self.sweeps.inc(sweeps)
        self.chunk_seconds.observe(wall_s)
        self.device_seconds.inc(device_s)
        self.host_seconds.inc(max(wall_s - device_s, 0.0))
        if wall_s > 0:
            self.sweeps_per_sec.set(sweeps / wall_s)

    def record_rungs(self, counters: dict[str, np.ndarray]) -> None:
        """Refresh the per-rung gauges from this chunk's counter deltas."""
        last = self._last_counters
        self._last_counters = counters
        if last is not None:
            att = counters["attempts"] - last["attempts"]
            acc = counters["accepts"] - last["accepts"]
        else:
            att, acc = counters["attempts"], counters["accepts"]
        for k, g in enumerate(self.swap_acc):
            if att[k] > 0:
                g.set(acc[k] / att[k])
        lab = counters["labeled"]
        up = counters["up"]
        for k, g in enumerate(self.flow_up):
            if lab[k] > 0:
                g.set(up[k] / lab[k])


# -- the engine ---------------------------------------------------------------


class Engine:
    """AOT-compiled chunked PT driver over a `System`.

    One instance owns the compiled-executable cache; `init` builds fresh
    state, `run` advances it.  The same instance can run many states (e.g.
    checkpoint restarts) as long as shapes match.
    """

    def __init__(
        self,
        system: System,
        config: EngineConfig,
        observables: Mapping[str, Callable] | None = None,
        adapt: AdaptConfig | None = None,
        obs=None,
        faults=None,
        strict_kernels: bool = False,
        on_degrade: Callable[[], Any] | None = None,
    ):
        if adapt is not None and not config.track_stats:
            raise ValueError(
                "adaptive ladders need the online swap counters: "
                "EngineConfig(track_stats=True) is required with adapt"
            )
        if adapt is not None and adapt.mode == "flow" and config.swap_mode != "temp":
            raise ValueError(
                "flow-optimized ladders consume the rung-flow diagnostic, "
                "which only exists in swap_mode='temp' (in 'state' mode "
                "rungs are pinned to slots)"
            )
        self.system = system
        self.config = config
        self.observables = dict(observables or {})
        self.adapt = adapt
        # the concrete device mesh is engine state, not config: MeshSpec is
        # pure shape (serializable through RunSpec), build() binds devices
        self._mesh = None if config.mesh is None else config.mesh.build()
        self._names = ["energy"] + sorted(self.observables)
        self._executables: dict[int, Any] = {}
        # mega-step compiles performed by this engine — the instrumentation
        # the serving layer's compile-amortization contract is asserted
        # against (repro.serve packs N tenants into one engine, so N jobs
        # must show exactly one compile here)
        self.n_compiles = 0
        # retune count for AdaptConfig.max_rounds — per Engine (i.e. per
        # ladder lifetime), not per run() call, so repeated/resumed runs
        # respect the cap cumulatively
        self._adapt_rounds = 0
        # live adaptation window (counter baselines at the last retune) —
        # persists across run() calls so the feedback window spans chunk and
        # phase boundaries, and is exported/restored through checkpoint meta
        # (repro.api.session) so a resumed run is bit-equal to an
        # uninterrupted one even mid-adapt-phase
        self._adapt_state: AdaptState | None = None
        # float64 ladder behind the f32 betas in the state: f32(1/T) is not
        # exactly invertible, so re-deriving temps from betas at run() entry
        # would feed a retune ulp-different inputs than the uninterrupted
        # host loop saw — track the authoritative f64 temps here instead
        # (restored from checkpoint meta on resume)
        self._temps: np.ndarray | None = None
        # observability handle (repro.obs.Observability) — None keeps every
        # instrumentation site down to a single `is None` test (the
        # zero-overhead-off contract pinned by tests/test_obs.py)
        self._eobs: _EngineObs | None = None
        if obs is not None:
            self.obs = obs
        # fault-injection handle (repro.resilience.FaultPlan) — same
        # zero-cost-off contract as obs: None in production, one `is None`
        # test per host-loop site, never traced into the mega-step
        self._faults = faults
        # kernel degradation policy: a failed fused/Pallas compile falls
        # back to the per-sweep path unless strict_kernels demands the
        # compile error propagate (repro run --strict-kernels)
        self.strict_kernels = strict_kernels
        self._on_degrade = on_degrade
        self._degraded = False

    @property
    def obs(self):
        """The attached `repro.obs.Observability`, or None (obs off)."""
        return self._eobs.obs if self._eobs is not None else None

    @obs.setter
    def obs(self, value):
        # metric handles resolve once here, so the host loop's obs-on path
        # is attribute access + float ops — no name lookups per chunk
        self._eobs = (
            None if value is None
            else _EngineObs(value, self.system, self.config)
        )

    # -- state construction ----------------------------------------------------
    def _init_single(self, key: jax.Array) -> PTState:
        # one chain = seed init verbatim (keeps pt-vs-engine bit-equality)
        return pt_init_replicas(self.system, self.config.n_replicas, key)

    def init(self, key: jax.Array, temps) -> EngineState:
        """Fresh engine state on the given temperature ladder.

        With an ensemble, chain ``c`` is seeded from ``fold_in(key, c)`` —
        independent of ``n_chains``, so growing the ensemble never perturbs
        existing chains.
        """
        temps = np.asarray(temps, np.float64)
        if temps.shape != (self.config.n_replicas,):
            raise ValueError(
                f"ladder shape {temps.shape} != (n_replicas={self.config.n_replicas},)"
            )
        self._temps = temps.copy()
        # a fresh state restarts the swap counters at zero — stale window
        # baselines from a previous state would starve the feedback loop
        self._adapt_state = None
        return self.place(self._fresh_state(key, temps))

    def init_ensemble(self, keys: Sequence[jax.Array], temps) -> EngineState:
        """Fresh state where chain ``c`` is seeded from ``keys[c]`` verbatim.

        This is the packing hook for `repro.serve`: a multi-tenant bucket
        hands each chain slot the exact key a *solo* ``n_chains=1`` run would
        start from (``jax.random.key(seed)``), so every packed chain's
        trajectory is bit-equal to running its spec alone.  The per-chain
        states are built one at a time and stacked — bit-equality with the
        solo `init` holds by construction, not by a vmap-equivalence
        argument.  ``len(keys)`` must equal ``config.n_chains``.
        """
        if len(keys) != self.config.n_chains:
            raise ValueError(
                f"init_ensemble got {len(keys)} keys != "
                f"n_chains={self.config.n_chains}"
            )
        temps = np.asarray(temps, np.float64)
        if temps.shape != (self.config.n_replicas,):
            raise ValueError(
                f"ladder shape {temps.shape} != (n_replicas={self.config.n_replicas},)"
            )
        self._temps = temps.copy()
        self._adapt_state = None
        c = self.config.n_chains
        per_chain = [self._init_single(k) for k in keys]
        if c == 1:
            pt_st = per_chain[0]
        else:
            pt_st = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per_chain
            )
        stats = stats_lib.init_stats(
            self.config.n_replicas, self._names, n_chains=0 if c == 1 else c
        )
        betas = jnp.asarray(1.0 / temps, jnp.float32)
        return self.place(EngineState(pt=pt_st, stats=stats, betas=betas))

    def _fresh_state(self, key: jax.Array, temps) -> EngineState:
        """`init` minus placement/host bookkeeping (eval_shape-safe)."""
        c = self.config.n_chains
        if c == 1:
            pt_st = self._init_single(key)
        else:
            keys = jax.vmap(jax.random.fold_in, (None, 0))(
                key, jnp.arange(c, dtype=jnp.uint32)
            )
            pt_st = jax.vmap(self._init_single)(keys)
        stats = stats_lib.init_stats(
            self.config.n_replicas, self._names, n_chains=0 if c == 1 else c
        )
        betas = jnp.asarray(1.0 / np.asarray(temps, np.float64), jnp.float32)
        return EngineState(pt=pt_st, stats=stats, betas=betas)

    def place(self, state: EngineState) -> EngineState:
        """Commit the state onto the mesh placement contract (DESIGN.md
        §Distributed); identity without a configured mesh.

        Placement is explicit `jax.device_put` with `NamedSharding`s — not a
        lazy constraint hint — so the AOT-lowered mega-step sees committed
        input shardings and never falls back to partitioner guessing.
        """
        if self._mesh is None:
            return state
        c = self.config.n_chains
        sh = EngineState(
            pt=dist_lib.named_shardings(
                self._mesh, dist_lib.pt_partition_specs(state.pt, c)
            ),
            stats=dist_lib.named_shardings(
                self._mesh, dist_lib.replicated_partition_specs(state.stats, c)
            ),
            betas=NamedSharding(self._mesh, P(None)),
        )
        return jax.device_put(state, sh)

    def reset_stats(self, state: EngineState) -> EngineState:
        """Zero the online accumulators (e.g. after burn-in).

        Flow labels (``direction``) are chain state, not statistics — they
        survive the reset so replicas keep their up/down identity and
        in-progress round trips complete in the new window.
        """
        c = self.config.n_chains
        stats = stats_lib.init_stats(
            self.config.n_replicas, self._names, n_chains=0 if c == 1 else c
        )
        stats = dataclasses.replace(stats, direction=state.stats.direction)
        if self._adapt_state is not None:
            # the swap counters just went back to zero — re-zero the adapt
            # window baselines with them or the window goes negative and the
            # feedback loop starves forever
            self._adapt_state.zero()
        return self.place(dataclasses.replace(state, stats=stats))

    # -- compiled mega-step ----------------------------------------------------
    def _make_mega(self, chunk_len: int, state: EngineState):
        cfg = self.config
        if self._mesh is not None:
            return self._make_mega_sharded(chunk_len, state)
        step = make_interval_step(self.system, cfg.spec, self.observables)

        def mega(pt_st, stats, betas):
            def body(carry, _):
                pt_st, stats = carry
                pt_st, rec = step(pt_st, betas)
                if cfg.track_stats:
                    stats = stats_lib.update_stats(stats, rec, pt_st.rung)
                return (pt_st, stats), (rec if cfg.record_trace else None)

            (pt_st, stats), trace = jax.lax.scan(
                body, (pt_st, stats), None, length=chunk_len
            )
            return pt_st, stats, trace

        if cfg.n_chains > 1:
            mega = jax.vmap(mega, in_axes=(0, 0, None))
        return mega

    def _make_mega_sharded(self, chunk_len: int, state: EngineState):
        """The chunk program as an explicit `shard_map` over the device mesh.

        The whole chunk scan runs inside one shard_map region, so the only
        cross-device traffic in the compiled program is the per-interval
        O(R) energy/rung/observable all-gathers (`make_sharded_interval_step`)
        — verifiable by `repro.hlo.collectives.parse_collectives` on the
        lowered text.  ``check_rep=False``: replicated outputs (stats, phase,
        t) are *computed* redundantly from identical inputs, which the static
        replication checker cannot prove.
        """
        cfg = self.config
        step = make_sharded_interval_step(self.system, cfg.spec, self.observables)

        def chain_mega(pt_st, stats, betas):
            def body(carry, _):
                pt_st, stats = carry
                pt_st, rec, rung_full = step(pt_st, betas)
                if cfg.track_stats:
                    stats = stats_lib.update_stats(stats, rec, rung_full)
                return (pt_st, stats), (rec if cfg.record_trace else None)

            (pt_st, stats), trace = jax.lax.scan(
                body, (pt_st, stats), None, length=chunk_len
            )
            return pt_st, stats, trace

        fn = chain_mega
        if cfg.n_chains > 1:
            # local chains only: the ensemble axis is carved by shard_map,
            # vmap batches over this device's C / ensemble chains
            fn = jax.vmap(chain_mega, in_axes=(0, 0, None))

        pt_specs = dist_lib.pt_partition_specs(state.pt, cfg.n_chains)
        stats_specs = dist_lib.replicated_partition_specs(state.stats, cfg.n_chains)
        trace_spec = P(CHAIN_AXIS) if cfg.n_chains > 1 else P()
        return shard_map(
            fn,
            mesh=self._mesh,
            in_specs=(pt_specs, stats_specs, P(None)),
            out_specs=(pt_specs, stats_specs, trace_spec),
            check_rep=False,
        )

    def _compiled(self, state: EngineState, chunk_len: int):
        """AOT executable for a chunk of ``chunk_len`` intervals.

        At most two entries ever exist per run length pattern (steady chunk +
        remainder), so compile cost is O(1) in total sweeps.  State buffers
        are donated: the engine updates in place, betas stay reusable.
        """
        exe = self._executables.get(chunk_len)
        if exe is None:
            eo = self._eobs
            t0 = time.perf_counter() if eo is not None else 0.0
            sds = lambda tree: jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=getattr(x, "sharding", None)), tree
            )
            donate = (0, 1) if self.config.donate else ()
            # mega construction stays OUTSIDE the try: unsupported-spec
            # errors (e.g. the fused-round preconditions in _round_interval)
            # are configuration mistakes and must stay loud, never silently
            # degraded
            jitted = jax.jit(self._make_mega(chunk_len, state), donate_argnums=donate)
            try:
                if self._faults is not None:
                    self._faults.fire("engine.compile")
                exe = jitted.lower(
                    sds(state.pt), sds(state.stats), sds(state.betas)
                ).compile()
            except Exception as err:
                return self._degrade(err, state, chunk_len)
            self._executables[chunk_len] = exe
            self.n_compiles += 1
            if eo is not None:
                dt = time.perf_counter() - t0
                eo.compiles.inc()
                eo.compile_seconds.inc(dt)
                eo.timeline.complete(
                    "compile", t0, dt, cat="compile",
                    args={"chunk_intervals": chunk_len,
                          "n_replicas": self.config.n_replicas,
                          "n_chains": self.config.n_chains},
                )
        return exe

    def _degrade(self, err: Exception, state: EngineState, chunk_len: int):
        """Graceful kernel degradation: recompile on the per-sweep path.

        A fused-round / interval-fused / Pallas compile failure (a backend
        without Mosaic support, a VMEM overflow at an untested shape, an
        injected ``engine.compile`` fault) falls back to the plain per-sweep
        XLA path — statistically identical results (the fused counter-PRNG
        stream was never bit-equal to per-sweep anyway; the degraded run IS
        bit-equal to a never-fused run of the same spec).  ``strict_kernels``
        turns the fallback into a loud error; systems with no kernel flags
        set have nothing to fall back to, so their compile errors always
        propagate (the serve Supervisor treats those as transient).
        """
        flags = [
            f for f in ("use_fused_round", "use_fused", "use_pallas",
                        "pack_bits")
            if getattr(self.system, f, False)
        ]
        if self.strict_kernels or not flags or self._degraded:
            raise err
        self._degraded = True
        warnings.warn(
            f"mega-step compile failed with {', '.join(flags)} enabled "
            f"({err!r}); degrading to the per-sweep path (statistically "
            "identical, not bit-equal to the fused stream).  Pass "
            "strict_kernels to make this fatal.",
            RuntimeWarning,
            stacklevel=3,
        )
        self.system = dataclasses.replace(
            self.system, **{f: False for f in flags}
        )
        self._executables.clear()
        if self._eobs is not None:
            self._eobs.degraded_kernel.inc()
        if self._on_degrade is not None:
            self._on_degrade()
        return self._compiled(state, chunk_len)

    # -- the host loop ---------------------------------------------------------
    def run(
        self,
        state: EngineState,
        n_sweeps: int,
        *,
        checkpoint=None,
        checkpoint_every_chunks: int = 0,
        on_chunk: Callable[[ChunkInfo], Any] | None = None,
        on_adapt: Callable[[AdaptInfo], Any] | None = None,
        keep_trace: bool = True,
    ) -> tuple[EngineState, RunResult]:
        """Advance ``n_sweeps`` sweeps (per chain) through compiled chunks.

        Between chunks the host loop (a) streams the opt-in trace out,
        (b) feeds measured swap acceptance to the ladder feedback when
        ``adapt`` is configured (re-entering the same executable with retuned
        betas), and (c) checkpoints the whole `EngineState` every
        ``checkpoint_every_chunks`` chunks via ``checkpoint`` (a
        `repro.checkpoint.manager.CheckpointManager`).

        ``on_chunk`` / ``on_adapt`` are the host-loop hook points the
        `repro.api.Session` callback pipeline rides on: ``on_chunk(info)``
        fires after every compiled chunk (checkpoint included) and may return
        truthy to stop the run early (``RunResult.stopped_early``);
        ``on_adapt(info)`` fires after each ladder retune.

        ``keep_trace=False`` (with ``record_trace`` on) hands each chunk's
        trace to ``on_chunk`` but does *not* accumulate it for
        ``RunResult.trace`` — host memory stays O(chunk) when a streaming
        consumer (e.g. `repro.api.TraceWriterCallback`) owns the trace.

        ``n_sweeps`` must be a multiple of the interval length
        (``swap_interval``, or ``measure_interval`` when swaps are off).
        """
        spi = self.config.spec.sweeps_per_interval
        if n_sweeps % spi != 0:
            raise ValueError(
                f"n_sweeps={n_sweeps} not a multiple of the interval ({spi} sweeps)"
            )
        n_intervals = n_sweeps // spi
        many = self.config.n_chains > 1
        # commit placement before the first donated call: an externally
        # built/restored state may still live on the default device
        state = self.place(state)
        temps = self._temps
        if temps is None or not np.array_equal(
            np.asarray(state.betas), (1.0 / temps).astype(np.float32)
        ):
            # unknown or different state (e.g. a fresh init on this engine):
            # fall back to inverting the f32 betas
            temps = 1.0 / np.asarray(state.betas, np.float64)
        ladder_history = [temps.astype(np.float32)]
        adapt_st = self._adapt_state
        if adapt_st is None:
            adapt_st = AdaptState.fresh(self.config.n_replicas)
            if self.adapt is not None:
                # First adaptive window of this engine: baselines start at
                # the *current* counters, so a raw restored state doesn't
                # double-count pre-checkpoint attempts.  From then on the
                # window persists across run() calls (baselines move only at
                # retunes / stats resets).
                adapt_st.rebase(self._pooled_counters(state))
        # the retune count carries across run() calls (max_rounds is per
        # ladder lifetime)
        adapt_st.rounds = self._adapt_rounds
        if self.adapt is not None:
            self._adapt_state = adapt_st
        chunks: list[dict[str, np.ndarray]] = []

        done = 0
        chunk_idx = 0
        stopped = False
        eo = self._eobs
        while done < n_intervals:
            this = min(self.config.chunk_intervals, n_intervals - done)
            if self._faults is not None:
                f = self._faults.check("engine.chunk.stall")
                if f is not None:
                    time.sleep(f.duration)
                self._faults.fire("engine.chunk.launch")
            if eo is not None:
                # instrumented launch: same executable, plus wall/device
                # timing and the one-shot jax.profiler window if armed.  The
                # block_until_ready makes the device-wait span honest; its
                # cost is covered by the <5% obs-on budget and never paid
                # when obs is off.
                t_chunk0 = time.perf_counter()
                exe = self._compiled(state, this)
                profiling = eo.obs.start_jax_profile()
                t_launch = time.perf_counter()
                pt_st, stats, trace = exe(state.pt, state.stats, state.betas)
                jax.block_until_ready(pt_st)
                device_s = time.perf_counter() - t_launch
                if profiling:
                    eo.obs.stop_jax_profile()
            else:
                pt_st, stats, trace = self._compiled(state, this)(
                    state.pt, state.stats, state.betas
                )
            state = EngineState(pt=pt_st, stats=stats, betas=state.betas)
            if self._faults is not None:
                f = self._faults.check("engine.energy.nonfinite")
                if f is not None:
                    # a failing device lane: poison one chain's energies on
                    # host (chains are independent — NaN never crosses the
                    # ensemble axis, so only the owning tenant is affected)
                    e = np.asarray(state.pt.energy).copy()
                    if e.ndim == 2:
                        e[f.chain % e.shape[0]] = np.nan
                    else:
                        e[:] = np.nan
                    state = self.place(dataclasses.replace(
                        state,
                        pt=dataclasses.replace(
                            state.pt, energy=jnp.asarray(e, state.pt.energy.dtype)
                        ),
                    ))
            done += this
            chunk_idx += 1
            if eo is not None:
                eo.timeline.complete(
                    "device_wait", t_launch, device_s, cat="engine",
                    args={"chunk": chunk_idx, "intervals": this},
                )
            chunk_np = None
            if self.config.record_trace:
                if eo is not None:
                    with eo.timeline.span("trace_drain", chunk=chunk_idx):
                        chunk_np = {k: np.asarray(v) for k, v in trace.items()}
                else:
                    chunk_np = {k: np.asarray(v) for k, v in trace.items()}
                if keep_trace:
                    chunks.append(chunk_np)
            if self.adapt is not None and done < n_intervals:
                t_adapt0 = time.perf_counter() if eo is not None else 0.0
                new_temps, acceptance = maybe_adapt(
                    temps, self._pooled_counters(state), self.adapt, adapt_st
                )
                if new_temps is not None:
                    temps = np.asarray(new_temps, np.float64)
                    self._temps = temps
                    ladder_history.append(temps.astype(np.float32))
                    self._adapt_rounds = adapt_st.rounds
                    # Restart the moment accumulators: per-rung means/vars
                    # must not pool samples drawn at two different ladders
                    # (swap counters stay — the adapt window is baselined,
                    # and flow/round-trip labels are chain state).  The
                    # weight totals are part of the moment state — a stale
                    # weight_sum would deflate post-retune variances and
                    # freeze the weighted (VMPT) mean updates.
                    zeros = lambda tree: jax.tree_util.tree_map(
                        jnp.zeros_like, tree
                    )
                    stats = dataclasses.replace(
                        state.stats,
                        n_records=zeros(state.stats.n_records),
                        weight_sum=zeros(state.stats.weight_sum),
                        mean=zeros(state.stats.mean),
                        m2=zeros(state.stats.m2),
                    )
                    state = self.place(dataclasses.replace(
                        state,
                        stats=stats,
                        betas=jnp.asarray(1.0 / temps, jnp.float32),
                    ))
                    if on_adapt is not None:
                        on_adapt(AdaptInfo(
                            round=adapt_st.rounds,
                            temps=temps.astype(np.float32).copy(),
                            acceptance=np.asarray(acceptance, np.float64),
                            sweeps_done=done * spi,
                        ))
                if eo is not None:
                    eo.timeline.complete(
                        "adapt", t_adapt0, time.perf_counter() - t_adapt0,
                        cat="engine",
                        args={"retuned": new_temps is not None,
                              "round": adapt_st.rounds},
                    )
                    if new_temps is not None:
                        eo.adapt_rounds.inc()
            if (
                checkpoint is not None
                and checkpoint_every_chunks > 0
                and (chunk_idx % checkpoint_every_chunks == 0 or done == n_intervals)
            ):
                sweep = int(np.asarray(pt_st.t).reshape(-1)[0])
                # same meta contract as repro.api.CheckpointCallback: the
                # exact f64 ladder plus the adaptation bookkeeping, so
                # either checkpoint path resumes bit-equal
                meta = {
                    "temps": [float(t) for t in temps],
                    "adapt_rounds": self._adapt_rounds,
                }
                if self._adapt_state is not None:
                    meta.update(self._adapt_state.to_meta())
                if eo is not None:
                    with eo.timeline.span("checkpoint", sweep=sweep):
                        checkpoint.save(sweep, state, meta=meta)
                    eo.checkpoints.inc()
                else:
                    checkpoint.save(sweep, state, meta=meta)
            if eo is not None:
                wall = time.perf_counter() - t_chunk0
                args = {"chunk": chunk_idx, "intervals": this,
                        "sweeps_done": done * spi}
                if eo.hbm_bytes is not None:
                    args["modeled_hbm_bytes"] = (
                        eo.hbm_bytes * this / self.config.chunk_intervals
                    )
                eo.timeline.complete("chunk", t_chunk0, wall,
                                     cat="engine", args=args)
                eo.record_chunk(state, intervals=this, spi=spi,
                                device_s=device_s, wall_s=wall)
                if self.config.track_stats:
                    eo.record_rungs(self._pooled_counters(state))
            if on_chunk is not None:
                info = ChunkInfo(
                    index=chunk_idx,
                    sweeps_done=done * spi,
                    n_sweeps=n_sweeps,
                    state=state,
                    trace=chunk_np,
                )
                if on_chunk(info):
                    # a stop request on the final chunk still counts: the
                    # caller (e.g. Session) must see it to skip later phases
                    stopped = True
                    break

        trace_out = None
        if chunks:
            axis = 1 if many else 0
            trace_out = {
                k: np.concatenate([c[k] for c in chunks], axis=axis)
                for k in chunks[0]
            }
        result = RunResult(
            summary=stats_lib.summarize(state.stats),
            trace=trace_out,
            ladder_history=np.stack(ladder_history),
            n_sweeps=done * spi,
            stopped_early=stopped,
        )
        return state, result

    def _pooled_counters(self, state: EngineState) -> dict[str, np.ndarray]:
        """Feedback counters pooled over the ensemble axis (host numpy).

        Returns the cumulative per-rung ``attempts``/``accepts`` swap
        counters and ``up``/``labeled`` flow-visit counters the two adapt
        modes consume (`repro.engine.adapt.maybe_adapt`).
        """
        out = {}
        for name, leaf in (
            ("attempts", state.stats.swap_attempts),
            ("accepts", state.stats.swap_accepts),
            ("up", state.stats.up_visits),
            ("labeled", state.stats.labeled_visits),
        ):
            arr = np.asarray(leaf, np.float64)
            out[name] = arr.sum(axis=0) if arr.ndim == 2 else arr
        return out

    # -- checkpoint integration ------------------------------------------------
    def restore(self, checkpoint):
        """Resume the latest engine checkpoint (or None if none exists).

        The shape template is built abstractly (`eval_shape` — no system
        init or energy evaluation runs) and every leaf is overwritten by the
        restored arrays.  Returns ``(EngineState, meta)`` with betas exactly
        as saved (including any mid-run adaptation).
        """
        temps = np.full((self.config.n_replicas,), 1.0, np.float32)
        shapes = jax.eval_shape(
            lambda k: self._fresh_state(k, temps), jax.random.key(0)
        )

        def materialize(s):
            if jax.dtypes.issubdtype(s.dtype, jax.dtypes.prng_key):
                return jnp.broadcast_to(jax.random.key(0), s.shape)
            return jnp.zeros(s.shape, s.dtype)

        template = jax.tree_util.tree_map(materialize, shapes)
        out = checkpoint.restore_latest(template)
        if out is None:
            return None
        state, meta = out
        # checkpoints are mesh-shape independent (gathered numpy on save);
        # re-commit onto THIS engine's placement, whatever mesh wrote them
        return self.place(state), meta
