"""Chunked streaming PT engine (DESIGN.md §1).

The engine layer sits between the physics core (`repro.core`) and everything
that runs long simulations (benchmarks, examples, launch, checkpointing):

* `repro.engine.driver` — AOT-compiled chunked mega-step driver with an
  ensemble (many-chain) axis and O(1) compile cost for arbitrarily long runs;
* `repro.engine.stats`  — device-side online statistics (Welford moments,
  swap-acceptance counters, round-trip tracking): O(R) state instead of the
  O(intervals x R) trace;
* `repro.engine.adapt`  — in-loop adaptive temperature ladders fed by the
  measured acceptance between chunks.
"""
from repro.engine.adapt import AdaptConfig
from repro.engine.driver import (
    AdaptInfo,
    ChunkInfo,
    Engine,
    EngineConfig,
    EngineState,
    RunResult,
    StepSpec,
)
from repro.engine.stats import (
    OnlineStats,
    chain_block,
    chain_slice,
    combine_chains,
    init_stats,
    summarize,
    update_stats,
)

__all__ = [
    "AdaptConfig",
    "AdaptInfo",
    "ChunkInfo",
    "Engine",
    "EngineConfig",
    "EngineState",
    "OnlineStats",
    "RunResult",
    "StepSpec",
    "chain_block",
    "chain_slice",
    "combine_chains",
    "init_stats",
    "summarize",
    "update_stats",
]
