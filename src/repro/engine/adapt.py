"""In-loop adaptive temperature ladders (DESIGN.md §1).

The seed only exposed `ladder.tune_ladder` as an offline utility: run, fetch
the whole trace, measure acceptance, retune, recompile, rerun.  The engine
closes the loop *during* a run: between compiled chunks it reads the O(R)
device-side swap counters (`repro.engine.stats`), computes the per-pair
acceptance over the window since the last retune, and feeds it to
`ladder.tune_ladder` (Kofke-style acceptance equalization; Earl & Deem,
physics/0508111, survey the family).  Because the engine treats betas as a
*traced* input of the mega-step — not a static config field — retuning re-uses
the already-compiled executable: zero recompiles per adaptation.

Acceptance is pooled across the ensemble axis when present (all chains share
one ladder), which multiplies the feedback signal per wall-clock chunk.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ladder as ladder_lib

__all__ = ["AdaptConfig", "AdaptState", "maybe_adapt"]


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Feedback-loop configuration.

    Attributes:
      target: desired uniform per-pair swap acceptance.
      rate: feedback gain in log-spacing space (see `ladder.tune_ladder`).
      min_attempts_per_pair: don't retune until every adjacent pair has at
        least this many attempts in the current window (pooled over chains) —
        low-count acceptance estimates are too noisy to act on.
      max_rounds: stop adapting after this many retunes, cumulative over the
        engine's lifetime — repeated/resumed ``run()`` calls share the cap
        (None = never stop).

    The cold/hot endpoints of the ladder are always pinned: feedback only
    redistributes the interior rungs (`ladder.tune_ladder` rescales to the
    endpoints unconditionally, so the temperature *range* is a modelling
    choice made at `Engine.init`, not something the feedback loop drifts).
    """

    target: float = 0.23
    rate: float = 0.5
    min_attempts_per_pair: int = 20
    max_rounds: int | None = None


@dataclasses.dataclass
class AdaptState:
    """Host-side bookkeeping between chunks (window baselines + history)."""

    attempts_base: np.ndarray  # (R,) counter snapshot at the last retune
    accepts_base: np.ndarray
    rounds: int = 0

    @classmethod
    def fresh(cls, n_replicas: int) -> "AdaptState":
        z = np.zeros((n_replicas,), np.float64)
        return cls(attempts_base=z, accepts_base=z.copy())


def maybe_adapt(
    temps: np.ndarray,
    attempts: np.ndarray,
    accepts: np.ndarray,
    adapt: AdaptConfig,
    st: AdaptState,
):
    """One feedback step if the window has enough signal.

    Args:
      temps: current ladder (R,), cold->hot.
      attempts/accepts: *cumulative* per-rung counters (chain-pooled: callers
        sum the ensemble axis first), lower-rung convention.
      adapt: feedback configuration.
      st: mutable window bookkeeping (updated in place on retune).

    Returns:
      (new_temps, window_acceptance) — both None when the window was too
      thin or ``max_rounds`` was reached.
    """
    if adapt.max_rounds is not None and st.rounds >= adapt.max_rounds:
        return None, None
    attempts = np.asarray(attempts, np.float64)
    accepts = np.asarray(accepts, np.float64)
    w_att = (attempts - st.attempts_base)[:-1]  # last rung is never "lower"
    w_acc = (accepts - st.accepts_base)[:-1]
    if w_att.min() < adapt.min_attempts_per_pair:
        return None, None
    acceptance = w_acc / np.maximum(w_att, 1.0)
    new_temps = ladder_lib.tune_ladder(
        np.asarray(temps),
        acceptance,
        target=adapt.target,
        rate=adapt.rate,
        t_min=float(temps[0]),
        t_max=float(temps[-1]),
    )
    st.attempts_base = attempts
    st.accepts_base = accepts
    st.rounds += 1
    return new_temps, acceptance
