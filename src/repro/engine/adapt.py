"""In-loop adaptive temperature ladders (DESIGN.md §1).

The seed only exposed `ladder.tune_ladder` as an offline utility: run, fetch
the whole trace, measure acceptance, retune, recompile, rerun.  The engine
closes the loop *during* a run: between compiled chunks it reads the O(R)
device-side counters (`repro.engine.stats`), computes the feedback signal
over the window since the last retune, and retunes.  Because the engine
treats betas as a *traced* input of the mega-step — not a static config
field — retuning re-uses the already-compiled executable: zero recompiles
per adaptation.

Two feedback modes:

* ``acceptance`` (default) — Kofke-style acceptance equalization via
  `ladder.tune_ladder`: per-pair swap acceptance is pushed toward a uniform
  target (Earl & Deem, physics/0508111, survey the family).
* ``flow`` — Katzgraber et al. feedback optimization: the ladder is
  re-spaced from the measured replica *flow fraction* ``f(T)`` (the
  ``flow_up`` diagnostic the stats layer has tracked all along — fraction of
  labelled visits at each rung travelling cold→hot).  The optimal rung
  density is ``η(T) ∝ sqrt(|df/dT|)``, which concentrates rungs at the
  mixing bottleneck and maximizes the round-trip rate — the
  accuracy-per-FLOP objective acceptance equalization only proxies.

Feedback signals are pooled across the ensemble axis when present (all
chains share one ladder), which multiplies the signal per wall-clock chunk.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import ladder as ladder_lib

__all__ = [
    "AdaptConfig",
    "AdaptState",
    "flow_optimized_ladder",
    "maybe_adapt",
]

ADAPT_MODES = ("acceptance", "flow")


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Feedback-loop configuration.

    Attributes:
      target: desired uniform per-pair swap acceptance (``acceptance`` mode).
      rate: feedback gain — log-spacing gain for ``acceptance`` (see
        `ladder.tune_ladder`), log-space blend toward the flow-optimal
        ladder for ``flow`` (1.0 = jump straight to it).
      min_attempts_per_pair: don't retune until every adjacent pair has at
        least this many attempts in the current window (pooled over chains) —
        low-count acceptance estimates are too noisy to act on.
      max_rounds: stop adapting after this many retunes, cumulative over the
        engine's lifetime — repeated/resumed ``run()`` calls share the cap
        (None = never stop).
      mode: "acceptance" (Kofke equalization) | "flow" (Katzgraber
        feedback-optimized; consumes the ``flow_up`` round-trip diagnostic,
        so it needs ``swap_mode="temp"`` where rung flow is meaningful).
      flow_min_visits: ``flow`` mode's window gate — every rung needs at
        least this many *labelled* visits (pooled over chains) before the
        measured f(T) is trusted.

    The cold/hot endpoints of the ladder are always pinned: feedback only
    redistributes the interior rungs, so the temperature *range* is a
    modelling choice made at `Engine.init`, not something the feedback loop
    drifts.
    """

    target: float = 0.23
    rate: float = 0.5
    min_attempts_per_pair: int = 20
    max_rounds: int | None = None
    mode: str = "acceptance"
    flow_min_visits: int = 100

    def __post_init__(self):
        if self.mode not in ADAPT_MODES:
            raise ValueError(
                f"unknown adapt mode {self.mode!r}; allowed: {list(ADAPT_MODES)}"
            )


@dataclasses.dataclass
class AdaptState:
    """Host-side bookkeeping between chunks (window baselines + history).

    Baselines snapshot the cumulative device counters at the last retune, so
    each feedback step sees only its own window.  All four ride in the
    checkpoint step meta so a resumed run re-enters the same window.
    """

    attempts_base: np.ndarray  # (R,) counter snapshot at the last retune
    accepts_base: np.ndarray
    up_base: np.ndarray  # (R,) flow-counter snapshots ("flow" mode window)
    labeled_base: np.ndarray
    rounds: int = 0

    @classmethod
    def fresh(cls, n_replicas: int) -> "AdaptState":
        z = np.zeros((n_replicas,), np.float64)
        return cls(
            attempts_base=z,
            accepts_base=z.copy(),
            up_base=z.copy(),
            labeled_base=z.copy(),
        )

    def rebase(self, counters: dict[str, np.ndarray]) -> None:
        """Move every window baseline to the given cumulative counters."""
        self.attempts_base = np.asarray(counters["attempts"], np.float64)
        self.accepts_base = np.asarray(counters["accepts"], np.float64)
        self.up_base = np.asarray(counters["up"], np.float64)
        self.labeled_base = np.asarray(counters["labeled"], np.float64)

    def to_meta(self) -> dict:
        """JSON-able checkpoint form — the single serialization of the
        window baselines, shared by every checkpoint writer."""
        return {
            "adapt_attempts_base": self.attempts_base.tolist(),
            "adapt_accepts_base": self.accepts_base.tolist(),
            "adapt_up_base": self.up_base.tolist(),
            "adapt_labeled_base": self.labeled_base.tolist(),
        }

    @classmethod
    def from_meta(cls, meta: dict, rounds: int = 0) -> "AdaptState | None":
        """Rebuild from checkpoint meta (None when no baselines were saved).

        Flow baselines default to zeros for pre-flow-mode checkpoints,
        where zeros reproduce the old behaviour exactly.
        """
        if "adapt_attempts_base" not in meta:
            return None
        attempts = np.asarray(meta["adapt_attempts_base"], np.float64)
        zeros = np.zeros_like(attempts)
        return cls(
            attempts_base=attempts,
            accepts_base=np.asarray(meta["adapt_accepts_base"], np.float64),
            up_base=np.asarray(meta.get("adapt_up_base", zeros), np.float64),
            labeled_base=np.asarray(
                meta.get("adapt_labeled_base", zeros), np.float64
            ),
            rounds=rounds,
        )

    def zero(self) -> None:
        """Re-zero all baselines (after a stats reset zeroed the counters)."""
        z = np.zeros_like(self.attempts_base)
        self.attempts_base = z
        self.accepts_base = z.copy()
        self.up_base = z.copy()
        self.labeled_base = z.copy()


def flow_optimized_ladder(
    temps: np.ndarray, flow_up: np.ndarray, rate: float = 1.0
) -> np.ndarray:
    """One Katzgraber feedback-optimization step from the measured flow f(T).

    The measured fraction of "up"-labelled visits per rung is forced to the
    boundary values (f = 1 cold, 0 hot) and monotonicity the method assumes,
    the optimal rung density ``η ∝ sqrt(Δf/ΔT)`` is integrated, and the new
    rungs are placed at equal quantiles of that integral — so temperatures
    crowd where the flow drops fastest (the round-trip bottleneck).
    ``rate`` blends old → optimal in log-temperature space; endpoints stay
    pinned exactly.
    """
    temps = np.asarray(temps, np.float64)
    f = np.asarray(flow_up, np.float64).copy()
    r = temps.shape[0]
    if f.shape != (r,):
        raise ValueError(f"flow_up shape {f.shape} != temps shape {(r,)}")
    f[0], f[-1] = 1.0, 0.0
    f = np.minimum.accumulate(f)  # enforce the non-increasing profile
    # per-gap drop, floored so η stays positive (flat windows would
    # otherwise collapse rungs onto each other)
    df = np.maximum(f[:-1] - f[1:], 1e-6)
    # Gap floor: a previous aggressive retune (rate=1.0 over a flat flow
    # profile) can leave two interior rungs (near-)coincident; an unfloored
    # d_t then makes η inf/NaN, which cum-normalization propagates into every
    # rung — and the poisoned betas are *traced* engine inputs, so the whole
    # rest of the run silently samples garbage.  η·d_t = sqrt(df·d_t) stays
    # finite (and ~0) for a degenerate gap, which is the right weight: a
    # zero-width gap should attract no rung density.
    d_t = np.maximum(np.diff(temps), 1e-12)
    eta = np.sqrt(df / d_t)
    cum = np.concatenate([[0.0], np.cumsum(eta * d_t)])
    total = cum[-1]
    if not np.isfinite(total) or total <= 0.0:
        # Fully degenerate ladder (all gaps collapsed): no usable density
        # signal — keep the current ladder rather than dividing by zero.
        return temps.astype(np.float32)
    cum /= total
    optimal = np.interp(np.linspace(0.0, 1.0, r), cum, temps)
    new = np.exp((1.0 - rate) * np.log(temps) + rate * np.log(optimal))
    new[0], new[-1] = temps[0], temps[-1]
    return new.astype(np.float32)


def maybe_adapt(
    temps: np.ndarray,
    counters: dict[str, np.ndarray],
    adapt: AdaptConfig,
    st: AdaptState,
):
    """One feedback step if the current window has enough signal.

    Args:
      temps: current ladder (R,), cold->hot.
      counters: *cumulative* chain-pooled per-rung counters from the stats
        layer — ``attempts``/``accepts`` (lower-rung convention) and
        ``up``/``labeled`` (flow visits).  Callers sum the ensemble axis
        first (`Engine._pooled_counters`).
      adapt: feedback configuration (mode selects the signal consumed).
      st: mutable window bookkeeping (rebased in place on retune).

    Returns:
      ``(new_temps, feedback)`` — ``feedback`` is the window's per-pair
      acceptance (R-1,) in ``acceptance`` mode or the window's flow fraction
      f(T) (R,) in ``flow`` mode; both are None when the window was too thin
      or ``max_rounds`` was reached.
    """
    if adapt.max_rounds is not None and st.rounds >= adapt.max_rounds:
        return None, None
    if adapt.mode == "flow":
        up = np.asarray(counters["up"], np.float64)
        labeled = np.asarray(counters["labeled"], np.float64)
        w_lab = labeled - st.labeled_base
        if w_lab.min() < adapt.flow_min_visits:
            return None, None
        feedback = (up - st.up_base) / np.maximum(w_lab, 1.0)
        new_temps = flow_optimized_ladder(temps, feedback, rate=adapt.rate)
    else:
        attempts = np.asarray(counters["attempts"], np.float64)
        accepts = np.asarray(counters["accepts"], np.float64)
        w_att = (attempts - st.attempts_base)[:-1]  # last rung never "lower"
        if w_att.min() < adapt.min_attempts_per_pair:
            return None, None
        w_acc = (accepts - st.accepts_base)[:-1]
        feedback = w_acc / np.maximum(w_att, 1.0)
        new_temps = ladder_lib.tune_ladder(
            np.asarray(temps),
            feedback,
            target=adapt.target,
            rate=adapt.rate,
            t_min=float(temps[0]),
            t_max=float(temps[-1]),
        )
    st.rebase(counters)
    st.rounds += 1
    return new_temps, feedback
