"""Sharding policy: logical-rule PartitionSpecs with divisibility fallback.

Rules are keyed on the trailing parameter-path component (the weight's role),
then validated against the actual mesh: any sharded dim that does not divide
by its mesh axes is dropped to replication (e.g. Mixtral's 8 experts cannot
take EP over a 16-way model axis, so expert weights fall back from
P('model',None,None) to the intra-expert TP alternative P(None,None,'model')).

Stacked scan groups ("groups" in the path) get a leading None prepended.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

# rule: name -> list of candidate dim-spec tuples, first fitting one wins.
# 'B' is replaced by the mesh's batch axes; 'M' by the model axis.
_RULES: dict[str, list[tuple]] = {
    # embeddings
    "embed": [("M", None)],
    "unembed": [(None, "M")],
    # attention
    "wq": [(None, "M", None), ("M", None, None)],
    "wk": [(None, "M", None), ("M", None, None)],
    "wv": [(None, "M", None), ("M", None, None)],
    "wo": [("M", None, None), (None, None, "M")],
    # dense ffn (2-D) and moe experts (3-D share the names)
    "w_gate": [(None, "M"), ("M", None, None), (None, None, "M")],
    "w_up": [(None, "M"), ("M", None, None), (None, None, "M")],
    "w_down": [("M", None), ("M", None, None), (None, "M", None)],
    "router": [(None, None)],
    # rglru
    "w_x": [(None, "M")],
    "w_gmlp": [(None, "M")],
    "conv_w": [(None, "M")],
    "w_r": [(None, "M")],
    "w_i": [(None, "M")],
    "w_out": [("M", None)],
    # rwkv time-mix
    "w_k": [(None, "M")],
    "w_v": [(None, "M"), ("M", None)],
    "w_g": [(None, "M")],
    "w_o": [("M", None)],
    "lora_a": [(None, None)],
    "lora_b": [(None, "M")],
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fits(mesh: Mesh, shape, spec) -> bool:
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            return False
    return True


def _leaf_spec(mesh: Mesh, path: str, shape, fsdp: bool = False) -> P:
    name = path.rstrip("]").split("'")[-2] if "'" in path else path.split(".")[-1]
    stacked = "groups" in path or re.search(r"\['(enc|dec)'\]", path) is not None
    base_shape = shape[1:] if stacked and len(shape) >= 2 else shape
    rules = _RULES.get(name, [])
    chosen = None
    for cand in rules:
        if len(cand) != len(base_shape):
            continue
        spec = tuple("model" if a == "M" else a for a in cand)
        if _fits(mesh, base_shape, spec):
            chosen = spec
            break
    if chosen is None:
        chosen = (None,) * len(base_shape)
    if fsdp:
        # ZeRO-3-style: additionally shard the largest unsharded dim over
        # 'data' so params + optimizer state fit HBM without a full DP copy
        # (weight all-gathers are generated per layer by GSPMD).
        chosen = list(chosen)
        free = [i for i, a in enumerate(chosen) if a is None]
        free.sort(key=lambda i: -base_shape[i])
        for i in free:
            if base_shape[i] % _axis_size(mesh, "data") == 0:
                chosen[i] = "data"
                break
        chosen = tuple(chosen)
    if stacked and len(shape) >= 2:
        chosen = (None,) + chosen
    return P(*chosen)


def param_shardings(mesh: Mesh, params_shapes: Any, fsdp: bool = False):
    """NamedSharding tree for a params (or optimizer-state) shape tree.

    fsdp=True additionally shards each weight's largest free dim over 'data'
    (train-time default: v5e HBM cannot hold a full f32 params+Adam copy per
    data-parallel group for the larger assigned archs — see DESIGN.md §Perf).
    """

    def fn(path, leaf):
        return NamedSharding(
            mesh, _leaf_spec(mesh, jax.tree_util.keystr(path), leaf.shape, fsdp=fsdp)
        )

    return jax.tree_util.tree_map_with_path(fn, params_shapes)


def batch_shardings(mesh: Mesh, batch_shapes: Any, extra_axes: tuple = (),
                    seq_axes: tuple = ()):
    """Batch inputs: leading axis over (pod, data) when divisible.

    ``extra_axes``: additional mesh axes to fold into the batch shard — e.g.
    ("model",) turns TP training into 256-way hierarchical DP (the §Perf
    "dp256" variant: per-device batch drops n_model-fold and the TP
    activation all-reduces shrink proportionally).
    ``seq_axes``: mesh axes for dim 1 (the sequence) — context parallelism;
    pairs with extra_axes on the 2x16x16 mesh where global_batch 256 cannot
    cover all 512 devices on the batch dim alone.
    """
    ba = batch_axes(mesh) + tuple(a for a in extra_axes if a in mesh.axis_names)
    ba = tuple(a for a in ba if a not in seq_axes)
    sa = tuple(a for a in seq_axes if a in mesh.axis_names)

    def fn(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # progressively drop leading axes until the batch divides (e.g.
        # global_batch 256 cannot take (pod,data,model)=512; (data,model)
        # still applies) — silently replicating instead is catastrophic
        # (measured 2x compute + 5x collectives, §Perf refuted-log).
        use = ba
        while use and leaf.shape[0] % _axis_size(mesh, use) != 0:
            use = use[1:]
        rest: list = [None] * (leaf.ndim - 1)
        if sa and leaf.ndim >= 2 and leaf.shape[1] % _axis_size(mesh, sa) == 0:
            rest[0] = sa if len(sa) > 1 else sa[0]
        if use:
            return NamedSharding(mesh, P(use, *rest))
        return NamedSharding(mesh, P(None, *rest))

    return jax.tree_util.tree_map(fn, batch_shapes)


def decode_state_shardings(mesh: Mesh, state_shapes: Any, cfg):
    """Decode-state sharding: KV caches (…, B, KV, S, hd) shard batch over
    (pod,data) and the *sequence* over 'model' (DESIGN.md §4); recurrent
    states shard their batch-ish leading dims and feature dims over 'model'
    when divisible."""
    ba = batch_axes(mesh)
    msize = mesh.shape["model"]
    bsize = _axis_size(mesh, ba)

    def fn(path, leaf):
        key = jax.tree_util.keystr(path)
        nd = leaf.ndim
        if nd >= 4 and ("'k'" in key or "'v'" in key):
            # (B,KV,S,hd) possibly with leading stack dims
            spec = [None] * nd
            if leaf.shape[nd - 4] % bsize == 0:
                spec[nd - 4] = ba
            if leaf.shape[nd - 2] % msize == 0:
                spec[nd - 2] = "model"
            return NamedSharding(mesh, P(*spec))
        if "wkv" in key and nd >= 3:
            # (BH, dk, dv) (+leading stack): shard the fused batch*head dim
            spec = [None] * nd
            if leaf.shape[nd - 3] % bsize == 0:
                spec[nd - 3] = ba
            return NamedSharding(mesh, P(*spec))
        if nd >= 2:
            # recurrent misc: (B, ..., C) -> batch on lead dim if divisible,
            # model on trailing feature dim if divisible
            lead = 1 if nd > 2 and "groups" in key else 0
            spec = [None] * nd
            if leaf.shape[lead] % bsize == 0:
                spec[lead] = ba
            if leaf.shape[-1] % msize == 0 and nd - 1 != lead:
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P(*(None,) * nd))

    return jax.tree_util.tree_map_with_path(fn, state_shapes)


def scalar_sharding(mesh: Mesh):
    return NamedSharding(mesh, P())
