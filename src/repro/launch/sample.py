"""Production PT sampling driver (the paper's experiment at cluster scale).

On real hardware this runs the paper's 300x300 Ising benchmark with 1500+
replicas sharded over the mesh; on this container use --smoke for a reduced
run.  The full-scale config is exercised structurally by ``--dryrun`` (AOT
lower/compile only), mirroring launch/dryrun.py for the PT workload.

    PYTHONPATH=src python -m repro.launch.sample --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=1536)  # paper: 1500 (padded to mesh)
    ap.add_argument("--length", type=int, default=300)  # paper: 300x300 spins
    ap.add_argument("--sweeps", type=int, default=2000)
    ap.add_argument("--swap-interval", type=int, default=100)
    ap.add_argument("--swap-mode", default="temp", choices=["temp", "state"])
    ap.add_argument("--smoke", action="store_true", help="reduced CPU run")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0, help="intervals between checkpoints")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.core import diagnostics, ising, ladder, pt

    if args.smoke:
        args.replicas, args.length, args.sweeps = 16, 32, 500

    system = ising.IsingSystem(length=args.length, j=1.0, b=0.0)
    temps = tuple(float(t) for t in ladder.paper_ladder(args.replicas))
    cfg = pt.PTConfig(
        n_replicas=args.replicas, temps=temps,
        swap_interval=args.swap_interval, swap_mode=args.swap_mode,
        criterion="logistic",
    )
    state = pt.init(system, cfg, jax.random.key(0))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_latest(state)
        if restored:
            state, meta = restored
            print(f"[restart] resumed at sweep {int(state.t)}")

    obs = {"am": lambda s: jnp.abs(ising.magnetization(s))}
    chunk = args.ckpt_every * args.swap_interval if args.ckpt_every else args.sweeps
    done = 0
    t0 = time.time()
    while done < args.sweeps:
        n = min(chunk, args.sweeps - done)
        state, trace = pt.run(system, cfg, state, n, observables=obs)
        done += n
        if mgr is not None:
            mgr.save(int(state.t), state, blocking=False)
        m = np.asarray(trace["am"])[-1]
        print(f"sweep {done:7d}  cold|m|={m[0]:.3f} hot|m|={m[-1]:.3f}  "
              f"{done * args.replicas / (time.time()-t0):.0f} replica-sweeps/s")
    if mgr is not None:
        mgr.wait()
    acc = diagnostics.swap_acceptance_rate(trace)
    print(f"final swap acceptance (cold pairs): {acc[:4]}")


if __name__ == "__main__":
    main()
