"""Production training driver: mesh-sharded train loop with checkpointing.

On this CPU container it runs reduced configs (--smoke); the full configs are
exercised by launch/dryrun.py (AOT lower+compile).  On a real multi-pod
deployment: one process per host, `jax.distributed.initialize()`, the same
mesh/sharding code, and the data pipeline shards per host.

    PYTHONPATH=src python -m repro.launch.train --arch gemma_2b --smoke --steps 50
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.data.synthetic import SyntheticLM
    from repro.train import optimizer as opt_lib
    from repro.train.train_step import init_state, make_train_step

    cfg = get_config(args.arch, reduced=args.smoke)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    opt_cfg = opt_lib.AdamWConfig(warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, microbatches=args.microbatches))

    state = init_state(cfg, jax.random.key(0))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest(state)
        if restored:
            state, meta = restored
            start = meta["step"]
            print(f"[restart] resumed at step {start}")

    t0 = time.time()
    for step, batch in data.batches(start):
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % 10 == 0:
            print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"{(step + 1 - start) / (time.time() - t0):.2f} it/s", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, blocking=False)
    if mgr is not None:
        mgr.wait()


if __name__ == "__main__":
    main()
