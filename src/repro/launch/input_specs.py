"""ShapeDtypeStruct stand-ins for every (architecture × input-shape) cell.

No device allocation — everything here is metadata.  The assigned shape set
(brief):

    train_4k     seq=4096    global_batch=256   (training)
    prefill_32k  seq=32768   global_batch=32    (inference-prefill)
    decode_32k   seq=32768   global_batch=128   (decode: 1 new token, 32k KV)
    long_500k    seq=524288  global_batch=1     (long-context decode)

`long_500k` requires sub-quadratic attention: it runs for rwkv6 (SSM),
recurrentgemma (hybrid local-attn) and mixtral (SWA) and is skipped for pure
full-attention archs (DESIGN.md §5).  Enc-dec/vlm frontends are stubs: specs
include precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = tuple(SHAPES)


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the brief's applicability rules."""
    if shape_name == "long_500k":
        subquad = cfg.family in ("rwkv", "hybrid") or cfg.swa_window > 0
        if not subquad:
            return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Model inputs for train/prefill kinds."""
    b, s = cell.batch, cell.seq
    out = {"tokens": _sds((b, s), jnp.int32)}
    if cell.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "vlm":
        out["img"] = _sds((b, cfg.img_tokens, cfg.d_model), cfg.compute_dtype)
    return out


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell (brief §2).

    train/prefill -> dict of batch specs; decode -> (state, token, pos, ctx).
    """
    cell = SHAPES[shape_name]
    if cell.kind in ("train", "prefill"):
        return batch_specs(cfg, cell)
    return decode_specs(cfg, cell)


def decode_specs(cfg: ModelConfig, cell: ShapeCell):
    """(state, token, pos, ctx) specs for the serve step."""
    state = jax.eval_shape(
        lambda: model_lib.init_decode_state(cfg, cell.batch, cell.seq)
    )
    token = _sds((cell.batch, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    ctx = None
    if cfg.family == "encdec":
        ctx = _sds((cell.batch, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
    elif cfg.family == "vlm":
        ctx = _sds((cell.batch, cfg.img_tokens, cfg.d_model), cfg.compute_dtype)
    return state, token, pos, ctx
