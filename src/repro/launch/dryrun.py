import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  This flag is set here and ONLY here (DESIGN.md §7).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Per cell:
  * delta-method lowerings (unrolled 1-group and 2-group configs) give exact
    per-partition FLOPs / bytes / collective payloads despite XLA's
    count-while-bodies-once cost analysis (DESIGN.md §7);
  * a full-config `lax.scan` lowering proves the production program compiles
    on the target mesh and yields `memory_analysis()` (does it fit?);
  * results land in results/dryrun/<arch>--<shape>--<mesh>[--variant].json,
    consumed by benchmarks/roofline_report.py (DESIGN.md §8).

Usage:
  python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  ... --override attn_chunk=4096 --variant chunk4k     (hillclimb variants)
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.hlo.collectives import parse_collectives
from repro.hlo.roofline import Roofline, analytic_hbm_bytes, model_flops
from repro.hlo.traffic import hbm_traffic_bytes
from repro.launch import input_specs as ispec
from repro.launch import sharding as shard_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.models import transformer
from repro.models.common import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train.train_step import init_state, make_train_step


# ---------------------------------------------------------------------------
# reduced-layer configs for the delta method
# ---------------------------------------------------------------------------
def delta_axes(cfg: ModelConfig) -> dict[str, tuple[int, int, int]]:
    """axis -> (full, base, step) layer counts."""
    if cfg.family == "encdec":
        return {
            "n_layers": (cfg.n_layers, 1, 1),
            "enc_layers": (cfg.enc_layers, 1, 1),
        }
    plen = len(transformer.layer_pattern(cfg))
    tail = cfg.n_layers % plen
    return {"n_layers": (cfg.n_layers, plen + tail, plen)}


def _with_layers(cfg: ModelConfig, **counts) -> ModelConfig:
    return dataclasses.replace(cfg, scan_layers=False, **counts)


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------
def _microbatches(cfg, cell, mesh) -> int:
    """Pick the microbatch count so saved per-layer scan residuals fit HBM.

    scan+remat saves one (B_loc, S, D) input per layer; target <= ~4 GB of
    residuals per device (leaving room for weights + transients on a 16 GB
    v5e).  Power of two so it divides the global batch.
    """
    n_batchpar = mesh.size // mesh.shape["model"]
    b_loc = max(cell.batch // n_batchpar, 1)
    l = cfg.n_layers + (cfg.enc_layers or 0)
    res_bytes = l * b_loc * cell.seq * cfg.d_model * 2
    m = 1
    while res_bytes / m > 4e9 and m < cell.batch:
        m *= 2
    return m


_BATCH_EXTRA_AXES: tuple = ()  # set by --batch-axes dpmodel (§Perf variant)
_SEQ_AXES: tuple = ()  # set by --batch-axes dpmodel_sp (context parallelism)


def _train_fn_and_specs(cfg, cell, mesh, fsdp=True, microbatches=1):
    opt_cfg = opt_lib.AdamWConfig()
    state_shapes = jax.eval_shape(lambda k: init_state(cfg, k), jax.random.key(0))
    batch_shapes = ispec.batch_specs(cfg, cell)
    cast_sh = shard_lib.param_shardings(mesh, state_shapes.params, fsdp=False)
    fsdp_sh = shard_lib.param_shardings(mesh, state_shapes.params, fsdp=True)
    step_fn = make_train_step(cfg, opt_cfg, microbatches=microbatches,
                              cast_shardings=cast_sh if fsdp else None,
                              grad_shardings=fsdp_sh if fsdp else None)
    in_sh = (
        shard_lib.param_shardings(mesh, state_shapes, fsdp=fsdp),
        shard_lib.batch_shardings(mesh, batch_shapes, extra_axes=_BATCH_EXTRA_AXES,
                                  seq_axes=_SEQ_AXES),
    )
    return step_fn, (state_shapes, batch_shapes), in_sh


def _serve_dtype(params_shapes):
    """Serving holds bf16 weights (production standard — halves HBM)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 and len(s.shape) >= 2 else s,
        params_shapes,
    )


def _prefill_fn_and_specs(cfg, cell, mesh):
    def fn(params, batch):
        return model_lib.prefill_logits(params, cfg, batch)

    params_shapes = jax.eval_shape(lambda k: model_lib.init_params(cfg, k), jax.random.key(0))
    params_shapes = _serve_dtype(params_shapes)
    batch_shapes = ispec.batch_specs(cfg, cell)
    in_sh = (
        shard_lib.param_shardings(mesh, params_shapes),
        shard_lib.batch_shardings(mesh, batch_shapes),
    )
    return fn, (params_shapes, batch_shapes), in_sh


def _decode_fn_and_specs(cfg, cell, mesh):
    state_shapes, token, pos, ctx = ispec.decode_specs(cfg, cell)

    if ctx is None:
        def fn(params, state, token, pos):
            return model_lib.decode_step(params, cfg, state, token, pos)
        args = (state_shapes, token, pos)
    else:
        def fn(params, state, token, pos, ctx):
            return model_lib.decode_step(params, cfg, state, token, pos, ctx=ctx)
        args = (state_shapes, token, pos, ctx)

    params_shapes = jax.eval_shape(lambda k: model_lib.init_params(cfg, k), jax.random.key(0))
    params_shapes = _serve_dtype(params_shapes)
    in_sh = [shard_lib.param_shardings(mesh, params_shapes),
             shard_lib.decode_state_shardings(mesh, state_shapes, cfg)]
    in_sh.append(shard_lib.batch_shardings(mesh, token))
    in_sh.append(shard_lib.scalar_sharding(mesh))
    if ctx is not None:
        in_sh.append(shard_lib.batch_shardings(mesh, ctx))
    return fn, (params_shapes,) + args, tuple(in_sh)


def lower_one(cfg, cell, mesh, label: str, microbatches: int = 1) -> dict:
    """Lower + compile one program; return cost/memory/collective record."""
    if cell.kind == "train":
        fn, arg_shapes, in_sh = _train_fn_and_specs(cfg, cell, mesh,
                                                    microbatches=microbatches)
    elif cell.kind == "prefill":
        fn, arg_shapes, in_sh = _prefill_fn_and_specs(cfg, cell, mesh)
    else:
        fn, arg_shapes, in_sh = _decode_fn_and_specs(cfg, cell, mesh)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)
    traffic = hbm_traffic_bytes(hlo_text)
    return {
        "label": label,
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "hbm_traffic_bytes": traffic,
        "collective_payload_bytes": coll.payload_bytes,
        "collective_wire_bytes": coll.wire_bytes,
        "collective_by_op": coll.by_op,
        "collective_count": coll.count,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
        "lower_s": t_lower,
        "compile_s": t_compile,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, overrides: dict,
             variant: str, out_dir: str, skip_full: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = ispec.SHAPES[shape_name]
    ok, reason = ispec.applicable(cfg, shape_name)
    mesh_name = "multi" if multi_pod else "single"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "overrides": overrides,
        "n_params": cfg.n_params, "n_active_params": cfg.n_active_params,
    }
    if not ok:
        rec["skipped"] = reason
        _dump(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    axes = delta_axes(cfg)

    # --- delta-method lowerings (unrolled) ---
    base_counts = {ax: base for ax, (_, base, _) in axes.items()}
    lows = {"base": lower_one(_with_layers(cfg, **base_counts), cell, mesh, "base")}
    for ax, (_, base, step) in axes.items():
        counts = dict(base_counts)
        counts[ax] = base + step
        lows[f"plus_{ax}"] = lower_one(_with_layers(cfg, **counts), cell, mesh, f"plus_{ax}")

    def compose(field: str) -> float:
        total = lows["base"][field]
        for ax, (full, base, step) in axes.items():
            per_group = (lows[f"plus_{ax}"][field] - lows["base"][field])
            total += (full - base) // step * per_group
        return total

    composed = {
        "flops_per_device": compose("flops"),
        "hbm_bytes_per_device": compose("hbm_traffic_bytes"),
        "hbm_bytes_prefusion_upper": compose("bytes_accessed"),
        "coll_payload_bytes": compose("collective_payload_bytes"),
        "coll_wire_bytes": compose("collective_wire_bytes"),
    }

    # --- full-config scan lowering: compile proof + memory analysis ---
    # production program: scan over layers + microbatched grad accumulation
    if not skip_full:
        mb = _microbatches(cfg, cell, mesh) if cell.kind == "train" else 1
        full = lower_one(dataclasses.replace(cfg, scan_layers=True), cell, mesh,
                         "full_scan", microbatches=mb)
        full["microbatches"] = mb
        rec["full_scan"] = full

    rec["lowerings"] = lows
    rec["composed"] = composed
    mf = model_flops(cfg, cell.kind, cell.batch, cell.seq)
    n_model = mesh.shape["model"]
    roof = Roofline(
        flops_per_device=composed["flops_per_device"],
        hbm_bytes_per_device=composed["hbm_bytes_per_device"],
        coll_wire_bytes_per_device=composed["coll_wire_bytes"],
        model_flops_global=mf,
        n_devices=n_dev,
        hbm_analytic_per_device=analytic_hbm_bytes(
            cfg, cell.kind, cell.batch, cell.seq, n_model, n_dev // n_model
        ),
    )
    rec["model_flops"] = mf
    rec["roofline"] = roof.row()
    _dump(rec, out_dir)
    return rec


def _dump(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    variant = rec.get("variant") or "baseline"
    name = f"{rec['arch']}--{rec['shape']}--{rec['mesh']}--{variant}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def _parse_overrides(pairs):
    out = {}
    for pair in pairs or []:
        k, v = pair.split("=", 1)
        for cast in (int, float):
            try:
                out[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            out[k] = {"true": True, "false": False}.get(v.lower(), v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--override", nargs="*", default=[])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-full", action="store_true",
                    help="skip the full-config scan compile (fast iteration)")
    ap.add_argument("--batch-axes", default="dp",
                    choices=["dp", "dpmodel", "dpmodel_sp"],
                    help="dpmodel: fold the model axis into the batch shard; "
                         "dpmodel_sp: additionally shard the sequence over "
                         "'pod' (context parallelism, §Perf variants)")
    args = ap.parse_args()
    global _BATCH_EXTRA_AXES, _SEQ_AXES
    if args.batch_axes in ("dpmodel", "dpmodel_sp"):
        _BATCH_EXTRA_AXES = ("model",)
    if args.batch_axes == "dpmodel_sp":
        _SEQ_AXES = ("pod",)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(ispec.SHAPE_NAMES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    overrides = _parse_overrides(args.override)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}/{shape}/{'multi' if mp else 'single'}"
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, mp, overrides, args.variant,
                                   args.out, skip_full=args.skip_full)
                    status = "SKIP " + rec.get("skipped", "") if "skipped" in rec else (
                        f"ok   dominant={rec['roofline']['dominant']}"
                        f" frac={rec['roofline']['fraction_of_roofline']:.3f}"
                    )
                except Exception as e:
                    traceback.print_exc()
                    failures.append(tag)
                    status = f"FAIL {type(e).__name__}: {e}"
                print(f"[dryrun] {tag:55s} {time.time()-t0:7.1f}s  {status}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
