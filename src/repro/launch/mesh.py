"""Production mesh construction (DESIGN.md §4).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required so smoke tests see 1 CPU device while the dry-run
sees 512 placeholder devices via its XLA_FLAGS preamble).
"""
from __future__ import annotations

import jax

BATCH_AXES = ("pod", "data")  # logical batch/replica axes (present subset used)
MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def batch_axes(mesh) -> tuple:
    """The subset of (pod, data) present in this mesh, for batch sharding."""
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)
