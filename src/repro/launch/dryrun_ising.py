import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede every other import (same rule as dryrun.py).

"""Dry-run for the paper's OWN workload: the 1500-replica (padded to 1536)
300x300 Ising MH/PT benchmark on the production meshes.

This is the paper-representative §Perf cell: it lowers one full PT interval
(``swap_interval`` sweeps + one parallel swap iteration) with the replica
axis sharded over the mesh, and records the collective traffic of the two
swap implementations:

  * ``state`` — faithful to the paper: accepted pairs exchange (L,L) int8
    lattices (a replica-axis gather -> all-to-all at shard boundaries);
  * ``temp``  — optimized: accepted pairs exchange rung indices (O(R) bytes).

The sweep itself is communication-free (replica-parallel, like the paper's
threads); `jnp.roll` halos stay on-device because lattices are unsharded.

  python -m repro.launch.dryrun_ising --mesh both
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, ising, ladder, pt
from repro.hlo.collectives import parse_collectives
from repro.hlo.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.hlo.traffic import hbm_traffic_bytes
from repro.launch.mesh import make_production_mesh


def lower_pt(mesh, *, replicas, length, interval, swap_mode, criterion="logistic"):
    system = ising.IsingSystem(length=length, j=1.0, b=0.0)
    temps = tuple(float(t) for t in ladder.paper_ladder(replicas))
    cfg = pt.PTConfig(
        n_replicas=replicas, temps=temps, swap_interval=interval,
        swap_mode=swap_mode, criterion=criterion,
    )
    state_shapes = jax.eval_shape(lambda k: pt.init(system, cfg, k), jax.random.key(0))
    shard = distributed.replica_sharding(mesh)
    scalar = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def like(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim >= 1 and leaf.shape[0] == replicas:
            return shard
        return scalar

    in_sh = (jax.tree_util.tree_map(like, state_shapes),)

    def run_interval(st):
        st, trace = pt.run(system, cfg, st, interval, shard=shard)
        # depend on the post-swap STATES (not just energies) with a
        # replica-weighted reduction — otherwise DCE deletes the state-swap
        # gather in a single-interval program and the collective vanishes
        w = jnp.arange(cfg.n_replicas, dtype=jnp.float32)[:, None, None]
        probe = jnp.sum(st.states.astype(jnp.float32) * w)
        return st.energy, trace["swap_accept"], probe

    t0 = time.time()
    with mesh:
        compiled = jax.jit(run_interval, in_shardings=in_sh).lower(state_shapes).compile()
    dt = time.time() - t0
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    txt = compiled.as_text()
    coll = parse_collectives(txt)
    ma = compiled.memory_analysis()
    # analytic FLOPs: 2 half-sweeps x ~12 ops/site per sweep, R*L^2 sites
    sweep_flops = replicas * length * length * 2 * 12 * interval
    return {
        "swap_mode": swap_mode,
        "replicas": replicas,
        "length": length,
        "interval": interval,
        "flops_per_device_hlo": float(ca.get("flops", 0.0)),
        "model_flops_per_device": sweep_flops / mesh.size,
        "hbm_traffic_per_device": hbm_traffic_bytes(txt),
        "coll_payload_bytes": coll.payload_bytes,
        "coll_wire_bytes": coll.wire_bytes,
        "coll_by_op": coll.by_op,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
        },
        "t_comp_s": sweep_flops / mesh.size / PEAK_FLOPS,
        "t_mem_s": hbm_traffic_bytes(txt) / HBM_BW,
        "t_coll_s": coll.wire_bytes / ICI_BW,
        "compile_s": dt,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--replicas", type=int, default=1536)  # paper's 1500, padded
    ap.add_argument("--length", type=int, default=300)  # paper's 300x300
    ap.add_argument("--interval", type=int, default=100)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        mesh_name = "multi" if mp else "single"
        for mode in ("state", "temp"):
            rec = lower_pt(
                mesh, replicas=args.replicas, length=args.length,
                interval=args.interval, swap_mode=mode,
            )
            rec.update({"arch": "ising_paper", "shape": f"pt{args.interval}",
                        "mesh": mesh_name, "variant": mode})
            name = f"ising_paper--pt{args.interval}--{mesh_name}--{mode}.json"
            with open(os.path.join(args.out, name), "w") as f:
                json.dump(rec, f, indent=1)
            print(
                f"[ising-dryrun] {mesh_name}/{mode}: compile {rec['compile_s']:.1f}s  "
                f"coll_wire={rec['coll_wire_bytes']/2**20:.2f} MiB/dev  "
                f"by_op={ {k: round(v/2**20, 2) for k, v in rec['coll_by_op'].items()} }",
                flush=True,
            )


if __name__ == "__main__":
    main()
