"""Deterministic fault injection: a seeded `FaultPlan` arming named sites.

The injection sites are threaded through the production code paths
(`repro.engine.driver`, `repro.checkpoint.manager`, `repro.serve`) in the
same structural style the obs layer pinned: every component holds a
``faults`` handle that is ``None`` in production, and every site costs
exactly one ``is None`` test when disarmed — nothing is constructed, no
registry is consulted, and the compiled mega-step jaxpr is byte-identical
with the plan armed or absent (all sites live in host loops, pinned by
``tests/test_resilience.py``).

A `Fault` arms one site at specific *occurrence indices* of that site
(0-based, counted per plan), so a schedule like "the second checkpoint
write tears" or "chunk launch 3 raises" is reproducible bit-for-bit.
`FaultPlan.from_seed` draws a whole schedule deterministically from one
integer — the chaos suite's seed matrix and CI's ``chaos-smoke`` job run
on exactly these plans.

Site registry (see DESIGN.md §Resilience for the taxonomy):

===================================   ========================================
site                                  behaviour when armed
===================================   ========================================
``checkpoint.write.torn``             staged arrays file truncated to half
                                      (a torn write that still got renamed)
``checkpoint.write.corrupt``          one byte flipped in the staged arrays
                                      (silent media corruption; digests
                                      catch it)
``checkpoint.write.crash_before_rename``  `InjectedCrash` with the staging
                                      dir left behind, step dir never
                                      created (process death mid-save)
``checkpoint.write.crash_after_rename``   `InjectedCrash` after the atomic
                                      swap landed (step dir is whole)
``engine.compile``                    `InjectedFault` from inside the AOT
                                      lower/compile call (drives the
                                      kernel-degradation fallback for fused
                                      systems, supervisor retry otherwise)
``engine.chunk.launch``               `InjectedFault` before a chunk launch
                                      (transient device/runtime error)
``engine.chunk.stall``                ``time.sleep(duration)`` before the
                                      launch (a hung chunk; trips watchdogs)
``engine.energy.nonfinite``           one chain's device energies set to NaN
                                      after a chunk (failing hardware lane;
                                      the owning tenant FAILs typed, bucket
                                      mates are untouched)
``serve.callback``                    `InjectedFault` from inside a tenant's
                                      stream callback (exercises per-job
                                      failure isolation)
===================================   ========================================
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

__all__ = [
    "SITES",
    "Fault",
    "FaultError",
    "FaultPlan",
    "InjectedCrash",
    "InjectedFault",
]

SITES = frozenset({
    "checkpoint.write.torn",
    "checkpoint.write.corrupt",
    "checkpoint.write.crash_before_rename",
    "checkpoint.write.crash_after_rename",
    "engine.compile",
    "engine.chunk.launch",
    "engine.chunk.stall",
    "engine.energy.nonfinite",
    "serve.callback",
})

# sites a Supervisor-recovered bucket replays through bit-equal (transient);
# the rest fail exactly one tenant cleanly instead of poisoning the bucket
RECOVERABLE_SITES = frozenset({
    "checkpoint.write.torn",
    "checkpoint.write.corrupt",
    "checkpoint.write.crash_before_rename",
    "checkpoint.write.crash_after_rename",
    "engine.compile",
    "engine.chunk.launch",
    "engine.chunk.stall",
})


class FaultError(RuntimeError):
    """Base class for every injected failure (typed: chaos assertions and
    retry classification match on this, never on bare RuntimeError)."""


class InjectedFault(FaultError):
    """A transient injected error (launch/compile/callback raise)."""


class InjectedCrash(FaultError):
    """Simulated process death at a crash site (checkpoint write seams)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """Arm ``site`` at the given 0-based occurrence indices.

    ``duration`` is the stall length for ``engine.chunk.stall``; ``chain``
    selects the poisoned ensemble slot for ``engine.energy.nonfinite``
    (taken modulo the live chain count at the site).
    """

    site: str
    at: tuple[int, ...] = (0,)
    duration: float = 0.0
    chain: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; known: {sorted(SITES)}"
            )


class FaultPlan:
    """A deterministic schedule of injected faults over named sites.

    Components call ``check(site)`` (returns the armed `Fault` or None and
    advances that site's occurrence counter) or ``fire(site)`` (raises
    `InjectedFault` when armed).  Counters are plan-global and thread-safe,
    so one plan threaded through a whole scheduler — engines, checkpoint
    managers, buckets — produces one reproducible interleaving per
    single-threaded host loop.

    ``on_fire`` (optional, settable after construction) is called with the
    `Fault` each time a site actually fires — the scheduler hangs its
    ``pt_fault_injected`` counter here.
    """

    def __init__(self, faults, on_fire=None):
        self.faults = [
            f if isinstance(f, Fault) else Fault(**f) for f in faults
        ]
        self.on_fire = on_fire
        self._counts: dict[str, int] = {}
        self._lock = threading.Lock()
        # (site, occurrence) of every fault that actually fired, in order —
        # quarantine manifests and the chaos suite read this
        self.log: list[tuple[str, int]] = []

    @classmethod
    def from_seed(cls, seed: int, n_faults: int = 3, sites=None,
                  max_occurrence: int = 4, on_fire=None) -> "FaultPlan":
        """A random-but-reproducible schedule: ``n_faults`` draws of
        (site, occurrence) from ``sites`` (default: every known site)."""
        rng = np.random.RandomState(seed)
        pool = sorted(sites if sites is not None else SITES)
        faults = []
        for _ in range(n_faults):
            site = pool[rng.randint(len(pool))]
            faults.append(Fault(
                site=site,
                at=(int(rng.randint(max_occurrence)),),
                duration=0.0,
                chain=int(rng.randint(8)),
            ))
        return cls(faults, on_fire=on_fire)

    def check(self, site: str) -> Fault | None:
        """Advance ``site``'s occurrence counter; return the armed `Fault`
        for this occurrence, or None."""
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            hit = None
            for f in self.faults:
                if f.site == site and n in f.at:
                    hit = f
                    break
            if hit is not None:
                self.log.append((site, n))
        if hit is not None and self.on_fire is not None:
            self.on_fire(hit)
        return hit

    def fire(self, site: str) -> None:
        """`check` and raise `InjectedFault` when armed (raise-type sites)."""
        f = self.check(site)
        if f is not None:
            raise InjectedFault(
                f"injected fault at {site} (occurrence "
                f"{self._counts[site] - 1})"
            )

    def fired(self, site: str | None = None) -> int:
        """How many faults have fired (at ``site``, or in total)."""
        with self._lock:
            if site is None:
                return len(self.log)
            return sum(1 for s, _ in self.log if s == site)

    def __repr__(self):
        return f"FaultPlan({self.faults!r}, fired={len(self.log)})"
