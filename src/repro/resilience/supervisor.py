"""Supervised execution of scheduler quanta: retry, watchdog, quarantine.

The `Supervisor` sits between `repro.serve.Scheduler.step` and
`PackedRun.run_quantum` and turns faults into one of exactly two outcomes
(the chaos invariant pinned by ``tests/test_resilience.py``):

* **recovered** — a transient failure (injected or real: a launch raise, a
  torn checkpoint write, a compile failure, a stalled chunk caught by the
  watchdog) triggers bucket recovery: the bucket is rebuilt from its last
  *intact* checkpoint generation (`CheckpointManager.restore_latest` walks
  past corrupt steps; with no manager, from scratch) and the quantum is
  retried after an exponential backoff with deterministic jitter.  Replay
  is bit-equal to the fault-free run — chunk boundaries and preemption are
  invisible to the PRNG stream, and completed-phase summaries recorded
  before the restore point are carried over.
* **quarantined** — after ``RetryPolicy.max_attempts`` consecutive
  failures of one quantum (or a wedged watchdog thread that never exits),
  the bucket's live jobs FAIL with a typed `BucketQuarantined` and a
  failure manifest (``quarantine.json``: error, attempt history, fired
  faults) is written next to the bucket's checkpoints.  The scheduler
  keeps serving every other bucket.

Watchdogs are wall-clock: the quantum (and, separately, the first compile)
runs on a worker thread joined with a timeout.  On expiry the bucket is
*abandoned* — its host loop observes the flag at the next chunk boundary
and stops without delivering further tenant updates — and the supervisor
waits ``grace_s`` for the worker to drain before retrying; a worker that
never exits is treated as wedged and the bucket is quarantined rather than
raced against.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Any, Callable

from repro.resilience.faults import FaultError

__all__ = [
    "BucketQuarantined",
    "CompileTimeout",
    "QuantumOutcome",
    "RetryPolicy",
    "Supervisor",
    "WatchdogTimeout",
]

QUARANTINE_NAME = "quarantine.json"


class WatchdogTimeout(FaultError):
    """A supervised step exceeded its wall-clock budget.

    ``wedged`` marks a worker thread that survived the post-abandon grace
    period — retrying would race the stuck thread, so the supervisor
    quarantines immediately instead.
    """

    def __init__(self, msg: str, wedged: bool = False):
        super().__init__(msg)
        self.wedged = wedged


class CompileTimeout(WatchdogTimeout):
    """The mega-step AOT compile exceeded its wall-clock budget."""


class BucketQuarantined(RuntimeError):
    """Raised through `Job.result` for every job of a quarantined bucket."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter.

    The jitter is a pure function of ``(key, attempt)`` (sha256-derived), so
    a replayed fault schedule sleeps the same wall pattern every run — the
    chaos suite stays reproducible while a real fleet still decorrelates
    (every bucket name hashes to a different fraction).
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    jitter: float = 0.25

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")

    def delay(self, key: str, attempt: int) -> float:
        base = min(self.max_delay_s, self.base_delay_s * (2 ** (attempt - 1)))
        u = int.from_bytes(
            hashlib.sha256(f"{key}:{attempt}".encode()).digest()[:8], "big"
        ) / 2.0**64
        return base * (1.0 + self.jitter * u)


@dataclasses.dataclass
class QuantumOutcome:
    """What one supervised quantum did.  ``bucket`` may be a recovered
    replacement for the instance the scheduler passed in."""

    bucket: Any
    finished: bool
    retries: int = 0
    quarantined: bool = False
    error: BaseException | None = None
    # one dict per recovery: {"t0", "seconds", "error", "sweep",
    # "fallback_depth"} — the scheduler turns these into timeline spans
    recoveries: list = dataclasses.field(default_factory=list)


class Supervisor:
    """Typed retry/quarantine around bucket quanta (DESIGN.md §Resilience).

    Args:
      policy: retry budget + backoff shape.
      watchdog_s: wall-clock budget per quantum (0 = no watchdog thread —
        the quantum runs inline and only raised exceptions are supervised).
      compile_watchdog_s: separate budget for the first mega-step compile
        of a bucket (0 = covered by the quantum watchdog, if any).
      grace_s: post-abandon wait for a timed-out worker before declaring
        it wedged.
      sleep: injectable clock for tests.
    """

    def __init__(
        self,
        policy: RetryPolicy | None = None,
        watchdog_s: float = 0.0,
        compile_watchdog_s: float = 0.0,
        grace_s: float = 10.0,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.policy = policy or RetryPolicy()
        self.watchdog_s = watchdog_s
        self.compile_watchdog_s = compile_watchdog_s
        self.grace_s = grace_s
        self._sleep = sleep
        # cumulative service counters (benchmarks/fault_recovery.py)
        self.totals = {
            "retries": 0,
            "quarantined_buckets": 0,
            "quarantined_jobs": 0,
            "recovery_seconds": 0.0,
            "fallback_depth": 0,
        }

    # -- execution -------------------------------------------------------------
    def run(self, bucket, quantum_chunks: int) -> QuantumOutcome:
        """Run one quantum under supervision; never raises for bucket-level
        faults (the outcome says what happened)."""
        attempt = 0
        recoveries: list[dict] = []
        while True:
            try:
                finished = self._attempt(bucket, quantum_chunks)
                return QuantumOutcome(
                    bucket=bucket, finished=finished, retries=attempt,
                    recoveries=recoveries,
                )
            except Exception as err:
                attempt += 1
                wedged = isinstance(err, WatchdogTimeout) and err.wedged
                if wedged or attempt >= self.policy.max_attempts:
                    self._quarantine(bucket, err, attempt, recoveries)
                    return QuantumOutcome(
                        bucket=bucket, finished=True, retries=attempt - 1,
                        quarantined=True, error=err, recoveries=recoveries,
                    )
                t0 = time.perf_counter()
                self._sleep(self.policy.delay(
                    getattr(bucket, "name", bucket.digest), attempt
                ))
                bucket = bucket.recover()
                dt = time.perf_counter() - t0
                depth = getattr(bucket, "restore_fallback_depth", 0)
                recoveries.append({
                    "t0": t0,
                    "seconds": dt,
                    "error": repr(err),
                    "sweep": bucket.sweeps_done,
                    "fallback_depth": depth,
                })
                self.totals["retries"] += 1
                self.totals["recovery_seconds"] += dt
                self.totals["fallback_depth"] += depth

    def _attempt(self, bucket, quantum_chunks: int):
        if self.compile_watchdog_s > 0:
            self._watchdogged(
                bucket.ensure_compiled, self.compile_watchdog_s,
                CompileTimeout, bucket, "compile",
            )
        if self.watchdog_s > 0:
            return self._watchdogged(
                lambda: bucket.run_quantum(quantum_chunks), self.watchdog_s,
                WatchdogTimeout, bucket, "quantum",
            )
        return bucket.run_quantum(quantum_chunks)

    def _watchdogged(self, fn, timeout: float, exc_type, bucket, label: str):
        box: dict[str, Any] = {}

        def target():
            try:
                box["value"] = fn()
            except BaseException as e:
                box["error"] = e

        worker = threading.Thread(
            target=target, daemon=True, name=f"repro-supervised-{label}"
        )
        worker.start()
        worker.join(timeout)
        if worker.is_alive():
            # cooperative cancellation: the bucket's host loop checks the
            # abandon flag at every chunk boundary and stops silently — no
            # tenant sees updates from an abandoned attempt
            bucket.abandon()
            worker.join(self.grace_s)
            raise exc_type(
                f"{label} for bucket {getattr(bucket, 'name', bucket.digest)}"
                f" exceeded {timeout}s"
                + (" and never drained (wedged)" if worker.is_alive() else ""),
                wedged=worker.is_alive(),
            )
        if "error" in box:
            raise box["error"]
        return box.get("value")

    # -- quarantine -------------------------------------------------------------
    def _quarantine(self, bucket, err, attempts: int, recoveries: list) -> None:
        qerr = BucketQuarantined(
            f"bucket {getattr(bucket, 'name', bucket.digest)} quarantined "
            f"after {attempts} attempt(s): {err!r}"
        )
        qerr.__cause__ = err
        jobs = bucket.live_jobs()
        for job in jobs:
            job._fail(qerr)
        bucket.finished = True  # drop from rotation; a stray requeue no-ops
        self.totals["quarantined_buckets"] += 1
        self.totals["quarantined_jobs"] += len(jobs)
        manager = getattr(bucket, "manager", None)
        if manager is None:
            return
        manifest = {
            "bucket": getattr(bucket, "name", bucket.digest),
            "signature": bucket.digest,
            "jobs": [j.id for j in bucket.jobs],
            "failed_jobs": sorted(bucket._failed),
            "attempts": attempts,
            "error": repr(err),
            "sweeps_done": bucket.sweeps_done,
            "recoveries": recoveries,
            "time": time.time(),
        }
        faults = getattr(bucket, "faults", None)
        if faults is not None:
            manifest["fired_faults"] = [list(x) for x in faults.log]
        path = os.path.join(manager.dir, QUARANTINE_NAME)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
