"""Fault injection, supervised recovery, and graceful degradation.

Three pieces (DESIGN.md §Resilience):

* `repro.resilience.faults` — the deterministic fault-injection harness: a
  seeded `FaultPlan` arming named sites threaded through the engine host
  loop, the checkpoint writer, and the serve scheduler.  Disarmed
  (``faults=None``, the production default) every site is a single
  ``is None`` test — the same zero-cost-off structural contract the obs
  layer pins, including byte-identical mega-step jaxprs.
* `repro.resilience.supervisor` — `Supervisor`: typed retry with
  exponential backoff + deterministic jitter, wall-clock watchdogs on
  compile and quantum steps, bit-equal bucket recovery from the last
  intact checkpoint, and max-attempts quarantine with a failure manifest.
* graceful degradation lives at its call sites: fused/Pallas compile
  failures fall back to the per-sweep path (`repro.engine.driver`, off
  with ``strict_kernels``), corrupt checkpoint generations fall back to
  the newest intact one (`repro.checkpoint.manager`, content digests in
  the step manifest), and the serve intake queue rejects past a bounded
  depth (`repro.serve.job.QueueFull`).

The global invariant, CI-gated by the chaos suite
(``tests/test_resilience.py``): under any injected fault schedule, every
job either completes **bit-equal** to its fault-free run or fails cleanly
with a **typed** error — and on-disk checkpoints stay loadable throughout.
"""
from repro.resilience.faults import (
    RECOVERABLE_SITES,
    SITES,
    Fault,
    FaultError,
    FaultPlan,
    InjectedCrash,
    InjectedFault,
)
from repro.resilience.supervisor import (
    BucketQuarantined,
    CompileTimeout,
    QuantumOutcome,
    RetryPolicy,
    Supervisor,
    WatchdogTimeout,
)

__all__ = [
    "BucketQuarantined",
    "CompileTimeout",
    "Fault",
    "FaultError",
    "FaultPlan",
    "InjectedCrash",
    "InjectedFault",
    "QuantumOutcome",
    "RECOVERABLE_SITES",
    "RetryPolicy",
    "SITES",
    "Supervisor",
    "WatchdogTimeout",
]
