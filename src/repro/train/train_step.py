"""Training step: loss → grad → AdamW, with microbatch gradient accumulation
and optional int8-compressed data-parallel gradient reduction."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.common import ModelConfig
from repro.train import optimizer as opt_lib


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: opt_lib.AdamWState
    step: jax.Array


def init_state(cfg: ModelConfig, key) -> TrainState:
    params = model_lib.init_params(cfg, key)
    return TrainState(params=params, opt=opt_lib.init(params), step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.AdamWConfig, *,
                    microbatches: int = 1, cast_shardings=None,
                    grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatches`` splits the per-step batch on the leading axis and
    accumulates gradients with a `lax.scan` — the standard trick to fit large
    global batches and to overlap the DP gradient reduction with backward
    compute (XLA schedules the accumulated psum once per step).

    ``cast_shardings``: mixed-precision FSDP pattern — master f32 params and
    Adam state live FSDP-sharded (model × data); at step start every ≥2-D
    weight is cast to bf16 and constrained to the given TP-only shardings,
    so the weight all-gather over 'data' happens ONCE per step *outside* the
    layer scan (a naive FSDP in_sharding makes GSPMD re-materialize inside
    the scan body — measured catastrophic, see DESIGN.md §Perf).
    Gradients flow back to the FSDP layout via GSPMD reduce-scatter.
    """

    def cast_params(params):
        dt = cfg.compute_dtype

        def one(p, s=None):
            if p.ndim >= 2 and p.dtype == jnp.float32:
                p = p.astype(dt)
            if s is not None:
                p = jax.lax.with_sharding_constraint(p, s)
            return p

        if cast_shardings is None:
            return jax.tree_util.tree_map(one, params)
        return jax.tree_util.tree_map(one, params, cast_shardings)

    def loss_fn(params, batch):
        return model_lib.forward_loss(cast_params(params), cfg, batch)

    grad_fn = jax.value_and_grad(loss_fn)

    def constrain_grads(g):
        # keep the accumulator in the master (FSDP) layout — without this the
        # f32 gradient tree stays TP-gathered and blows the per-device HBM
        if grad_shardings is None:
            return g
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, grad_shardings
        )

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            loss, grads = grad_fn(state.params, batch)
            grads = constrain_grads(grads)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def body(acc, mbatch):
                l, g = grad_fn(state.params, mbatch)
                g = constrain_grads(g)
                return (
                    acc[0] + l,
                    jax.tree_util.tree_map(jnp.add, acc[1], g),
                ), None

            zero = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            ))
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zero), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        params, opt, metrics = opt_lib.apply(opt_cfg, state.params, grads, state.opt)
        metrics["loss"] = loss
        return TrainState(params, opt, state.step + 1), metrics

    return train_step
