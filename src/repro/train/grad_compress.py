"""int8 gradient compression with error feedback, for DP all-reduce.

At multi-pod scale the inter-pod (DCN / slow-link) gradient all-reduce can
dominate step time.  Compressing f32/bf16 gradients to int8 with a per-tensor
scale cuts those bytes 4x/2x; the quantization error is fed back into the
next step (error-feedback SGD, Seide et al. 2014 / Karimireddy et al. 2019),
which keeps convergence unchanged to first order.

Usage pattern (shard_map over the 'pod' axis — the slow links):

    g_sum, new_err = compressed_psum(g, err, axis_name="pod")

The intra-pod reduction stays full-precision (fast ICI); only the hierarchy
level you name pays the quantization.  tests/test_train.py checks the
error-feedback contraction property numerically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jnp.ndarray, err: jnp.ndarray):
    """Quantize (g + carried error); return (q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize(q, scale)
    return q, scale, new_err


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """int8 all-reduce over `axis_name` with error feedback.

    Must be called inside `shard_map`/`pmap` with the named axis.  The int8
    payload is summed in int32 (no overflow for <= 2^23 participants); scales
    are max-reduced so every participant dequantizes identically.
    """
    q, scale, new_err = compress_with_feedback(g, err)
    # max scale across participants -> requantize against the common scale
    common = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(
        jnp.round(dequantize(q, scale) / common), -127, 127
    ).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * common, new_err
