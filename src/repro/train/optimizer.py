"""AdamW with decoupled weight decay and global-norm clipping.

Self-contained (no optax in the container).  Optimizer state mirrors the
parameter pytree — so it shards with the same PartitionSpec rules and
checkpoints with the same code paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # cosine decay to lr*min_ratio over total_steps (0 = constant after warmup)
    total_steps: int = 0
    min_ratio: float = 0.1


def init(params) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        mu=jax.tree_util.tree_map(z, params),
        nu=jax.tree_util.tree_map(z, params),
        count=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.total_steps:
        frac = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = cfg.min_ratio + (1 - cfg.min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    else:
        cos = 1.0
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0
    count = state.count + 1
    lr = schedule(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count), {"grad_norm": gnorm, "lr": lr}
