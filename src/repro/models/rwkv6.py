"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free token mixing via a
data-dependent-decay linear recurrence + squared-ReLU channel mixing.

Per layer:
  time-mix: token-shift lerp -> r,k,v,g projections + LoRA decay w_t
            -> wkv6 recurrence (Pallas kernel on TPU; jnp oracle elsewhere)
            -> per-head RMS "group norm" -> SiLU(g) gate -> output proj
  channel-mix: token-shift lerp -> relu(W_k x)^2 -> W_v, gated by sigmoid(W_r x)

Decode state per layer: time-mix shift (B,D), channel-mix shift (B,D) and the
wkv state (B,H,dk,dv) — O(1) in sequence length, which is why `long_500k`
runs for this family (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys

LORA_RANK = 64
HEAD_DIM = 64  # dk = dv = 64 (RWKV-6 default)


def heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def init_rwkv_layer(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    h = heads(cfg)
    ks = split_keys(
        key,
        ["w_r", "w_k", "w_v", "w_g", "w_o", "lora_a", "lora_b", "cm_k", "cm_v", "cm_r"],
    )
    mu = lambda: jnp.full((d,), 0.5, jnp.float32)
    return {
        "tm": {
            "mu_r": mu(), "mu_k": mu(), "mu_v": mu(), "mu_w": mu(), "mu_g": mu(),
            "w_r": dense_init(ks["w_r"], (d, d)),
            "w_k": dense_init(ks["w_k"], (d, d)),
            "w_v": dense_init(ks["w_v"], (d, d)),
            "w_g": dense_init(ks["w_g"], (d, d)),
            "w_o": dense_init(ks["w_o"], (d, d)),
            "w0": jnp.full((d,), -3.0, jnp.float32),  # base decay (slow)
            "lora_a": dense_init(ks["lora_a"], (d, LORA_RANK)),
            "lora_b": dense_init(ks["lora_b"], (LORA_RANK, d)),
            "u": jnp.zeros((h, HEAD_DIM), jnp.float32),
            "ln_scale": jnp.zeros((d,), jnp.float32),
        },
        "cm": {
            "mu_k": mu(), "mu_r": mu(),
            "w_k": dense_init(ks["cm_k"], (d, f)),
            "w_v": dense_init(ks["cm_v"], (f, d)),
            "w_r": dense_init(ks["cm_r"], (d, d)),
        },
    }


def _shift(x, last):
    """Token shift: x_{t-1} with `last` filling t=0. Returns (shifted, new_last)."""
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def _lerp(x, prev, mu):
    return x + (prev - x) * mu.astype(x.dtype)


def _head_rms(x, scale, h):
    b, s, d = x.shape
    xh = x.reshape(b, s, h, d // h).astype(jnp.float32)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + 1e-6)
    return (xh.reshape(b, s, d) * (1.0 + scale)).astype(x.dtype)


def time_mix(p, cfg: ModelConfig, x, shift_last, wkv_state, use_pallas=False):
    """x: (B,S,D). Returns (out, new_shift_last, new_wkv_state)."""
    dt = cfg.compute_dtype
    b, s, d = x.shape
    h = heads(cfg)
    prev, new_last = _shift(x, shift_last)
    xr = _lerp(x, prev, p["mu_r"])
    xk = _lerp(x, prev, p["mu_k"])
    xv = _lerp(x, prev, p["mu_v"])
    xw = _lerp(x, prev, p["mu_w"])
    xg = _lerp(x, prev, p["mu_g"])

    r = jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(dt))
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"].astype(dt))
    g = jnp.einsum("bsd,de->bse", xg, p["w_g"].astype(dt))
    # data-dependent decay (f32): w_t = exp(-exp(w0 + tanh(x A) B))
    dd = jnp.einsum(
        "bsr,re->bse",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["lora_a"].astype(dt))),
        p["lora_b"].astype(dt),
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"] + dd))  # in (0,1)

    # reshape to (B*H, S, 64) slabs for the recurrence
    def to_heads(z):
        return (
            z.reshape(b, s, h, HEAD_DIM).transpose(0, 2, 1, 3).reshape(b * h, s, HEAD_DIM)
        )

    from repro.kernels import ops as kops

    u = jnp.broadcast_to(p["u"][None], (b, h, HEAD_DIM)).reshape(b * h, HEAD_DIM)
    o, new_state = kops.wkv6(
        to_heads(r).astype(jnp.float32),
        to_heads(k).astype(jnp.float32),
        to_heads(v).astype(jnp.float32),
        to_heads(w),
        u,
        wkv_state,
        use_pallas=use_pallas,
    )
    o = (
        o.reshape(b, h, s, HEAD_DIM).transpose(0, 2, 1, 3).reshape(b, s, d).astype(dt)
    )
    o = _head_rms(o, p["ln_scale"], h)
    o = o * jax.nn.silu(g)
    return jnp.einsum("bse,ed->bsd", o, p["w_o"].astype(dt)), new_last, new_state


def channel_mix(p, cfg: ModelConfig, x, shift_last):
    dt = cfg.compute_dtype
    prev, new_last = _shift(x, shift_last)
    xk = _lerp(x, prev, p["mu_k"])
    xr = _lerp(x, prev, p["mu_r"])
    kk = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["w_v"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(dt)))
    return rr * vv, new_last


def init_rwkv_state(cfg: ModelConfig, batch: int):
    h = heads(cfg)
    return {
        "tm_last": jnp.zeros((batch, cfg.d_model), cfg.compute_dtype),
        "cm_last": jnp.zeros((batch, cfg.d_model), cfg.compute_dtype),
        "wkv": jnp.zeros((batch * h, HEAD_DIM, HEAD_DIM), jnp.float32),
    }
