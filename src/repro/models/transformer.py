"""Decoder-only LM assembly for the dense / moe / hybrid / rwkv / vlm families.

Layer-kind plan: each architecture expands to a cyclic *pattern* of layer
kinds (dense: ("attn",); mixtral: ("attn",) with SWA; recurrentgemma:
("rglru","rglru","attn_local"); vlm: ("attn","attn","attn","cross","attn")).
Layers are stacked into `n_layers // len(pattern)` scanned *groups* plus an
unscanned tail of `n_layers % len(pattern)` layers — identical parameter
layout whether executed with `lax.scan` (production) or a python loop
(`scan_layers=False`, used by the dry-run delta method, DESIGN.md §7).

Params pytree:
  {"embed": (V,D), "groups": {<kind_i>: stacked (G, ...)}, "tail": [layer...],
   "final_norm": (D,), "unembed": (D,V)}
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.common import ModelConfig, dense_init, rms_norm, split_keys


# ---------------------------------------------------------------------------
# layer plans
# ---------------------------------------------------------------------------
def layer_pattern(cfg: ModelConfig) -> tuple:
    if cfg.family == "hybrid":
        return cfg.pattern or ("rglru", "rglru", "attn_local")
    if cfg.family == "vlm":
        k = cfg.cross_attn_every or 5
        return tuple("cross" if i == k - 2 else "attn" for i in range(k))
    if cfg.family == "rwkv":
        return ("rwkv",)
    if cfg.family == "moe":
        return ("attn_moe",)
    return ("attn",)


def plan(cfg: ModelConfig):
    pat = layer_pattern(cfg)
    return pat, cfg.n_layers // len(pat), cfg.n_layers % len(pat)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    ks = split_keys(key, ["a", "b"])
    norm = lambda: jnp.zeros((d,), jnp.float32)
    if kind == "rwkv":
        p = rwkv_lib.init_rwkv_layer(ks["a"], cfg)
        p["norm1"] = norm()
        p["norm2"] = norm()
        return p
    if kind == "rglru":
        return {"norm1": norm(), "mix": rglru_lib.init_rglru(ks["a"], cfg),
                "norm2": norm(), "ffn": ffn_lib.init_ffn(ks["b"], cfg)}
    if kind in ("attn", "attn_local"):
        return {"norm1": norm(), "attn": attn_lib.init_attention(ks["a"], cfg),
                "norm2": norm(), "ffn": ffn_lib.init_ffn(ks["b"], cfg)}
    if kind == "attn_moe":
        return {"norm1": norm(), "attn": attn_lib.init_attention(ks["a"], cfg),
                "norm2": norm(), "moe": moe_lib.init_moe(ks["b"], cfg)}
    if kind == "cross":
        return {"norm1": norm(),
                "attn": attn_lib.init_attention(ks["a"], cfg, cross=True),
                "norm2": norm(), "ffn": ffn_lib.init_ffn(ks["b"], cfg)}
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key) -> dict:
    pat, n_groups, tail = plan(cfg)
    ks = split_keys(key, ["embed", "groups", "tail", "unembed"])
    d = cfg.d_model

    def group_init(gkey):
        gks = jax.random.split(gkey, len(pat))
        return {f"{i}_{kind}": _init_layer(gks[i], cfg, kind)
                for i, kind in enumerate(pat)}

    params: dict[str, Any] = {
        "embed": dense_init(ks["embed"], (cfg.vocab, d), in_axis=1),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if n_groups:
        gkeys = jax.random.split(ks["groups"], n_groups)
        params["groups"] = jax.vmap(group_init)(gkeys)
    if tail:
        tkeys = jax.random.split(ks["tail"], tail)
        params["tail"] = [
            _init_layer(tkeys[i], cfg, pat[i % len(pat)]) for i in range(tail)
        ]
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks["unembed"], (d, cfg.vocab))
    return params


# ---------------------------------------------------------------------------
# forward (full-sequence: train / prefill)
# ---------------------------------------------------------------------------
def _apply_layer(lp, cfg: ModelConfig, kind: str, x, positions, ctx, state):
    """One layer. state: None (train) or this layer's decode state."""
    new_state = state
    if kind == "rwkv":
        h = rms_norm(x, lp["norm1"])
        if state is None:
            st = rwkv_lib.init_rwkv_state(cfg, x.shape[0])
        else:
            st = state
        o, tm_last, wkv = rwkv_lib.time_mix(
            lp["tm"], cfg, h, st["tm_last"], st["wkv"]
        )
        x = x + o
        h = rms_norm(x, lp["norm2"])
        o, cm_last = rwkv_lib.channel_mix(lp["cm"], cfg, h, st["cm_last"])
        x = x + o
        new_state = {"tm_last": tm_last, "cm_last": cm_last, "wkv": wkv}
    elif kind == "rglru":
        h = rms_norm(x, lp["norm1"])
        o, new_state = rglru_lib.rglru_block(lp["mix"], cfg, h, state)
        x = x + o
        x = x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm2"]))
    elif kind in ("attn", "attn_local"):
        window = cfg.local_window if kind == "attn_local" else None
        h = rms_norm(x, lp["norm1"])
        x = x + attn_lib.attention(lp["attn"], cfg, h, positions, layer_window=window)
        x = x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm2"]))
    elif kind == "attn_moe":
        h = rms_norm(x, lp["norm1"])
        x = x + attn_lib.attention(lp["attn"], cfg, h, positions)
        x = x + moe_lib.moe_ffn(lp["moe"], cfg, rms_norm(x, lp["norm2"]))
    elif kind == "cross":
        h = rms_norm(x, lp["norm1"])
        x = x + attn_lib.cross_attention(lp["attn"], cfg, h, ctx, gated=True)
        x = x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm2"]))
    else:
        raise ValueError(kind)
    return x, new_state


def _group_fn(cfg, pat):
    def fn(x, gparams, positions, ctx):
        for i, kind in enumerate(pat):
            x, _ = _apply_layer(gparams[f"{i}_{kind}"], cfg, kind, x, positions, ctx, None)
        return x

    return fn


def backbone(params, cfg: ModelConfig, tokens, ctx=None):
    """Token ids -> final hidden states (B,S,D)."""
    pat, n_groups, tail = plan(cfg)
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt) * cfg.embed_scale
    positions = jnp.broadcast_to(
        jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape
    )
    gfn = _group_fn(cfg, pat)
    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots" else None
        )
        gfn = jax.checkpoint(gfn, policy=policy)
    if n_groups:
        if cfg.scan_layers:
            def body(carry, gp):
                return gfn(carry, gp, positions, ctx), None

            x, _ = jax.lax.scan(body, x, params["groups"])
        else:
            for g in range(n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
                x = gfn(x, gp, positions, ctx)
    for i, lp in enumerate(params.get("tail", [])):
        x, _ = _apply_layer(lp, cfg, pat[i % len(pat)], x, positions, ctx, None)
    return rms_norm(x, params["final_norm"])


def unembed_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def lm_loss(params, cfg: ModelConfig, hidden, labels):
    """Chunked-softmax cross-entropy — never materializes (B,S,V) at once.

    Operands stay bf16 (MXU-native); accumulation is f32 via
    preferred_element_type, so only the (B, chunk, V) logits chunk is ever
    f32 — this halves the CE working set vs casting hidden/unembed to f32.
    """
    b, s, d = hidden.shape
    w = unembed_matrix(params, cfg).astype(cfg.compute_dtype)
    chunk = min(cfg.logit_chunk or s, s)
    n = (s + chunk - 1) // chunk
    total = jnp.float32(0)
    count = jnp.float32(0)
    for i in range(n):
        h = hidden[:, i * chunk : (i + 1) * chunk].astype(cfg.compute_dtype)
        y = labels[:, i * chunk : (i + 1) * chunk]
        logits = jnp.einsum(
            "bsd,dv->bsv", h, w, preferred_element_type=jnp.float32
        )
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(lse - gold)
        count = count + y.size
    return total / count


def forward_loss(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    ctx = batch.get("img") if isinstance(batch, dict) else None
    hidden = backbone(params, cfg, batch["tokens"], ctx=ctx)
    return lm_loss(params, cfg, hidden, batch["labels"])


def last_logits(params, cfg: ModelConfig, hidden):
    w = unembed_matrix(params, cfg).astype(cfg.compute_dtype)
    return jnp.einsum(
        "bd,dv->bv", hidden[:, -1].astype(cfg.compute_dtype), w,
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# decode (single-token serve step)
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int, ctx_len: int = 0):
    """Per-layer decode state, stacked like the params (groups + tail)."""
    pat, n_groups, tail = plan(cfg)

    def one(kind):
        if kind == "rwkv":
            return rwkv_lib.init_rwkv_state(cfg, batch)
        if kind == "rglru":
            return rglru_lib.init_rglru_state(cfg, batch)
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        window = cfg.local_window if kind == "attn_local" else cfg.swa_window
        s = max_seq
        if window and cfg.ring_cache:
            s = min(max_seq, window)  # ring buffer (§Perf optimization)
        return {
            "k": jnp.zeros((batch, kv, s, hd), cfg.compute_dtype),
            "v": jnp.zeros((batch, kv, s, hd), cfg.compute_dtype),
        }

    def group_state():
        return {f"{i}_{kind}": one(kind) for i, kind in enumerate(pat)}

    state = {}
    if n_groups:
        state["groups"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), group_state()
        )
    if tail:
        state["tail"] = [one(pat[i % len(pat)]) for i in range(tail)]
    return state


def _decode_layer(lp, cfg: ModelConfig, kind: str, x, pos, ctx, st):
    if kind in ("rwkv", "rglru"):
        return _apply_layer(lp, cfg, kind, x, None, ctx, st)
    if kind == "cross":
        h = rms_norm(x, lp["norm1"])
        x = x + attn_lib.cross_attention(lp["attn"], cfg, h, ctx, gated=True)
        x = x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm2"]))
        return x, st
    window = cfg.local_window if kind == "attn_local" else None
    h = rms_norm(x, lp["norm1"])
    o, ck, cv = attn_lib.decode_attention(
        lp["attn"], cfg, h, st["k"], st["v"], pos, layer_window=window
    )
    x = x + o
    if kind == "attn_moe":
        x = x + moe_lib.moe_ffn(lp["moe"], cfg, rms_norm(x, lp["norm2"]))
    else:
        x = x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm2"]))
    return x, {"k": ck, "v": cv}


def decode_step(params, cfg: ModelConfig, state, token, pos, ctx=None):
    """One serve step: token (B,1) at scalar position `pos`.

    Returns (logits (B,V) f32, new_state).
    """
    pat, n_groups, tail = plan(cfg)
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"], token, axis=0).astype(dt) * cfg.embed_scale

    def gbody(x, inputs):
        gp, gst = inputs
        new = {}
        for i, kind in enumerate(pat):
            nm = f"{i}_{kind}"
            x, new[nm] = _decode_layer(gp[nm], cfg, kind, x, pos, ctx, gst[nm])
        return x, new

    if n_groups:
        if cfg.scan_layers:
            x, new_gstate = jax.lax.scan(gbody, x, (params["groups"], state["groups"]))
        else:
            outs = []
            for g in range(n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
                gst = jax.tree_util.tree_map(lambda a: a[g], state["groups"])
                x, ns = gbody(x, (gp, gst))
                outs.append(ns)
            new_gstate = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *outs)
        state = dict(state, groups=new_gstate)
    if tail:
        new_tail = []
        for i, lp in enumerate(params["tail"]):
            x, ns = _decode_layer(lp, cfg, pat[i % len(pat)], x, pos, ctx, state["tail"][i])
            new_tail.append(ns)
        state = dict(state, tail=new_tail)
    hidden = rms_norm(x, params["final_norm"])
    return last_logits(params, cfg, hidden), state
