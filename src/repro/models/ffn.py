"""Feed-forward layers: gated (SwiGLU/GeGLU) and plain MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def is_gated(act: str) -> bool:
    return act in ("silu", "geglu", "swiglu", "gelu_glu")


def _gate_fn(act: str):
    if act in ("silu", "swiglu"):
        return _ACTS["silu"]
    if act in ("geglu", "gelu_glu"):
        return _ACTS["gelu"]
    return _ACTS[act]


def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if is_gated(cfg.act):
        ks = split_keys(key, ["w_gate", "w_up", "w_down"])
        return {
            "w_gate": dense_init(ks["w_gate"], (d, f)),
            "w_up": dense_init(ks["w_up"], (d, f)),
            "w_down": dense_init(ks["w_down"], (f, d)),
        }
    ks = split_keys(key, ["w_up", "w_down"])
    return {
        "w_up": dense_init(ks["w_up"], (d, f)),
        "w_down": dense_init(ks["w_down"], (f, d)),
    }


def ffn(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    dt = cfg.compute_dtype
    if is_gated(cfg.act):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = _gate_fn(cfg.act)(g) * h
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = _ACTS[cfg.act](h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
