"""Unified model API — family dispatch for the assigned architecture pool.

  init_params(cfg, key)                 -> params pytree
  forward_loss(params, cfg, batch)      -> scalar loss  (train)
  prefill_logits(params, cfg, batch)    -> (B, V) last-position logits
  init_decode_state(cfg, batch, seq)    -> decode-state pytree
  decode_step(params, cfg, state, ...)  -> (logits, new state)   (serve)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import transformer, whisper
from repro.models.common import ModelConfig


def init_params(cfg: ModelConfig, key):
    if cfg.family == "encdec":
        return whisper.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def forward_loss(params, cfg: ModelConfig, batch):
    if cfg.family == "encdec":
        return whisper.forward_loss(params, cfg, batch)
    return transformer.forward_loss(params, cfg, batch)


def prefill_logits(params, cfg: ModelConfig, batch):
    """Inference-prefill: full-sequence forward, last-position logits.

    (Cache emission during prefill is byte-traffic ≈ the KV cache size and is
    accounted analytically in the roofline notes — see DESIGN.md §7/§Perf.)
    """
    if cfg.family == "encdec":
        enc_out = whisper.encode(params, cfg, batch["frames"])
        import jax

        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
        positions = jnp.broadcast_to(
            jnp.arange(batch["tokens"].shape[1], dtype=jnp.int32)[None],
            batch["tokens"].shape,
        )
        def layer(lp, x):
            return whisper._dec_layer(lp, cfg, x, positions, enc_out)

        fn = jax.checkpoint(layer) if cfg.remat else layer
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["dec"])
        else:
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i], params["dec"])
                x = fn(lp, x)
        hidden = whisper.rms_norm(x, params["final_norm"])
        return transformer.last_logits(params, cfg, hidden)
    ctx = batch.get("img")
    hidden = transformer.backbone(params, cfg, batch["tokens"], ctx=ctx)
    return transformer.last_logits(params, cfg, hidden)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.family == "encdec":
        return whisper.init_decode_state(cfg, batch, max_seq)
    return transformer.init_decode_state(cfg, batch, max_seq)


def decode_step(params, cfg: ModelConfig, state, token, pos, ctx=None):
    """ctx: encoder output (encdec) or image embeddings (vlm); else None."""
    if cfg.family == "encdec":
        return whisper.decode_step(params, cfg, state, token, pos, ctx)
    return transformer.decode_step(params, cfg, state, token, pos, ctx=ctx)
