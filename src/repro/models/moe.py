"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP/TP-shardable).

Design (DESIGN.md §3): router → top-k → flatten assignments → stable sort by
expert id → rank-within-expert → capacity-bounded slotting → gather tokens
into (E, C, D) → per-expert gated-FFN einsum → weighted scatter-add combine.

Unlike the one-hot GShard dispatch einsum (whose FLOPs are quadratic in
tokens), sort-based dispatch is gather/scatter (memory-bound) and the expert
compute is exactly ``2·T·top_k·capacity_factor·(3·D·F)`` — so the roofline
compute term honestly reflects *active* parameters.  Capacity overflow drops
tokens (standard "dropping" MoE); the residual stream carries them unchanged.

Sharding intent: experts over the 'model' axis (EP) when E % model == 0
(qwen3-moe: 128/16), else intra-expert TP on F (mixtral: E=8 < 16).
Token/capacity axes follow the data axis.  The argsort over T·k assignments
is the main collective cost at scale — measured in DESIGN.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys
from repro.models.ffn import _gate_fn


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = split_keys(key, ["router", "w_gate", "w_up", "w_down"])
    return {
        "router": dense_init(ks["router"], (d, e)),
        "w_gate": dense_init(ks["w_gate"], (e, d, f), in_axis=1),
        "w_up": dense_init(ks["w_up"], (e, d, f), in_axis=1),
        "w_down": dense_init(ks["w_down"], (e, f, d), in_axis=1),
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


def moe_ffn(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    dt = cfg.compute_dtype
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, t)
    xt = x.reshape(t, d)

    # --- route (f32 for numerics) ---
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (t, k)
    if cfg.renorm_gates:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch ---
    flat_expert = expert_idx.reshape(-1)  # (t*k,)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)  # (t*k,)
    sorted_expert = flat_expert[order]
    # rank within expert: position − first-occurrence index of that expert
    first = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
    rank = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = rank < c
    slot = jnp.where(keep, sorted_expert * c + rank, e * c)  # e*c = dropped bin

    # slot -> source token / gate (scatter into E*C+1 buffers, drop the tail)
    token_for_slot = jnp.zeros((e * c + 1,), jnp.int32).at[slot].set(
        flat_token[order], mode="drop"
    )[: e * c]
    gate_for_slot = jnp.zeros((e * c + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, flat_gate[order], 0.0), mode="drop"
    )[: e * c]
    valid = jnp.zeros((e * c + 1,), jnp.bool_).at[slot].set(keep, mode="drop")[: e * c]

    x_g = jnp.take(xt, token_for_slot, axis=0).reshape(e, c, d)
    x_g = jnp.where(valid.reshape(e, c, 1), x_g, 0).astype(dt)

    def tokstat(z):
        """2-D MoE sharding: pin the capacity axis to 'data' while the expert
        f-dim stays on 'model' — the (E, C, ·) tensors then carry BOTH axes
        and the w_down psum payload shrinks n_data-fold.  (Sharding C over
        (data, model) jointly conflicts with the f-sharded weights and makes
        GSPMD replicate — measured, see §Perf.)"""
        if not cfg.moe_token_stationary:
            return z
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(z, P(None, "data", None))

    # --- expert compute (exact active FLOPs) ---
    x_g = tokstat(x_g)
    g = jnp.einsum("ecd,edf->ecf", x_g, p["w_gate"].astype(dt))
    h = jnp.einsum("ecd,edf->ecf", x_g, p["w_up"].astype(dt))
    h = tokstat(_gate_fn(cfg.act)(g) * h)
    y_g = tokstat(jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt)))

    # --- combine (gate-weighted scatter-add) ---
    y_flat = (y_g.reshape(e * c, d).astype(jnp.float32)) * gate_for_slot[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[token_for_slot].add(
        jnp.where(valid[:, None], y_flat, 0.0)
    )
    return out.reshape(b, s, d).astype(dt)


def router_load(cfg: ModelConfig, x: jnp.ndarray, p: dict):
    """Diagnostics: per-expert assignment counts and dropped-token fraction."""
    b, s, d = x.shape
    t = b * s
    logits = jnp.einsum("td,de->te", x.reshape(t, d).astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    _, expert_idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    counts = jnp.bincount(expert_idx.reshape(-1), length=cfg.n_experts)
    c = capacity(cfg, t)
    dropped = jnp.maximum(counts - c, 0).sum() / (t * cfg.top_k)
    return counts, dropped
