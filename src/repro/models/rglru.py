"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  y = W_out( GeLU(W_gmlp x) ⊙ RG-LRU(conv1d(W_x x)) )

RG-LRU per channel::

    r_t = sigmoid(W_r u_t + b_r)        # recurrence gate
    i_t = sigmoid(W_i u_t + b_i)        # input gate
    a_t = exp(c * r_t * log(sigmoid(Lambda)))     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Train/prefill uses `jax.lax.associative_scan` (log-depth, parallelizes over
the sequence — the sub-quadratic path that makes `long_500k` feasible);
decode carries `h` plus the causal-conv tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, split_keys

_C = 8.0


def init_rglru(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    lru = cfg.lru_width or d
    w = cfg.conv1d_width
    ks = split_keys(key, ["w_x", "w_gmlp", "conv", "w_r", "w_i", "lam", "w_out"])
    return {
        "w_x": dense_init(ks["w_x"], (d, lru)),
        "w_gmlp": dense_init(ks["w_gmlp"], (d, lru)),
        "conv_w": dense_init(ks["conv"], (w, lru)),
        "conv_b": jnp.zeros((lru,), jnp.float32),
        "w_r": dense_init(ks["w_r"], (lru, lru)),
        "b_r": jnp.zeros((lru,), jnp.float32),
        "w_i": dense_init(ks["w_i"], (lru, lru)),
        "b_i": jnp.zeros((lru,), jnp.float32),
        # Lambda init so that a = sigmoid(lam) in ~[0.9, 0.999]
        "lam": jnp.linspace(2.2, 6.9, lru, dtype=jnp.float32),
        "w_out": dense_init(ks["w_out"], (lru, d)),
    }


def causal_conv1d(u, conv_w, conv_b, state=None):
    """Depthwise causal conv. u: (B,S,C), conv_w: (W,C).

    state: (B, W-1, C) trailing inputs from the previous segment (decode).
    Returns (y, new_state).
    """
    w = conv_w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], w - 1, u.shape[-1]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)  # (B, S+W-1, C)
    y = sum(
        ext[:, i : i + u.shape[1]] * conv_w[i].astype(u.dtype) for i in range(w)
    ) + conv_b.astype(u.dtype)
    return y, ext[:, -(w - 1) :]


def _gates(p, u, dt):
    r = jax.nn.sigmoid(
        jnp.einsum("bsc,ck->bsk", u, p["w_r"].astype(dt)).astype(jnp.float32)
        + p["b_r"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsc,ck->bsk", u, p["w_i"].astype(dt)).astype(jnp.float32)
        + p["b_i"]
    )
    log_a = -jax.nn.softplus(-p["lam"])  # log sigmoid(lam)  (f32)
    a = jnp.exp(_C * r * log_a)  # (B,S,C) f32
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * i * u.astype(jnp.float32)
    return a, gated


def rglru_scan(p, cfg: ModelConfig, u, h0=None):
    """Sequence-parallel RG-LRU. u: (B,S,C). Returns (y (B,S,C), h_last)."""
    dt = cfg.compute_dtype
    a, bterm = _gates(p, u, dt)
    if h0 is not None:
        # fold initial state in as a virtual step: h_t = (prod a) h0 + ...
        bterm = bterm.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    return h.astype(dt), h[:, -1]


def rglru_step(p, cfg: ModelConfig, u_t, h):
    """One decode step. u_t: (B,1,C); h: (B,C). Returns (y (B,1,C), h)."""
    a, bterm = _gates(p, u_t, cfg.compute_dtype)
    h = a[:, 0] * h.astype(jnp.float32) + bterm[:, 0]
    return h[:, None].astype(cfg.compute_dtype), h


def rglru_block(p, cfg: ModelConfig, x, state=None):
    """Full Griffin recurrent block.

    x: (B,S,D).  state: None (train/prefill) or dict(conv, h) for decode.
    Returns (y (B,S,D), new_state).
    """
    dt = cfg.compute_dtype
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dc->bsc", x, p["w_gmlp"].astype(dt)), approximate=True
    )
    u = jnp.einsum("bsd,dc->bsc", x, p["w_x"].astype(dt))
    conv_state = state["conv"] if state is not None else None
    u, new_conv = causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state)
    if state is None:
        h_seq, h_last = rglru_scan(p, cfg, u)
    else:
        h_seq, h_last = rglru_step(p, cfg, u, state["h"])
    y = jnp.einsum("bsc,cd->bsd", gate * h_seq, p["w_out"].astype(dt))
    return y, {"conv": new_conv, "h": h_last}


def init_rglru_state(cfg: ModelConfig, batch: int):
    lru = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, lru), cfg.compute_dtype),
        "h": jnp.zeros((batch, lru), jnp.float32),
    }
