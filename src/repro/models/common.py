"""Shared model components: config, norms, RoPE, embeddings, initializers.

Everything takes/returns plain pytrees (nested dicts of jnp arrays) — no
framework dependency — so parameters stack cleanly for `lax.scan` over layers
and shard with simple PartitionSpec rules (repro/launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description (one instance per assigned arch in configs/)."""

    name: str
    family: str  # dense | moe | hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"  # silu | gelu | geglu-style gating handled by ffn
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    renorm_gates: bool = True
    # token-stationary MoE: capacity axis sharded over (data, model); expert
    # weights all-gathered per layer instead of all-reducing the (E,C,D)
    # activation tensor — the §Perf fix for small-E archs (mixtral: E=8 < 16)
    moe_token_stationary: bool = False
    # --- attention variants ---
    swa_window: int = 0  # sliding-window size; 0 = full causal
    attn_chunk: int = 0  # 0 = dense scores; else flash-style chunked
    ring_cache: bool = False  # windowed decode: ring-buffer KV (W slots) vs full S
    # --- hybrid (RG-LRU / Griffin) ---
    pattern: tuple = ()  # cyclic layer pattern, e.g. ("rglru","rglru","attn")
    lru_width: int = 0
    conv1d_width: int = 4
    local_window: int = 2048  # hybrid local-attention window
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 1500  # precomputed frame embeddings (stub frontend)
    # --- vlm ---
    cross_attn_every: int = 0  # every k-th layer is cross-attn (0 = none)
    img_tokens: int = 0
    # --- numerics / execution ---
    dtype: str = "bfloat16"  # matmul/activation dtype
    param_dtype: str = "float32"  # master weights
    remat: bool = True
    remat_policy: str = "full"  # full (recompute all) | dots (save matmul outs)
    scan_layers: bool = True  # False: python-unrolled (dry-run delta method)
    logit_chunk: int = 512  # CE computed in seq chunks of this size
    tie_embeddings: bool = False
    embed_scale: float = 1.0  # sqrt(d_model) for gemma-family

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_params(self) -> int:
        """Total parameter count (analytic; used for MODEL_FLOPS)."""
        return param_count(self)

    @property
    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: only routed experts)."""
        return param_count(self, active_only=True)


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic parameter count, matching init_params leaf sizes."""
    d, hd = cfg.d_model, cfg.head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.qk_norm:
        attn += 2 * hd
    gated = cfg.act in ("silu", "gelu_glu", "geglu", "swiglu")
    dense_ffn = (3 if gated else 2) * d * cfg.d_ff
    per_layer_norms = 2 * d
    emb = cfg.vocab * d
    out = 0
    if cfg.family == "moe":
        e_used = cfg.top_k if active_only else cfg.n_experts
        ffn = e_used * (3 * d * cfg.d_ff) + d * cfg.n_experts
        out = cfg.n_layers * (attn + ffn + per_layer_norms)
    elif cfg.family == "rwkv":
        # time-mix: r,k,v,g,o (5 d^2) + decay lora (2*64d) + bonus u (d)
        tm = 5 * d * d + 2 * d * 64 + d
        cm = 2 * d * cfg.d_ff + d * d  # channel-mix k/v + receptance gate
        out = cfg.n_layers * (tm + cm + per_layer_norms)
    elif cfg.family == "hybrid":
        n_attn = sum(1 for i in range(cfg.n_layers) if _hybrid_kind(cfg, i) == "attn")
        n_rec = cfg.n_layers - n_attn
        lru = cfg.lru_width or d
        rec = 2 * d * lru + lru * cfg.conv1d_width + 3 * lru + lru * d + 2 * lru * lru
        out = n_attn * (attn + dense_ffn + per_layer_norms) + n_rec * (
            rec + dense_ffn + per_layer_norms
        )
    elif cfg.family == "encdec":
        enc = cfg.enc_layers * (attn + dense_ffn + per_layer_norms)
        dec = cfg.n_layers * (2 * attn + dense_ffn + 3 * d)
        out = enc + dec
    elif cfg.family == "vlm":
        n_cross = sum(1 for i in range(cfg.n_layers) if _is_cross_layer(cfg, i))
        out = (cfg.n_layers - n_cross) * (attn + dense_ffn + per_layer_norms) + n_cross * (
            attn + dense_ffn + per_layer_norms + d  # gate
        )
    else:  # dense
        out = cfg.n_layers * (attn + dense_ffn + per_layer_norms)
    out += emb + d  # embedding + final norm
    if not cfg.tie_embeddings:
        out += cfg.vocab * d  # untied unembed
    return out


def _hybrid_kind(cfg: ModelConfig, i: int) -> str:
    return cfg.pattern[i % len(cfg.pattern)] if cfg.pattern else "attn"


def _is_cross_layer(cfg: ModelConfig, i: int) -> bool:
    # Llama-3.2-Vision style: cross-attn at layers 3, 8, 13, ... (every 5th).
    k = cfg.cross_attn_every
    return bool(k) and (i % k == k - 2)


# ----------------------------------------------------------------------------
# primitives
# ----------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, n, head_dim); positions: (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(n: int, d: int) -> jnp.ndarray:
    """Classic transformer sinusoidal table (whisper encoder)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------------
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis] if in_axis >= 0 else int(jnp.prod(jnp.asarray(shape[:-1])))
    scale = 1.0 / jnp.sqrt(jnp.float32(max(fan_in, 1)))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, names):
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))
