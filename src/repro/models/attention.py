"""Attention layers: GQA/MQA, qk-norm, sliding-window/local, cross-attn,
flash-style chunked computation, and KV-cache decode.

Conventions:
  x: (B, S, D); q: (B, S, H, hd); k/v: (B, S, KV, hd); cache k/v: (B, KV, S, hd)
  (cache layout puts S after KV so the *sequence* axis can be sharded over the
  'model' mesh axis for decode — GQA kv-head counts (1–8) don't divide 16;
  see DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, apply_rope, dense_init, rms_norm, split_keys

NEG_INF = -2.3819763e38  # large negative for masking (bf16-safe)


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    p = {
        "wq": dense_init(ks["wq"], (d, h, hd)),
        "wk": dense_init(ks["wk"], (d, kv, hd)),
        "wv": dense_init(ks["wv"], (d, kv, hd)),
        "wo": dense_init(ks["wo"], (h, hd, d), in_axis=0),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    if cross:
        p["gate"] = jnp.zeros((1,), jnp.float32)  # llama-3.2-vision tanh gate
    return p


def _project_qkv(p, cfg: ModelConfig, x, kv_x=None):
    """Project to q, k, v (kv_x: cross-attention context)."""
    dt = cfg.compute_dtype
    x = x.astype(dt)
    kv_src = x if kv_x is None else kv_x.astype(dt)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dgk->btgk", kv_src, p["wk"].astype(dt))
    v = jnp.einsum("btd,dgk->btgk", kv_src, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _gqa_scores(q, k, scale):
    """(B,S,H,hd) x (B,T,KV,hd) -> (B, KV, H/KV, S, T) grouped scores."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, s, kv, h // kv, hd)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) * scale


def _gqa_out(probs, v):
    """(B,KV,G,S,T) x (B,T,KV,hd) -> (B,S,H,hd)."""
    b, kv, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, kv * g, v.shape[-1])


def _causal_mask(s, t, offset: int = 0, window: int = 0):
    """(s, t) boolean keep-mask. offset = (kv length − q length)."""
    qi = jax.lax.broadcasted_iota(jnp.int32, (s, t), 0) + offset
    kj = jax.lax.broadcasted_iota(jnp.int32, (s, t), 1)
    keep = kj <= qi
    if window:
        keep &= kj > qi - window
    return keep


def attend_full(q, k, v, cfg: ModelConfig, *, causal=True, offset=0):
    """Dense-scores attention (train/prefill path for moderate S)."""
    scale = cfg.head_dim**-0.5
    scores = _gqa_scores(q, k, scale).astype(jnp.float32)
    if causal:
        keep = _causal_mask(q.shape[1], k.shape[1], offset, cfg.swa_window)
        scores = jnp.where(keep[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_out(probs, v)


def attend_chunked(q, k, v, cfg: ModelConfig, *, chunk: int, window: int = 0):
    """Flash-style causal attention: static triangular loop over chunks.

    Online-softmax accumulation over kv chunks keeps the live score block at
    (B, KV, G, c, c) instead of (…, S, S) — the 32k-prefill memory fix.  The
    triangular structure is *static* (python loop), so HLO contains only the
    ~(n²/2) needed blocks and the roofline FLOP count stays honest (no wasted
    upper-triangle compute).  With `window`, off-diagonal blocks outside the
    sliding window are skipped entirely (mixtral/recurrentgemma local attn).
    """
    b, s, h, hd = q.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    scale = hd**-0.5
    kvh = k.shape[2]
    outs = []
    for i in range(n):
        qi = q[:, i * chunk : (i + 1) * chunk]
        m = jnp.full((b, kvh, h // kvh, chunk, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((b, kvh, h // kvh, chunk, 1), jnp.float32)
        acc = jnp.zeros((b, kvh, h // kvh, chunk, hd), jnp.float32)
        j_lo = 0
        if window:
            j_lo = max(0, (i * chunk - window + 1) // chunk)
        for jc in range(j_lo, i + 1):
            kj = k[:, jc * chunk : (jc + 1) * chunk]
            vj = v[:, jc * chunk : (jc + 1) * chunk]
            sc = _gqa_scores(qi, kj, scale).astype(jnp.float32)
            if jc == i or window:
                keep = _causal_mask(chunk, chunk, offset=(i - jc) * chunk, window=window)
                sc = jnp.where(keep[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1, keepdims=True))
            p = jnp.exp(sc - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(q.dtype), vj
            ).astype(jnp.float32)
            m = m_new
        out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
        outs.append(out.reshape(b, chunk, h, hd))
    return jnp.concatenate(outs, axis=1)


def attention(p, cfg: ModelConfig, x, positions, *, layer_window: int | None = None):
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.swa_window if layer_window is None else layer_window
    if cfg.attn_chunk and x.shape[1] > cfg.attn_chunk:
        ctx = attend_chunked(q, k, v, cfg, chunk=cfg.attn_chunk, window=window)
    else:
        if layer_window is not None:
            # local-attention layer in a hybrid stack
            scale = cfg.head_dim**-0.5
            scores = _gqa_scores(q, k, scale).astype(jnp.float32)
            keep = _causal_mask(x.shape[1], x.shape[1], 0, window)
            scores = jnp.where(keep[None, None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
            ctx = _gqa_out(probs, v)
        else:
            ctx = attend_full(q, k, v, cfg, causal=True)
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(cfg.compute_dtype))


def cross_attention(p, cfg: ModelConfig, x, context, *, gated=False):
    """Cross-attention (whisper decoder / llama-vision image layers)."""
    q, k, v = _project_qkv(p, cfg, x, kv_x=context)
    scale = cfg.head_dim**-0.5
    scores = _gqa_scores(q, k, scale).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = _gqa_out(probs, v)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(cfg.compute_dtype))
    if gated:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return out


# ---------------------------------------------------------------------------
# decode path (KV cache)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_seq: int):
    """Cache layout (layers, B, KV, S, hd); S shardable over 'model'."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, batch, kv, max_seq, hd)
    return {
        "k": jnp.zeros(shape, cfg.compute_dtype),
        "v": jnp.zeros(shape, cfg.compute_dtype),
    }


def decode_attention(p, cfg: ModelConfig, x, cache_k, cache_v, pos, *,
                     layer_window: int | None = None):
    """One-token self-attention against a cache.

    Two cache layouts (DESIGN.md §4/§Perf):
      * full:  cache (B, KV, S, hd), write at `pos`, mask to causality (and
        the sliding window if any).
      * ring (``cfg.ring_cache``, windowed layers only): cache (B, KV, W, hd)
        with W = window; write at ``pos % W``.  Keys carry RoPE at their true
        position, so slot order is irrelevant to the scores; every slot is in
        the window by construction once warm (slots > pos masked while cold).
        Cuts decode cache traffic S/W-fold for SWA/local-attention archs.

    Args:
      x: (B, 1, D); cache_k/v: (B, KV, S|W, hd); pos: scalar position.
    Returns (out (B,1,D), new cache_k, new cache_v).
    """
    dt = cfg.compute_dtype
    window = cfg.swa_window if layer_window is None else layer_window
    ring = bool(window) and cfg.ring_cache
    q, k_new, v_new = _project_qkv(p, cfg, x)
    q = apply_rope(q, jnp.full((x.shape[0], 1), pos), cfg.rope_theta)
    k_new = apply_rope(k_new, jnp.full((x.shape[0], 1), pos), cfg.rope_theta)
    k_in = k_new.transpose(0, 2, 1, 3).astype(dt)  # (B, KV, 1, hd)
    v_in = v_new.transpose(0, 2, 1, 3).astype(dt)
    slot = (pos % cache_k.shape[2]) if ring else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_in, slot, axis=2)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_in, slot, axis=2)

    b, kv, s, hd = cache_k.shape
    h = q.shape[2]
    qg = q.reshape(b, 1, kv, h // kv, hd)
    scores = jnp.einsum("bokgd,bktd->bkgot", qg, cache_k) * (hd**-0.5)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (s,), 0)
    if ring:
        keep = t_idx <= pos  # cold-start only; warm ring is fully valid
    else:
        keep = t_idx <= pos
        if window:
            keep &= t_idx > pos - window
    scores = jnp.where(keep[None, None, None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bkgot,bktd->bokgd", probs, cache_v).reshape(b, 1, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(dt))
    return out, cache_k, cache_v
