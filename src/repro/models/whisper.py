"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the brief: `input_specs()` supplies
precomputed frame embeddings (B, enc_seq, D) — the two stride-2 conv1d layers
would map 30 s of log-mel (3000 frames) to 1500 positions; we start there.
"24L" (whisper-medium) is interpreted as 24 encoder + 24 decoder layers, the
published architecture (DESIGN.md §5).

Encoder: bidirectional self-attn + GELU MLP, sinusoidal positions.
Decoder: causal self-attn (KV-cached for serve) + cross-attn + GELU MLP,
learned positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models.common import (
    ModelConfig, dense_init, rms_norm, sinusoid_positions, split_keys,
)
from repro.models.transformer import lm_loss, last_logits


def init_enc_layer(key, cfg: ModelConfig):
    ks = split_keys(key, ["a", "b"])
    d = cfg.d_model
    return {"norm1": jnp.zeros((d,)), "attn": attn_lib.init_attention(ks["a"], cfg),
            "norm2": jnp.zeros((d,)), "ffn": ffn_lib.init_ffn(ks["b"], cfg)}


def init_dec_layer(key, cfg: ModelConfig):
    ks = split_keys(key, ["a", "b", "c"])
    d = cfg.d_model
    return {
        "norm1": jnp.zeros((d,)), "self": attn_lib.init_attention(ks["a"], cfg),
        "norm2": jnp.zeros((d,)), "cross": attn_lib.init_attention(ks["b"], cfg),
        "norm3": jnp.zeros((d,)), "ffn": ffn_lib.init_ffn(ks["c"], cfg),
    }


def init_params(cfg: ModelConfig, key):
    ks = split_keys(key, ["enc", "dec", "embed", "unembed", "pos"])
    ek = jax.random.split(ks["enc"], cfg.enc_layers)
    dk = jax.random.split(ks["dec"], cfg.n_layers)
    d = cfg.d_model
    return {
        "enc": jax.vmap(lambda k: init_enc_layer(k, cfg))(ek),
        "enc_norm": jnp.zeros((d,)),
        "dec": jax.vmap(lambda k: init_dec_layer(k, cfg))(dk),
        "embed": dense_init(ks["embed"], (cfg.vocab, d), in_axis=1),
        "final_norm": jnp.zeros((d,)),
        "unembed": dense_init(ks["unembed"], (d, cfg.vocab)),
    }


def _enc_layer(lp, cfg, x):
    h = rms_norm(x, lp["norm1"])
    q, k, v = attn_lib._project_qkv(lp["attn"], cfg, h)
    ctx = attn_lib.attend_full(q, k, v, cfg, causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", ctx, lp["attn"]["wo"].astype(cfg.compute_dtype))
    return x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm2"]))


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, enc_seq, D) stub embeddings -> encoder states."""
    dt = cfg.compute_dtype
    x = frames.astype(dt) + sinusoid_positions(frames.shape[1], cfg.d_model).astype(dt)

    def layer(lp, x):  # cfg captured statically by closure (remat-safe)
        return _enc_layer(lp, cfg, x)

    fn = jax.checkpoint(layer) if cfg.remat else layer
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["enc"])
    else:
        for i in range(cfg.enc_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["enc"])
            x = fn(lp, x)
    return rms_norm(x, params["enc_norm"])


def _dec_layer(lp, cfg, x, positions, enc_out):
    h = rms_norm(x, lp["norm1"])
    x = x + attn_lib.attention(lp["self"], cfg, h, positions)
    h = rms_norm(x, lp["norm2"])
    x = x + attn_lib.cross_attention(lp["cross"], cfg, h, enc_out)
    return x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm3"]))


def forward_loss(params, cfg: ModelConfig, batch):
    """batch: {"frames": (B,enc_seq,D), "tokens": (B,S), "labels": (B,S)}."""
    dt = cfg.compute_dtype
    enc_out = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = jnp.broadcast_to(
        jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape
    )
    def layer(lp, x):
        return _dec_layer(lp, cfg, x, positions, enc_out)

    fn = jax.checkpoint(layer) if cfg.remat else layer
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (fn(lp, c), None), x, params["dec"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["dec"])
            x = fn(lp, x)
    hidden = rms_norm(x, params["final_norm"])
    return lm_loss(params, cfg, hidden, batch["labels"])


# --- serve ------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    z = lambda: jnp.zeros((cfg.n_layers, batch, kv, max_seq, hd), cfg.compute_dtype)
    return {"k": z(), "v": z()}


def decode_step(params, cfg: ModelConfig, state, token, pos, enc_out):
    """One decoder token against cached self-attn KV + (re)computed cross-KV.

    Production serving would precompute cross-attn K/V once per request; here
    cross K/V are recomputed from enc_out each step — an explicit perf
    trade-off candidate measured in §Perf (whisper decode cell).
    """
    dt = cfg.compute_dtype
    x = jnp.take(params["embed"], token, axis=0).astype(dt)

    def body(x, inputs):
        lp, ck, cv = inputs
        h = rms_norm(x, lp["norm1"])
        o, ck, cv = attn_lib.decode_attention(lp["self"], cfg, h, ck, cv, pos)
        x = x + o
        h = rms_norm(x, lp["norm2"])
        x = x + attn_lib.cross_attention(lp["cross"], cfg, h, enc_out)
        x = x + ffn_lib.ffn(lp["ffn"], cfg, rms_norm(x, lp["norm3"]))
        return x, (ck, cv)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, (params["dec"], state["k"], state["v"]))
        state = {"k": ks, "v": vs}
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["dec"])
            x, (ck, cv) = body(x, (lp, state["k"][i], state["v"][i]))
            ks.append(ck)
            vs.append(cv)
        state = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    hidden = rms_norm(x, params["final_norm"])
    return last_logits(params, cfg, hidden), state
