"""Deterministic synthetic token pipeline.

Stateless hash-based generation: `batch(step)` is a pure function of
(seed, step, shard), so

* every host generates exactly its own shard (no data redistribution),
* restart-after-failure is exact: the checkpoint stores only `step`,
* elastic re-sharding just changes the (host_index, host_count) split.

The stream is a unigram-with-bigram-structure language: token t+1 is a noisy
function of token t, giving a learnable signal so example training losses
actually decrease (examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        if self.global_batch % self.host_count:
            raise ValueError("global_batch must divide evenly across hosts")
        self.local_batch = self.global_batch // self.host_count

    def _rng(self, step: int) -> np.random.Generator:
        # independent, reproducible stream per (seed, step, host)
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )

    def batch(self, step: int) -> dict:
        rng = self._rng(step)
        b, s, v = self.local_batch, self.seq_len, self.vocab
        # structured stream: x_{t+1} = (a * x_t + c + noise) mod V
        a = 31
        x = np.empty((b, s + 1), np.int32)
        x[:, 0] = rng.integers(0, v, size=b)
        noise = (rng.random((b, s)) < 0.1) * rng.integers(1, v, size=(b, s))
        for t in range(s):
            x[:, t + 1] = (a * x[:, t] + 7 + noise[:, t]) % v
        return {"tokens": x[:, :-1], "labels": x[:, 1:]}

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield step, self.batch(step)
            step += 1
