"""2-D Ising model system (the paper's benchmark model).

Energy follows the paper's Eq. (3) exactly::

    E(sigma) = B * sum_i sigma_i  -  J * sum_<i,j> sigma_i sigma_j

with periodic boundary conditions (the paper does not specify boundaries; PBC
is the standard Ising benchmark choice — recorded in DESIGN.md §2).  Spins are
stored as ``int8`` in {-1, +1}; replica-batched state is ``(R, L, L)``.

Two MH update modes (DESIGN.md §2):

* ``single_flip`` — faithful to the paper's per-iteration semantics: one
  random spin-flip proposal per MH iteration, via ``lax.fori_loop``.
* ``checkerboard`` — TPU-native: a *sweep* updates each colour class of the
  checkerboard in parallel (spins of one colour do not interact, so flipping
  them simultaneously with per-site MH acceptance preserves detailed balance
  per half-sweep).  This is the standard massively-parallel Metropolis update
  and is what the Pallas kernel (`repro.kernels.ising_sweep`) implements with
  VMEM-resident tiles; the pure-XLA path here is its oracle and the
  auto-partitionable fallback for lattices too large for VMEM.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

__all__ = ["IsingSystem", "lattice_energy", "neighbor_sum", "magnetization"]

UpdateMode = Literal["single_flip", "checkerboard"]


def neighbor_sum(spins: jnp.ndarray) -> jnp.ndarray:
    """Sum of the 4 nearest neighbours (PBC), same shape as ``spins``.

    Works in any float/int dtype; rolls lower to collective-permute halo
    exchanges under GSPMD when the lattice dims are sharded.
    """
    return (
        jnp.roll(spins, 1, axis=-2)
        + jnp.roll(spins, -1, axis=-2)
        + jnp.roll(spins, 1, axis=-1)
        + jnp.roll(spins, -1, axis=-1)
    )


def lattice_energy(spins: jnp.ndarray, j: float, b: float) -> jnp.ndarray:
    """Paper Eq. (3) with PBC; counts each bond once. Returns float32."""
    s = spins.astype(jnp.float32)
    # Each bond once: right + down neighbours only.
    bonds = s * (jnp.roll(s, -1, axis=-1) + jnp.roll(s, -1, axis=-2))
    return b * jnp.sum(s, axis=(-2, -1)) - j * jnp.sum(bonds, axis=(-2, -1))


def magnetization(spins: jnp.ndarray) -> jnp.ndarray:
    """Mean spin in [-1, 1]; the paper's Fig. 3a reports |m| as a percentage."""
    return jnp.mean(spins.astype(jnp.float32), axis=(-2, -1))


def _delta_e(spins_f: jnp.ndarray, nbr: jnp.ndarray, j: float, b: float) -> jnp.ndarray:
    """Energy change of flipping each spin individually.

    dE = 2*sigma_k*(J * sum_nbr(sigma) - B)   [derived from Eq. (3)]
    """
    return 2.0 * spins_f * (j * nbr - b)


@dataclasses.dataclass(frozen=True)
class IsingSystem:
    """One replica of the 2-D Ising model; vmapped by the PT driver.

    Attributes:
      length: lattice side L (L*L spins; the paper's perf runs use L=300).
      j: spin-interaction constant (paper: J=1, ferromagnet).
      b: external field (paper: B=0).
      update: "single_flip" (faithful) or "checkerboard" (TPU-native sweeps).
      flips_per_step: for single_flip, how many sequential MH iterations are
        fused into one `mcmc_step` call (keeps the scan short).
      use_pallas: checkerboard only — route the sweep through the Pallas
        kernel (interpret=True on CPU) instead of the pure-XLA path.
      use_fused: checkerboard only — run whole swap intervals through the
        interval-fused kernel (`repro.kernels.ops.ising_sweep_fused`) with
        counter-PRNG uniforms generated in-kernel instead of per-sweep
        launches fed an externally generated uniforms stream.  The random
        stream *differs* from the per-sweep path (gated statistically by the
        conformance suite, not bit-equal — DESIGN.md §6); with
        ``use_pallas=False`` the fused pure-JAX reference runs instead,
        bit-exact with the fused kernel.
      use_fused_round: checkerboard + temp-mode DEO/SEO only — fuse whole PT
        rounds (sweeps *plus* the exchange) into one launch via
        `repro.kernels.ops.ising_round_fused`; the swap uniforms come from
        the counter PRNG's swap stream instead of the engine's
        ``fold_in(key, 2t+1)`` draw (gated statistically by conformance,
        like ``use_fused``; bit-equality is pinned against the round
        kernel's own pure-JAX oracle).  Implies the ``use_fused`` sweep
        stream for the sweeps.
      pack_bits: fused paths only — bit-plane multispin spin storage inside
        the kernel (bitwise-identical trajectory; VMEM/ALU density knob).
      accept_rule: "metropolis" (paper Eq. 1) or "glauber" (heat-bath) —
        glauber keeps simultaneous checkerboard updates strictly stochastic
        (see repro.kernels.ref.accept_prob for the ergodicity caveat).
      init_balance: initial fraction of +1 spins (the paper fixes the same
        ratio of -1/+1 across replicas; 0.5 = random balanced).
      r_blk: replicas per Pallas grid step; 8 is the documented
        v5e-VMEM-safe block at the paper's L=300 (`kernels.ising_sweep`).
    """

    length: int
    j: float = 1.0
    b: float = 0.0
    update: UpdateMode = "checkerboard"
    flips_per_step: int = 1
    use_pallas: bool = False
    use_fused: bool = False
    use_fused_round: bool = False
    pack_bits: bool = False
    accept_rule: str = "metropolis"
    init_balance: float = 0.5
    r_blk: int = 8

    def __post_init__(self):
        if self.update == "checkerboard" and self.length % 2 != 0:
            # With periodic boundaries an odd lattice is NOT 2-colourable:
            # wrap-around neighbours share parity, so simultaneous same-colour
            # flips would interact (caught by hypothesis property testing).
            raise ValueError(
                f"checkerboard update needs even L under PBC, got L={self.length}; "
                "use update='single_flip' for odd lattices"
            )
        if self.use_fused and self.update != "checkerboard":
            raise ValueError(
                "use_fused=True needs update='checkerboard' (the fused "
                "kernel is an interval of checkerboard sweeps)"
            )
        if self.use_fused_round and not self.use_fused:
            raise ValueError(
                "use_fused_round=True needs use_fused=True (the round "
                "kernel is the interval-fused kernel plus an in-kernel "
                "exchange)"
            )

    # -- System protocol ---------------------------------------------------
    def init_state(self, key: jax.Array) -> jnp.ndarray:
        u = jax.random.uniform(key, (self.length, self.length))
        return jnp.where(u < self.init_balance, 1, -1).astype(jnp.int8)

    def energy(self, spins: jnp.ndarray) -> jnp.ndarray:
        return lattice_energy(spins, self.j, self.b)

    def mcmc_step(self, key: jax.Array, spins: jnp.ndarray, beta: jnp.ndarray):
        if self.update == "single_flip":
            return self._single_flip_steps(key, spins, beta)
        return self._checkerboard_sweep(key, spins, beta)

    # -- faithful mode ------------------------------------------------------
    def _single_flip_steps(self, key, spins, beta):
        """``flips_per_step`` sequential single-spin MH iterations."""
        L = self.length

        def body(i, carry):
            spins, de_acc, n_acc, key = carry
            key, k_site, k_u = jax.random.split(key, 3)
            site = jax.random.randint(k_site, (2,), 0, L)
            r, c = site[0], site[1]
            s = spins[r, c].astype(jnp.float32)
            nbr = (
                spins[(r + 1) % L, c]
                + spins[(r - 1) % L, c]
                + spins[r, (c + 1) % L]
                + spins[r, (c - 1) % L]
            ).astype(jnp.float32)
            de = 2.0 * s * (self.j * nbr - self.b)
            from repro.kernels.ref import accept_prob

            accept = jax.random.uniform(k_u, ()) < accept_prob(de, beta, self.accept_rule)
            spins = spins.at[r, c].set(jnp.where(accept, -spins[r, c], spins[r, c]))
            de_acc = de_acc + jnp.where(accept, de, 0.0)
            n_acc = n_acc + accept.astype(jnp.int32)
            return spins, de_acc, n_acc, key

        spins, de, n_acc, _ = jax.lax.fori_loop(
            0, self.flips_per_step, body, (spins, jnp.float32(0), jnp.int32(0), key)
        )
        return spins, de, n_acc

    # -- TPU-native mode ----------------------------------------------------
    def _checkerboard_sweep(self, key, spins, beta):
        """One full sweep = colour-0 then colour-1 half-sweeps (one replica)."""
        u = jax.random.uniform(key, (2, self.length, self.length), jnp.float32)
        from repro.kernels import ref as kref

        s, de, na = kref.ising_sweep(
            spins[None], u[None], beta[None], j=self.j, b=self.b, rule=self.accept_rule
        )
        return s[0], de[0], na[0]

    # -- batched fast path (used by the PT driver instead of vmap) ----------
    def batched_mcmc_step(self, keys, spins, betas):
        """Natively replica-batched step: (R,...) in, (R,...) out.

        Dispatches to the Pallas kernel (`use_pallas=True`) or the pure-XLA
        oracle; `single_flip` mode is vmapped (its control flow is scalar).
        """
        if self.update == "single_flip":
            return jax.vmap(self._single_flip_steps)(keys, spins, betas)
        shape = (2, self.length, self.length)
        u = jax.vmap(lambda k: jax.random.uniform(k, shape, jnp.float32))(keys)
        from repro.kernels import ops as kops

        return kops.ising_sweep(
            spins, u, betas, j=self.j, b=self.b, rule=self.accept_rule,
            r_blk=self.r_blk, use_pallas=self.use_pallas,
        )

    # -- fused whole-interval fast path (used when use_fused=True) -----------
    def batched_mcmc_interval(self, key, t, spins, betas, *, n_sweeps,
                              replica_offset=0):
        """``n_sweeps`` replica-batched sweeps in one fused launch.

        ``key`` is the chain's root PRNG key and ``t`` the global sweep
        counter at interval entry; the counter PRNG derives every uniform
        from ``(key, t + sweep, replica, colour)``, so the result is
        independent of chunking and of how intervals were grouped into
        calls.  ``replica_offset`` (traced uint32 scalar) is the global
        index of local replica 0 when the replica axis is sharded across a
        device mesh — the counter streams stay those of the global slots.
        Returns ``(spins', delta_e, n_accepted)`` summed over the interval.
        """
        from repro.kernels import ops as kops

        return kops.ising_sweep_fused(
            spins, key, t, betas, n_sweeps=n_sweeps,
            replica_offset=replica_offset, j=self.j, b=self.b,
            rule=self.accept_rule, r_blk=self.r_blk,
            pack_bits=self.pack_bits, use_pallas=self.use_pallas,
        )

    # -- whole-round fast path (used when use_fused_round=True) --------------
    def batched_mcmc_round(self, key, t, phase, spins, rung, energy, betas,
                           *, n_sweeps, n_rounds=1, criterion="logistic",
                           pairing="deo"):
        """``n_rounds`` whole PT rounds (sweeps + temp-mode exchange) fused.

        ``phase`` is the global swap-iteration counter (keys the in-kernel
        swap draw), ``rung``/``energy`` the per-slot rung map and energies,
        ``betas`` the rung-ordered ladder.  Returns ``(spins', rung',
        energy', n_accepted, accept, prob, attempt)`` — see
        `repro.kernels.ops.ising_round_fused`.
        """
        from repro.kernels import ops as kops

        return kops.ising_round_fused(
            spins, key, t, phase, rung, energy, betas,
            n_sweeps=n_sweeps, n_rounds=n_rounds, j=self.j, b=self.b,
            rule=self.accept_rule, criterion=criterion, pairing=pairing,
            pack_bits=self.pack_bits, use_pallas=self.use_pallas,
        )
