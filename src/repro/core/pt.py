"""Parallel-Tempering driver: replica-parallel MH with interval-scheduled swaps.

Maps the paper's execution scheme (section 3, Fig. 2) onto JAX:

* replicas advance **in parallel** between swap iterations — here the replica
  axis is a leading array dimension, vectorized over VPU lanes and sharded
  over the device mesh (`repro.core.distributed`);
* computation is scheduled in *intervals*: an inner `lax.scan` of
  ``swap_interval`` sweeps, then one parallel swap phase (`repro.core.swap`);
* the whole simulation — all intervals — is a single jitted `lax.scan`:
  state never leaves device memory (the paper's CUDA device-residency
  insight, §2 of DESIGN.md).

Swap modes:

* ``state``  — faithful to the paper: temperature is bound to the replica
  index and accepted pairs exchange their *states* (O(L²) bytes per pair).
* ``temp``   — optimized: accepted pairs exchange *rungs* (temperature
  indices); states stay put and the chain-per-temperature is reconstructed
  from the tracked permutation. O(1) bytes per pair — this is what makes the
  swap phase free on a multi-pod mesh (EXPERIMENTS.md §Perf).

Both produce the same extended-ensemble Markov chain law.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import swap as swap_lib
from repro.core.systems import System, batched_energy, batched_init

__all__ = ["PTConfig", "PTState", "init", "run", "make_run"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PTState:
    """Device-resident simulation state (a pytree; donate-able)."""

    states: Any  # system states, leaves shaped (R, ...)
    energy: jax.Array  # (R,) f32 — tracked incrementally from step deltas
    rung: jax.Array  # (R,) i32 — rung (ladder position) held by each slot
    key: jax.Array  # PRNG key
    phase: jax.Array  # i32 swap-phase alternator (paper Fig. 2)
    t: jax.Array  # i32 sweep counter


@dataclasses.dataclass(frozen=True)
class PTConfig:
    """Static PT configuration.

    Attributes:
      n_replicas: |R|.
      temps: ladder, cold->hot, tuple of float (hashable for jit static use).
      swap_interval: sweeps between swap iterations (0 disables swaps — the
        paper's "without swaps" baseline used for its speed-up figures).
      criterion: "logistic" (paper) | "metropolis".
      swap_mode: "temp" (optimized) | "state" (faithful).
      record_interval: record diagnostics every k-th interval (1 = all).
    """

    n_replicas: int
    temps: tuple
    swap_interval: int = 100
    criterion: str = "logistic"
    swap_mode: str = "temp"
    record_interval: int = 1

    @property
    def betas(self) -> np.ndarray:
        return 1.0 / np.asarray(self.temps, dtype=np.float32)

    def __post_init__(self):
        if len(self.temps) != self.n_replicas:
            raise ValueError(
                f"ladder has {len(self.temps)} rungs != n_replicas={self.n_replicas}"
            )
        if self.swap_mode not in ("temp", "state"):
            raise ValueError(f"bad swap_mode {self.swap_mode!r}")


def _batched_step(system: System):
    """System step batched over replicas (kernel fast-path if provided)."""
    fn = getattr(system, "batched_mcmc_step", None)
    if fn is not None:
        return fn
    return jax.vmap(system.mcmc_step)


def init(system: System, config: PTConfig, key: jax.Array, *, shard=None) -> PTState:
    """Build the initial PT state (paper's "initialization phase")."""
    k_init, k_run = jax.random.split(key)
    states = batched_init(system, k_init, config.n_replicas)
    if shard is not None:
        states = jax.lax.with_sharding_constraint(states, shard)
    energy = batched_energy(system, states)
    return PTState(
        states=states,
        energy=energy.astype(jnp.float32),
        rung=jnp.arange(config.n_replicas, dtype=jnp.int32),
        key=k_run,
        phase=jnp.int32(0),
        t=jnp.int32(0),
    )


def _sweep_once(system, config, betas, st: PTState, shard=None) -> PTState:
    """One parallel sweep of every replica at its current temperature."""
    r = config.n_replicas
    # 2t/2t+1 split keeps sweep and swap key streams disjoint for any R.
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.fold_in(st.key, 2 * st.t), jnp.arange(r, dtype=jnp.uint32)
    )
    if shard is not None:
        # pin the per-replica key axis: the per-replica random lattices then
        # generate shard-local (otherwise the partitioner replicates the
        # whole PRNG stream — measured 16x redundant HBM traffic)
        keys = jax.lax.with_sharding_constraint(keys, shard)
    betas_slot = betas[st.rung]
    states, de, _ = _batched_step(system)(keys, st.states, betas_slot)
    return dataclasses.replace(
        st,
        states=states,
        energy=st.energy + de.astype(jnp.float32),
        t=st.t + 1,
    )


def _swap_phase(config, betas, st: PTState):
    """One parallel swap iteration; returns (state, diagnostics)."""
    r = config.n_replicas
    k_swap = jax.random.fold_in(st.key, 2 * st.t + 1)
    inv = jnp.argsort(st.rung)  # slot holding rung r
    e_rung = st.energy[inv]
    perm, accept, prob = swap_lib.swap_permutation(
        k_swap, st.phase, betas, e_rung, n=r, criterion=config.criterion
    )
    if config.swap_mode == "temp":
        # Slot inv[r] now holds rung perm[r]; states stay in place.
        new_rung = jnp.zeros((r,), jnp.int32).at[inv].set(perm)
        st = dataclasses.replace(st, rung=new_rung)
    else:
        # Faithful mode: rung == slot identity; move the states themselves.
        states = jax.tree_util.tree_map(lambda x: jnp.take(x, perm, axis=0), st.states)
        st = dataclasses.replace(st, states=states, energy=st.energy[perm])
    st = dataclasses.replace(st, phase=st.phase + 1)
    return st, {"swap_accept": accept, "swap_prob": prob}


def _observe(system, config, observables, st: PTState) -> Mapping[str, jax.Array]:
    """Per-rung diagnostics (rung order, cold->hot)."""
    inv = jnp.argsort(st.rung)
    out = {"energy": st.energy[inv]}
    for name, fn in (observables or {}).items():
        vals = jax.vmap(fn)(st.states)
        out[name] = vals[inv]
    return out


@partial(
    jax.jit,
    static_argnames=("system", "config", "n_sweeps", "observables_tuple", "shard"),
)
def _run_jit(system, config, state, n_sweeps, observables_tuple, shard=None):
    observables = dict(observables_tuple)
    betas = jnp.asarray(config.betas)
    interval = config.swap_interval if config.swap_interval > 0 else n_sweeps
    n_intervals = max(n_sweeps // interval, 1)

    def constrain(st):
        # keep the replica axis sharded through the loop — without this the
        # partitioner may replicate the whole simulation (measured: 256x
        # redundant compute on the production mesh; EXPERIMENTS.md §Perf)
        if shard is None:
            return st
        from repro.core.distributed import shard_state

        return shard_state(st, shard)

    def interval_body(st, _):
        def sweep_body(s, _):
            return constrain(_sweep_once(system, config, betas, s, shard)), None

        st, _ = jax.lax.scan(sweep_body, st, None, length=interval)
        if config.swap_interval > 0:
            st, swap_diag = _swap_phase(config, betas, st)
        else:
            z = jnp.zeros((config.n_replicas,))
            swap_diag = {"swap_accept": z.astype(bool), "swap_prob": z}
        rec = dict(_observe(system, config, observables, st))
        rec.update(swap_diag)
        return constrain(st), rec

    state, trace = jax.lax.scan(interval_body, state, None, length=n_intervals)
    return state, trace


def run(
    system: System,
    config: PTConfig,
    state: PTState,
    n_sweeps: int,
    observables: Mapping[str, Callable] | None = None,
    shard=None,
):
    """Run ``n_sweeps`` sweeps of PT; returns (final_state, trace).

    ``trace`` holds per-interval, per-rung arrays: ``energy``, each observable,
    ``swap_accept``/``swap_prob`` (at the lower rung of each attempted pair).
    The full simulation is one XLA program — no host round-trips (paper §3:
    "all the simulation information is located inside the device").
    ``shard``: optional NamedSharding for the replica axis, enforced through
    the loop (see `repro.core.distributed.replica_sharding`).
    """
    obs = tuple(sorted((observables or {}).items()))
    return _run_jit(system, config, state, n_sweeps, obs, shard)


def make_run(system: System, config: PTConfig, n_sweeps: int, observables=None,
             shard=None):
    """AOT-compilable closure (used by benchmarks and the dry-run)."""

    def fn(state):
        return run(system, config, state, n_sweeps, observables, shard=shard)

    return fn
