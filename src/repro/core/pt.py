"""Parallel-Tempering driver: replica-parallel MH with interval-scheduled swaps.

Maps the paper's execution scheme (section 3, Fig. 2) onto JAX:

* replicas advance **in parallel** between swap iterations — here the replica
  axis is a leading array dimension, vectorized over VPU lanes and sharded
  over the device mesh (`repro.core.distributed`);
* computation is scheduled in *intervals*: an inner `lax.scan` of
  ``swap_interval`` sweeps, then one parallel swap phase (`repro.core.swap`);
* the whole simulation — all intervals — is a single jitted `lax.scan`:
  state never leaves device memory (the paper's CUDA device-residency
  insight, DESIGN.md §2).

This module is the **monolithic compatibility shim**: the physics of one
interval lives in `repro.engine.driver.make_interval_step`, shared with the
chunked streaming engine (`repro.engine.Engine`, DESIGN.md §1).  `run` here
keeps the seed API — one jitted program per ``n_sweeps`` and a full
O(intervals x R) trace — which is convenient for tests and short runs but
recompiles per sweep count; long or adaptive runs should use the engine.

Swap modes:

* ``state``  — faithful to the paper: temperature is bound to the replica
  index and accepted pairs exchange their *states* (O(L²) bytes per pair).
* ``temp``   — optimized: accepted pairs exchange *rungs* (temperature
  indices); states stay put and the chain-per-temperature is reconstructed
  from the tracked permutation. O(1) bytes per pair — this is what makes the
  swap phase free on a multi-pod mesh (DESIGN.md §Perf).

Both produce the same extended-ensemble Markov chain law.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.systems import System, batched_energy, batched_init

__all__ = ["PTConfig", "PTState", "init", "init_replicas", "run", "make_run"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PTState:
    """Device-resident simulation state (a pytree; donate-able)."""

    states: Any  # system states, leaves shaped (R, ...)
    energy: jax.Array  # (R,) f32 — tracked incrementally from step deltas
    rung: jax.Array  # (R,) i32 — rung (ladder position) held by each slot
    key: jax.Array  # PRNG key
    phase: jax.Array  # i32 swap-phase alternator (paper Fig. 2)
    t: jax.Array  # i32 sweep counter


@dataclasses.dataclass(frozen=True)
class PTConfig:
    """Static PT configuration.

    Attributes:
      n_replicas: |R|.
      temps: ladder, cold->hot, tuple of float (hashable for jit static use).
      swap_interval: sweeps between swap iterations (0 disables swaps — the
        paper's "without swaps" baseline used for its speed-up figures).
      criterion: "logistic" (paper) | "metropolis".
      swap_mode: "temp" (optimized) | "state" (faithful).
      record_interval: record diagnostics every k-th interval (1 = all).
    """

    n_replicas: int
    temps: tuple
    swap_interval: int = 100
    criterion: str = "logistic"
    swap_mode: str = "temp"
    record_interval: int = 1

    @property
    def betas(self) -> np.ndarray:
        return 1.0 / np.asarray(self.temps, dtype=np.float32)

    def __post_init__(self):
        if len(self.temps) != self.n_replicas:
            raise ValueError(
                f"ladder has {len(self.temps)} rungs != n_replicas={self.n_replicas}"
            )
        if self.swap_mode not in ("temp", "state"):
            raise ValueError(f"bad swap_mode {self.swap_mode!r}")

    def step_spec(self, n_sweeps: int):
        """The engine `StepSpec` + interval count equivalent to this config."""
        from repro.engine.driver import StepSpec

        interval = self.swap_interval if self.swap_interval > 0 else n_sweeps
        spec = StepSpec(
            n_replicas=self.n_replicas,
            sweeps_per_interval=interval,
            do_swap=self.swap_interval > 0,
            criterion=self.criterion,
            swap_mode=self.swap_mode,
        )
        return spec, max(n_sweeps // interval, 1)


def init_replicas(
    system: System, n_replicas: int, key: jax.Array, *, shard=None
) -> PTState:
    """Build the initial PT state (paper's "initialization phase").

    The single source of truth for state construction — the engine
    (`repro.engine.driver`) and the `init` wrapper below both use it, which
    keeps their PRNG streams (and hence trajectories) identical.
    """
    k_init, k_run = jax.random.split(key)
    states = batched_init(system, k_init, n_replicas)
    if shard is not None:
        states = jax.lax.with_sharding_constraint(states, shard)
    energy = batched_energy(system, states)
    return PTState(
        states=states,
        energy=energy.astype(jnp.float32),
        rung=jnp.arange(n_replicas, dtype=jnp.int32),
        key=k_run,
        phase=jnp.int32(0),
        t=jnp.int32(0),
    )


def init(system: System, config: PTConfig, key: jax.Array, *, shard=None) -> PTState:
    """Seed-compatible `init` (see `init_replicas`)."""
    return init_replicas(system, config.n_replicas, key, shard=shard)


@partial(
    jax.jit,
    static_argnames=("system", "config", "n_sweeps", "observables_tuple", "shard"),
)
def _run_jit(system, config, state, n_sweeps, observables_tuple, shard=None):
    from repro.engine.driver import make_interval_step

    spec, n_intervals = config.step_spec(n_sweeps)
    step = make_interval_step(system, spec, dict(observables_tuple), shard)
    betas = jnp.asarray(config.betas)

    def interval_body(st, _):
        return step(st, betas)

    state, trace = jax.lax.scan(interval_body, state, None, length=n_intervals)
    return state, trace


def run(
    system: System,
    config: PTConfig,
    state: PTState,
    n_sweeps: int,
    observables: Mapping[str, Callable] | None = None,
    shard=None,
):
    """Run ``n_sweeps`` sweeps of PT; returns (final_state, trace).

    ``trace`` holds per-interval, per-rung arrays: ``energy``, each observable,
    ``swap_accept``/``swap_prob`` (at the lower rung of each attempted pair).
    The full simulation is one XLA program — no host round-trips (paper §3:
    "all the simulation information is located inside the device").
    ``shard``: optional NamedSharding for the replica axis, enforced through
    the loop (see `repro.core.distributed.replica_sharding`).

    For long, adaptive, or many-chain runs prefer `repro.engine.Engine`: same
    per-interval physics (bit-equal PRNG streams), O(1) compile cost and O(R)
    streaming diagnostics instead of this full trace.
    """
    obs = tuple(sorted((observables or {}).items()))
    return _run_jit(system, config, state, n_sweeps, obs, shard)


def make_run(system: System, config: PTConfig, n_sweeps: int, observables=None,
             shard=None):
    """AOT-compilable closure (used by benchmarks and the dry-run)."""

    def fn(state):
        return run(system, config, state, n_sweeps, observables, shard=shard)

    return fn
