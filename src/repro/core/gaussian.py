"""Gaussian-mixture system: an exactly-solvable target for correctness tests.

The paper (section 2.1) motivates PT with multimodal distributions that trap
plain MH.  A 1-D mixture of well-separated Gaussians is the canonical example
and has a closed-form density, so we can (i) verify MH detailed balance
against the exact Boltzmann weights and (ii) demonstrate the paper's central
qualitative claim — PT crosses modes that trap a single cold chain
(tests/test_pt.py::test_pt_mixes_bimodal_better_than_mh).

Energy: ``E(x) = -log sum_k w_k N(x; mu_k, sigma_k)`` so the Boltzmann
distribution at ``beta = 1`` *is* the mixture.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["GaussianMixture"]


@dataclasses.dataclass(frozen=True)
class GaussianMixture:
    """1-D Gaussian mixture replica (System protocol).

    Attributes:
      mus/sigmas/weights: mixture parameters (tuples — hashable for jit).
      step_size: random-walk proposal scale.
      init_scale: initial-state spread.
    """

    mus: tuple = (-4.0, 4.0)
    sigmas: tuple = (1.0, 1.0)
    weights: tuple = (0.5, 0.5)
    step_size: float = 1.0
    init_scale: float = 0.1

    def init_state(self, key: jax.Array) -> jnp.ndarray:
        # Start in the left mode deliberately: tests check mode escape.
        return jnp.asarray(self.mus[0]) + self.init_scale * jax.random.normal(key, ())

    def energy(self, x: jnp.ndarray) -> jnp.ndarray:
        mus = jnp.asarray(self.mus)
        sig = jnp.asarray(self.sigmas)
        w = jnp.asarray(self.weights)
        logp = (
            jnp.log(w)
            - 0.5 * ((x - mus) / sig) ** 2
            - jnp.log(sig)
            - 0.5 * jnp.log(2 * jnp.pi)
        )
        return -jax.scipy.special.logsumexp(logp)

    def mcmc_step(self, key: jax.Array, x: jnp.ndarray, beta: jnp.ndarray):
        k_prop, k_u = jax.random.split(key)
        trial = x + self.step_size * jax.random.normal(k_prop, ())
        e0, e1 = self.energy(x), self.energy(trial)
        de = e1 - e0
        accept = jax.random.uniform(k_u, ()) < jnp.exp(-beta * de)
        x = jnp.where(accept, trial, x)
        return x, jnp.where(accept, de, 0.0), accept.astype(jnp.int32)
