"""Edwards-Anderson ±J spin glass: quenched disorder carried in the state.

The EA model is the canonical rugged-landscape PT workload (Earl & Deem;
Katzgraber's feedback-optimized PT was developed on it)::

    E(s) = - sum_<x,y> J_xy s_x s_y,     J_xy = ±J quenched (fixed per run)

on an (H, W) periodic lattice.  Frustration (loops whose coupling product is
negative) produces the many-valley landscape that motivates replica exchange
in the first place — and makes it the natural stress test for the adaptive
ladder (DESIGN.md §Validate).

Architecturally this is the first system whose *state is a pytree carrying
per-replica data beyond the lattice*: each replica's state bundles its spins
with the coupling planes ``{"spins", "jr", "jd"}``.  Every replica of a run
holds the *same* disorder realization (drawn deterministically from
``disorder_seed``), as PT requires — replicas must sample one common target
at different temperatures — but the couplings ride inside the state pytree,
so `temp`-mode swaps, `state`-mode swaps (tree_map gather), checkpointing and
the ensemble axis all exercise the generic pytree path through
`engine.driver`.

The update is the same simultaneous checkerboard MH as Ising (the EA lattice
is bipartite; PBC needs even dims), in pure XLA — bond disorder breaks the
single-J premise of the Ising Pallas kernel, so this system documents the
XLA fallback path for inhomogeneous couplings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.ref import accept_prob

__all__ = ["EASpinGlass", "ea_energy"]


def ea_energy(state: dict, j_scale: float = 1.0) -> jnp.ndarray:
    """E = -sum(jr * s * s_right) - sum(jd * s * s_down); PBC, f32.

    ``jr[x, y]`` couples site (x, y) to its right neighbour (y+1 mod W);
    ``jd`` to its down neighbour (x+1 mod H).  Each bond counted once.
    """
    s = state["spins"].astype(jnp.float32)
    right = jnp.roll(s, -1, axis=-1)
    down = jnp.roll(s, -1, axis=-2)
    return -j_scale * (
        jnp.sum(state["jr"] * s * right, axis=(-2, -1))
        + jnp.sum(state["jd"] * s * down, axis=(-2, -1))
    )


@dataclasses.dataclass(frozen=True)
class EASpinGlass:
    """One replica of the 2-D ±J Edwards-Anderson model (System protocol).

    Attributes:
      shape: lattice (H, W), both even (checkerboard under PBC).
      j: coupling magnitude (bonds are ±j with equal probability).
      disorder_seed: seed of the quenched coupling draw — *every* replica
        gets the same realization (the PT extended ensemble shares one
        target), carried inside each replica's state pytree.
      accept_rule: "metropolis" or "glauber" (see repro.kernels.ref).
    """

    shape: tuple
    j: float = 1.0
    disorder_seed: int = 0
    accept_rule: str = "metropolis"

    def __post_init__(self):
        h, w = self.shape
        if h % 2 != 0 or w % 2 != 0:
            raise ValueError(
                f"checkerboard EA needs even dims under PBC, got {self.shape}"
            )

    def disorder(self) -> tuple:
        """The quenched ±j coupling planes (jr, jd) — deterministic."""
        kr, kd = jax.random.split(jax.random.key(self.disorder_seed))
        draw = lambda k: jnp.where(
            jax.random.uniform(k, self.shape) < 0.5, self.j, -self.j
        ).astype(jnp.float32)
        return draw(kr), draw(kd)

    # -- System protocol ---------------------------------------------------
    def init_state(self, key: jax.Array) -> dict:
        jr, jd = self.disorder()
        u = jax.random.uniform(key, self.shape)
        return {
            "spins": jnp.where(u < 0.5, 1, -1).astype(jnp.int8),
            "jr": jr,
            "jd": jd,
        }

    def energy(self, state: dict) -> jnp.ndarray:
        return ea_energy(state)

    def mcmc_step(self, key: jax.Array, state: dict, beta: jnp.ndarray):
        """One full checkerboard sweep (colour 0 then colour 1)."""
        h, w = self.shape
        s = state["spins"].astype(jnp.float32)
        jr, jd = state["jr"], state["jd"]
        ii = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)
        parity = (ii + jj) % 2
        u = jax.random.uniform(key, (2, h, w), jnp.float32)

        de_total = jnp.float32(0.0)
        n_acc = jnp.int32(0)
        for color in (0, 1):
            # Local field of each site through its 4 (disordered) bonds.
            field = (
                jr * jnp.roll(s, -1, axis=-1)
                + jnp.roll(jr, 1, axis=-1) * jnp.roll(s, 1, axis=-1)
                + jd * jnp.roll(s, -1, axis=-2)
                + jnp.roll(jd, 1, axis=-2) * jnp.roll(s, 1, axis=-2)
            )
            de = 2.0 * s * field  # flip s -> -s changes E by +2 s h
            accept = (u[color] < accept_prob(de, beta, self.accept_rule)) & (
                parity == color
            )
            s = jnp.where(accept, -s, s)
            de_total = de_total + jnp.sum(jnp.where(accept, de, 0.0))
            n_acc = n_acc + jnp.sum(accept.astype(jnp.int32))
        new = dict(state)
        new["spins"] = s.astype(jnp.int8)
        return new, de_total, n_acc
