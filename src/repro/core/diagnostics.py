"""Convergence and mixing diagnostics for PT runs (paper section 4.1).

Host-side (numpy) post-processing of the device-side traces produced by
`repro.core.pt.run` — the paper's Fig. 3a (magnetization vs temperature),
Fig. 3b (iterations-to-converge vs model size) and the swap-acceptance
observations behind Fig. 7 are all computed from these.
"""
from __future__ import annotations

import warnings

import numpy as np

__all__ = [
    "swap_acceptance_rate",
    "iterations_to_converge",
    "integrated_autocorrelation",
    "grand_mean_by_rung",
]


def swap_acceptance_rate(trace: dict) -> np.ndarray:
    """Mean accepted/attempted per adjacent rung pair, shape (R-1,).

    `swap_accept`/`swap_attempt` are recorded at the *lower* rung of each
    attempted pair; a rung pair (r, r+1) is attempted on alternating phases.
    Attempts come from the structural pairing mask when the trace carries it
    (engine-era traces); older traces fall back to `prob > 0`, which can
    undercount pairs whose acceptance probability underflows to 0 in f32.
    """
    acc = np.asarray(trace["swap_accept"], dtype=np.float64)  # (T, R)
    if "swap_attempt" in trace:
        attempts = np.asarray(trace["swap_attempt"], dtype=np.float64).sum(axis=0)
    else:
        warnings.warn(
            "trace has no 'swap_attempt' channel; inferring attempts from "
            "swap_prob > 0, which undercounts pairs whose acceptance "
            "probability underflows to 0 in f32 (biasing the rate up). "
            "Re-record with an engine-era trace for exact counts.",
            RuntimeWarning,
            stacklevel=2,
        )
        prob = np.asarray(trace["swap_prob"], dtype=np.float64)
        attempts = (prob > 0).sum(axis=0)  # (R,)
    accepted = acc.sum(axis=0)
    with np.errstate(invalid="ignore", divide="ignore"):
        rate = np.where(attempts > 0, accepted / np.maximum(attempts, 1), 0.0)
    return rate[:-1]  # last rung is never a "lower" pair member


def iterations_to_converge(
    series: np.ndarray, threshold: float, window: int = 8
) -> int:
    """First index where a rolling mean of ``|series|`` crosses ``threshold``.

    The paper's Fig. 3b counts iterations until replicas "converge to the
    target distribution"; for the cold-rung ferromagnetic Ising chain the
    standard operationalization is |m| reaching near-saturation.
    Returns -1 if never converged.
    """
    s = np.abs(np.asarray(series, dtype=np.float64))
    if len(s) < window:
        return -1
    roll = np.convolve(s, np.ones(window) / window, mode="valid")
    hits = np.nonzero(roll >= threshold)[0]
    return int(hits[0]) + window - 1 if len(hits) else -1


def integrated_autocorrelation(x: np.ndarray, c: float = 5.0) -> float:
    """Sokal's windowed IAT estimate of a scalar chain (FFT-based)."""
    x = np.asarray(x, dtype=np.float64)
    x = x - x.mean()
    n = len(x)
    if n < 8 or np.allclose(x, 0):
        return 1.0
    f = np.fft.rfft(x, n=2 * n)
    acf = np.fft.irfft(f * np.conjugate(f))[:n]
    acf /= acf[0]
    tau = 1.0
    for m in range(1, n):
        tau += 2.0 * acf[m]
        if m >= c * tau:
            break
    return float(max(tau, 1.0))


def grand_mean_by_rung(trace: dict, key: str, burn_frac: float = 0.5) -> np.ndarray:
    """Posterior mean of an observable per rung, discarding burn-in."""
    arr = np.asarray(trace[key], dtype=np.float64)  # (T, R)
    t0 = int(len(arr) * burn_frac)
    return arr[t0:].mean(axis=0)
