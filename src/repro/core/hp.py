"""HP-model lattice protein: the paper's protein-folding motivation.

The hydrophobic-polar model (Lau & Dill) folds a fixed H/P sequence as a
self-avoiding chain on the 2-D square lattice; every non-bonded H-H contact
(lattice-adjacent, not chain-adjacent) contributes ``-eps``::

    E(conf) = -eps * #{ (i, j) : |i - j| > 1, ||p_i - p_j||_1 = 1, H_i H_j }

Low temperature favours compact hydrophobic cores behind high entropic
barriers — exactly the rugged landscape Hansmann used to introduce PT for
biomolecules, and the workload the source paper names as PT's motivation.

The state is the (N, 2) int32 coordinate chain on an unbounded lattice (the
walk is translation-invariant; observables only use relative positions).
This is the first *non-lattice-array* state through the PT stack: no
checkerboard, no Pallas tile — it exercises the generic vmapped
`System.mcmc_step` path and pytree handling through `engine.driver`.

Move set (symmetric proposals => plain MH):

* **end move** — a terminal monomer relocates to a uniformly drawn neighbour
  of its chain neighbour;
* **corner move** — an interior monomer at a right-angle corner flips to the
  opposite corner of the square spanned by its chain neighbours.

This Verdier-Stockmayer set is non-ergodic for long chains (frozen
double-spiral traps) but provably ergodic at validation scale: the
conformance suite BFS-checks the move graph against the full SAW enumeration
for the registered chain (`repro.validate.exact.hp_move_graph_connected`,
DESIGN.md §Validate) — ergodicity is an executable property here, not an
assumption.  Pull moves (ergodic at every chain length, but with asymmetric
proposal probabilities that need Hastings corrections) are the documented
upgrade path when production chains outgrow the BFS-checkable regime.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["HPChain", "hp_energy", "radius_of_gyration_sq"]

_DIRS = ((1, 0), (-1, 0), (0, 1), (0, -1))


def _hmask(sequence: str) -> jnp.ndarray:
    if not sequence or set(sequence) - {"H", "P"}:
        raise ValueError(f"sequence must be a nonempty H/P string, got {sequence!r}")
    return jnp.asarray([c == "H" for c in sequence], jnp.float32)


def hp_energy(pos: jnp.ndarray, hmask: jnp.ndarray, eps: float) -> jnp.ndarray:
    """-eps * (number of non-bonded H-H lattice contacts); f32 scalar."""
    n = pos.shape[0]
    manh = jnp.sum(jnp.abs(pos[:, None, :] - pos[None, :, :]), axis=-1)
    idx = jnp.arange(n)
    nonbonded = jnp.abs(idx[:, None] - idx[None, :]) > 1
    hh = hmask[:, None] * hmask[None, :]
    contacts = jnp.sum(jnp.where((manh == 1) & nonbonded, hh, 0.0))
    return -eps * contacts / 2.0  # each unordered pair counted twice above


def radius_of_gyration_sq(pos: jnp.ndarray) -> jnp.ndarray:
    """Squared radius of gyration (translation-invariant chain-size proxy)."""
    p = pos.astype(jnp.float32)
    c = jnp.mean(p, axis=0)
    return jnp.mean(jnp.sum((p - c) ** 2, axis=-1))


@dataclasses.dataclass(frozen=True)
class HPChain:
    """One replica of a 2-D HP lattice protein (System protocol).

    Attributes:
      sequence: H/P string; its length N fixes the chain length.
      eps: H-H contact energy magnitude.
      moves_per_step: attempted single-monomer moves fused into one
        `mcmc_step` (defaults to N — one "sweep" per call — when 0).
    """

    sequence: str
    eps: float = 1.0
    moves_per_step: int = 0

    def __post_init__(self):
        _hmask(self.sequence)  # validate eagerly
        if len(self.sequence) < 3:
            raise ValueError("HP chain needs at least 3 monomers")

    @property
    def n_monomers(self) -> int:
        return len(self.sequence)

    def _n_moves(self) -> int:
        return self.moves_per_step if self.moves_per_step > 0 else self.n_monomers

    # -- System protocol ---------------------------------------------------
    def init_state(self, key: jax.Array) -> jnp.ndarray:
        """Straight rod along a random axis direction (always self-avoiding)."""
        n = self.n_monomers
        d = jnp.asarray(_DIRS, jnp.int32)[jax.random.randint(key, (), 0, 4)]
        return jnp.arange(n, dtype=jnp.int32)[:, None] * d[None, :]

    def energy(self, pos: jnp.ndarray) -> jnp.ndarray:
        return hp_energy(pos, _hmask(self.sequence), self.eps)

    def mcmc_step(self, key: jax.Array, pos: jnp.ndarray, beta: jnp.ndarray):
        n = self.n_monomers
        hmask = _hmask(self.sequence)
        dirs = jnp.asarray(_DIRS, jnp.int32)
        idx = jnp.arange(n)
        nonbonded = jnp.abs(idx[:, None] - idx[None, :]) > 1  # (N, N)

        def contacts_of(i, p, site):
            """H-H contacts monomer i makes from ``site`` (|i-j| > 1 only)."""
            manh = jnp.sum(jnp.abs(p - site[None, :]), axis=-1)
            return jnp.sum(jnp.where((manh == 1) & nonbonded[i], hmask[i] * hmask, 0.0))

        def body(_, carry):
            pos, de_acc, n_acc, key = carry
            key, k_site, k_dir, k_u = jax.random.split(key, 4)
            i = jax.random.randint(k_site, (), 0, n)
            is_end = (i == 0) | (i == n - 1)
            # End move: uniform neighbour of the terminal's chain neighbour.
            anchor = pos[jnp.where(i == 0, 1, n - 2)]
            end_cand = anchor + dirs[jax.random.randint(k_dir, (), 0, 4)]
            # Corner move: deterministic opposite corner (valid iff i-1, i+1
            # span a right angle).  Clipped indices are junk for ends but the
            # is_end select discards them.
            a = pos[jnp.clip(i - 1, 0, n - 1)]
            b = pos[jnp.clip(i + 1, 0, n - 1)]
            corner_ok = (a[0] != b[0]) & (a[1] != b[1])
            corner_cand = a + b - pos[i]
            cand = jnp.where(is_end, end_cand, corner_cand)
            movable = jnp.where(is_end, True, corner_ok)
            moved = jnp.any(cand != pos[i])
            occupied = jnp.any(jnp.all(pos == cand[None, :], axis=-1) & (idx != i))

            de = -self.eps * (
                contacts_of(i, pos, cand) - contacts_of(i, pos, pos[i])
            )
            accept = (
                movable
                & moved
                & ~occupied
                & (jax.random.uniform(k_u, ()) < jnp.exp(-beta * de))
            )
            pos = pos.at[i].set(jnp.where(accept, cand, pos[i]))
            de_acc = de_acc + jnp.where(accept, de, 0.0)
            n_acc = n_acc + accept.astype(jnp.int32)
            return pos, de_acc, n_acc, key

        pos, de, n_acc, _ = jax.lax.fori_loop(
            0, self._n_moves(), body, (pos, jnp.float32(0), jnp.int32(0), key)
        )
        return pos, de, n_acc
