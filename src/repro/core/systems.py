"""The ``System`` interface: what a model must expose to be PT-sampled.

A *system* is the object being simulated (the paper's: a 2-D Ising model).
MH/PT is generic over systems — the paper notes its implementation "allows
inserting and running another model" as future work; here that generality is
first-class.

All methods are written for a **single replica** and are `vmap`-ed by the PT
driver over the replica axis (the paper's replica-level parallelism).  The
state may be any pytree.

`REGISTRY` holds the validation **system zoo**: one small exact-answerable
instance per implemented system, with the observables and engine settings the
statistical conformance suite (`tests/test_conformance.py`, backed by
`repro.validate`) runs against ground truth.  Register new systems here and
they are conformance-tested automatically (DESIGN.md §Validate).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Protocol, runtime_checkable

import jax

State = Any  # pytree


@runtime_checkable
class System(Protocol):
    """Protocol for MH/PT-sampleable systems."""

    def init_state(self, key: jax.Array) -> State:
        """Random initial state for one replica."""
        ...

    def energy(self, state: State) -> jax.Array:
        """Scalar energy E(state); the target density is exp(-beta * E)."""
        ...

    def mcmc_step(self, key: jax.Array, state: State, beta: jax.Array):
        """One MH iteration at inverse temperature ``beta``.

        Returns ``(new_state, delta_e, n_accepted)`` where ``delta_e`` is the
        exact energy change (so the driver can track energies incrementally —
        device-resident, no O(L^2) recomputation per iteration) and
        ``n_accepted`` counts accepted proposals (for diagnostics).
        """
        ...


def batched_init(system: System, key: jax.Array, n_replicas: int) -> State:
    """Initialize ``n_replicas`` independent replica states.

    Systems may provide a natively-batched `init_state_batched` fast path
    (e.g. the PT-LM system, whose states are token matrices); otherwise the
    per-replica `init_state` is vmapped.
    """
    fast = getattr(system, "init_state_batched", None)
    if fast is not None:
        return fast(key, n_replicas)
    keys = jax.random.split(key, n_replicas)
    return jax.vmap(system.init_state)(keys)


def batched_energy(system: System, states: State) -> jax.Array:
    fast = getattr(system, "batched_energy", None)
    if fast is not None:
        return fast(states)
    return jax.vmap(system.energy)(states)


# -- validation system zoo -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegisteredSystem:
    """One system-zoo entry: a small instance with an exact ground truth.

    The conformance suite runs the chunked engine (adaptive ladder on,
    ensemble axis on) on ``make()`` and checks every registered observable
    against exact enumeration / analytic values within MCSE-derived
    tolerances (`repro.validate.conformance`).

    Attributes:
      name: registry key; `repro.validate.conformance.EXACT` maps it to the
        matching exact-reference function.
      make: zero-arg factory for the validation-scale system instance.
      observables: system -> {name: per-replica observable fn} (built lazily
        so entries stay importable without constructing the system).
      temps: initial ladder, cold->hot (the adaptive run retunes the
        interior; exact references are evaluated at the *final* ladder).
      swap_interval / n_chains / chunk_intervals: engine settings.
      burn_sweeps: adaptation + equilibration sweeps discarded before
        measurement (sized so `adapt_rounds` retunes all fire here).
      n_batches / sweeps_per_batch: batch-means measurement schedule.
      adapt_rounds: AdaptConfig.max_rounds for the validation run.
      slow: exact reference costs > ~10 s -> conformance case runs in the
        `slow` test tier, keeping tier-1 latency flat.
    """

    name: str
    make: Callable[[], Any]
    observables: Callable[[Any], Mapping[str, Callable]]
    temps: tuple
    swap_interval: int = 2
    n_chains: int = 2
    chunk_intervals: int = 25
    burn_sweeps: int = 1200
    n_batches: int = 8
    sweeps_per_batch: int = 400
    adapt_rounds: int = 2
    slow: bool = False


REGISTRY: dict[str, RegisteredSystem] = {}


def register(entry: RegisteredSystem) -> RegisteredSystem:
    if entry.name in REGISTRY:
        raise ValueError(f"system {entry.name!r} already registered")
    REGISTRY[entry.name] = entry
    return entry


def _register_zoo():
    """Populate the default zoo.

    System imports live inside this function (not at module top level)
    because system modules import *this* module for the `System` protocol —
    top-level imports here would be a cycle waiting to happen.
    """
    import jax.numpy as jnp

    from repro.core.gaussian import GaussianMixture
    from repro.core.hp import HPChain, radius_of_gyration_sq
    from repro.core.ising import IsingSystem, magnetization
    from repro.core.potts import PottsSystem, potts_magnetization
    from repro.core.spin_glass import EASpinGlass

    # Glauber per-site acceptance everywhere checkerboard updates run:
    # strictly stochastic flips keep the simultaneous update aperiodic on
    # the tiny validation lattices (see repro.kernels.ref.accept_prob).
    register(RegisteredSystem(
        name="ising",
        make=lambda: IsingSystem(length=4, accept_rule="glauber"),
        observables=lambda s: {"absmag": lambda x: jnp.abs(magnetization(x))},
        temps=(1.5, 2.0, 2.6, 3.4, 4.4),
    ))
    register(RegisteredSystem(
        name="gaussian",
        make=lambda: GaussianMixture(
            mus=(-3.0, 3.0), sigmas=(0.8, 0.8), weights=(0.5, 0.5), step_size=1.0
        ),
        observables=lambda s: {"absx": jnp.abs},
        temps=(1.0, 1.8, 3.2, 5.6, 10.0),
    ))
    register(RegisteredSystem(
        name="potts",
        make=lambda: PottsSystem(shape=(4, 4), q=3, accept_rule="glauber",
                                 use_pallas=True),
        observables=lambda s: {"pmag": lambda x: potts_magnetization(x, s.q)},
        temps=(0.7, 1.0, 1.4, 2.0, 2.9),
        slow=True,  # exact reference enumerates 3^16 ~ 43M states (~20 s)
    ))
    register(RegisteredSystem(
        name="ea_spin_glass",
        make=lambda: EASpinGlass(shape=(4, 4), disorder_seed=1,
                                 accept_rule="glauber"),
        observables=lambda s: {
            "absmag": lambda x: jnp.abs(jnp.mean(x["spins"].astype(jnp.float32)))
        },
        temps=(0.8, 1.2, 1.8, 2.7, 4.0),
    ))
    register(RegisteredSystem(
        name="hp_protein",
        make=lambda: HPChain(sequence="HPHPPHHPHH"),
        observables=lambda s: {"rg2": radius_of_gyration_sq},
        temps=(0.6, 0.9, 1.4, 2.2, 3.4),
        # chain moves are sequential fori_loop iterations — keep the
        # measurement window lighter than the lattice systems'
        sweeps_per_batch=300,
        burn_sweeps=900,
    ))


_register_zoo()
