"""The ``System`` interface: what a model must expose to be PT-sampled.

A *system* is the object being simulated (the paper's: a 2-D Ising model).
MH/PT is generic over systems — the paper notes its implementation "allows
inserting and running another model" as future work; here that generality is
first-class.

All methods are written for a **single replica** and are `vmap`-ed by the PT
driver over the replica axis (the paper's replica-level parallelism).  The
state may be any pytree.

Two registries live here (DESIGN.md §API, §Validate):

* `CONSTRUCTORS` — the **constructor registry**: every in-tree system is
  nameable (``make_system("ising", {"length": 32})``) and carries a
  **named-observable registry** (``named_observables("ising", sys,
  ["absmag"])``), so a run description can reference systems and observables
  by string instead of un-serializable lambdas.  This is what
  `repro.api.SystemSpec` resolves through.
* `REGISTRY` — the validation **system zoo**: one small exact-answerable
  instance per implemented system, with the observables and engine settings
  the statistical conformance suite (`tests/test_conformance.py`, backed by
  `repro.validate`) runs against ground truth.  Zoo entries are declared by
  constructor params + observable names, so each entry compiles to a
  `repro.api.RunSpec`.  Register new systems in both and they are
  conformance-tested automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Protocol, Sequence, runtime_checkable

import jax

State = Any  # pytree


@runtime_checkable
class System(Protocol):
    """Protocol for MH/PT-sampleable systems."""

    def init_state(self, key: jax.Array) -> State:
        """Random initial state for one replica."""
        ...

    def energy(self, state: State) -> jax.Array:
        """Scalar energy E(state); the target density is exp(-beta * E)."""
        ...

    def mcmc_step(self, key: jax.Array, state: State, beta: jax.Array):
        """One MH iteration at inverse temperature ``beta``.

        Returns ``(new_state, delta_e, n_accepted)`` where ``delta_e`` is the
        exact energy change (so the driver can track energies incrementally —
        device-resident, no O(L^2) recomputation per iteration) and
        ``n_accepted`` counts accepted proposals (for diagnostics).
        """
        ...


def batched_init(system: System, key: jax.Array, n_replicas: int) -> State:
    """Initialize ``n_replicas`` independent replica states.

    Systems may provide a natively-batched `init_state_batched` fast path
    (e.g. the PT-LM system, whose states are token matrices); otherwise the
    per-replica `init_state` is vmapped.
    """
    fast = getattr(system, "init_state_batched", None)
    if fast is not None:
        return fast(key, n_replicas)
    keys = jax.random.split(key, n_replicas)
    return jax.vmap(system.init_state)(keys)


def batched_energy(system: System, states: State) -> jax.Array:
    fast = getattr(system, "batched_energy", None)
    if fast is not None:
        return fast(states)
    return jax.vmap(system.energy)(states)


# -- constructor + named-observable registry -----------------------------------


@dataclasses.dataclass(frozen=True)
class SystemEntry:
    """One nameable system family: constructor + named observables.

    Attributes:
      name: registry key (the `repro.api.SystemSpec.name` namespace).
      build: constructor called as ``build(**params)``; params must stay
        JSON-representable (numbers, strings, bools, tuples) so a
        `SystemSpec` round-trips losslessly.
      observables: observable name -> factory ``(system) -> per-replica fn``.
        The factory closes over instance attributes (e.g. the Potts ``q``),
        which is exactly what a bare lambda in an example used to do — but
        here the closure is *reconstructible from the name*, so run
        descriptions serialize.
    """

    name: str
    build: Callable[..., Any]
    observables: Mapping[str, Callable[[Any], Callable]]


CONSTRUCTORS: dict[str, SystemEntry] = {}


def register_constructor(
    name: str,
    build: Callable[..., Any],
    observables: Mapping[str, Callable[[Any], Callable]] | None = None,
) -> SystemEntry:
    if name in CONSTRUCTORS:
        raise ValueError(f"system constructor {name!r} already registered")
    entry = SystemEntry(name=name, build=build, observables=dict(observables or {}))
    CONSTRUCTORS[name] = entry
    return entry


def make_system(name: str, params: Mapping[str, Any] | None = None):
    """Instantiate a registered system family from JSON-able params."""
    if name not in CONSTRUCTORS:
        raise KeyError(
            f"unknown system {name!r}; registered: {sorted(CONSTRUCTORS)}"
        )
    return CONSTRUCTORS[name].build(**dict(params or {}))


def named_observables(
    name: str, system: Any, names: "Sequence[str]"
) -> dict[str, Callable]:
    """Resolve observable names to per-replica functions for ``system``."""
    avail = CONSTRUCTORS[name].observables
    out = {}
    for obs in names:
        if obs not in avail:
            raise KeyError(
                f"system {name!r} has no observable {obs!r}; "
                f"registered: {sorted(avail)}"
            )
        out[obs] = avail[obs](system)
    return out


# -- validation system zoo -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegisteredSystem:
    """One system-zoo entry: a small instance with an exact ground truth.

    The conformance suite runs the chunked engine (adaptive ladder on,
    ensemble axis on) on ``make()`` and checks every registered observable
    against exact enumeration / analytic values within MCSE-derived
    tolerances (`repro.validate.conformance`).

    Entries are *declarative*: the instance is named by ``params`` through
    the constructor registry and observables by ``observable_names`` through
    the named-observable registry, so every entry compiles to a serializable
    `repro.api.RunSpec` (`repro.validate.conformance.entry_runspec`).

    Attributes:
      name: registry key; `repro.validate.conformance.EXACT` maps it to the
        matching exact-reference function, and `CONSTRUCTORS` to the builder.
      params: constructor params of the validation-scale instance.
      observable_names: named observables the conformance gate checks.
      temps: initial ladder, cold->hot (the adaptive run retunes the
        interior; exact references are evaluated at the *final* ladder).
      swap_interval / n_chains / chunk_intervals: engine settings.
      burn_sweeps: adaptation + equilibration sweeps discarded before
        measurement (sized so `adapt_rounds` retunes all fire here).
      n_batches / sweeps_per_batch: batch-means measurement schedule.
      adapt_rounds: AdaptConfig.max_rounds for the validation run.
      slow: exact reference costs > ~10 s -> conformance case runs in the
        `slow` test tier, keeping tier-1 latency flat.
    """

    name: str
    params: Mapping[str, Any]
    observable_names: tuple
    temps: tuple
    swap_interval: int = 2
    n_chains: int = 2
    chunk_intervals: int = 25
    burn_sweeps: int = 1200
    n_batches: int = 8
    sweeps_per_batch: int = 400
    adapt_rounds: int = 2
    slow: bool = False

    def make(self) -> Any:
        """The validation-scale system instance (via the constructor registry)."""
        return make_system(self.name, self.params)

    def observables(self, system: Any) -> dict[str, Callable]:
        """Resolved per-replica observable fns for ``system``."""
        return named_observables(self.name, system, self.observable_names)


REGISTRY: dict[str, RegisteredSystem] = {}


def register(entry: RegisteredSystem) -> RegisteredSystem:
    if entry.name in REGISTRY:
        raise ValueError(f"system {entry.name!r} already registered")
    REGISTRY[entry.name] = entry
    return entry


def _register_zoo():
    """Populate the constructor registry and the default zoo.

    System imports live inside this function (not at module top level)
    because system modules import *this* module for the `System` protocol —
    top-level imports here would be a cycle waiting to happen.
    """
    import jax.numpy as jnp

    from repro.core.gaussian import GaussianMixture
    from repro.core.hp import HPChain, radius_of_gyration_sq
    from repro.core.ising import IsingSystem, magnetization
    from repro.core.potts import PottsSystem, potts_magnetization
    from repro.core.spin_glass import EASpinGlass

    register_constructor(
        "ising",
        IsingSystem,
        observables={
            "mag": lambda s: magnetization,
            "absmag": lambda s: (lambda x: jnp.abs(magnetization(x))),
            "energy_per_site": lambda s: (
                lambda x: s.energy(x) / (s.length * s.length)
            ),
        },
    )
    register_constructor(
        "gaussian",
        GaussianMixture,
        observables={
            "x": lambda s: (lambda x: x),
            "absx": lambda s: jnp.abs,
        },
    )
    register_constructor(
        "potts",
        PottsSystem,
        observables={
            "pmag": lambda s: (lambda x: potts_magnetization(x, s.q)),
        },
    )
    register_constructor(
        "ea_spin_glass",
        EASpinGlass,
        observables={
            "absmag": lambda s: (
                lambda x: jnp.abs(jnp.mean(x["spins"].astype(jnp.float32)))
            ),
        },
    )
    register_constructor(
        "hp_protein",
        HPChain,
        observables={
            "rg2": lambda s: radius_of_gyration_sq,
        },
    )

    # Glauber per-site acceptance everywhere checkerboard updates run:
    # strictly stochastic flips keep the simultaneous update aperiodic on
    # the tiny validation lattices (see repro.kernels.ref.accept_prob).
    register(RegisteredSystem(
        name="ising",
        params={"length": 4, "accept_rule": "glauber"},
        observable_names=("absmag",),
        temps=(1.5, 2.0, 2.6, 3.4, 4.4),
    ))
    register(RegisteredSystem(
        name="gaussian",
        params={"mus": (-3.0, 3.0), "sigmas": (0.8, 0.8),
                "weights": (0.5, 0.5), "step_size": 1.0},
        observable_names=("absx",),
        temps=(1.0, 1.8, 3.2, 5.6, 10.0),
    ))
    register(RegisteredSystem(
        name="potts",
        params={"shape": (4, 4), "q": 3, "accept_rule": "glauber",
                "use_pallas": True},
        observable_names=("pmag",),
        temps=(0.7, 1.0, 1.4, 2.0, 2.9),
        slow=True,  # exact reference enumerates 3^16 ~ 43M states (~20 s)
    ))
    register(RegisteredSystem(
        name="ea_spin_glass",
        params={"shape": (4, 4), "disorder_seed": 1, "accept_rule": "glauber"},
        observable_names=("absmag",),
        temps=(0.8, 1.2, 1.8, 2.7, 4.0),
    ))
    register(RegisteredSystem(
        name="hp_protein",
        params={"sequence": "HPHPPHHPHH"},
        observable_names=("rg2",),
        temps=(0.6, 0.9, 1.4, 2.2, 3.4),
        # chain moves are sequential fori_loop iterations — keep the
        # measurement window lighter than the lattice systems'
        sweeps_per_batch=300,
        burn_sweeps=900,
    ))


_register_zoo()
