"""The ``System`` interface: what a model must expose to be PT-sampled.

A *system* is the object being simulated (the paper's: a 2-D Ising model).
MH/PT is generic over systems — the paper notes its implementation "allows
inserting and running another model" as future work; here that generality is
first-class.

All methods are written for a **single replica** and are `vmap`-ed by the PT
driver over the replica axis (the paper's replica-level parallelism).  The
state may be any pytree.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax

State = Any  # pytree


@runtime_checkable
class System(Protocol):
    """Protocol for MH/PT-sampleable systems."""

    def init_state(self, key: jax.Array) -> State:
        """Random initial state for one replica."""
        ...

    def energy(self, state: State) -> jax.Array:
        """Scalar energy E(state); the target density is exp(-beta * E)."""
        ...

    def mcmc_step(self, key: jax.Array, state: State, beta: jax.Array):
        """One MH iteration at inverse temperature ``beta``.

        Returns ``(new_state, delta_e, n_accepted)`` where ``delta_e`` is the
        exact energy change (so the driver can track energies incrementally —
        device-resident, no O(L^2) recomputation per iteration) and
        ``n_accepted`` counts accepted proposals (for diagnostics).
        """
        ...


def batched_init(system: System, key: jax.Array, n_replicas: int) -> State:
    """Initialize ``n_replicas`` independent replica states.

    Systems may provide a natively-batched `init_state_batched` fast path
    (e.g. the PT-LM system, whose states are token matrices); otherwise the
    per-replica `init_state` is vmapped.
    """
    fast = getattr(system, "init_state_batched", None)
    if fast is not None:
        return fast(key, n_replicas)
    keys = jax.random.split(key, n_replicas)
    return jax.vmap(system.init_state)(keys)


def batched_energy(system: System, states: State) -> jax.Array:
    fast = getattr(system, "batched_energy", None)
    if fast is not None:
        return fast(states)
    return jax.vmap(system.energy)(states)
