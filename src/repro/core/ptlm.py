"""Parallel Tempering over LM token sequences (beyond-paper integration).

The paper's technique is a *sampling-layer* accelerator; here it drives the
assigned-architecture pool (DESIGN.md §5): the state is a token sequence, the
energy is the sequence NLL under the model, and the temperature ladder
flattens the sequence distribution exactly like Fig. 1a flattens the
Boltzmann distribution.

MH proposal: pick a random position (past the prompt), resample that token
from the model's own conditional at that position (an independence-sampler
coordinate move).  Acceptance for target pi_beta(x) ∝ p(x)^beta:

    A = min(1, [p(x')^beta * q_pos(x_old)] / [p(x)^beta * q_pos(x_new)])

where q_pos is the conditional both proposals are drawn from (it depends only
on the unchanged prefix).  beta=1 recovers exact-ish Gibbs-style sampling;
cold rungs (beta>1) sharpen toward MAP sequences; hot rungs explore — and PT
swaps move good continuations to the cold rungs.  This is the LM analogue of
the paper's Ising setup and runs on every arch exposing the backbone API.

All replicas advance in one batched forward (replica-level parallelism, as
in the paper); the sequence scoring reuses the chunked-CE machinery.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class LMSystem:
    """PT-sampleable wrapper around a decoder-only LM.

    Hashable/static: model params are captured via closure in `bind`.
    """

    cfg: ModelConfig
    seq_len: int
    prompt_len: int = 1

    def bind(self, params):
        return _BoundLMSystem(self, params)


class _BoundLMSystem:
    """System-protocol object (batched fast paths) closed over params.

    Identity-hashed so the PT driver can treat it as a static jit argument;
    the params are then closure constants of the compiled run — fine for the
    example/test scale this sampler targets (a large-scale deployment would
    thread params as a traced argument through a custom driver).
    """

    def __init__(self, spec: LMSystem, params):
        self.spec = spec
        self.params = params
        self.cfg = spec.cfg

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other

    # -- scoring -------------------------------------------------------------
    def _hidden(self, tokens):
        return transformer.backbone(self.params, self.cfg, tokens)

    def _token_logprobs(self, tokens):
        """(R, S-1) log p(x_t | x_<t) for t = 1..S-1."""
        cfg = self.cfg
        hidden = self._hidden(tokens)
        w = transformer.unembed_matrix(self.params, cfg).astype(cfg.compute_dtype)
        logits = jnp.einsum(
            "bsd,dv->bsv", hidden[:, :-1].astype(cfg.compute_dtype), w,
            preferred_element_type=jnp.float32,
        )
        logp = jax.nn.log_softmax(logits, axis=-1)
        return jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]

    def batched_energy(self, tokens):
        """E(x) = -log p(x_{prompt:} | prompt): sum NLL past the prompt."""
        lp = self._token_logprobs(tokens)
        mask = jnp.arange(1, tokens.shape[1]) >= self.spec.prompt_len
        return -(lp * mask).sum(axis=-1)

    # -- System protocol (batched) --------------------------------------------
    def init_state_batched(self, key, n_replicas):
        s = self.spec.seq_len
        return jax.random.randint(key, (n_replicas, s), 0, self.cfg.vocab, jnp.int32)

    def batched_mcmc_step(self, keys, tokens, betas):
        """One coordinate MH move per replica, fully batched.

        Returns (new_tokens, delta_e, accepted) like the System protocol.
        """
        cfg, spec = self.cfg, self.spec
        r, s = tokens.shape
        key = keys[0]  # driver hands per-replica keys; derive common draws
        k_pos, k_tok, k_acc = jax.random.split(key, 3)
        pos = jax.random.randint(k_pos, (r,), spec.prompt_len, s)  # site per replica

        # current conditionals at pos (depend only on the prefix — identical
        # for old and proposed sequence)
        hidden = self._hidden(tokens)
        w = transformer.unembed_matrix(self.params, cfg).astype(cfg.compute_dtype)
        h_at = jnp.take_along_axis(hidden, (pos - 1)[:, None, None], axis=1)[:, 0]
        logits = jnp.einsum(
            "bd,dv->bv", h_at.astype(cfg.compute_dtype), w,
            preferred_element_type=jnp.float32,
        )
        q = jax.nn.log_softmax(logits, axis=-1)  # (R, V)
        new_tok = jax.random.categorical(k_tok, logits, axis=-1)  # sample q
        old_tok = jnp.take_along_axis(tokens, pos[:, None], axis=1)[:, 0]

        proposed = tokens.at[jnp.arange(r), pos].set(new_tok)

        e_old = self.batched_energy(tokens)
        e_new = self.batched_energy(proposed)
        q_new = jnp.take_along_axis(q, new_tok[:, None], axis=1)[:, 0]
        q_old = jnp.take_along_axis(q, old_tok[:, None], axis=1)[:, 0]
        log_a = -betas * (e_new - e_old) + (q_old - q_new)
        accept = jnp.log(jax.random.uniform(k_acc, (r,), minval=1e-20)) < log_a
        tokens = jnp.where(accept[:, None], proposed, tokens)
        de = jnp.where(accept, e_new - e_old, 0.0)
        return tokens, de, accept.astype(jnp.int32)

    # per-replica protocol methods (used by generic helpers)
    def init_state(self, key):
        return self.init_state_batched(key, 1)[0]

    def energy(self, tokens):
        return self.batched_energy(tokens[None])[0]

    def mcmc_step(self, key, tokens, beta):
        t, de, acc = self.batched_mcmc_step(key[None], tokens[None], beta[None])
        return t[0], de[0], acc[0]
