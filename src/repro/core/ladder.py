"""Temperature ladders for Parallel Tempering.

The paper assigns replica ``i`` the temperature ``T_i = 1 + i * 3 / |R|``,
covering ``[1.0, 4.0)`` (section 3).  We implement that ladder faithfully plus
the standard geometric ladder and a feedback-tuned ladder (Kofke-style
acceptance equalization) as beyond-paper options.

Conventions: ``k_B = 1``; ``beta = 1 / T``.  Ladders are returned **cold to
hot** (rung 0 = lowest temperature), matching the paper's indexing.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "paper_ladder",
    "linear_ladder",
    "geometric_ladder",
    "betas_from_temps",
    "tune_ladder",
]


def paper_ladder(n_replicas: int, t_min: float = 1.0, t_span: float = 3.0) -> jnp.ndarray:
    """The paper's ladder: ``T_i = t_min + i * t_span / n_replicas``.

    Note the paper divides by ``|R|`` (not ``|R| - 1``), so ``T_max`` is
    ``t_min + t_span * (R-1)/R`` — the hot end is exclusive.
    """
    i = jnp.arange(n_replicas, dtype=jnp.float32)
    return t_min + i * (t_span / n_replicas)


def linear_ladder(n_replicas: int, t_min: float, t_max: float) -> jnp.ndarray:
    """Inclusive linear ladder on ``[t_min, t_max]``."""
    return jnp.linspace(t_min, t_max, n_replicas, dtype=jnp.float32)


def geometric_ladder(n_replicas: int, t_min: float, t_max: float) -> jnp.ndarray:
    """Geometric ladder — constant ratio ``T_{i+1}/T_i``.

    The classical choice for systems whose heat capacity is roughly constant
    over the ladder; gives approximately uniform swap acceptance.
    """
    return jnp.asarray(
        np.geomspace(t_min, t_max, n_replicas), dtype=jnp.float32
    )


def betas_from_temps(temps: jnp.ndarray) -> jnp.ndarray:
    return (1.0 / temps).astype(jnp.float32)


def tune_ladder(
    temps: np.ndarray,
    swap_acceptance: np.ndarray,
    target: float = 0.23,
    rate: float = 0.5,
    t_min: float | None = None,
    t_max: float | None = None,
) -> np.ndarray:
    """One feedback step of acceptance-equalizing ladder adaptation.

    Adjusts the log-spacing between adjacent rungs: spacings whose measured
    swap acceptance exceeds ``target`` are widened, under-accepting spacings
    are narrowed.  Endpoints are pinned (to ``t_min``/``t_max`` or the current
    ends).  This is a practical variant of Kofke's equal-acceptance rule used
    by adaptive PT schemes [Miasojedow et al. 2013, paper ref 12].

    Args:
      temps: current ladder, shape (R,), cold→hot.
      swap_acceptance: measured acceptance per adjacent pair, shape (R-1,).
      target: desired uniform acceptance.
      rate: feedback gain in log-spacing space.

    Returns the new ladder (numpy, host-side — tuning runs between intervals).
    """
    temps = np.asarray(temps, dtype=np.float64)
    acc = np.clip(np.asarray(swap_acceptance, dtype=np.float64), 1e-3, 1.0)
    log_gaps = np.diff(np.log(temps))
    # Larger acceptance -> gap can grow; smaller -> shrink.
    log_gaps = log_gaps * (1.0 + rate * np.tanh(np.log(acc / target)))
    new = np.concatenate([[np.log(temps[0])], np.log(temps[0]) + np.cumsum(log_gaps)])
    new = np.exp(new)
    lo = temps[0] if t_min is None else t_min
    hi = temps[-1] if t_max is None else t_max
    # Rescale interior to pinned endpoints.
    new = lo + (new - new[0]) * (hi - lo) / max(new[-1] - new[0], 1e-12)
    return new.astype(np.float32)
