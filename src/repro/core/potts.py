"""q-state Potts model system (the first beyond-paper lattice workload).

The Potts model generalizes Ising to ``q`` colours per site::

    E(s) = -J * sum_<x,y> delta(s_x, s_y)        (each bond counted once)

with periodic boundaries on a rectangular ``(H, W)`` lattice.  At q=2 it is
the Ising model up to an energy rescale (delta = (1 + s s')/2), and for
q >= 3 the 2-D transition turns first-order at q > 4 — a genuinely harder
free-energy landscape for PT to cross, which is why it appears in the
validation zoo (DESIGN.md §Validate).

The update is the same TPU-native checkerboard scheme as the Ising system:
sites of one parity share no bonds (PBC needs even dims — enforced), so a
whole colour class updates simultaneously with per-site MH acceptance.  The
proposal is a uniformly random *different* colour (symmetric, so plain MH
applies).  The sweep reuses the Pallas replica-tile strategy via
`repro.kernels.ops.potts_sweep` (`use_pallas=True`) with
`repro.kernels.ref.potts_sweep` as the bit-exact oracle and XLA fallback.

Order parameter: ``m = (q * max_colour_fraction - 1) / (q - 1)`` in [~0, 1] —
the standard Potts magnetization, reducing to |m| for q=2.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["PottsSystem", "potts_energy", "potts_magnetization"]


def potts_energy(states: jnp.ndarray, q: int, j: float) -> jnp.ndarray:
    """E = -J * sum over right+down bonds of delta(s, s_nbr); PBC, f32.

    Counts each bond once.  (On a 2-wide dim the two wrap bonds between the
    same site pair are both counted — consistent with the 4-neighbour dE the
    sweep uses.)
    """
    s = states.astype(jnp.int32)
    match = (s == jnp.roll(s, -1, axis=-1)).astype(jnp.float32) + (
        s == jnp.roll(s, -1, axis=-2)
    ).astype(jnp.float32)
    return -j * jnp.sum(match, axis=(-2, -1))


def potts_magnetization(states: jnp.ndarray, q: int) -> jnp.ndarray:
    """Potts order parameter ``(q * rho_max - 1)/(q - 1)`` per replica.

    ``rho_max`` is the occupation fraction of the most common colour; the
    parameter is ~0 in the disordered phase and -> 1 at saturation.
    """
    s = states.astype(jnp.int32)
    n = s.shape[-2] * s.shape[-1]
    counts = jnp.stack(
        [jnp.sum((s == c).astype(jnp.float32), axis=(-2, -1)) for c in range(q)],
        axis=-1,
    )
    rho_max = jnp.max(counts, axis=-1) / n
    return (q * rho_max - 1.0) / (q - 1.0)


@dataclasses.dataclass(frozen=True)
class PottsSystem:
    """One replica of the q-state Potts model; vmapped by the PT driver.

    Attributes:
      shape: lattice (H, W); both even (checkerboard 2-colourability, PBC).
      q: number of colours (>= 2).
      j: coupling constant (ferromagnetic for j > 0).
      use_pallas: route the sweep through the Pallas kernel
        (interpret=True on CPU) instead of the pure-XLA oracle.
      use_fused: run whole swap intervals through the interval-fused kernel
        (`repro.kernels.ops.potts_sweep_fused`) with counter-PRNG uniforms
        generated in-kernel.  The random stream differs from the per-sweep
        path (statistically gated, not bit-equal — DESIGN.md §6); with
        ``use_pallas=False`` the bit-exact fused pure-JAX reference runs.
      use_fused_round: temp-mode DEO/SEO only — fuse whole PT rounds
        (sweeps *plus* the exchange) into one launch via
        `repro.kernels.ops.potts_round_fused` (see
        `repro.core.ising.IsingSystem` for the stream contract).
      pack_bits: fused paths only — keep the lattice in dense int8 lanes
        in-kernel instead of widening to int32 (bitwise-identical; needs
        q ≤ 64).
      accept_rule: "metropolis" or "glauber" (see repro.kernels.ref).
      r_blk: replicas per Pallas grid step; 4 is the documented VMEM-safe
        block at the paper's L=300 (`kernels.potts_sweep`).
    """

    shape: tuple
    q: int = 3
    j: float = 1.0
    use_pallas: bool = False
    use_fused: bool = False
    use_fused_round: bool = False
    pack_bits: bool = False
    accept_rule: str = "metropolis"
    r_blk: int = 4

    def __post_init__(self):
        h, w = self.shape
        if h % 2 != 0 or w % 2 != 0:
            # Same constraint as IsingSystem: with PBC an odd dim breaks
            # 2-colourability (wrap-around neighbours share parity).
            raise ValueError(
                f"checkerboard Potts needs even dims under PBC, got {self.shape}"
            )
        if self.q < 2:
            raise ValueError(f"Potts needs q >= 2, got q={self.q}")
        if self.use_fused_round and not self.use_fused:
            raise ValueError(
                "use_fused_round=True needs use_fused=True (the round "
                "kernel is the interval-fused kernel plus an in-kernel "
                "exchange)"
            )
        if self.pack_bits and self.q > 64:
            raise ValueError(
                f"pack_bits needs q <= 64 (int8 lanes), got q={self.q}"
            )

    # -- System protocol ---------------------------------------------------
    def init_state(self, key: jax.Array) -> jnp.ndarray:
        return jax.random.randint(key, self.shape, 0, self.q).astype(jnp.int8)

    def energy(self, states: jnp.ndarray) -> jnp.ndarray:
        return potts_energy(states, self.q, self.j)

    def magnetization(self, states: jnp.ndarray) -> jnp.ndarray:
        return potts_magnetization(states, self.q)

    def mcmc_step(self, key: jax.Array, states: jnp.ndarray, beta: jnp.ndarray):
        s, de, na = self._sweep(states[None], key[None], beta[None])
        return s[0], de[0], na[0]

    # -- batched fast path (used by the PT driver instead of vmap) ----------
    def batched_mcmc_step(self, keys, states, betas):
        """Natively replica-batched sweep: (R, H, W) in, (R, H, W) out."""
        return self._sweep(states, keys, betas)

    def _sweep(self, states, keys, betas):
        h, w = self.shape
        u = jax.vmap(
            lambda k: jax.random.uniform(k, (2, 2, h, w), jnp.float32)
        )(keys)
        from repro.kernels import ops as kops

        return kops.potts_sweep(
            states, u, betas, q=self.q, j=self.j, rule=self.accept_rule,
            r_blk=self.r_blk, use_pallas=self.use_pallas,
        )

    # -- fused whole-interval fast path (used when use_fused=True) -----------
    def batched_mcmc_interval(self, key, t, states, betas, *, n_sweeps,
                              replica_offset=0):
        """``n_sweeps`` replica-batched sweeps in one fused launch (see
        `repro.core.ising.IsingSystem.batched_mcmc_interval`)."""
        from repro.kernels import ops as kops

        return kops.potts_sweep_fused(
            states, key, t, betas, n_sweeps=n_sweeps, q=self.q,
            replica_offset=replica_offset, j=self.j,
            rule=self.accept_rule, r_blk=self.r_blk,
            pack_bits=self.pack_bits, use_pallas=self.use_pallas,
        )

    # -- whole-round fast path (used when use_fused_round=True) --------------
    def batched_mcmc_round(self, key, t, phase, states, rung, energy, betas,
                           *, n_sweeps, n_rounds=1, criterion="logistic",
                           pairing="deo"):
        """``n_rounds`` whole PT rounds fused (see
        `repro.core.ising.IsingSystem.batched_mcmc_round`)."""
        from repro.kernels import ops as kops

        return kops.potts_round_fused(
            states, key, t, phase, rung, energy, betas,
            n_sweeps=n_sweeps, q=self.q, n_rounds=n_rounds, j=self.j,
            rule=self.accept_rule, criterion=criterion, pairing=pairing,
            pack_bits=self.pack_bits, use_pallas=self.use_pallas,
        )
