"""Distributed replica placement, swap communication and elastic rebalance.

The paper distributes replicas over OpenMP/CUDA threads (|R|/H replicas per
thread).  On a TPU mesh the replica axis is sharded over mesh axes; each
device owns ``R / n_devices`` replicas and advances them between swap
iterations with zero communication.  At a swap iteration:

* ``temp`` swap mode: the decision needs only the (R,) energy/rung vectors —
  an all-gather of a few KB — and *no state movement*.  This is the
  O(R·L²) → O(R) swap-traffic reduction measured in DESIGN.md §Perf.
* ``state`` swap mode (faithful): accepted pairs exchange (L,L) lattices;
  pairs that straddle a shard boundary become GSPMD-generated
  collective-permutes/all-to-alls.

Elastic scaling: replicas are independent between swaps, so PT is
*embarrassingly elastic* — `rebalance` reshapes the replica population onto a
new mesh, growing by cloning (with fresh PRNG noise injected by subsequent
sweeps) or shrinking by dropping interior rungs while preserving the ladder
endpoints.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pt import PTState

__all__ = ["replica_sharding", "shard_state", "rebalance_ladder", "rebalance_state"]


def replica_sharding(mesh: Mesh, axes=None) -> NamedSharding:
    """NamedSharding placing the leading replica axis over the given mesh axes.

    Replicas are embarrassingly parallel between swap iterations, so the
    default shards them over EVERY mesh axis (pod x data x model) — the
    paper's "one replica per thread" at mesh scale."""
    axes = mesh.axis_names if axes is None else axes
    use = tuple(a for a in axes if a in mesh.axis_names)
    return NamedSharding(mesh, P(use if use else None))


def shard_state(state: PTState, shard: NamedSharding) -> PTState:
    """Constrain all (R, ...) leaves of the PT state to the replica sharding."""

    def constrain(x):
        if hasattr(x, "ndim") and x.ndim >= 1:
            return jax.lax.with_sharding_constraint(x, shard)
        return x

    return PTState(
        states=jax.tree_util.tree_map(constrain, state.states),
        energy=constrain(state.energy),
        rung=constrain(state.rung),
        key=state.key,
        phase=state.phase,
        t=state.t,
    )


def rebalance_ladder(temps: np.ndarray, new_r: int) -> np.ndarray:
    """Resample a ladder to ``new_r`` rungs, preserving endpoints (geometric
    interpolation in log-T)."""
    temps = np.asarray(temps, dtype=np.float64)
    x_old = np.linspace(0.0, 1.0, len(temps))
    x_new = np.linspace(0.0, 1.0, new_r)
    return np.exp(np.interp(x_new, x_old, np.log(temps))).astype(np.float32)


def rebalance_state(state: PTState, new_r: int) -> PTState:
    """Elastically grow/shrink the replica population to ``new_r``.

    Growing tiles existing replicas (their chains decorrelate after a few
    sweeps — each slot gets an independent PRNG stream via fold_in(slot)).
    Shrinking keeps an endpoint-preserving subsample in rung order.
    Rungs are re-assigned to the identity; callers pair this with
    `rebalance_ladder` for the new temperature ladder.
    """
    r_old = state.energy.shape[0]
    if new_r == r_old:
        return state
    if new_r > r_old:
        sel = jnp.arange(new_r, dtype=jnp.int32) % r_old
    else:
        # Endpoint-preserving subsample in rung order.
        pick = np.unique(np.round(np.linspace(0, r_old - 1, new_r)).astype(np.int64))
        while len(pick) < new_r:  # guard duplicates on tiny ladders
            extra = np.setdiff1d(np.arange(r_old), pick)[: new_r - len(pick)]
            pick = np.sort(np.concatenate([pick, extra]))
        inv = jnp.argsort(state.rung)
        sel = inv[jnp.asarray(pick, dtype=jnp.int32)]
    states = jax.tree_util.tree_map(lambda x: jnp.take(x, sel, axis=0), state.states)
    return dataclasses.replace(
        state,
        states=states,
        energy=jnp.take(state.energy, sel),
        rung=jnp.arange(new_r, dtype=jnp.int32),
    )
