"""Distributed replica placement: the (ensemble x replica) PT mesh layout.

The paper distributes replicas over OpenMP/CUDA threads (|R|/H replicas per
thread).  Here the same decomposition is a named 2-D device mesh
(`MeshSpec`): the ``chains`` axis holds whole independent chains (the
embarrassingly parallel ensemble layout) and the ``replicas`` axis splits
each chain's rung population into contiguous slot blocks.  Each device
advances its ``R / replica`` replicas between swap iterations with zero
communication; at a swap iteration:

* ``temp`` swap mode: the decision needs only the (R,) energy/rung rows —
  one ``all-gather`` of O(R) *scalars* per exchange, computed redundantly on
  every device, and *no lattice movement* (rung labels permute in place).
  This is the O(R·L²) → O(R) swap-traffic reduction measured by
  `benchmarks.swap_overhead` via `repro.hlo.collectives`.
* ``state`` swap mode (faithful): accepted pairs exchange (L,L) lattices, so
  the explicit shard_map path only supports it with ``replica == 1`` (whole
  rung populations per device); sharding the replica axis requires ``temp``
  mode — the engine raises otherwise instead of silently moving O(R·L²)
  bytes per swap.

The placement contract (consumed by `repro.engine.driver.Engine`):

=====================  =========================  =========================
state leaf             C == 1                     C > 1 (ensemble)
=====================  =========================  =========================
``pt.states`` leaves   P('replicas', ...)         P('chains', 'replicas', ...)
``pt.energy/rung``     P('replicas')              P('chains', 'replicas')
``pt.key/phase/t``     P() (replicated)           P('chains')
``stats`` leaves       P(None, ...) (replicated)  P('chains', None, ...)
``betas``              P(None) (replicated)       P(None) (replicated)
=====================  =========================  =========================

O(R) rows (stats, betas, the swap decision) are replicated along the
replica axis and kept identical on every device — which is what makes the
sharded mega-step bit-equal to the single-device path.

Elastic scaling: replicas are independent between swaps, so PT is
*embarrassingly elastic* — `rebalance_state` reshapes the replica population
onto a new ladder size, growing by cloning (with fresh PRNG noise injected
by subsequent sweeps) or shrinking by dropping interior rungs while
preserving the ladder endpoints.

`replica_sharding` / `shard_state` remain as the legacy single-launch GSPMD
constraint-hint path used by the monolithic `repro.core.pt.run` shim; the
chunked engine now places state explicitly through `MeshSpec` instead.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pt import PTState

__all__ = [
    "CHAIN_AXIS",
    "REPLICA_AXIS",
    "MeshSpec",
    "pt_partition_specs",
    "replicated_partition_specs",
    "named_shardings",
    "replica_sharding",
    "shard_state",
    "rebalance_ladder",
    "rebalance_state",
]

CHAIN_AXIS = "chains"
REPLICA_AXIS = "replicas"


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Serializable description of the (ensemble x replica) device mesh.

    ``ensemble`` devices along the ``chains`` axis (whole chains per device)
    times ``replica`` devices along the ``replicas`` axis (contiguous rung
    slot blocks per device).  ``MeshSpec(1, 1)`` still runs the explicit
    shard_map mega-step — on a 1-device mesh — which is what lets tier-1
    pin sharded-vs-plain bit-equality without a multi-device host.
    """

    ensemble: int = 1
    replica: int = 1

    def __post_init__(self):
        if self.ensemble < 1 or self.replica < 1:
            raise ValueError(
                f"mesh axes must be >= 1, got ensemble={self.ensemble} "
                f"replica={self.replica}"
            )

    @property
    def n_devices(self) -> int:
        return self.ensemble * self.replica

    def validate(self, n_replicas: int, n_chains: int) -> None:
        """Check the run shape divides onto this mesh (fail at config time)."""
        if n_replicas % self.replica != 0:
            raise ValueError(
                f"n_replicas={n_replicas} does not divide over the "
                f"{self.replica}-way replica mesh axis"
            )
        if n_chains % self.ensemble != 0:
            raise ValueError(
                f"n_chains={n_chains} does not divide over the "
                f"{self.ensemble}-way ensemble mesh axis"
            )

    def build(self, devices=None) -> Mesh:
        """The concrete `jax.sharding.Mesh` (first ``n_devices`` by default).

        Device order is deterministic (`jax.devices()` order, ensemble-major)
        so the slot -> device assignment — and therefore the all-gather row
        order — is reproducible across processes.
        """
        devices = list(jax.devices()) if devices is None else list(devices)
        if len(devices) < self.n_devices:
            raise ValueError(
                f"mesh {self.ensemble}x{self.replica} needs "
                f"{self.n_devices} devices, only {len(devices)} available "
                "(simulate with XLA_FLAGS=--xla_force_host_platform_device_count=N)"
            )
        arr = np.array(devices[: self.n_devices]).reshape(
            self.ensemble, self.replica
        )
        return Mesh(arr, (CHAIN_AXIS, REPLICA_AXIS))


def _is_spec(x) -> bool:
    return isinstance(x, P)


def pt_partition_specs(state: PTState, n_chains: int) -> PTState:
    """PartitionSpec tree for a `PTState` under the placement contract.

    Replica-population leaves shard their slot axis over ``replicas`` (and
    the leading chain axis over ``chains`` with an ensemble); per-chain
    scalars (key/phase/t) replicate along ``replicas``.
    """
    lead = (CHAIN_AXIS,) if n_chains > 1 else ()
    nl = len(lead)

    def rep(x):
        return P(*lead, REPLICA_AXIS, *([None] * (x.ndim - nl - 1)))

    def chain_only(x):
        return P(*lead)

    return PTState(
        states=jax.tree_util.tree_map(rep, state.states),
        energy=rep(state.energy),
        rung=rep(state.rung),
        key=chain_only(state.key),
        phase=chain_only(state.phase),
        t=chain_only(state.t),
    )


def replicated_partition_specs(tree, n_chains: int):
    """Specs for O(R) diagnostic trees (stats): chain-sharded, replica-replicated.

    Every device along the replica axis carries the full (R,) rows and
    updates them redundantly from the all-gathered record — identical values
    by construction, so no reduction is ever needed.
    """
    lead = (CHAIN_AXIS,) if n_chains > 1 else ()
    nl = len(lead)

    def spec(x):
        return P(*lead, *([None] * (x.ndim - nl)))

    return jax.tree_util.tree_map(spec, tree)


def named_shardings(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (for `jax.device_put`)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=_is_spec
    )


def replica_sharding(mesh: Mesh, axes=None) -> NamedSharding:
    """NamedSharding placing the leading replica axis over the given mesh axes.

    Legacy GSPMD-hint layout (used by the monolithic `repro.core.pt.run`
    path): replicas are embarrassingly parallel between swap iterations, so
    the default shards them over EVERY mesh axis — the paper's "one replica
    per thread" at mesh scale.  The chunked engine uses `MeshSpec` instead."""
    axes = mesh.axis_names if axes is None else axes
    use = tuple(a for a in axes if a in mesh.axis_names)
    return NamedSharding(mesh, P(use if use else None))


def shard_state(state: PTState, shard: NamedSharding) -> PTState:
    """Constrain all (R, ...) leaves of the PT state to the replica sharding."""

    def constrain(x):
        if hasattr(x, "ndim") and x.ndim >= 1:
            return jax.lax.with_sharding_constraint(x, shard)
        return x

    return PTState(
        states=jax.tree_util.tree_map(constrain, state.states),
        energy=constrain(state.energy),
        rung=constrain(state.rung),
        key=state.key,
        phase=state.phase,
        t=state.t,
    )


def rebalance_ladder(temps: np.ndarray, new_r: int) -> np.ndarray:
    """Resample a ladder to ``new_r`` rungs, preserving endpoints (geometric
    interpolation in log-T)."""
    temps = np.asarray(temps, dtype=np.float64)
    x_old = np.linspace(0.0, 1.0, len(temps))
    x_new = np.linspace(0.0, 1.0, new_r)
    return np.exp(np.interp(x_new, x_old, np.log(temps))).astype(np.float32)


def rebalance_state(state: PTState, new_r: int) -> PTState:
    """Elastically grow/shrink the replica population to ``new_r``.

    Growing tiles existing replicas (their chains decorrelate after a few
    sweeps — each slot gets an independent PRNG stream via fold_in(slot)).
    Shrinking keeps an endpoint-preserving subsample in rung order.
    Rungs are re-assigned to the identity; callers pair this with
    `rebalance_ladder` for the new temperature ladder.
    """
    r_old = state.energy.shape[0]
    if new_r == r_old:
        return state
    if new_r > r_old:
        sel = jnp.arange(new_r, dtype=jnp.int32) % r_old
    else:
        # Endpoint-preserving subsample in rung order.
        pick = np.unique(np.round(np.linspace(0, r_old - 1, new_r)).astype(np.int64))
        while len(pick) < new_r:  # guard duplicates on tiny ladders
            extra = np.setdiff1d(np.arange(r_old), pick)[: new_r - len(pick)]
            pick = np.sort(np.concatenate([pick, extra]))
        inv = jnp.argsort(state.rung)
        sel = inv[jnp.asarray(pick, dtype=jnp.int32)]
    states = jax.tree_util.tree_map(lambda x: jnp.take(x, sel, axis=0), state.states)
    return dataclasses.replace(
        state,
        states=states,
        energy=jnp.take(state.energy, sel),
        rung=jnp.arange(new_r, dtype=jnp.int32),
    )
