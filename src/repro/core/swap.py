"""Replica-exchange (swap) scheduling and acceptance for Parallel Tempering.

Faithful to the paper (section 3):

* pairing rule (i): a replica may only exchange with one of its two ladder
  neighbours; (ii): a replica is exchanged at most once per swap iteration.
* the pairing alternates between *even* phase ``(0,1),(2,3),…`` and *odd*
  phase ``(1,2),(3,4),…`` so state can propagate across the whole ladder.
* acceptance (following Coluzza & Frenkel, paper ref [13]):
  ``P_swap(i,j) = exp(Δβ·ΔE) / (1 + exp(Δβ·ΔE))`` with ``Δβ = β_i − β_j`` and
  ``ΔE = E_i − E_j`` — the *logistic* (Barker/Glauber) rule.  The classical
  Metropolis rule ``min(1, exp(Δβ·ΔE))`` is provided as an option; both
  satisfy detailed balance for the extended ensemble.

All functions are shape-polymorphic in the number of replicas and fully
vectorized: every pair's decision is computed in parallel (the paper
parallelizes the swap phase across threads; here it is a fused vector op, and
under `pjit` the work is sharded with the replica axis).
"""
from __future__ import annotations

from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

__all__ = [
    "pair_partners",
    "swap_probability",
    "accept_pairs",
    "swap_permutation",
]

Criterion = Literal["logistic", "metropolis"]


def pair_partners(n: int, phase) -> jnp.ndarray:
    """Partner index for each rung under the alternating neighbour pairing.

    Args:
      n: number of replicas (static).
      phase: 0 for pairs (0,1),(2,3),…; 1 for pairs (1,2),(3,4),….  May be a
        traced integer (phase alternates inside `lax.scan`).

    Returns:
      ``partner`` with ``partner[i] = j`` if ``{i, j}`` is a pair this phase,
      else ``partner[i] = i`` (unpaired boundary rung).
    """
    idx = jnp.arange(n, dtype=jnp.int32)
    phase = jnp.asarray(phase, dtype=jnp.int32) % 2
    # even phase: i ^ 1 ; odd phase: shift by one -> ((i-1) ^ 1) + 1, i>=1
    even = idx ^ 1
    odd = jnp.where(idx == 0, 0, ((idx - 1) ^ 1) + 1)
    partner = jnp.where(phase == 0, even, odd)
    # Boundary: an index that fell off the end stays unpaired.
    return jnp.where(partner >= n, idx, partner).astype(jnp.int32)


def swap_probability(
    beta_lo: jnp.ndarray,
    beta_hi: jnp.ndarray,
    e_lo: jnp.ndarray,
    e_hi: jnp.ndarray,
    criterion: Criterion = "logistic",
) -> jnp.ndarray:
    """Vectorized swap acceptance probability for pairs (lo, hi).

    The argument is ``Δβ·ΔE`` with differences taken in the same order on both
    factors, so the function is symmetric in the pair labelling.
    """
    arg = (beta_lo - beta_hi) * (e_lo - e_hi)
    if criterion == "logistic":
        # exp(a)/(1+exp(a)) == sigmoid(a); numerically stable.
        return jax.nn.sigmoid(arg)
    if criterion == "metropolis":
        # Clamp the argument to avoid inf; min(1, exp(a)) saturates anyway.
        return jnp.minimum(1.0, jnp.exp(jnp.minimum(arg, 80.0)))
    raise ValueError(f"unknown criterion {criterion!r}")


def accept_pairs(
    key: jax.Array,
    partner: jnp.ndarray,
    betas: jnp.ndarray,
    energies: jnp.ndarray,
    criterion: Criterion = "logistic",
    *,
    uniforms: jnp.ndarray | None = None,
):
    """Accept/reject every proposed pair of an involution, in parallel.

    The pairing itself is policy (`repro.exchange` strategies propose it);
    this is the policy-independent acceptance core: one uniform per rung,
    one decision per pair made at the *lower* member and broadcast to both.

    Args:
      key: PRNG key for the iteration (one uniform per rung).  Ignored when
        ``uniforms`` is given (may then be None).
      partner: (R,) involution — ``partner[i] = j`` iff ``{i, j}`` is a
        proposed pair, ``partner[i] = i`` for unpaired rungs.  Pairs need
        not be ladder-adjacent (windowed strategies propose distant rungs).
      betas: (R,) inverse temperatures *in rung order* (cold→hot).
      energies: (R,) energy of the replica currently holding each rung.
      uniforms: optional (R,) f32 acceptance uniforms to use instead of
        drawing from ``key`` — the hook that lets the whole-round fused
        kernels' counter-stream exchange (`repro.kernels.exchange`) be
        pinned bit-equal against this oracle at the same draws.

    Returns:
      perm: (R,) permutation in rung space — ``perm[r]`` is the rung whose
        state the holder of rung ``r`` receives (``perm[r] = r`` if no swap).
      accept_pair: (R,) bool, True at the *lower* rung of each accepted pair
        (for acceptance-rate diagnostics).
      prob_pair: (R,) acceptance probability at the lower rung of each pair,
        0 elsewhere (for diagnostics; masked like ``accept_pair``).
      attempt_pair: (R,) bool, True at the lower rung of each *attempted*
        pair this phase — the structural pairing mask.  This is the single
        source of truth for what counts as an attempt (acceptance statistics
        and the adaptive-ladder feedback both normalize by it).
    """
    n = partner.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    lower = jnp.minimum(idx, partner)
    is_lower = (partner != idx) & (idx == lower)

    p = swap_probability(
        betas, betas[partner], energies, energies[partner], criterion=criterion
    )
    if uniforms is None:
        u = jax.random.uniform(key, (n,), dtype=jnp.float32)
    else:
        u = uniforms
    # Decision is made once per pair, at the lower index, then broadcast.
    accept_at_lower = (u < p) & is_lower
    pair_accept = accept_at_lower[lower] & (partner != idx)
    perm = jnp.where(pair_accept, partner, idx)
    prob_at_lower = jnp.where(is_lower, p, 0.0)
    return perm, accept_at_lower, prob_at_lower, is_lower


@partial(jax.jit, static_argnames=("n", "criterion"))
def swap_permutation(
    key: jax.Array,
    phase: jax.Array,
    betas: jnp.ndarray,
    energies: jnp.ndarray,
    *,
    n: int,
    criterion: Criterion = "logistic",
):
    """The paper's swap iteration: alternating even/odd pairing + `accept_pairs`.

    Kept as the seed-compatible one-call form; the exchange-strategy layer
    (`repro.exchange`) composes `pair_partners`-style proposals with
    `accept_pairs` to express the same thing plus its generalizations.
    Returns ``(perm, accept_pair, prob_pair, attempt_pair)`` — see
    `accept_pairs` for the conventions.
    """
    partner = pair_partners(n, phase)
    return accept_pairs(key, partner, betas, energies, criterion=criterion)
