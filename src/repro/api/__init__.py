"""Declarative run-description API: the single front door to the sampler.

`RunSpec` (a serializable dataclass tree) describes a PT run — system,
ladder, engine knobs, adaptation, phase schedule, named observables — and
`Session` executes it through the chunked streaming engine with a composable
`Callback` pipeline.  The same spec JSON runs identically from a script, a
test, a benchmark, the conformance harness, or ``python -m repro``
(DESIGN.md §API).

    from repro.api import RunSpec, SystemSpec, LadderSpec, ScheduleSpec, \\
        PhaseSpec, Session

    spec = RunSpec(
        system=SystemSpec("ising", {"length": 32}),
        ladder=LadderSpec(kind="paper", n_replicas=16),
        schedule=simple_schedule(burn_sweeps=1000, measure_sweeps=1000),
        adapt=AdaptSpec(target=0.25),
        observables=("absmag",),
    )
    result = Session(spec).run()
    Path("run.json").write_text(spec.to_json())   # lossless round trip
"""
from repro.api.session import (
    Callback,
    CheckpointCallback,
    EarlyStopCallback,
    ObsCallback,
    ProgressCallback,
    Session,
    SessionResult,
    TraceWriterCallback,
)
from repro.api.spec import (
    SPEC_VERSION,
    AdaptSpec,
    EngineSpec,
    ExchangeSpec,
    LadderSpec,
    PhaseSpec,
    RunSpec,
    ScheduleSpec,
    SystemSpec,
    simple_schedule,
)

__all__ = [
    "SPEC_VERSION",
    "AdaptSpec",
    "Callback",
    "CheckpointCallback",
    "EarlyStopCallback",
    "ObsCallback",
    "EngineSpec",
    "ExchangeSpec",
    "LadderSpec",
    "PhaseSpec",
    "ProgressCallback",
    "RunSpec",
    "ScheduleSpec",
    "Session",
    "SessionResult",
    "SystemSpec",
    "TraceWriterCallback",
    "simple_schedule",
]
