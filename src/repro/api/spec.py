"""The declarative `RunSpec` tree: one serializable description of a PT run.

Every consumer of the sampler — scripts, tests, benchmarks, the conformance
harness, the ``python -m repro`` CLI — describes a run as the same dataclass
tree and executes it through `repro.api.Session` (DESIGN.md §API):

    RunSpec
    ├── SystemSpec    what to sample      (constructor registry name + params)
    ├── LadderSpec    initial temperatures (paper/linear/geometric/custom)
    ├── EngineSpec    how to execute      (wraps `repro.engine.EngineConfig`)
    ├── ExchangeSpec  replica-exchange strategy (resolved via `repro.exchange`)
    ├── AdaptSpec?    ladder feedback     (wraps `repro.engine.AdaptConfig`)
    ├── ScheduleSpec  burn-in / measurement phases (tuple of PhaseSpec)
    └── observables   named observables   (per-system observable registry)

Design rules that make the tree a viable interchange format:

* **lossless JSON round-trip** — ``RunSpec.from_json(spec.to_json()) ==
  spec`` exactly: every field is a JSON scalar, a tuple of them, or a nested
  spec; lists are canonicalized to tuples at construction so the dataclass
  equality survives the JSON list/tuple collapse;
* **no callables** — systems and observables are *names* resolved through
  `repro.core.systems.CONSTRUCTORS` (the constructor + named-observable
  registry), never lambdas;
* **versioned** — ``spec_version`` is checked on load and unknown versions
  are rejected, so persisted specs fail loudly instead of misexecuting;
* **strict** — unknown keys anywhere in the tree are an error (typos in a
  hand-written JSON spec must not silently fall back to defaults), and
  every enum-valued field (ladder ``kind``, engine ``criterion`` /
  ``swap_mode``, exchange ``strategy``, adapt ``mode``) is validated at
  construction — a bad value fails at parse time with the allowed values
  named, never deep inside the first compiled chunk.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

import numpy as np

from repro.core import ladder as ladder_lib
from repro.core import systems as systems_lib
from repro.core.distributed import MeshSpec
from repro.engine import AdaptConfig, EngineConfig
from repro.engine.adapt import ADAPT_MODES
from repro.exchange import available_strategies, make_strategy

__all__ = [
    "SPEC_VERSION",
    "SystemSpec",
    "LadderSpec",
    "EngineSpec",
    "ExchangeSpec",
    "AdaptSpec",
    "PhaseSpec",
    "ScheduleSpec",
    "RunSpec",
    "simple_schedule",
]

SPEC_VERSION = 1


# -- (de)serialization helpers -------------------------------------------------


def _freeze(value):
    """Canonicalize JSON-decoded values: lists -> tuples, recursively.

    Tuples are what the system constructors expect (``shape``, ``mus`` — they
    must be hashable for jit-static use) and what makes dataclass equality
    hold across a JSON round trip.
    """
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v) for v in value)
    if isinstance(value, dict):
        return {k: _freeze(v) for k, v in value.items()}
    return value


def _check_keys(data: Mapping, allowed, what: str):
    unknown = set(data) - set(allowed)
    if unknown:
        raise ValueError(
            f"unknown key(s) {sorted(unknown)} in {what}; allowed: {sorted(allowed)}"
        )


def _fields(cls):
    return [f.name for f in dataclasses.fields(cls)]


def _from_dict(cls, data: Mapping, what: str):
    """Strict flat-dataclass construction (tuple canonicalization included)."""
    if not isinstance(data, Mapping):
        raise ValueError(f"{what} must be an object, got {type(data).__name__}")
    _check_keys(data, _fields(cls), what)
    return cls(**{k: _freeze(v) for k, v in data.items()})


def _to_dict(obj):
    """Dataclass tree -> plain JSON-able dict (tuples become lists in json)."""
    if dataclasses.is_dataclass(obj):
        return {f.name: _to_dict(getattr(obj, f.name)) for f in dataclasses.fields(obj)}
    if isinstance(obj, (list, tuple)):
        return [_to_dict(v) for v in obj]
    if isinstance(obj, dict):
        return {k: _to_dict(v) for k, v in obj.items()}
    return obj


# -- the spec tree -------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """A nameable system instance: constructor-registry name + params.

    ``params`` must be JSON-representable (numbers, strings, bools, and
    tuples of them) and are passed to the registered constructor verbatim —
    ``SystemSpec("ising", {"length": 32})`` builds ``IsingSystem(length=32)``.
    """

    name: str
    params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "params", _freeze(dict(self.params)))

    def build(self):
        return systems_lib.make_system(self.name, self.params)

    def observables(self, system, names) -> dict:
        return systems_lib.named_observables(self.name, system, names)


@dataclasses.dataclass(frozen=True)
class LadderSpec:
    """The initial temperature ladder, cold->hot.

    ``kind``: "paper" (``T_i = t_min + i*(t_max - t_min)/R``, hot end
    exclusive — the paper's §3 ladder), "linear", "geometric", or "custom"
    (explicit ``temps``).  Adaptation (see `AdaptSpec`) later moves interior
    rungs; the endpoints of whatever this builds stay pinned.
    """

    kind: str = "paper"
    n_replicas: int = 8
    t_min: float = 1.0
    t_max: float = 4.0
    temps: tuple | None = None

    def __post_init__(self):
        if self.kind not in ("paper", "linear", "geometric", "custom"):
            raise ValueError(f"bad ladder kind {self.kind!r}")
        if self.temps is not None:
            object.__setattr__(
                self, "temps", tuple(float(t) for t in self.temps)
            )
        if self.kind == "custom":
            if not self.temps:
                raise ValueError("custom ladder needs explicit temps")
            if len(self.temps) != self.n_replicas:
                raise ValueError(
                    f"custom ladder has {len(self.temps)} rungs "
                    f"!= n_replicas={self.n_replicas}"
                )
        elif self.temps is not None:
            raise ValueError(f"temps only valid with kind='custom', not {self.kind!r}")
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")

    def build(self) -> np.ndarray:
        if self.kind == "custom":
            return np.asarray(self.temps, np.float64)
        if self.kind == "paper":
            return np.asarray(
                ladder_lib.paper_ladder(
                    self.n_replicas, self.t_min, self.t_max - self.t_min
                ),
                np.float64,
            )
        if self.kind == "linear":
            return np.asarray(
                ladder_lib.linear_ladder(self.n_replicas, self.t_min, self.t_max),
                np.float64,
            )
        return np.asarray(
            ladder_lib.geometric_ladder(self.n_replicas, self.t_min, self.t_max),
            np.float64,
        )


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Execution knobs — a serializable mirror of `repro.engine.EngineConfig`
    (minus ``n_replicas``, which the ladder owns, and ``exchange``, which
    `ExchangeSpec` owns).

    ``mesh`` (optional) selects the explicit multi-device shard_map path:
    a nested `repro.core.distributed.MeshSpec` — two ints, ``ensemble``
    devices over whole chains times ``replica`` devices over the rung
    population.  Serialized as ``{"ensemble": E, "replica": D}``; null keeps
    the single-device path.
    """

    swap_interval: int = 100
    criterion: str = "logistic"
    swap_mode: str = "temp"
    chunk_intervals: int = 8
    n_chains: int = 1
    record_trace: bool = False
    track_stats: bool = True
    measure_interval: int = 100
    donate: bool = True
    mesh: MeshSpec | None = None

    def __post_init__(self):
        if self.criterion not in ("logistic", "metropolis"):
            raise ValueError(
                f"unknown criterion {self.criterion!r}; "
                "allowed: ['logistic', 'metropolis']"
            )
        if self.swap_mode not in ("temp", "state"):
            raise ValueError(
                f"unknown swap_mode {self.swap_mode!r}; "
                "allowed: ['state', 'temp']"
            )
        if self.mesh is not None and not isinstance(self.mesh, MeshSpec):
            object.__setattr__(
                self, "mesh", _from_dict(MeshSpec, self.mesh, "engine.mesh")
            )

    def build(self, n_replicas: int, exchange=None) -> EngineConfig:
        # asdict flattens the nested MeshSpec to its dict form;
        # EngineConfig.__post_init__ coerces it back
        return EngineConfig(
            n_replicas=n_replicas, exchange=exchange, **dataclasses.asdict(self)
        )


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """The replica-exchange strategy, by registry name (DESIGN.md §Exchange).

    ``strategy`` resolves through `repro.exchange.make_strategy`:
    "deo" (paper even/odd; default), "seo" (stochastic even/odd),
    "windowed" (random in-window matchings; ``window`` rungs per window),
    "vmpt" (virtual-move PT with waste-recycled estimators).  ``window``
    only applies to "windowed" (it is carried, but ignored, elsewhere so
    sweeping strategies over one spec stays a one-field edit).
    """

    strategy: str = "deo"
    window: int = 4

    def __post_init__(self):
        if self.strategy not in available_strategies():
            raise ValueError(
                f"unknown exchange strategy {self.strategy!r}; "
                f"allowed: {available_strategies()}"
            )
        if self.window < 2:
            raise ValueError(f"exchange window must be >= 2, got {self.window}")

    def build(self):
        params = {"window": self.window} if self.strategy == "windowed" else {}
        return make_strategy(self.strategy, params)


@dataclasses.dataclass(frozen=True)
class AdaptSpec:
    """Ladder-feedback knobs — serializable mirror of `repro.engine.AdaptConfig`.

    ``mode``: "acceptance" (Kofke equalization, default) or "flow"
    (Katzgraber feedback-optimized ladders driven by the round-trip flow
    diagnostic; see `repro.engine.adapt`).
    """

    target: float = 0.23
    rate: float = 0.5
    min_attempts_per_pair: int = 20
    max_rounds: int | None = None
    mode: str = "acceptance"
    flow_min_visits: int = 100

    def __post_init__(self):
        if self.mode not in ADAPT_MODES:
            raise ValueError(
                f"unknown adapt mode {self.mode!r}; allowed: {list(ADAPT_MODES)}"
            )

    def build(self) -> AdaptConfig:
        return AdaptConfig(**dataclasses.asdict(self))


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One schedule phase: ``n_sweeps`` sweeps with per-phase behaviour.

    Attributes:
      name: phase label (unique within a schedule; keys the results dict).
      n_sweeps: sweep budget (must be a multiple of the engine interval).
      adapt: ladder feedback active during this phase (needs `RunSpec.adapt`).
      reset_stats: zero the O(R) online accumulators at phase start — the
        streaming analogue of "discard the burn-in trace", and what makes a
        phase a self-contained measurement window (batch means).
    """

    name: str
    n_sweeps: int
    adapt: bool = False
    reset_stats: bool = False

    def __post_init__(self):
        if self.n_sweeps < 1:
            raise ValueError(f"phase {self.name!r}: n_sweeps must be >= 1")


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Ordered phases executed back-to-back on one engine state."""

    phases: tuple = ()

    def __post_init__(self):
        phases = tuple(
            p if isinstance(p, PhaseSpec) else _from_dict(PhaseSpec, p, "phase")
            for p in self.phases
        )
        object.__setattr__(self, "phases", phases)
        names = [p.name for p in phases]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate phase names in schedule: {names}")
        if not phases:
            raise ValueError("schedule needs at least one phase")

    @property
    def total_sweeps(self) -> int:
        return sum(p.n_sweeps for p in self.phases)


def simple_schedule(burn_sweeps: int, measure_sweeps: int) -> ScheduleSpec:
    """The canonical two-phase schedule: adapt+equilibrate, then measure."""
    return ScheduleSpec(phases=(
        PhaseSpec(name="burn", n_sweeps=burn_sweeps, adapt=True),
        PhaseSpec(name="measure", n_sweeps=measure_sweeps, reset_stats=True),
    ))


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """The complete, serializable description of one PT run.

    ``Session(spec).run()`` executes it; ``spec.to_json()`` /
    ``RunSpec.from_json(...)`` round-trip it losslessly; the ``python -m
    repro`` CLI runs the JSON form end-to-end.  Same spec + same seed =
    same run, bit-for-bit, from any entry point.
    """

    system: SystemSpec
    ladder: LadderSpec
    schedule: ScheduleSpec
    engine: EngineSpec = EngineSpec()
    exchange: ExchangeSpec = ExchangeSpec()
    adapt: AdaptSpec | None = None
    observables: tuple = ()
    seed: int = 0
    spec_version: int = SPEC_VERSION

    def __post_init__(self):
        object.__setattr__(
            self, "observables", tuple(str(o) for o in self.observables)
        )
        if self.spec_version != SPEC_VERSION:
            raise ValueError(
                f"unsupported spec_version {self.spec_version!r} "
                f"(this build reads version {SPEC_VERSION})"
            )
        for phase in self.schedule.phases:
            if phase.adapt and self.adapt is None:
                raise ValueError(
                    f"phase {phase.name!r} sets adapt=True but the spec has "
                    "no AdaptSpec"
                )
            interval = (
                self.engine.swap_interval
                if self.engine.swap_interval > 0
                else self.engine.measure_interval
            )
            if phase.n_sweeps % interval != 0:
                raise ValueError(
                    f"phase {phase.name!r}: n_sweeps={phase.n_sweeps} is not "
                    f"a multiple of the engine interval ({interval} sweeps)"
                )

    # -- serialization ---------------------------------------------------------
    def to_dict(self) -> dict:
        return _to_dict(self)

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, data: Mapping) -> "RunSpec":
        if not isinstance(data, Mapping):
            raise ValueError(f"run spec must be an object, got {type(data).__name__}")
        _check_keys(data, _fields(cls), "run spec")
        version = data.get("spec_version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise ValueError(
                f"unsupported spec_version {version!r} "
                f"(this build reads version {SPEC_VERSION})"
            )
        if "system" not in data or "ladder" not in data or "schedule" not in data:
            raise ValueError("run spec needs 'system', 'ladder' and 'schedule'")
        sched = data["schedule"]
        if not isinstance(sched, Mapping):
            raise ValueError("'schedule' must be an object with a 'phases' list")
        _check_keys(sched, _fields(ScheduleSpec), "schedule")
        adapt = data.get("adapt")
        return cls(
            system=_from_dict(SystemSpec, data["system"], "system"),
            ladder=_from_dict(LadderSpec, data["ladder"], "ladder"),
            schedule=ScheduleSpec(phases=tuple(
                _from_dict(PhaseSpec, p, "phase") for p in sched.get("phases", ())
            )),
            engine=_from_dict(EngineSpec, data.get("engine", {}), "engine"),
            exchange=_from_dict(
                ExchangeSpec, data.get("exchange", {}), "exchange"
            ),
            adapt=None if adapt is None else _from_dict(AdaptSpec, adapt, "adapt"),
            observables=tuple(data.get("observables", ())),
            seed=int(data.get("seed", 0)),
            spec_version=int(version),
        )

    @classmethod
    def from_json(cls, text: str | bytes | Mapping) -> "RunSpec":
        """Parse a spec from a JSON string (or an already-decoded dict)."""
        if isinstance(text, Mapping):
            return cls.from_dict(text)
        return cls.from_dict(json.loads(text))
