"""``python -m repro`` — run, resume and validate PT runs from spec JSONs.

Subcommands (DESIGN.md §API):

  run SPEC.json [--out DIR]     execute a `RunSpec` end-to-end; write
                                ``manifest.json`` (+ spec copy, checkpoints)
  resume DIR                    continue a checkpointed run from
                                ``(spec.json, newest checkpoint)`` alone
  validate SYSTEM [...]         conformance-run a system-zoo entry against
                                its exact reference (exit 1 on failure);
                                --exchange gates a non-default strategy,
                                --fused the interval-fused kernel path
  serve SPEC.json [...]         multi-tenant scheduler: submit --jobs seed
                                variants of each spec, pack same-shaped jobs
                                into one compiled mega-step (`repro.serve`),
                                write per-job results + service counters
  list-systems                  registered systems, params and observables
  list-strategies               registered replica-exchange strategies

The CLI is a thin shell over `repro.api.Session` — a spec executes
identically from here, a script, a test, or a benchmark.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import numpy as np

from repro.api.session import (
    CheckpointCallback,
    ObsCallback,
    ProgressCallback,
    Session,
)
from repro.api.spec import RunSpec

__all__ = ["main"]


def _cmd_run(args) -> int:
    with open(args.spec) as f:
        spec = RunSpec.from_json(f.read())
    if args.mesh_chains > 0 or args.mesh_replicas > 0:
        # command-line mesh override: run the same spec sharded without
        # editing the JSON (simulate devices on CPU with
        # XLA_FLAGS=--xla_force_host_platform_device_count=N)
        from repro.core.distributed import MeshSpec

        mesh = MeshSpec(
            ensemble=max(args.mesh_chains, 1),
            replica=max(args.mesh_replicas, 1),
        )
        spec = dataclasses.replace(
            spec, engine=dataclasses.replace(spec.engine, mesh=mesh)
        )
    out = args.out or os.path.join(
        "runs", os.path.splitext(os.path.basename(args.spec))[0]
    )
    os.makedirs(out, exist_ok=True)
    callbacks = []
    if not args.quiet:
        callbacks.append(ProgressCallback(every=args.progress_every))
    ckpt = CheckpointCallback(
        os.path.join(out, "checkpoints"), every_chunks=args.checkpoint_every
    )
    callbacks.append(ckpt)
    obs_cb = None
    if args.timeline or args.metrics_out or args.jax_profile:
        obs_cb = ObsCallback(
            timeline_path=args.timeline,
            metrics_path=args.metrics_out,
            jax_profile_dir=args.jax_profile,
        )
        callbacks.append(obs_cb)
    session = Session(
        spec, callbacks=callbacks, strict_kernels=args.strict_kernels
    )
    result = session.run()
    path = result.write_manifest(os.path.join(out, "manifest.json"))
    if obs_cb is not None:
        for kind, p in sorted(obs_cb.write().items()):
            if not args.quiet:
                print(f"{kind}: {p}", file=sys.stderr)
    if not args.quiet:
        temps = 1.0 / np.asarray(result.state.betas, np.float64)
        print(f"final ladder: {np.round(temps, 4).tolist()}", file=sys.stderr)
    print(path)
    return 0


def _cmd_resume(args) -> int:
    ckdir = os.path.join(args.dir, "checkpoints")
    callbacks = [] if args.quiet else [ProgressCallback(every=args.progress_every)]
    callbacks.append(
        CheckpointCallback(ckdir, every_chunks=args.checkpoint_every)
    )
    session = Session.from_checkpoint(ckdir, callbacks=callbacks)
    if session.remaining_sweeps == 0:
        print(
            f"nothing to resume: the checkpointed run already covers all "
            f"{session.spec.schedule.total_sweeps} scheduled sweeps",
            file=sys.stderr,
        )
        return 0
    result = session.run()
    path = result.write_manifest(os.path.join(args.dir, "manifest.json"))
    print(path)
    return 0


def _cmd_validate(args) -> int:
    # Lazy import: validate builds on the api layer (conformance compiles
    # zoo entries to RunSpecs), so importing it at module scope would cycle.
    from repro.core import systems
    from repro.validate import assert_conforms, run_conformance

    if args.system not in systems.REGISTRY:
        print(
            f"unknown system {args.system!r}; registered: "
            f"{sorted(systems.REGISTRY)}",
            file=sys.stderr,
        )
        return 2
    from repro.exchange import available_strategies

    if args.exchange not in available_strategies():
        print(
            f"unknown exchange strategy {args.exchange!r}; registered: "
            f"{available_strategies()}",
            file=sys.stderr,
        )
        return 2
    entry = systems.REGISTRY[args.system]
    # use_pallas rides along so the gate exercises the fused *kernel* (its
    # interpret path off-TPU), not just the pure-JAX fused reference
    system_params = (
        {"use_fused": True, "use_pallas": True} if args.fused else None
    )
    if args.fused:
        try:
            systems.make_system(
                entry.name, {**entry.params, **system_params}
            )
        except TypeError:
            print(
                f"system {args.system!r} has no fused kernel path "
                "(no use_fused constructor option)",
                file=sys.stderr,
            )
            return 2
    report = run_conformance(
        entry, seed=args.seed, exchange=args.exchange,
        system_params=system_params,
    )
    worst_series, worst_z = report.worst()
    kernel = " fused" if args.fused else ""
    print(
        f"{args.system} [{args.exchange}{kernel}]: {report.n_batches} batch means, "
        f"ladder retuned {report.n_retunes}x, "
        f"worst |z| = {worst_z:.2f} ({worst_series})"
    )
    for k in sorted(report.means):
        for r, t in enumerate(report.temps):
            print(
                f"  T={t:7.3f}  <{k}> = {report.means[k][r]: .5f} "
                f"(exact {report.exact[k][r]: .5f}, |z|={abs(report.z[k][r]):.2f})"
            )
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, f"validate_{args.system}.json")
        payload = {"system": args.system, "seed": args.seed,
                   "exchange": args.exchange, "fused": bool(args.fused)}
        for f in dataclasses.fields(report):
            v = getattr(report, f.name)
            if isinstance(v, dict):
                v = {k: np.asarray(a, np.float64).tolist() for k, a in v.items()}
            elif isinstance(v, np.ndarray):
                v = v.tolist()
            payload[f.name] = v
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(path)
    try:
        assert_conforms(report)
    except AssertionError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print("PASS: all observables within tolerance of the exact reference")
    return 0


def _cmd_serve(args) -> int:
    # Lazy import: the serve layer builds on api (Session-equivalent packing),
    # importing it at module scope would cycle through repro.api.
    from repro.serve import JobFailedError, Scheduler

    out = args.out or "runs/serve"
    obs = None
    if args.timeline:
        from repro.obs import Observability

        obs = Observability.create(timeline=True)
    metrics_path = args.metrics_out or os.path.join(out, "metrics.prom")
    sched = Scheduler(
        checkpoint_dir=args.checkpoint_dir,
        quantum_chunks=args.quantum_chunks,
        pack_window=args.pack_window,
        checkpoint_every_quanta=args.checkpoint_every,
        obs=obs,
        metrics_every=args.metrics_every,
        metrics_path=metrics_path if args.metrics_every else None,
        max_attempts=args.max_attempts,
        watchdog_s=args.watchdog_s,
        queue_depth=args.queue_depth,
    )
    handles = []
    for path in args.specs:
        with open(path) as f:
            spec = RunSpec.from_json(f.read())
        stem = os.path.splitext(os.path.basename(path))[0]
        for i in range(args.jobs):
            tenant = dataclasses.replace(spec, seed=args.seed0 + i)
            handles.append(sched.submit(
                tenant, job_id=f"{stem}-seed{args.seed0 + i}"
            ))
    sched.run_until_idle()
    stats = sched.stats()
    results, failed = {}, {}
    for job in handles:
        try:
            results[job.id] = sched.result(job, timeout=0).manifest()
        except JobFailedError as e:
            failed[job.id] = repr(e)
    os.makedirs(out, exist_ok=True)
    sched.write_metrics(metrics_path)
    if obs is not None:
        obs.timeline.write(args.timeline)
        if not args.quiet:
            print(f"timeline: {args.timeline}", file=sys.stderr)
    if not args.quiet:
        print(f"metrics: {metrics_path}", file=sys.stderr)
    path = os.path.join(out, "serve_results.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(
            {"scheduler": stats, "results": results, "failed": failed},
            f, indent=2, sort_keys=True,
        )
    os.replace(tmp, path)
    if not args.quiet:
        print(
            f"{stats['n_jobs']} jobs, {stats['n_engines']} packed engine(s), "
            f"{stats['n_compiles']} compile(s), {stats['n_quanta']} quanta",
            file=sys.stderr,
        )
    print(path)
    return 1 if failed else 0


def _cmd_list_systems(args) -> int:
    from repro.core import systems

    for name in sorted(systems.CONSTRUCTORS):
        entry = systems.CONSTRUCTORS[name]
        zoo = systems.REGISTRY.get(name)
        obs = ", ".join(sorted(entry.observables)) or "-"
        print(f"{name}")
        print(f"  observables: {obs}")
        if zoo is not None:
            print(f"  validation instance: {dict(zoo.params)}")
    return 0


def _cmd_list_strategies(args) -> int:
    from repro import exchange

    for name in exchange.available_strategies():
        print(f"{name}")
        print(f"  {exchange.strategy_help(name)}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Declarative PT runs: execute serializable RunSpec JSONs.",
    )
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("run", help="execute a RunSpec JSON end-to-end")
    p.add_argument("spec", help="path to the spec JSON")
    p.add_argument("--out", default=None, help="output dir (default runs/<spec stem>)")
    p.add_argument("--checkpoint-every", type=int, default=10,
                   help="chunks between checkpoints")
    p.add_argument("--progress-every", type=int, default=10,
                   help="chunks between progress lines")
    p.add_argument("--mesh-chains", type=int, default=0, metavar="E",
                   help="shard whole chains over E devices (MeshSpec "
                        "ensemble axis; overrides the spec's engine.mesh)")
    p.add_argument("--mesh-replicas", type=int, default=0, metavar="D",
                   help="shard the replica axis over D devices (MeshSpec "
                        "replica axis; overrides the spec's engine.mesh)")
    p.add_argument("--timeline", default=None, metavar="OUT.trace.json",
                   help="record a Perfetto/Chrome trace of the run "
                        "(compile, chunk, device-wait, adapt, checkpoint "
                        "spans) to this path")
    p.add_argument("--metrics-out", default=None, metavar="OUT.prom",
                   help="write the run's metrics (Prometheus text format) "
                        "to this path")
    p.add_argument("--jax-profile", default=None, metavar="DIR",
                   help="wrap one compiled chunk in jax.profiler and write "
                        "the device profile under DIR")
    p.add_argument("--strict-kernels", action="store_true",
                   help="fail loudly if a fused/Pallas mega-step compile "
                        "errors instead of degrading to the per-sweep path")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("resume", help="continue a checkpointed run directory")
    p.add_argument("dir", help="a previous `run` output dir")
    p.add_argument("--checkpoint-every", type=int, default=10,
                   help="chunks between checkpoints")
    p.add_argument("--progress-every", type=int, default=10)
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=_cmd_resume)

    p = sub.add_parser(
        "validate", help="conformance-run a zoo system vs its exact reference"
    )
    p.add_argument("system", help="registry name (see list-systems)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--exchange", default="deo",
                   help="replica-exchange strategy (see list-strategies)")
    p.add_argument("--fused", action="store_true",
                   help="run the interval-fused kernel path (use_fused=True; "
                        "its counter-PRNG stream is gated statistically)")
    p.add_argument("--out", default=None, help="also write the report JSON here")
    p.set_defaults(fn=_cmd_validate)

    p = sub.add_parser(
        "serve", help="pack seed-variant jobs of each spec into shared "
                      "mega-steps (repro.serve scheduler)"
    )
    p.add_argument("specs", nargs="+", help="spec JSONs; same-shaped specs "
                                            "share one compiled engine")
    p.add_argument("--jobs", type=int, default=4,
                   help="seed variants submitted per spec (default 4)")
    p.add_argument("--seed0", type=int, default=0, help="first tenant seed")
    p.add_argument("--out", default=None,
                   help="output dir for serve_results.json (default runs/serve)")
    p.add_argument("--quantum-chunks", type=int, default=1,
                   help="compiled chunks per scheduler time-slice")
    p.add_argument("--pack-window", type=float, default=0.0,
                   help="seconds to hold a new shape open for bucket-mates")
    p.add_argument("--checkpoint-dir", default=None,
                   help="enable preemption persistence under this root")
    p.add_argument("--checkpoint-every", type=int, default=0,
                   help="quanta between bucket checkpoints (0 = seal/finish only)")
    p.add_argument("--metrics-every", type=int, default=0, metavar="N",
                   help="rewrite the Prometheus metrics file every N quanta "
                        "(0 = only once at the end)")
    p.add_argument("--metrics-out", default=None, metavar="OUT.prom",
                   help="metrics destination (default <out>/metrics.prom)")
    p.add_argument("--timeline", default=None, metavar="OUT.trace.json",
                   help="record a Perfetto trace of the scheduler (quantum "
                        "lanes, job flows, engine spans)")
    p.add_argument("--max-attempts", type=int, default=3,
                   help="supervised retries per quantum before the bucket "
                        "is quarantined (DESIGN.md §Resilience)")
    p.add_argument("--watchdog-s", type=float, default=0.0,
                   help="wall-clock budget per quantum/compile; 0 disables "
                        "the watchdog threads")
    p.add_argument("--queue-depth", type=int, default=0,
                   help="bound the intake queue (QueueFull backpressure); "
                        "0 = unbounded")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=_cmd_serve)

    p = sub.add_parser("list-systems", help="registered systems + observables")
    p.set_defaults(fn=_cmd_list_systems)

    p = sub.add_parser(
        "list-strategies", help="registered replica-exchange strategies"
    )
    p.set_defaults(fn=_cmd_list_strategies)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
