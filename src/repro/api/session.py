"""`Session`: compile a `RunSpec` into an `Engine` and execute its schedule.

The Session is the *single execution path* behind every front door (script,
test, benchmark, conformance harness, ``python -m repro``): it resolves the
spec's names through the registries, builds the chunked streaming engine,
runs the phase schedule, and threads a **callback pipeline** through the
engine's host loop — checkpointing, trace streaming, progress logging and
early stopping are composable `Callback`s instead of hardwired driver flags
(DESIGN.md §API).

Determinism contract: a Session run is bit-equal to hand-driving the raw
engine with the same spec fields — `Session.run` does exactly
``Engine.init(key(seed), ladder)`` followed by one ``Engine.run`` per phase,
and callbacks only *observe* device state, they never perturb the PRNG
stream.  ``tests/test_api.py`` pins this with a Session-vs-Engine
final-energy equality check.

Resume contract: `CheckpointCallback` persists ``(spec, EngineState)``;
`Session.from_checkpoint` rebuilds the Session from the saved spec alone,
restores the newest state, and replays the *remaining* sweeps of the
schedule — the sweep counter inside the state locates the run within the
phase schedule, so no extra driver bookkeeping is stored anywhere.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from typing import Any, Sequence

import jax
import numpy as np

from repro.api.spec import PhaseSpec, RunSpec
from repro.checkpoint.manager import CheckpointManager
from repro.engine import AdaptInfo, ChunkInfo, Engine, EngineState, RunResult
from repro.engine.adapt import AdaptState

__all__ = [
    "Callback",
    "CheckpointCallback",
    "EarlyStopCallback",
    "ObsCallback",
    "ProgressCallback",
    "TraceWriterCallback",
    "Session",
    "SessionResult",
]


# -- the callback pipeline -----------------------------------------------------


class Callback:
    """Observer hooks along a Session run.  Subclass and override.

    ``on_chunk`` may return truthy to stop the whole run early (the engine
    finishes the current chunk, the Session skips the remaining phases and
    marks the result ``stopped_early``).  Callbacks must treat the engine
    state as read-only: they run between compiled chunks on the host and are
    invisible to the PRNG stream only as long as they don't mutate state.

    ``consumes_trace = True`` declares that the callback takes ownership of
    the streamed per-chunk trace (`ChunkInfo.trace`): the Session then tells
    the engine not to also accumulate the chunks for ``RunResult.trace``, so
    host memory stays O(chunk) on arbitrarily long traced runs.
    """

    consumes_trace = False

    def on_phase_start(self, session: "Session", phase: PhaseSpec) -> None:
        pass

    def on_chunk(self, session: "Session", info: ChunkInfo):
        pass

    def on_adapt(self, session: "Session", info: AdaptInfo) -> None:
        pass

    def on_phase_end(
        self, session: "Session", phase: PhaseSpec, result: RunResult
    ) -> None:
        pass

    def on_checkpoint(self, session: "Session", step: int) -> None:
        pass


class ProgressCallback(Callback):
    """Phase/chunk progress lines on stderr (rate-limited by ``every``)."""

    def __init__(self, every: int = 1, stream=None):
        self.every = max(1, every)
        self.stream = stream if stream is not None else sys.stderr

    def on_phase_start(self, session, phase):
        print(
            f"[{phase.name}] {phase.n_sweeps} sweeps"
            + (" (adapt)" if phase.adapt else ""),
            file=self.stream,
        )

    def on_chunk(self, session, info):
        if info.index % self.every == 0 or info.sweeps_done == info.n_sweeps:
            print(
                f"[{session.current_phase.name}] sweep "
                f"{info.sweeps_done}/{info.n_sweeps}",
                file=self.stream,
            )

    def on_adapt(self, session, info):
        print(
            f"[{session.current_phase.name}] ladder retune #{info.round}: "
            f"T = {np.round(info.temps, 3).tolist()}",
            file=self.stream,
        )


class CheckpointCallback(Callback):
    """Periodic ``(spec, EngineState)`` checkpointing via `CheckpointManager`.

    The spec is saved once per directory (`save_spec`), states every
    ``every_chunks`` compiled chunks and at every phase end — so
    `Session.from_checkpoint` can resume from the directory alone.
    """

    def __init__(self, directory_or_manager, every_chunks: int = 1, keep: int = 3):
        if isinstance(directory_or_manager, CheckpointManager):
            self.manager = directory_or_manager
        else:
            self.manager = CheckpointManager(str(directory_or_manager), keep=keep)
        self.every_chunks = max(1, every_chunks)
        self._spec_saved = False
        self._last_sweep: int | None = None

    def _save(self, session, state: EngineState):
        if not self._spec_saved:
            self.manager.save_spec(session.spec.to_json())
            self._spec_saved = True
        sweep = int(np.asarray(state.pt.t).reshape(-1)[0])
        if sweep == self._last_sweep:
            return  # phase end right after an on_chunk save — same state
        self._last_sweep = sweep
        # the AUTHORITATIVE f64 ladder, not 1/f32(betas): f32 inversion is
        # ulp-lossy and would desync a resumed retune from the uninterrupted
        # host loop
        temps = session.engine._temps
        if temps is None:
            temps = 1.0 / np.asarray(state.betas, np.float64)
        # The adaptation bookkeeping rides in the meta so a resumed engine
        # keeps honouring AdaptConfig.max_rounds cumulatively AND re-enters
        # the same feedback window — resume stays bit-equal even mid-phase.
        meta = {"temps": np.asarray(temps, np.float64).tolist(),
                "adapt_rounds": session.engine._adapt_rounds}
        adapt_st = session.engine._adapt_state
        if adapt_st is not None:
            meta.update(adapt_st.to_meta())
        obs = getattr(session.engine, "obs", None)
        if obs is not None:
            with obs.timeline.span("checkpoint", cat="session", sweep=sweep):
                self.manager.save(sweep, state, meta=meta)
        else:
            self.manager.save(sweep, state, meta=meta)
        session.dispatch("on_checkpoint", sweep)

    def on_chunk(self, session, info):
        if info.index % self.every_chunks == 0:
            self._save(session, info.state)

    def on_phase_end(self, session, phase, result):
        self._save(session, session.state)


class EarlyStopCallback(Callback):
    """Stop the run when ``predicate(ChunkInfo) -> truthy``.

    The predicate reads the live engine state (e.g. an online mean crossing
    a threshold) — the streaming replacement for "run long, inspect the
    trace, truncate".
    """

    def __init__(self, predicate):
        self.predicate = predicate

    def on_chunk(self, session, info):
        return self.predicate(info)


class TraceWriterCallback(Callback):
    """Stream the opt-in per-chunk trace to disk as it is produced.

    Requires ``EngineSpec(record_trace=True)``.  Each chunk lands in
    ``<dir>/trace_<phase>_<chunk>.npz`` — and because this callback declares
    ``consumes_trace``, the engine skips accumulating ``RunResult.trace``,
    so host *and* device trace memory stay bounded by one chunk regardless
    of run length.
    """

    consumes_trace = True

    def __init__(self, directory: str):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)

    def on_chunk(self, session, info):
        if info.trace is None:
            return
        path = os.path.join(
            self.directory,
            f"trace_{session.current_phase.name}_{info.index:06d}.npz",
        )
        np.savez(path, **info.trace)


class ObsCallback(Callback):
    """Attach a `repro.obs.Observability` to the run and export its artifacts.

    The composition point between the callback pipeline and the telemetry
    layer (DESIGN.md §Observability): on phase start the bundle is attached
    to the Session's engine (arming the per-chunk spans and metrics inside
    the host loop), phases land as spans on a ``session`` track, and after
    *every* phase the timeline/metrics files are (re)written atomically — a
    run that dies mid-schedule still leaves loadable artifacts on disk.

    Args:
      obs: an existing `Observability` to ride on; built fresh when None.
      timeline_path: where `write()` puts the Chrome-trace JSON (skipped
        when None or when the bundle records no timeline).
      metrics_path: where `write()` puts the Prometheus text exposition.
      jax_profile_dir: arm the one-shot ``jax.profiler`` window around the
        first engine chunk (only honoured when ``obs`` is built here).
    """

    def __init__(
        self,
        obs=None,
        timeline_path: str | None = None,
        metrics_path: str | None = None,
        jax_profile_dir: str | None = None,
    ):
        if obs is None:
            from repro.obs import Observability

            obs = Observability.create(
                timeline=timeline_path is not None,
                jax_profile_dir=jax_profile_dir,
            )
        self.obs = obs
        self.timeline_path = timeline_path
        self.metrics_path = metrics_path
        self._phase_t0: dict[str, float] = {}

    def on_phase_start(self, session, phase):
        if session.engine.obs is not self.obs:
            session.engine.obs = self.obs
        self._phase_t0[phase.name] = time.perf_counter()

    def on_phase_end(self, session, phase, result):
        t0 = self._phase_t0.pop(phase.name, None)
        if t0 is not None:
            self.obs.timeline.complete(
                f"phase:{phase.name}", t0, time.perf_counter() - t0,
                cat="session", track="session",
                args={"n_sweeps": int(result.n_sweeps),
                      "stopped_early": bool(result.stopped_early)},
            )
        self.write()

    def write(self) -> dict:
        """Write the requested artifacts (atomic); returns ``{kind: path}``."""
        out = {}
        if self.timeline_path and getattr(self.obs.timeline, "enabled", False):
            out["timeline"] = self.obs.timeline.write(self.timeline_path)
        if self.metrics_path:
            from repro.obs import write_prometheus

            out["metrics"] = write_prometheus(self.obs.metrics, self.metrics_path)
        return out


# -- results -------------------------------------------------------------------


@dataclasses.dataclass
class SessionResult:
    """Outcome of `Session.run`: per-phase results + the final state.

    Attributes:
      spec: the spec that produced this result.
      phases: phase name -> `repro.engine.RunResult`, schedule order
        (phases skipped by an early stop or already completed before a
        resume are absent).
      state: final `EngineState` (live device arrays).
      stopped_early: a callback stopped the run before the schedule ended.
    """

    spec: RunSpec
    phases: dict[str, RunResult]
    state: EngineState
    stopped_early: bool = False

    @property
    def final(self) -> RunResult:
        """The last executed phase's result."""
        return next(reversed(self.phases.values()))

    def final_energies(self) -> np.ndarray:
        """Final per-rung energies, cold->hot (``(R,)`` or ``(C, R)``)."""
        e = np.asarray(self.state.pt.energy)
        rung = np.asarray(self.state.pt.rung)
        if e.ndim == 1:
            return e[np.argsort(rung)]
        return np.stack([ec[np.argsort(rc)] for ec, rc in zip(e, rung)])

    def manifest(self) -> dict:
        """JSON-able result manifest (what the CLI writes next to a run)."""
        phases = {}
        for name, res in self.phases.items():
            phases[name] = {
                "n_sweeps": int(res.n_sweeps),
                "stopped_early": bool(res.stopped_early),
                "ladder_history": np.asarray(res.ladder_history, np.float64).tolist(),
                "summary": {
                    k: np.asarray(v, np.float64).tolist()
                    for k, v in res.summary.items()
                },
            }
        t = np.asarray(self.state.pt.t).reshape(-1)
        return {
            "spec": self.spec.to_dict(),
            "spec_version": self.spec.spec_version,
            "phases": phases,
            "stopped_early": bool(self.stopped_early),
            "final": {
                "sweep": int(t[0]),
                "temps": (1.0 / np.asarray(self.state.betas, np.float64)).tolist(),
                "energy": self.final_energies().tolist(),
            },
        }

    def write_manifest(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.manifest(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)
        return path


# -- the session ---------------------------------------------------------------


class Session:
    """Compiled form of a `RunSpec`: system + engine + schedule + callbacks.

    One Session owns one `Engine` (and therefore one compiled-executable
    cache and one cumulative adapt-round counter).  ``run()`` executes the
    spec's schedule from a fresh ``init`` — or, after `from_checkpoint`,
    from the restored state, replaying only the remaining sweeps.
    """

    def __init__(
        self,
        spec: RunSpec,
        callbacks: Sequence[Callback] = (),
        strict_kernels: bool = False,
    ):
        self.spec = spec
        self.callbacks = list(callbacks)
        self.system = spec.system.build()
        self.temps = spec.ladder.build()
        self.observables = spec.system.observables(self.system, spec.observables)
        self._adapt = spec.adapt.build() if spec.adapt is not None else None
        self.engine = Engine(
            self.system,
            spec.engine.build(
                spec.ladder.n_replicas, exchange=spec.exchange.build()
            ),
            observables=self.observables,
            # Engine.adapt is toggled per phase; constructing with it also
            # validates it against the engine config (track_stats etc.).
            adapt=self._adapt,
            # a failed fused/Pallas compile normally degrades to the
            # per-sweep path with a warning; --strict-kernels makes it fatal
            strict_kernels=strict_kernels,
        )
        self.state: EngineState | None = None
        self.current_phase: PhaseSpec | None = None
        self._restored_sweeps = 0

    # -- callback dispatch -----------------------------------------------------
    def dispatch(self, hook: str, *args):
        """Fan one hook out to every callback; truthy results OR together."""
        stop = False
        for cb in self.callbacks:
            if getattr(cb, hook)(self, *args):
                stop = True
        return stop

    # -- state construction / resume -------------------------------------------
    def init_state(self) -> EngineState:
        """Fresh engine state exactly as the spec describes it."""
        return self.engine.init(jax.random.key(self.spec.seed), self.temps)

    @classmethod
    def from_checkpoint(
        cls,
        directory: str,
        callbacks: Sequence[Callback] = (),
        strict_kernels: bool = False,
    ) -> "Session":
        """Rebuild a Session from ``(spec.json, newest checkpoint)`` alone.

        The returned Session's ``run()`` continues the schedule from the
        restored sweep counter, re-entering the checkpointed adaptation
        window (baselines + retune count ride in the step meta) so the
        resumed trajectory is bit-equal to the uninterrupted one.  Unless a
        `CheckpointCallback` is already among ``callbacks``, one pointing at
        the same directory is appended with the default cadence (pass your
        own to control ``every_chunks``).
        """
        manager = CheckpointManager(directory)
        data = manager.load_spec()
        if data is None:
            raise FileNotFoundError(f"no spec.json in {directory!r}")
        spec = RunSpec.from_json(data)
        session = cls(spec, callbacks=callbacks, strict_kernels=strict_kernels)
        out = session.engine.restore(manager)
        if out is None:
            raise FileNotFoundError(f"no restorable checkpoint in {directory!r}")
        state, meta = out
        session.state = state
        session._restored_sweeps = int(np.asarray(state.pt.t).reshape(-1)[0])
        session.engine._adapt_rounds = int(meta.get("adapt_rounds", 0))
        if "temps" in meta:
            # the exact f64 ladder — f32 betas alone can't reproduce it
            session.engine._temps = np.asarray(meta["temps"], np.float64)
        restored_adapt = AdaptState.from_meta(
            meta, rounds=session.engine._adapt_rounds
        )
        if restored_adapt is not None:
            session.engine._adapt_state = restored_adapt
        if not any(isinstance(cb, CheckpointCallback) for cb in session.callbacks):
            session.callbacks.append(CheckpointCallback(manager))
        return session

    @property
    def remaining_sweeps(self) -> int:
        """Schedule sweeps still to run (0 when a resumed run is complete)."""
        return max(0, self.spec.schedule.total_sweeps - self._restored_sweeps)

    # -- execution -------------------------------------------------------------
    def run(self) -> SessionResult:
        """Execute the schedule (or its remainder, when resumed)."""
        if self.state is None:
            self.state = self.init_state()
        skip = self._restored_sweeps
        self._restored_sweeps = 0
        results: dict[str, RunResult] = {}
        stopped = False
        for phase in self.spec.schedule.phases:
            if skip >= phase.n_sweeps:
                skip -= phase.n_sweeps  # phase fully done before the resume
                continue
            budget = phase.n_sweeps - skip
            fresh_phase = skip == 0
            skip = 0
            self.current_phase = phase
            self.dispatch("on_phase_start", phase)
            # Resuming mid-phase keeps the checkpointed accumulators: the
            # reset already happened in the original run's phase start.
            if phase.reset_stats and fresh_phase:
                self.state = self.engine.reset_stats(self.state)
            self.engine.adapt = self._adapt if phase.adapt else None
            self.state, result = self.engine.run(
                self.state,
                budget,
                on_chunk=lambda info: self.dispatch("on_chunk", info),
                on_adapt=lambda info: self.dispatch("on_adapt", info),
                # a trace-consuming callback owns the stream: don't also
                # buffer every chunk for RunResult.trace
                keep_trace=not any(
                    getattr(cb, "consumes_trace", False) for cb in self.callbacks
                ),
            )
            results[phase.name] = result
            self.dispatch("on_phase_end", phase, result)
            if result.stopped_early:
                stopped = True
                break
        self.current_phase = None
        if not results:
            raise RuntimeError(
                "nothing to run: the checkpointed sweep counter already "
                "covers the whole schedule"
            )
        return SessionResult(
            spec=self.spec, phases=results, state=self.state, stopped_early=stopped
        )
