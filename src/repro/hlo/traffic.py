"""Post-fusion HBM traffic estimate from compiled HLO text.

XLA's `cost_analysis()['bytes accessed']` is per-instruction (pre-fusion): it
counts every producer/consumer pair even when the compiler fuses them into a
single kernel, overestimating real HBM traffic ~10-20x (measured on this
backend — DESIGN.md §7).  This module walks only
**top-level** instructions (ENTRY, while bodies/conds, conditional branches —
not fusion subcomputations): each one reads its operand buffers from and
writes its result buffer to HBM, which is exactly the fusion-boundary
traffic.  While-body contributions are multiplied by the loop trip count
(same best-effort constant recovery as hlo/collectives.py).

Skipped as free: parameter/constant/tuple/get-tuple-element/bitcast (no data
movement of their own — their bytes are charged at their consumers).

This module also owns the *analytic* sweep-kernel traffic model
(`hbm_bytes_per_cell_sweep`): the single source of truth behind the kernels'
per-system models (`repro.kernels.ising_sweep` / `potts_sweep` delegate
here), the ≥5× fused-traffic assertions in tests, and the roofline report
(`benchmarks/roofline_report.py`) — one formula, three consumers.
"""
from __future__ import annotations

import re

from repro.hlo.collectives import _COMP_RE, _DEF_RE, _SHAPE_RE, _shape_bytes


def hbm_bytes_per_cell_sweep(
    *,
    fused: bool,
    sweeps_per_interval: int = 1,
    rounds_per_launch: int = 1,
    state_bytes: float = 2.0,
    uniform_plane_bytes: float = 8.0,
) -> float:
    """Modeled HBM bytes per lattice cell per sweep (O(R) scalars excluded).

    Per-sweep path: ``state_bytes`` (int8 state in + out) **plus the
    uniforms stream** — ``uniform_plane_bytes`` written per cell by the
    external generator and the same read back by the kernel.  Fused path:
    the state block crosses HBM once each way per *launch*
    (``state_bytes`` amortized over ``sweeps_per_interval`` sweeps per PT
    round × ``rounds_per_launch`` rounds — the whole-round kernels fold the
    exchange in, so a multi-round launch never touches HBM between rounds);
    the randoms come from the in-kernel counter PRNG and never exist in HBM.

    Defaults model the Ising kernel (one f32 uniform per cell per colour =
    8 B/cell/sweep each way -> 18 B/cell/sweep unfused); Potts passes
    ``uniform_plane_bytes=16.0`` (proposal + acceptance planes -> 34).
    """
    if not fused:
        return state_bytes + 2.0 * uniform_plane_bytes
    if sweeps_per_interval < 1:
        raise ValueError("sweeps_per_interval must be >= 1")
    if rounds_per_launch < 1:
        raise ValueError("rounds_per_launch must be >= 1")
    return state_bytes / (sweeps_per_interval * rounds_per_launch)

_FREE_OPS = (
    "parameter(", "constant(", "tuple(", "get-tuple-element(", "bitcast(",
    "after-all(", "iota(",
)

_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _opcode_of(rhs: str) -> str:
    # rhs looks like: "f32[8,16]{1,0} fusion(%a, %b), kind=kLoop, ..."
    m = re.search(r"\}?\s([a-z][\w\-]*)\(", rhs)
    return m.group(1) if m else ""


def hbm_traffic_bytes(hlo_text: str) -> float:
    lines = hlo_text.splitlines()
    name_type: dict[str, str] = {}
    comp_of_line: list[str] = []
    current = "<module>"
    fusion_comps: set[str] = set()
    for ln in lines:
        m = _COMP_RE.match(ln)
        if m:
            current = m.group(1)
        comp_of_line.append(current)
        d = _DEF_RE.match(ln)
        if d:
            name, rhs = d.groups()
            if rhs.startswith("("):
                name_type[name] = rhs.split(") ")[0] + ")"
            else:
                name_type[name] = rhs.split(" ")[0]
            # computations referenced as fused kernels / reducer lambdas
            for ref in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", rhs):
                fusion_comps.add(ref)

    # computations that are loop bodies/conditions/branches stay top-level:
    loop_comps: set[str] = set()
    for ln in lines:
        for ref in re.findall(
            r"(?:true_computation|false_computation)=%?([\w.\-]+)", ln
        ):
            loop_comps.add(ref)
        mbr = re.search(r"branch_computations=\{([^}]*)\}", ln)
        if mbr:
            for ref in re.findall(r"%?([\w.\-]+)", mbr.group(1)):
                loop_comps.add(ref)
    body_trip: dict[str, int] = {}
    for ln in lines:
        if " while(" in ln:
            mb = re.search(r"body=%?([\w.\-]+)", ln)
            mc = re.search(r"condition=%?([\w.\-]+)", ln)
            trip = 1
            if mc:
                loop_comps.add(mc.group(1))
                consts = [
                    int(c)
                    for i, l2 in enumerate(lines)
                    if comp_of_line[i] == mc.group(1)
                    for c in re.findall(r"constant\((\d+)\)", l2)
                ]
                if consts:
                    trip = max(consts)
            if mb:
                loop_comps.add(mb.group(1))
                body_trip[mb.group(1)] = trip

    total = 0.0
    for i, ln in enumerate(lines):
        comp = comp_of_line[i]
        if comp in fusion_comps and comp not in loop_comps:
            continue  # inside a fused kernel: no HBM traffic
        d = _DEF_RE.match(ln)
        if not d:
            continue
        name, rhs = d.groups()
        if any(op in rhs for op in _FREE_OPS):
            continue
        opcode = _opcode_of(rhs)
        if not opcode:
            continue
        out_b = _shape_bytes(rhs.split(" ")[0] if not rhs.startswith("(") else rhs)
        args_str = rhs[rhs.find("(") :]
        in_b = sum(
            _shape_bytes(name_type.get(nm, "")) for nm in _OPERANDS_RE.findall(args_str)
        )
        total += (out_b + in_b) * body_trip.get(comp, 1)
    return total
