"""Three-term roofline model (TPU v5e targets; DESIGN.md §7).

    T_comp = FLOPs_per_device / 197e12        (bf16 peak per chip)
    T_mem  = HBM_bytes_per_device / 819e9
    T_coll = collective_wire_bytes_per_device / 50e9   (per-link ICI)

`cost_analysis()` on this JAX/XLA build reports *per-partition* flops/bytes
(verified in tests/test_hlo.py), so no division by chip count is applied.
The dominant term is the step-time lower bound; `fraction_of_roofline` =
T_comp / max(all terms) — how close the program is to being compute-bound at
peak (the §Perf score).  MODEL_FLOPS cross-checks HLO flops for remat /
redundancy waste.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float  # HLO-derived (brief formula; pre-fusion upper)
    coll_wire_bytes_per_device: float
    model_flops_global: float  # 6·N·D (train) or 2·N_active·tokens (serve)
    n_devices: int
    hbm_analytic_per_device: float = 0.0  # minimum-traffic model (lower bound)

    @property
    def t_comp(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_mem(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_mem_analytic(self) -> float:
        return self.hbm_analytic_per_device / HBM_BW

    @property
    def t_coll(self) -> float:
        return self.coll_wire_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        """Dominant term under the HLO memory bytes (the brief's formula)."""
        terms = {"compute": self.t_comp, "memory": self.t_mem, "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def dominant_analytic(self) -> str:
        """Dominant term with the analytic (TPU-fusion-realistic) memory
        model — the CPU backend barely fuses, so the HLO byte count is a
        10-20x overestimate of TPU HBM traffic (DESIGN.md §7)."""
        terms = {
            "compute": self.t_comp,
            "memory": self.t_mem_analytic,
            "collective": self.t_coll,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def bound_time_analytic(self) -> float:
        return max(self.t_comp, self.t_mem_analytic, self.t_coll)

    @property
    def fraction_of_roofline(self) -> float:
        """T_comp / max-term: 1.0 = perfectly compute-bound at peak."""
        return self.t_comp / self.bound_time if self.bound_time else 0.0

    @property
    def fraction_of_roofline_analytic(self) -> float:
        return self.t_comp / self.bound_time_analytic if self.bound_time_analytic else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (catches remat/redundancy waste)."""
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on model-FLOPs utilization implied by the roofline:
        useful flops / (chips · peak · bound_time)."""
        denom = self.n_devices * PEAK_FLOPS * self.bound_time
        return self.model_flops_global / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "t_comp_s": self.t_comp,
            "t_mem_s": self.t_mem,
            "t_mem_analytic_s": self.t_mem_analytic,
            "t_coll_s": self.t_coll,
            "dominant": self.dominant,
            "dominant_analytic": self.dominant_analytic,
            "bound_time_s": self.bound_time,
            "fraction_of_roofline": self.fraction_of_roofline,
            "fraction_of_roofline_analytic": self.fraction_of_roofline_analytic,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def analytic_hbm_bytes(cfg, shape_kind: str, batch: int, seq: int,
                       n_model: int, n_batchpar: int) -> float:
    """Minimum-HBM-traffic model per device per step (lower bound).

    Stream accounting (bytes each tensor must cross HBM at fusion
    boundaries a TPU compiler reliably achieves):

    train:   28·P/chips        master f32 params r/w + Adam m,v r/w + grad r
           +  2·P_active/n_model   step-start bf16 weight cast/gather write
           +  3·2·P_active/n_model bf16 weight reads (fwd + remat-fwd + bwd)
           + ACT_TRAIN streams of (B_loc·S·d·2B) per layer
           + chunked-CE logits r/w
    prefill: bf16 weight read + ACT_PREFILL act streams + KV-cache write
    decode:  bf16 active-weight read + KV-cache (or recurrent-state) r/w
             + per-token activations (negligible but counted)
    """
    p_total, p_act = cfg.n_params, cfg.n_active_params
    chips = n_model * n_batchpar
    d = cfg.d_model
    l = cfg.n_layers + (cfg.enc_layers or 0)
    tok_loc = batch * seq / n_batchpar
    kv_bytes_total = 0.0
    if cfg.family not in ("rwkv",):
        # kv cache bytes across layers (hybrid: only attention layers)
        n_attn = l
        if cfg.family == "hybrid":
            n_attn = sum(
                1 for i in range(cfg.n_layers)
                if (cfg.pattern or ("attn",))[i % len(cfg.pattern or ("attn",))].startswith("attn")
            )
        eff_seq = seq
        window = cfg.local_window if cfg.family == "hybrid" else cfg.swa_window
        if cfg.ring_cache and window:
            eff_seq = min(seq, window)  # ring-buffer cache (§Perf)
        kv_bytes_total = n_attn * batch * cfg.n_kv_heads * eff_seq * cfg.head_dim * 2 * 2
    else:
        kv_bytes_total = (
            cfg.n_layers * batch * (cfg.d_model // 64) * 64 * 64 * 4 * 2
        )  # wkv f32 state k/v planes

    if shape_kind == "train":
        ACT_STREAMS = 40.0  # fwd(~14 tensors r+w) + remat refwd + bwd ≈ 40
        opt = 28.0 * p_total / chips
        gather = 2.0 * p_act / n_model
        wreads = 6.0 * p_act / n_model
        acts = l * tok_loc * d * 2.0 * ACT_STREAMS
        logits = tok_loc * (cfg.vocab / n_model) * 4.0 * 2.0
        return opt + gather + wreads + acts + logits
    if shape_kind == "prefill":
        ACT_STREAMS = 16.0
        wreads = 2.0 * p_act / n_model
        acts = l * tok_loc * d * 2.0 * ACT_STREAMS
        cache_w = kv_bytes_total / chips / 2  # write once
        return wreads + acts + cache_w
    # decode: every step reads all active weights + the (masked) cache
    wreads = 2.0 * p_act / n_model
    cache_rw = kv_bytes_total / chips
    acts = l * (batch / n_batchpar) * d * 2.0 * 12.0
    return wreads + cache_rw + acts


def model_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """Analytic MODEL_FLOPS for a step.

    train: 6·N_active·tokens (fwd 2x + bwd 4x), tokens = batch·seq.
    prefill: 2·N_active·tokens.
    decode: 2·N_active·batch (one token per sequence).
    """
    n = cfg.n_active_params
    if shape_kind == "train":
        return 6.0 * n * batch * seq
    if shape_kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch
