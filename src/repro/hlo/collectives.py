"""HLO-text analysis: collective payload bytes per device.

`compiled.cost_analysis()` has no collective accounting, so we parse the
compiled HLO module (DESIGN.md §7):

1. build a name -> (dtype, shape) table from every instruction definition;
2. for each all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute instruction, sum its *operand* sizes (looked up in the
   table — for all-gather the operand is the pre-gather shard, which is what
   each device actually sends);
3. attribute instructions to their enclosing computation; instructions inside
   a `while` body are multiplied by the loop trip count (best-effort: the
   largest integer constant in the loop-condition computation — exact for
   `lax.scan`).  The dry-run's delta method avoids relying on this (layers
   are unrolled), but the correction makes the parser usable on production
   scan programs too (tested in tests/test_hlo.py).

The per-op "wire factor" models a ring schedule: all-reduce moves ~2x its
payload per device (reduce-scatter + all-gather phases), the others ~1x,
scaled by (G-1)/G for group size G when replica_groups are parseable.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    payload_bytes: float  # sum of operand bytes (per device), trip-corrected
    wire_bytes: float  # ring-model bytes moved per device
    by_op: dict
    count: int


def parse_collectives(hlo_text: str) -> CollectiveStats:
    lines = hlo_text.splitlines()
    # pass 1: name -> type for all defs; computation spans; while bodies
    name_type: dict[str, str] = {}
    comp_of_line: list[str] = []
    current = "<module>"
    for ln in lines:
        m = _COMP_RE.match(ln)
        if m:
            current = m.group(1)
        comp_of_line.append(current)
        d = _DEF_RE.match(ln)
        if d:
            name, rhs = d.groups()
            # the type is the prefix of rhs before the opcode; defs like
            # get-tuple-element print their operand's full tuple type inline,
            # so keeping the whole rhs would charge the collective for every
            # buffer in the loop-carry tuple
            if rhs.startswith("("):
                name_type[name] = rhs.split(") ")[0] + ")"
            else:
                name_type[name] = rhs.split(" ")[0]
    # while instructions: body/condition computation names
    body_trip: dict[str, int] = {}
    for ln in lines:
        if " while(" in ln:
            mb = re.search(r"body=%?([\w.\-]+)", ln)
            mc = re.search(r"condition=%?([\w.\-]+)", ln)
            trip = 1
            if mc:
                # largest integer constant inside the condition computation
                consts = [
                    int(c)
                    for i, l2 in enumerate(lines)
                    if comp_of_line[i] == mc.group(1)
                    for c in re.findall(r"constant\((\d+)\)", l2)
                ]
                if consts:
                    trip = max(consts)
            if mb:
                body_trip[mb.group(1)] = trip

    payload = 0.0
    wire = 0.0
    by_op: dict[str, float] = defaultdict(float)
    count = 0
    for i, ln in enumerate(lines):
        d = _DEF_RE.match(ln)
        if not d:
            continue
        rhs = d.group(2)
        opm = re.search(r"\b(" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        if "-done(" in rhs:
            continue  # counted at -start
        # operand bytes
        args_str = rhs[opm.end() :]
        args_str = args_str.split("),")[0]
        operand_names = _OPERANDS_RE.findall(args_str)
        b = sum(_shape_bytes(name_type.get(nm, "")) for nm in operand_names)
        if b == 0:  # fallback: use the result type
            b = _shape_bytes(rhs.split(" ")[0])
        gm = _GROUPS_RE.search(rhs)
        gfrac = 1.0
        if gm:
            g = int(gm.group(2))
            gfrac = (g - 1) / g if g > 1 else 0.0
        factor = 2.0 if op == "all-reduce" else 1.0
        trip = body_trip.get(comp_of_line[i], 1)
        payload += b * trip
        wire += b * factor * gfrac * trip
        by_op[op] += b * trip
        count += 1
    return CollectiveStats(payload, wire, dict(by_op), count)
