"""Statistical validation: exact ground truth + MCSE machinery (DESIGN.md §Validate).

Sampler correctness is an *executable property* here, not a visual benchmark:

* `repro.validate.exact` — exact enumeration of Z/⟨E⟩/⟨order parameter⟩ for
  small lattices (4x4 Ising/Potts/EA) and short HP chains, plus analytic /
  quadrature moments for the Gaussian-mixture system;
* `repro.validate.mcse` — effective sample size and Monte-Carlo standard
  errors via batch means over the engine's Welford accumulators, and a
  Geweke-style equality-in-distribution z-score;
* `repro.validate.conformance` — drives the chunked engine (adaptive ladder
  on, ensemble axis on) over a `repro.core.systems.REGISTRY` entry and
  compares every observable to its exact reference within MCSE-derived
  tolerances (`tests/test_conformance.py`).
"""
from repro.validate.conformance import ConformanceReport, assert_conforms, run_conformance
from repro.validate.mcse import batch_mean_stats, effective_sample_size, geweke_z

__all__ = [
    "ConformanceReport",
    "assert_conforms",
    "batch_mean_stats",
    "effective_sample_size",
    "geweke_z",
    "run_conformance",
]
