"""ESS / MCSE via batch means, on top of the engine's Welford accumulators.

The streaming engine never materializes a sample trace, so classical
autocorrelation-based error estimates don't apply directly.  Batch means
recovers honest uncertainty from exactly what the engine *does* expose: run
the measurement phase as ``B`` consecutive windows (resetting the O(R) moment
accumulators between windows — `Engine.reset_stats`), treat each window's
Welford mean as one draw of the batch-mean distribution, and estimate

    MCSE(grand mean) = sd(batch means) / sqrt(M)

over the ``M = B x n_chains`` windows (chains are independent, so each
chain x window cell is its own batch).  When the batch length comfortably
exceeds the integrated autocorrelation time the estimator is consistent —
the conformance suite sizes windows in the hundreds of sweeps for chains
whose IATs are a few sweeps.

`effective_sample_size` inverts the same relation (ESS = pooled variance /
MCSE²), and `geweke_z` turns the first-vs-last-window comparison into the
classic equality-in-distribution drift check.
"""
from __future__ import annotations

import numpy as np

__all__ = ["batch_mean_stats", "effective_sample_size", "geweke_z"]


def batch_mean_stats(batch_means: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Grand mean and MCSE from per-batch means.

    Args:
      batch_means: (M, ...) — one row per batch (chain x window), trailing
        axes arbitrary (typically the rung axis).

    Returns:
      (grand_mean (...,), mcse (...,), m) with
      ``mcse = sd(batch means, ddof=1) / sqrt(M)``.
    """
    bm = np.asarray(batch_means, np.float64)
    m = bm.shape[0]
    if m < 2:
        raise ValueError(f"batch means need M >= 2 batches, got {m}")
    return bm.mean(axis=0), bm.std(axis=0, ddof=1) / np.sqrt(m), m


def effective_sample_size(
    pooled_var: np.ndarray, mcse: np.ndarray
) -> np.ndarray:
    """ESS implied by a variance and an MCSE: the n for which sd/sqrt(n)=MCSE.

    ``pooled_var`` is the plain sample variance of the series (e.g. the mean
    over batches of the engine's per-window `var_<k>`); dividing by the
    squared batch-means MCSE yields the autocorrelation-discounted sample
    count.  Zero-variance series (saturated observables) report ESS 0 —
    treat as "no information", not "infinite precision".
    """
    v = np.asarray(pooled_var, np.float64)
    se2 = np.asarray(mcse, np.float64) ** 2
    return np.where(se2 > 0, v / np.maximum(se2, 1e-300), 0.0)


def geweke_z(first: np.ndarray, second: np.ndarray) -> np.ndarray:
    """Geweke-style drift z-score between two disjoint run segments.

    Args:
      first/second: (M1, ...) and (M2, ...) batch means from an early and a
        late measurement window.

    Returns ``(mean_1 - mean_2) / sqrt(se_1² + se_2²)`` — approximately
    standard normal when both segments sample the same stationary law.
    Degenerate segments (both errors 0) return 0 when the means agree and
    ±inf when they don't.
    """
    m1, se1, _ = batch_mean_stats(first)
    m2, se2, _ = batch_mean_stats(second)
    denom = np.sqrt(se1**2 + se2**2)
    diff = m1 - m2
    with np.errstate(divide="ignore", invalid="ignore"):
        z = np.where(
            denom > 0, diff / np.maximum(denom, 1e-300),
            np.where(diff == 0, 0.0, np.inf * np.sign(diff)),
        )
    return z
