"""Exact ground truth for the validation system zoo (DESIGN.md §Validate).

Every function returns per-temperature Boltzmann expectations computed
*outside* the sampler — brute-force enumeration over the full configuration
space for the discrete systems, quadrature (or closed form) for the
continuous one — so the conformance suite can test the PT engine against
answers with no Monte-Carlo error of their own.

All enumeration is host-side numpy in float64.  Sizes are validation-scale
by construction: 2^16 configs for 4x4 spin systems, q^16 for 4x4 Potts
(chunked; ~20 s for q=3 — its conformance case rides the `slow` tier), and
the full self-avoiding-walk set for short HP chains.
"""
from __future__ import annotations

import functools
from collections import deque

import numpy as np

__all__ = [
    "boltzmann_means",
    "ising_exact",
    "potts_exact",
    "ea_exact",
    "gaussian_exact",
    "hp_exact",
    "enumerate_saws",
    "hp_move_graph_connected",
]


def boltzmann_means(
    energies: np.ndarray, observables: dict, temps
) -> dict[str, np.ndarray]:
    """Boltzmann expectations over an explicit configuration list.

    Args:
      energies: (M,) energy of every configuration.
      observables: {name: (M,) per-configuration values}.
      temps: (R,) temperatures.

    Returns ``{"energy": (R,), **{name: (R,)}}`` with
    ``<f>_T = sum_c f(c) e^{-E_c/T} / Z_T`` (max-shifted for stability).
    """
    e = np.asarray(energies, np.float64)
    betas = 1.0 / np.asarray(temps, np.float64)
    logw = -betas[:, None] * e[None, :]  # (R, M)
    logw -= logw.max(axis=1, keepdims=True)
    w = np.exp(logw)
    z = w.sum(axis=1)
    out = {"energy": (w * e[None, :]).sum(axis=1) / z}
    for name, vals in observables.items():
        out[name] = (w * np.asarray(vals, np.float64)[None, :]).sum(axis=1) / z
    return out


# -- spin lattices -------------------------------------------------------------


def _spin_configs(n_sites: int) -> np.ndarray:
    """All 2^n ±1 configurations, shape (2^n, n) int8."""
    ints = np.arange(1 << n_sites, dtype=np.int64)
    bits = (ints[:, None] >> np.arange(n_sites)) & 1
    return (2 * bits - 1).astype(np.int8)


def ising_exact(system, temps) -> dict[str, np.ndarray]:
    """Exact ⟨E⟩ / ⟨|m|⟩ for `repro.core.ising.IsingSystem` (PBC Eq. 3)."""
    l = system.length
    s = _spin_configs(l * l).reshape(-1, l, l).astype(np.float64)
    bonds = s * (np.roll(s, -1, axis=2) + np.roll(s, -1, axis=1))
    e = system.b * s.sum(axis=(1, 2)) - system.j * bonds.sum(axis=(1, 2))
    absm = np.abs(s.mean(axis=(1, 2)))
    return boltzmann_means(e, {"absmag": absm}, temps)


def ea_exact(system, temps) -> dict[str, np.ndarray]:
    """Exact ⟨E⟩ / ⟨|m|⟩ for `repro.core.spin_glass.EASpinGlass`.

    Uses the system's own quenched disorder draw, so the reference matches
    the couplings every replica carries in its state pytree.
    """
    h, w = system.shape
    jr, jd = (np.asarray(x, np.float64) for x in system.disorder())
    s = _spin_configs(h * w).reshape(-1, h, w).astype(np.float64)
    e = -(jr[None] * s * np.roll(s, -1, axis=2)).sum(axis=(1, 2)) - (
        jd[None] * s * np.roll(s, -1, axis=1)
    ).sum(axis=(1, 2))
    absm = np.abs(s.mean(axis=(1, 2)))
    return boltzmann_means(e, {"absmag": absm}, temps)


def potts_exact(system, temps, chunk: int = 1 << 18) -> dict[str, np.ndarray]:
    """Exact ⟨E⟩ / ⟨m⟩ for `repro.core.potts.PottsSystem` by chunked sweep
    over all q^(H·W) configurations (mixed-radix decode, float64 accumulators;
    weights are shifted by the -2·J·n energy lower bound so exponents stay
    finite at every validation temperature)."""
    h, w = system.shape
    q, j = system.q, system.j
    n = h * w
    total = q**n
    betas = 1.0 / np.asarray(temps, np.float64)
    e_ref = -abs(j) * 2 * n
    zw = np.zeros(len(betas))
    ze = np.zeros(len(betas))
    zm = np.zeros(len(betas))
    for start in range(0, total, chunk):
        m = min(chunk, total - start)
        ints = np.arange(start, start + m, dtype=np.int64)
        digits = np.empty((m, n), np.int8)
        for k in range(n):
            digits[:, k] = ints % q
            ints //= q
        s = digits.reshape(m, h, w)
        match = (s == np.roll(s, -1, axis=2)).sum(axis=(1, 2)) + (
            s == np.roll(s, -1, axis=1)
        ).sum(axis=(1, 2))
        e = -j * match.astype(np.float64)
        counts = np.stack([(s == c).sum(axis=(1, 2)) for c in range(q)], axis=1)
        mag = (q * counts.max(axis=1) / n - 1.0) / (q - 1.0)
        for bi, b in enumerate(betas):
            wgt = np.exp(-b * (e - e_ref))
            zw[bi] += wgt.sum()
            ze[bi] += (wgt * e).sum()
            zm[bi] += (wgt * mag).sum()
    return {"energy": ze / zw, "pmag": zm / zw}


# -- Gaussian mixture ----------------------------------------------------------


def gaussian_exact(
    system, temps, *, span: float = 12.0, n_grid: int = 40001
) -> dict[str, np.ndarray]:
    """Quadrature moments for `repro.core.gaussian.GaussianMixture`.

    The tempered density ``p_beta(x) ∝ exp(-beta E(x))`` of a K>1 mixture has
    no closed form, so expectations come from trapezoidal quadrature on a grid
    spanning ``span`` standard deviations past the extreme modes — effectively
    exact (refinement error ~1e-10) for validation purposes.  For a single
    component the analytic answers are ``<E> = 1/(2 beta) + log(sigma
    sqrt(2 pi))`` and ``x ~ N(mu, sigma^2/beta)`` (unit-tested against this
    quadrature in tests/test_validate.py).
    """
    mus = np.asarray(system.mus, np.float64)
    sig = np.asarray(system.sigmas, np.float64)
    wts = np.asarray(system.weights, np.float64)
    lo = (mus - span * sig).min()
    hi = (mus + span * sig).max()
    x = np.linspace(lo, hi, n_grid)
    comp = (
        np.log(wts)[:, None]
        - 0.5 * ((x[None, :] - mus[:, None]) / sig[:, None]) ** 2
        - np.log(sig)[:, None]
        - 0.5 * np.log(2 * np.pi)
    )
    cmax = comp.max(axis=0)
    energy = -(cmax + np.log(np.exp(comp - cmax[None, :]).sum(axis=0)))

    betas = 1.0 / np.asarray(temps, np.float64)
    logw = -betas[:, None] * energy[None, :]
    logw -= logw.max(axis=1, keepdims=True)
    w = np.exp(logw)
    trapz = getattr(np, "trapezoid", np.trapz)  # numpy 2 renamed trapz
    z = trapz(w, x, axis=1)
    mean_of = lambda f: trapz(w * f[None, :], x, axis=1) / z
    return {"energy": mean_of(energy), "absx": mean_of(np.abs(x))}


# -- HP lattice protein --------------------------------------------------------

_DIRS = ((1, 0), (-1, 0), (0, 1), (0, -1))


@functools.lru_cache(maxsize=8)
def enumerate_saws(n_steps: int) -> np.ndarray:
    """All self-avoiding walks of ``n_steps`` from the origin.

    Returns (M, n_steps+1, 2) int64 — monomer 0 anchored at the origin, all
    four first-step directions included.  This is exactly the chain's state
    space modulo translation (the sampler is uniform over translations and
    every observable is translation-invariant).
    """
    out: list[tuple] = []
    path = [(0, 0)]
    occ = {(0, 0)}

    def rec():
        if len(path) == n_steps + 1:
            out.append(tuple(path))
            return
        x, y = path[-1]
        for dx, dy in _DIRS:
            p = (x + dx, y + dy)
            if p not in occ:
                occ.add(p)
                path.append(p)
                rec()
                path.pop()
                occ.remove(p)

    rec()
    return np.asarray(out, np.int64)


def hp_exact(system, temps) -> dict[str, np.ndarray]:
    """Exact ⟨E⟩ / ⟨R_g²⟩ for `repro.core.hp.HPChain` over all SAWs."""
    n = system.n_monomers
    pos = enumerate_saws(n - 1).astype(np.float64)  # (M, N, 2)
    hmask = np.asarray([c == "H" for c in system.sequence], np.float64)
    manh = np.abs(pos[:, :, None, :] - pos[:, None, :, :]).sum(axis=-1)
    idx = np.arange(n)
    nonbonded = np.abs(idx[:, None] - idx[None, :]) > 1
    hh = hmask[:, None] * hmask[None, :]
    contacts = ((manh == 1) & nonbonded[None]) * hh[None]
    e = -system.eps * contacts.sum(axis=(1, 2)) / 2.0
    c = pos.mean(axis=1, keepdims=True)
    rg2 = ((pos - c) ** 2).sum(axis=-1).mean(axis=1)
    return boltzmann_means(e, {"rg2": rg2}, temps)


def _hp_neighbors(path: tuple) -> list[tuple]:
    """States one accepted end/corner move away (normalized to origin).

    Host-side mirror of `repro.core.hp.HPChain.mcmc_step`'s proposal set,
    used to BFS the move graph.
    """
    n = len(path)
    occ = set(path)
    res = []
    for i in range(n):
        if i == 0 or i == n - 1:
            ax, ay = path[1] if i == 0 else path[n - 2]
            for dx, dy in _DIRS:
                c = (ax + dx, ay + dy)
                if c != path[i] and c not in occ:
                    q = list(path)
                    q[i] = c
                    res.append(q)
        else:
            a, b = path[i - 1], path[i + 1]
            if a[0] != b[0] and a[1] != b[1]:
                c = (a[0] + b[0] - path[i][0], a[1] + b[1] - path[i][1])
                if c not in occ:
                    q = list(path)
                    q[i] = c
                    res.append(q)
    norm = []
    for q in res:
        x0, y0 = q[0]
        norm.append(tuple((x - x0, y - y0) for x, y in q))
    return norm


def hp_move_graph_connected(n_monomers: int) -> bool:
    """True iff end+corner moves reach *every* SAW of the given length.

    The Verdier-Stockmayer move set is non-ergodic for long chains; this
    makes ergodicity at validation scale an executable property — the HP
    conformance case is only sound while this holds for the registered
    sequence length (it does through at least N=10).
    """
    target = {tuple(map(tuple, p)) for p in enumerate_saws(n_monomers - 1)}
    start = tuple((i, 0) for i in range(n_monomers))
    seen = {start}
    dq = deque([start])
    while dq:
        s = dq.popleft()
        for t in _hp_neighbors(s):
            if t not in seen:
                seen.add(t)
                dq.append(t)
    return seen == target
