"""Engine-vs-ground-truth conformance harness (DESIGN.md §Validate).

`run_conformance` compiles one `repro.core.systems.REGISTRY` entry to a
declarative `repro.api.RunSpec` (`entry_runspec`) and executes it through
the *production* sampling path — `repro.api.Session` over the chunked
streaming engine with the adaptive ladder enabled and the ensemble axis on —
then compares every registered observable (plus the energy) at every rung
against the system's exact reference, evaluated at the **final adapted
ladder** (adaptation pins the endpoints but moves interior rungs; exact
answers are a function of temperature, so the reference simply follows).

Schedule per entry (one `ScheduleSpec`):

1. burn-in phase: ``burn_sweeps`` with ``adapt=True`` and
   `AdaptSpec(max_rounds=adapt_rounds)` — all retunes fire here; the run
   *uses* the adaptive machinery rather than bypassing it;
2. measurement phases: ``n_batches`` windows of ``sweeps_per_batch`` sweeps,
   each with ``reset_stats=True`` so the O(R) moment accumulators restart;
   each chain x window Welford mean is one batch mean (`repro.validate.mcse`);
3. verdict: ``z = (grand mean - exact) / MCSE`` per series per rung, plus a
   first-half vs second-half Geweke drift score.  A ladder retune during
   measurement would invalidate the reference and raises instead.

`assert_conforms` is the test-facing gate: |z| <= z_max (default 4 — a
~6e-5 two-sided tail per comparison under normality) with a small absolute
floor guarding saturated observables whose MCSE collapses to ~0.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import (
    AdaptSpec,
    Callback,
    EngineSpec,
    ExchangeSpec,
    LadderSpec,
    PhaseSpec,
    RunSpec,
    ScheduleSpec,
    Session,
    SystemSpec,
)
from repro.core.systems import RegisteredSystem
from repro.validate import exact as exact_lib
from repro.validate.mcse import batch_mean_stats, effective_sample_size, geweke_z

__all__ = [
    "EXACT",
    "ConformanceReport",
    "entry_runspec",
    "run_conformance",
    "assert_conforms",
]


# Registry name -> exact-reference function (system, temps) -> {series: (R,)}.
EXACT = {
    "ising": exact_lib.ising_exact,
    "gaussian": exact_lib.gaussian_exact,
    "potts": exact_lib.potts_exact,
    "ea_spin_glass": exact_lib.ea_exact,
    "hp_protein": exact_lib.hp_exact,
}


@dataclasses.dataclass
class ConformanceReport:
    """Outcome of one conformance run (all arrays rung-ordered, cold->hot)."""

    name: str
    temps: np.ndarray  # final adapted ladder (R,)
    n_retunes: int  # ladder retunes that fired during burn-in
    means: dict[str, np.ndarray]  # engine grand means per series (R,)
    mcse: dict[str, np.ndarray]  # batch-means standard errors (R,)
    exact: dict[str, np.ndarray]  # ground truth at `temps` (R,)
    z: dict[str, np.ndarray]  # (means - exact) / mcse (R,)
    ess: dict[str, np.ndarray]  # implied effective sample size (R,)
    geweke: dict[str, np.ndarray]  # first-vs-second-half drift z (R,)
    n_batches: int  # chain x window batch count

    def worst(self) -> tuple[str, float]:
        """(series, max |z|) — the closest-to-failing comparison."""
        name, val = "", 0.0
        for k, zk in self.z.items():
            m = float(np.abs(zk).max())
            if m >= val:
                name, val = k, m
        return name, val


def entry_runspec(
    entry: RegisteredSystem,
    seed: int = 0,
    exchange: str | ExchangeSpec | None = None,
    system_params: dict | None = None,
    mesh=None,
) -> RunSpec:
    """Compile a zoo entry to the declarative `RunSpec` conformance executes.

    One burn-in phase with the ladder feedback on, then ``n_batches``
    measurement phases whose ``reset_stats`` makes each a self-contained
    batch-means window.  The spec is fully serializable — ``python -m repro
    run`` on its JSON form performs the identical simulation.

    ``exchange`` selects the replica-exchange strategy (name or
    `ExchangeSpec`; None = the default "deo") — the gate that makes the
    strategy × system conformance matrix (`tests/test_conformance.py`) a
    one-argument sweep.  ``system_params`` overlays the entry's constructor
    params — how kernel-option variants (e.g. ``use_fused=True``, whose
    random stream is deliberately *not* bit-equal to the per-sweep path)
    join the same matrix without duplicating zoo entries.  ``mesh`` (a
    `repro.core.distributed.MeshSpec`) runs the same conformance simulation
    through the sharded shard_map mega-step — the multi-device entry of the
    matrix.
    """
    if exchange is None:
        exchange = ExchangeSpec()
    elif isinstance(exchange, str):
        exchange = ExchangeSpec(strategy=exchange)
    if entry.n_chains < 2:
        raise ValueError("conformance requires the ensemble axis (n_chains >= 2)")
    phases = [PhaseSpec(name="burn", n_sweeps=entry.burn_sweeps, adapt=True)]
    for b in range(entry.n_batches):
        # adapt stays ON during measurement on purpose: with a well-sized
        # burn all `adapt_rounds` retunes already fired (max_rounds makes
        # further retunes a no-op, bit-identical trajectory), but a too-thin
        # burn lets a leftover retune fire here and trip the frozen-ladder
        # guard in run_conformance instead of silently skewing the reference.
        phases.append(PhaseSpec(
            name=f"batch{b:02d}", n_sweeps=entry.sweeps_per_batch,
            adapt=True, reset_stats=True,
        ))
    return RunSpec(
        system=SystemSpec(
            name=entry.name, params={**entry.params, **(system_params or {})}
        ),
        ladder=LadderSpec(
            kind="custom", n_replicas=len(entry.temps), temps=entry.temps
        ),
        engine=EngineSpec(
            swap_interval=entry.swap_interval,
            chunk_intervals=entry.chunk_intervals,
            n_chains=entry.n_chains,
            mesh=mesh,
        ),
        exchange=exchange,
        adapt=AdaptSpec(
            target=0.3, min_attempts_per_pair=10, max_rounds=entry.adapt_rounds
        ),
        schedule=ScheduleSpec(phases=tuple(phases)),
        observables=entry.observable_names,
        seed=seed,
    )


def run_conformance(
    entry: RegisteredSystem,
    seed: int = 0,
    exact_fn=None,
    exchange=None,
    system_params: dict | None = None,
    mesh=None,
) -> ConformanceReport:
    """Run one zoo entry through the adaptive ensemble Session vs ground truth."""
    if exact_fn is None:
        exact_fn = EXACT[entry.name]
    spec = entry_runspec(
        entry, seed=seed, exchange=exchange, system_params=system_params,
        mesh=mesh,
    )

    # A tiny callback freezes the post-burn ladder so the measurement phases
    # can be audited against it — the callback pipeline replacing what used
    # to be hand-rolled driver code between engine calls.
    frozen: dict[str, np.ndarray] = {}

    class _FreezeLadder(Callback):
        def on_phase_end(self, session, phase, result):
            if phase.name == "burn":
                frozen["betas"] = np.asarray(session.state.betas).copy()

    session = Session(spec, callbacks=[_FreezeLadder()])
    outcome = session.run()
    system = session.system

    # 1. burn-in — equilibration plus every allowed ladder retune.
    burn = outcome.phases["burn"]
    betas_frozen = frozen["betas"]
    temps = 1.0 / betas_frozen.astype(np.float64)

    # 2. measurement — batch means over chain x window cells.
    series = ["energy"] + sorted(entry.observable_names)
    bm = {k: [] for k in series}  # per-window (C, R) means
    pv = {k: [] for k in series}  # per-window (C, R) variances
    for phase in spec.schedule.phases[1:]:
        res = outcome.phases[phase.name]
        for k in series:
            bm[k].append(np.atleast_2d(res.summary[f"mean_{k}"]))
            pv[k].append(np.atleast_2d(res.summary[f"var_{k}"]))
    if not np.array_equal(np.asarray(outcome.state.betas), betas_frozen):
        raise RuntimeError(
            f"{entry.name}: ladder retuned during measurement — increase "
            "burn_sweeps so all adapt_rounds fire before the batches start"
        )

    # 3. verdict vs exact at the adapted ladder.
    exact = {k: np.asarray(v, np.float64) for k, v in exact_fn(system, temps).items()}
    means, mcse, z, ess, geweke = {}, {}, {}, {}, {}
    half = entry.n_batches // 2
    for k in series:
        cells = np.concatenate(bm[k], axis=0)  # (B*C, R)
        grand, se, _ = batch_mean_stats(cells)
        means[k], mcse[k] = grand, se
        z[k] = (grand - exact[k]) / np.maximum(se, 1e-300)
        ess[k] = effective_sample_size(
            np.concatenate(pv[k], axis=0).mean(axis=0), se
        )
        geweke[k] = geweke_z(
            np.concatenate(bm[k][:half], axis=0),
            np.concatenate(bm[k][half:], axis=0),
        )
    return ConformanceReport(
        name=entry.name,
        temps=temps,
        n_retunes=len(burn.ladder_history) - 1,
        means=means,
        mcse=mcse,
        exact=exact,
        z=z,
        ess=ess,
        geweke=geweke,
        n_batches=entry.n_batches * entry.n_chains,
    )


def assert_conforms(
    report: ConformanceReport,
    z_max: float = 4.0,
    geweke_max: float = 4.0,
    atol: float = 2e-3,
) -> None:
    """Raise AssertionError unless every series conforms at every rung.

    ``|mean - exact| <= z_max * MCSE + atol * (1 + |exact|)`` — the absolute
    floor covers saturated observables (e.g. |m| -> 1 at the cold end) whose
    batch means collapse to near-identical values and make raw z unstable.
    The Geweke score guards stationarity of the measurement window itself.
    """
    for k in report.means:
        err = np.abs(report.means[k] - report.exact[k])
        tol = z_max * report.mcse[k] + atol * (1.0 + np.abs(report.exact[k]))
        assert np.all(err <= tol), (
            f"{report.name}/{k}: engine mean disagrees with exact reference\n"
            f"  temps={report.temps.round(4)}\n  mean ={report.means[k]}\n"
            f"  exact={report.exact[k]}\n  mcse ={report.mcse[k]}\n"
            f"  |z|  ={np.abs(report.z[k]).round(2)} (max {z_max})"
        )
        g = np.abs(report.geweke[k])
        assert np.all(g <= geweke_max), (
            f"{report.name}/{k}: Geweke drift |z|={g.round(2)} exceeds "
            f"{geweke_max} — measurement window not stationary"
        )
