"""``python -m repro`` — the CLI front door (see `repro.api.cli`)."""
from repro.api.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
