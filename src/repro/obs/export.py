"""Metric exposition: Prometheus text format + JSON snapshots.

Consumes `repro.obs.metrics.MetricsRegistry.snapshot()` (plain data — the
registry is never touched while serializing) and renders:

* `to_prometheus` — the Prometheus text exposition format (0.0.4): # HELP /
  # TYPE headers, labeled samples, `_bucket`/`_sum`/`_count` expansion for
  histograms.  ``repro serve --metrics-every N`` scrapes itself with this.
* `to_json` / `snapshot_digest` — canonical JSON of the snapshot and its
  short sha1.  The digest is what `benchmarks.common` stamps into
  ``BENCH_*.json`` records so a bench row is traceable to the timeline +
  metrics files written by the same run.

Writers are atomic (tmp + rename), matching every other artifact writer in
the repo — a scrape never reads a half-written file.
"""
from __future__ import annotations

import hashlib
import json
import os

__all__ = [
    "to_prometheus",
    "to_json",
    "snapshot_digest",
    "write_prometheus",
    "write_json",
]


def _escape(value: str) -> str:
    return (
        str(value).replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')
    )


def _labels_str(labels: dict, extra: dict | None = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(items.items()))
    return "{" + inner + "}"


def _fmt(v) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_prometheus(snapshot: dict) -> str:
    """Render a registry snapshot as Prometheus text exposition."""
    lines: list[str] = []
    for name, fam in snapshot.items():
        if fam.get("help"):
            lines.append(f"# HELP {name} {_escape(fam['help'])}")
        lines.append(f"# TYPE {name} {fam['type']}")
        for sample in fam["samples"]:
            labels = sample.get("labels", {})
            if fam["type"] == "histogram":
                for le, cum in sample["buckets"]:
                    lines.append(
                        f"{name}_bucket{_labels_str(labels, {'le': le})} {cum}"
                    )
                lines.append(f"{name}_sum{_labels_str(labels)} {_fmt(sample['sum'])}")
                lines.append(f"{name}_count{_labels_str(labels)} {sample['count']}")
            else:
                lines.append(f"{name}{_labels_str(labels)} {_fmt(sample['value'])}")
    return "\n".join(lines) + "\n"


def to_json(snapshot: dict, **meta) -> str:
    """Canonical JSON of a snapshot (sorted keys, compact separators)."""
    payload = {"metrics": snapshot}
    if meta:
        payload.update(meta)
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def snapshot_digest(snapshot: dict) -> str:
    """Short sha1 of the canonical snapshot JSON — the provenance stamp."""
    return hashlib.sha1(to_json(snapshot).encode()).hexdigest()[:12]


def _atomic_write(path: str, text: str) -> str:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return path


def write_prometheus(registry, path: str) -> str:
    """Snapshot ``registry`` and write Prometheus text to ``path`` (atomic)."""
    return _atomic_write(path, to_prometheus(registry.snapshot()))


def write_json(registry, path: str, **meta) -> str:
    """Snapshot ``registry`` and write canonical JSON to ``path`` (atomic)."""
    return _atomic_write(path, to_json(registry.snapshot(), **meta))
