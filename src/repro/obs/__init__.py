"""Unified observability: metrics registry + Perfetto timelines + exporters.

The subsystem behind "where does a PT round spend its time" (DESIGN.md
§Observability).  Three pieces:

* `repro.obs.metrics`  — thread-safe labeled counters/gauges/histograms with
  cheap snapshot semantics;
* `repro.obs.timeline` — span recorder emitting Chrome trace-event JSON
  (load in ui.perfetto.dev), per-thread + virtual tracks, flow arrows;
* `repro.obs.export`   — Prometheus text / canonical JSON exposition and
  the snapshot digest benchmarks stamp into their records.

`Observability` bundles one registry + one timeline into the single handle
instrumented components accept (`Engine(obs=...)`, `Scheduler(obs=...)`,
`ObsCallback`).  The overhead contract every consumer relies on:

* **off is structurally free** — components hold ``obs=None`` and guard
  every instrumentation site with one `is None` check: no recorder objects,
  no dict churn, no extra device traffic (pinned by ``tests/test_obs.py``);
* **on is cheap** — spans are one dict append, metrics one locked float op;
  the engine's per-chunk obs work is <5% of chunk wall time at smoke size
  (gated by ``benchmarks/obs_overhead.py`` in CI);
* **compiled computations are untouched** — instrumentation lives entirely
  in host loops; the mega-step jaxpr is byte-identical with obs on or off
  (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

import dataclasses

from repro.obs.export import (
    snapshot_digest,
    to_json,
    to_prometheus,
    write_json,
    write_prometheus,
)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.timeline import NULL, NullTimeline, Timeline

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Timeline",
    "NullTimeline",
    "NULL",
    "Observability",
    "to_prometheus",
    "to_json",
    "snapshot_digest",
    "write_prometheus",
    "write_json",
]


@dataclasses.dataclass
class Observability:
    """One registry + one timeline: the handle instrumented code accepts.

    ``jax_profile_dir`` arms a one-shot `jax.profiler` window: the first
    engine chunk after arming runs under ``start_trace``/``stop_trace`` and
    lands a TensorBoard-loadable profile in the directory.  One chunk only —
    the profiler's own overhead must not pollute the rest of the timeline.
    """

    metrics: MetricsRegistry
    timeline: Timeline | NullTimeline
    jax_profile_dir: str | None = None
    _jax_profiling: bool = dataclasses.field(default=False, repr=False)

    @classmethod
    def create(cls, timeline: bool = True,
               jax_profile_dir: str | None = None) -> "Observability":
        return cls(
            metrics=MetricsRegistry(),
            timeline=Timeline() if timeline else NULL,
            jax_profile_dir=jax_profile_dir,
        )

    # -- one-shot jax.profiler window ------------------------------------------
    def start_jax_profile(self) -> bool:
        """Open the profiler window if armed and unused; True if opened."""
        if self.jax_profile_dir is None or self._jax_profiling:
            return False
        import jax

        try:
            jax.profiler.start_trace(self.jax_profile_dir)
        except Exception as e:  # profiler backends vary; never kill the run
            self.timeline.instant("jax_profile_failed", error=repr(e))
            self.jax_profile_dir = None
            return False
        self._jax_profiling = True
        self.timeline.instant("jax_profile_start", dir=self.jax_profile_dir)
        return True

    def stop_jax_profile(self) -> None:
        if not self._jax_profiling:
            return
        import jax

        try:
            jax.profiler.stop_trace()
        finally:
            self._jax_profiling = False
            # disarm: the window is one chunk, ever
            self.jax_profile_dir = None
        self.timeline.instant("jax_profile_stop")
