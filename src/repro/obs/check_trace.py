"""Schema check for Chrome trace-event JSON files (the CI timeline gate).

Perfetto is forgiving about extra keys but silently drops malformed events,
so "the file loads" is not a regression gate — a refactor that breaks event
emission would still produce a loadable-but-empty timeline.  This validator
pins the structural contract instead:

* top level: ``traceEvents`` list (JSON object form);
* every event: ``ph``/``pid``/``tid``/``name`` present with sane types;
  ``X`` events carry numeric ``ts`` >= 0 and ``dur`` >= 0; flow events
  (``s``/``t``/``f``) carry an ``id``; metadata (``M``) events are exempt
  from timestamp rules;
* flow arrows balance: every flow id that starts also finishes (warn-level
  by default — a preempted run legitimately has open flows);
* optional ``--require-span NAME`` assertions: the named span must appear as
  at least one ``X`` event (CI requires compile/chunk/adapt/checkpoint on
  the smoke run).

Usable as a library (`validate_trace`, raises `TraceError`) or a CLI::

    python -m repro.obs.check_trace out.trace.json \
        --require-span compile --require-span chunk
"""
from __future__ import annotations

import argparse
import json
import sys

__all__ = ["TraceError", "validate_trace", "main"]

_KNOWN_PH = {"X", "B", "E", "i", "I", "C", "M", "s", "t", "f", "b", "e", "n"}


class TraceError(ValueError):
    """A structural violation of the trace-event contract."""


def validate_trace(
    data: dict,
    require_spans: list[str] | None = None,
    require_balanced_flows: bool = False,
) -> dict:
    """Validate a parsed trace file; returns summary stats.

    Raises `TraceError` on any structural violation.  The summary maps
    ``n_events`` / ``n_spans`` / ``span_names`` / ``tracks`` /
    ``open_flows`` — the CI step prints it next to the artifact upload.
    """
    if not isinstance(data, dict) or "traceEvents" not in data:
        raise TraceError("top level must be an object with 'traceEvents'")
    events = data["traceEvents"]
    if not isinstance(events, list) or not events:
        raise TraceError("'traceEvents' must be a non-empty list")

    span_names: dict[str, int] = {}
    tracks: dict[int, str] = {}
    flow_open: dict[str, str] = {}
    n_spans = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise TraceError(f"{where}: event is not an object")
        ph = ev.get("ph")
        if ph not in _KNOWN_PH:
            raise TraceError(f"{where}: unknown or missing ph {ph!r}")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                raise TraceError(f"{where}: {key} missing or non-integer")
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            raise TraceError(f"{where}: name missing or empty")
        if ph == "M":
            if name == "thread_name":
                tracks[ev["tid"]] = ev.get("args", {}).get("name", "")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise TraceError(f"{where}: ts missing or negative ({ts!r})")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise TraceError(f"{where}: X event dur missing or negative")
            n_spans += 1
            span_names[name] = span_names.get(name, 0) + 1
        if ph in ("s", "t", "f"):
            fid = ev.get("id")
            if fid is None:
                raise TraceError(f"{where}: flow event without id")
            if ph == "s":
                flow_open[str(fid)] = name
            elif ph == "f":
                flow_open.pop(str(fid), None)
        if ph == "C" and not isinstance(ev.get("args"), dict):
            raise TraceError(f"{where}: counter event without args dict")

    for want in require_spans or []:
        if want not in span_names:
            raise TraceError(
                f"required span {want!r} absent; spans present: "
                f"{sorted(span_names)}"
            )
    if require_balanced_flows and flow_open:
        raise TraceError(f"unfinished flows: {sorted(flow_open.items())}")
    return {
        "n_events": len(events),
        "n_spans": n_spans,
        "span_names": dict(sorted(span_names.items())),
        "tracks": [tracks[t] for t in sorted(tracks)],
        "open_flows": len(flow_open),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="path to a .trace.json file")
    ap.add_argument("--require-span", action="append", default=[],
                    metavar="NAME", help="fail unless an X event named NAME exists")
    ap.add_argument("--require-balanced-flows", action="store_true",
                    help="fail if any flow id starts but never finishes")
    args = ap.parse_args(argv)
    try:
        with open(args.trace) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot parse {args.trace}: {e}", file=sys.stderr)
        return 1
    try:
        summary = validate_trace(
            data,
            require_spans=args.require_span,
            require_balanced_flows=args.require_balanced_flows,
        )
    except TraceError as e:
        print(f"FAIL: {args.trace}: {e}", file=sys.stderr)
        return 1
    print(
        f"OK: {args.trace}: {summary['n_events']} events, "
        f"{summary['n_spans']} spans over tracks {summary['tracks']}; "
        f"spans: {summary['span_names']}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
