"""Span recorder emitting Chrome trace-event JSON (DESIGN.md §Observability).

`Timeline` records spans, instants, counter series and flow arrows in the
Trace Event Format consumed by Perfetto (ui.perfetto.dev) and
``chrome://tracing``.  Design points:

* **tracks** — every event lands on a named track.  By default the track is
  the *current thread* (so the engine host loop, the serve scheduler thread
  and client threads separate naturally); an explicit ``track=`` gives
  virtual lanes (one per serve bucket, one per scheduler phase) that render
  as their own rows.  Tracks map to stable small ``tid``s with
  ``thread_name`` metadata events, which is all Perfetto needs.
* **complete events** — spans are single ``"ph": "X"`` records (timestamp +
  duration) rather than begin/end pairs: half the events, and a crashed run
  still yields a loadable file of everything that *finished*.
* **flow events** — ``"ph": "s"/"t"/"f"`` arrows stitch one logical object
  (a serve job: PENDING → RUNNING → DONE) across tracks.
* **recording cost** — one dict append under a lock per event.  The
  zero-overhead-off contract lives a level up: disabled components hold *no
  recorder at all* (`Engine.obs is None`), so this module's cost is only
  ever paid by runs that asked for a timeline.

Timestamps are `time.perf_counter()` microseconds relative to the Timeline's
creation; `write()` lands atomically (tmp + rename).  The file passes
`repro.obs.check_trace` — the schema gate CI runs on the smoke timeline.
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["Timeline", "NullTimeline", "NULL"]


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("_tl", "name", "cat", "track", "args", "_t0")

    def __init__(self, tl: "Timeline", name, cat, track, args):
        self._tl = tl
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def annotate(self, **kv) -> "_Span":
        """Attach extra args to the span before it closes."""
        if self.args is None:
            self.args = {}
        self.args.update(kv)
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        if exc_type is not None:
            self.annotate(error=exc_type.__name__)
        self._tl.complete(
            self.name, self._t0, t1 - self._t0,
            cat=self.cat, track=self.track, args=self.args,
        )
        return False


class Timeline:
    """Accumulates trace events; `write()` emits Perfetto-loadable JSON."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self._tracks: dict[str, int] = {}  # track name -> tid
        self.enabled = True

    # -- track bookkeeping -----------------------------------------------------
    def _tid(self, track: str | None) -> int:
        if track is None:
            track = threading.current_thread().name
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.get(track)
                if tid is None:
                    tid = self._tracks[track] = len(self._tracks) + 1
                    self._events.append({
                        "name": "thread_name", "ph": "M", "pid": self._pid,
                        "tid": tid, "args": {"name": track},
                    })
        return tid

    def _ts(self, t: float | None = None) -> float:
        return ((time.perf_counter() if t is None else t) - self._t0) * 1e6

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    # -- recording API ---------------------------------------------------------
    def span(self, name: str, cat: str = "engine", track: str | None = None,
             **args) -> _Span:
        """``with timeline.span("chunk", index=3): ...`` — one X event."""
        return _Span(self, name, cat, track, args or None)

    def complete(self, name: str, start: float, duration: float, *,
                 cat: str = "engine", track: str | None = None,
                 args: dict | None = None) -> None:
        """Record a finished span from explicit perf_counter start/duration
        (for begin/end pairs that cross callback boundaries, e.g. phases)."""
        ev = {
            "name": name, "ph": "X", "cat": cat, "pid": self._pid,
            "tid": self._tid(track), "ts": self._ts(start),
            "dur": max(duration, 0.0) * 1e6,
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, cat: str = "engine",
                track: str | None = None, **args) -> None:
        ev = {
            "name": name, "ph": "i", "s": "t", "cat": cat, "pid": self._pid,
            "tid": self._tid(track), "ts": self._ts(),
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, values: dict, track: str | None = None,
                cat: str = "engine") -> None:
        """A counter ("C") sample — renders as a stacked area chart."""
        self._emit({
            "name": name, "ph": "C", "cat": cat, "pid": self._pid,
            "tid": self._tid(track), "ts": self._ts(),
            "args": {k: float(v) for k, v in values.items()},
        })

    def _flow(self, ph: str, name: str, flow_id, track, args) -> None:
        ev = {
            "name": name, "ph": ph, "cat": "flow", "pid": self._pid,
            "tid": self._tid(track), "ts": self._ts(), "id": str(flow_id),
        }
        if ph == "f":
            ev["bp"] = "e"  # bind to the enclosing slice's end
        if args:
            ev["args"] = args
        self._emit(ev)

    def flow_start(self, name: str, flow_id, track: str | None = None, **args):
        self._flow("s", name, flow_id, track, args)

    def flow_step(self, name: str, flow_id, track: str | None = None, **args):
        self._flow("t", name, flow_id, track, args)

    def flow_end(self, name: str, flow_id, track: str | None = None, **args):
        self._flow("f", name, flow_id, track, args)

    # -- output ----------------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def to_dict(self) -> dict:
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.obs.timeline"},
        }

    def write(self, path: str) -> str:
        """Atomically write the Chrome-trace JSON; returns the path.

        Safe to call repeatedly mid-run (each call rewrites the full file),
        which is how `ObsCallback` keeps a loadable timeline on disk even if
        the process dies between phases.
        """
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = f"{path}.{self._pid}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f)
        os.replace(tmp, path)
        return path


class _NullSpan:
    """Reusable no-op span: no allocation per `span()` call."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def annotate(self, **kv):
        return self


_NULL_SPAN = _NullSpan()


class NullTimeline:
    """API-compatible no-op recorder.

    Components that *sometimes* record can hold this instead of branching on
    None at every site; every method returns immediately and `span()` hands
    back one shared reusable object — structurally zero per-call allocation.
    (The engine host loop goes further and holds no recorder at all when
    observability is off.)
    """

    enabled = False

    def span(self, name, cat="engine", track=None, **args):
        return _NULL_SPAN

    def complete(self, *a, **k):
        pass

    def instant(self, *a, **k):
        pass

    def counter(self, *a, **k):
        pass

    def flow_start(self, *a, **k):
        pass

    def flow_step(self, *a, **k):
        pass

    def flow_end(self, *a, **k):
        pass

    def events(self):
        return []

    def __len__(self):
        return 0

    def to_dict(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write(self, path):
        raise RuntimeError(
            "NullTimeline records nothing; construct the Observability with "
            "timeline=True to write a trace file"
        )


NULL = NullTimeline()
