"""Thread-safe labeled metrics registry (DESIGN.md §Observability).

One `MetricsRegistry` holds every counter/gauge/histogram a process exports.
The design constraints come from where the registry sits — *inside* the
engine host loop and the serve scheduler's quantum loop:

* **hot-path cost is a dict lookup + a lock + a float add.**  Metric
  families cache their labeled children, so steady-state `inc()`/`set()`/
  `observe()` never allocates; the per-family lock is uncontended in the
  single-writer loops that dominate (the reader is `snapshot()`).
* **cheap snapshot semantics** — `snapshot()` returns a plain, JSON-able
  dict copied under the locks (O(series), no device traffic, no references
  into live state), so exporters (`repro.obs.export`) can serialize without
  racing writers.
* **no global state.**  Registries are plain objects handed around
  explicitly (`Engine(obs=...)`, `Scheduler(obs=...)`); two engines never
  share counters by accident, and tests never need to reset a singleton.

The exposition mapping (Prometheus text / JSON) lives in `repro.obs.export`;
this module is pure accumulation.
"""
from __future__ import annotations

import threading
from typing import Mapping, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
]

# Seconds-oriented log-ish buckets: wide enough for µs spans (a metrics
# write) through multi-second compiles.  Prometheus convention: upper bounds,
# +Inf implicit.
DEFAULT_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class _Family:
    """One named metric family: labeled children cached by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str, label_names: Sequence[str]):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], object] = {}

    def labels(self, *values, **kv):
        """The child at these label values (created on first use, cached)."""
        if kv:
            if values:
                raise ValueError("pass label values positionally OR by name")
            values = tuple(str(kv[k]) for k in self.label_names)
        else:
            values = tuple(str(v) for v in values)
        if len(values) != len(self.label_names):
            raise ValueError(
                f"{self.name}: got {len(values)} label values for "
                f"labels {self.label_names}"
            )
        child = self._children.get(values)
        if child is None:
            with self._lock:
                child = self._children.setdefault(values, self._make_child())
        return child

    def _default_child(self):
        """The label-less child (families declared with no labels)."""
        if self.label_names:
            raise ValueError(
                f"{self.name} is labeled {self.label_names}; use .labels(...)"
            )
        return self.labels()

    def _make_child(self):
        raise NotImplementedError

    def samples(self) -> list[dict]:
        """Plain-data samples for `MetricsRegistry.snapshot` (thread-safe)."""
        with self._lock:
            items = list(self._children.items())
        out = []
        for values, child in items:
            out.append(
                {"labels": dict(zip(self.label_names, values)),
                 **child.sample()}
            )
        return out


class _CounterChild:
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def sample(self) -> dict:
        return {"value": self.value}


class Counter(_Family):
    kind = "counter"

    def _make_child(self):
        return _CounterChild()

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)

    @property
    def value(self) -> float:
        return self._default_child().value


class _GaugeChild:
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def sample(self) -> dict:
        return {"value": self.value}


class Gauge(_Family):
    kind = "gauge"

    def _make_child(self):
        return _GaugeChild()

    def set(self, value: float) -> None:
        self._default_child().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default_child().dec(amount)

    @property
    def value(self) -> float:
        return self._default_child().value


class _HistogramChild:
    __slots__ = ("_lock", "_bounds", "_counts", "_sum", "_count")

    def __init__(self, bounds: tuple[float, ...]):
        self._lock = threading.Lock()
        self._bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # last slot = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        value = float(value)
        # linear scan: bucket lists are short (~16) and the loop is cheaper
        # than bisect's call overhead at this size
        i = 0
        for b in self._bounds:
            if value <= b:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += value
            self._count += 1

    def sample(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, count = self._sum, self._count
        cum, buckets = 0, []
        for b, c in zip(self._bounds, counts):
            cum += c
            buckets.append([b, cum])
        buckets.append(["+Inf", count])
        return {"buckets": buckets, "sum": total, "count": count}

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name, help, label_names, buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, label_names)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("a histogram needs at least one bucket bound")

    def _make_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, value: float) -> None:
        self._default_child().observe(value)

    @property
    def count(self) -> int:
        return self._default_child().count

    @property
    def sum(self) -> float:
        return self._default_child().sum


_NAME_OK = set("abcdefghijklmnopqrstuvwxyz_:0123456789")


class MetricsRegistry:
    """A process-local set of metric families, keyed by name.

    Declaring the same name twice returns the *same* family (and raises if
    the second declaration disagrees on kind or labels) — instrumentation
    sites can therefore declare-and-use locally without coordinating on a
    central schema module.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _declare(self, cls, name, help, labels, **kw):
        if not name or name[0].isdigit() or not set(name.lower()) <= _NAME_OK:
            raise ValueError(f"bad metric name {name!r}")
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = cls(name, help, tuple(labels), **kw)
                return fam
        if not isinstance(fam, cls) or fam.label_names != tuple(labels):
            raise ValueError(
                f"metric {name!r} re-declared as {cls.kind}{tuple(labels)} "
                f"but exists as {fam.kind}{fam.label_names}"
            )
        return fam

    def counter(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Counter:
        return self._declare(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels: Sequence[str] = ()) -> Gauge:
        return self._declare(Gauge, name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        labels: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ) -> Histogram:
        return self._declare(Histogram, name, help, labels, buckets=buckets)

    def snapshot(self) -> dict:
        """Plain JSON-able view: name -> {type, help, label_names, samples}.

        Copied under the per-family locks — safe against concurrent writers,
        never holds references into live metric state.
        """
        with self._lock:
            families = list(self._families.items())
        out = {}
        for name, fam in sorted(families):
            out[name] = {
                "type": fam.kind,
                "help": fam.help,
                "label_names": list(fam.label_names),
                "samples": fam.samples(),
            }
        return out
