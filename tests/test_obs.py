"""Telemetry layer contracts (DESIGN.md §Observability).

The pins, in dependency order:

* **registry semantics** — labeled families cache children, re-declaration
  is idempotent-or-error, counters only go up, histogram buckets cumulate;
* **exposition** — Prometheus text renders HELP/TYPE/labels/histogram
  expansion; the snapshot digest is deterministic and content-sensitive;
* **timeline** — spans/instants/flows land as schema-valid Chrome trace
  events, tracks get stable tids + thread_name metadata, `write` round-trips
  through the `check_trace` validator; `NullTimeline` allocates nothing;
* **zero-overhead-off** — an engine with ``obs=None`` constructs no
  `_EngineObs`, records no events and touches no metric even when the obs
  classes are booby-trapped to raise; the mega-step jaxpr is byte-identical
  with obs on or off, and an instrumented run is *bit-equal* to a bare one;
* **obs-on** — the engine's spans and counters actually appear (compile /
  device_wait / chunk / adapt / checkpoint), `ObsCallback` lands artifacts
  on disk through a full Session, and `Scheduler.metrics()` exposes the
  serve-side series;
* **diagnostics fallback** — legacy traces without a `swap_attempt` channel
  warn when the `prob > 0` inference kicks in; engine-era traces don't.

The <5%-obs-on wall-clock budget is a *benchmark* contract
(`benchmarks/obs_overhead.py`, CI-gated); the slow-marked test here runs
the same measurement end-to-end as a local check.
"""
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import diagnostics, ising, ladder
from repro.engine import Engine, EngineConfig
from repro.engine.driver import _EngineObs
from repro.obs import (
    MetricsRegistry,
    NullTimeline,
    Observability,
    Timeline,
    snapshot_digest,
    to_prometheus,
    write_prometheus,
)
from repro.obs.check_trace import TraceError, validate_trace
from repro.obs.timeline import _NULL_SPAN

R, L = 4, 4
TEMPS = np.asarray(ladder.linear_ladder(R, 1.5, 3.5))


def _engine(obs=None, **kw):
    system = ising.IsingSystem(length=L)
    defaults = dict(n_replicas=R, swap_interval=2, chunk_intervals=2)
    defaults.update(kw)
    return Engine(system, EngineConfig(**defaults), obs=obs)


# ---------- metrics registry ----------------------------------------------------


def test_counter_accumulates_and_rejects_negative():
    m = MetricsRegistry()
    c = m.counter("requests_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = MetricsRegistry().gauge("depth")
    g.set(5)
    g.inc(2)
    g.dec()
    assert g.value == 6.0


def test_histogram_buckets_cumulative():
    h = MetricsRegistry().histogram("lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    (s,) = h.samples()
    assert s["count"] == 5 and s["sum"] == pytest.approx(56.05)
    # cumulative per upper bound, +Inf == count
    assert s["buckets"] == [[0.1, 1], [1.0, 3], [10.0, 4], ["+Inf", 5]]


def test_labeled_children_cached_and_validated():
    m = MetricsRegistry()
    g = m.gauge("occupancy", labels=("bucket",))
    assert g.labels("a") is g.labels("a")
    assert g.labels(bucket="a") is g.labels("a")
    g.labels("b").set(2)
    samples = {s["labels"]["bucket"]: s["value"] for s in g.samples()}
    assert samples == {"a": 0.0, "b": 2.0}
    with pytest.raises(ValueError, match="label values"):
        g.labels("a", "extra")
    with pytest.raises(ValueError, match="labeled"):
        g.set(1)  # label-less use of a labeled family


def test_redeclare_same_returns_same_family_mismatch_raises():
    m = MetricsRegistry()
    c1 = m.counter("hits_total", "first")
    assert m.counter("hits_total", "second declaration ignored") is c1
    with pytest.raises(ValueError, match="re-declared"):
        m.gauge("hits_total")
    with pytest.raises(ValueError, match="re-declared"):
        m.counter("hits_total", labels=("route",))
    with pytest.raises(ValueError, match="bad metric name"):
        m.counter("1bad")
    with pytest.raises(ValueError, match="bad metric name"):
        m.counter("has space")


def test_snapshot_is_plain_json_data():
    m = MetricsRegistry()
    m.counter("a_total").inc()
    m.histogram("b").observe(0.2)
    snap = m.snapshot()
    json.dumps(snap)  # must be JSON-able as-is
    assert snap["a_total"]["type"] == "counter"
    assert snap["b"]["type"] == "histogram"
    assert snap["a_total"]["samples"][0]["value"] == 1.0


def test_registry_thread_safety_under_contention():
    m = MetricsRegistry()
    c = m.counter("n_total")
    h = m.histogram("h", buckets=(1.0,))

    def work():
        for _ in range(500):
            c.inc()
            h.observe(0.5)

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 2000
    assert h.count == 2000


# ---------- exposition ----------------------------------------------------------


def test_prometheus_text_rendering():
    m = MetricsRegistry()
    m.counter("hits_total", "total hits").inc(3)
    m.gauge("depth", labels=("queue",)).labels("main").set(2)
    m.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)).observe(0.05)
    text = to_prometheus(m.snapshot())
    assert "# HELP hits_total total hits" in text
    assert "# TYPE hits_total counter" in text
    assert "hits_total 3" in text
    assert 'depth{queue="main"} 2' in text
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_sum 0.05" in text
    assert "lat_seconds_count 1" in text


def test_snapshot_digest_deterministic_and_content_sensitive():
    m = MetricsRegistry()
    m.counter("a_total").inc()
    d1 = snapshot_digest(m.snapshot())
    assert d1 == snapshot_digest(m.snapshot())
    assert len(d1) == 12
    m.counter("a_total").inc()
    assert snapshot_digest(m.snapshot()) != d1


def test_write_prometheus_atomic(tmp_path):
    m = MetricsRegistry()
    m.counter("x_total").inc()
    path = write_prometheus(m, str(tmp_path / "sub" / "metrics.prom"))
    assert "x_total 1" in open(path).read()


# ---------- timeline ------------------------------------------------------------


def test_span_records_complete_event_with_args():
    tl = Timeline()
    with tl.span("chunk", cat="engine", index=3) as sp:
        sp.annotate(sweeps=40)
    (meta, ev) = tl.events()
    assert meta["ph"] == "M" and meta["args"]["name"] == threading.current_thread().name
    assert ev["ph"] == "X" and ev["name"] == "chunk"
    assert ev["args"] == {"index": 3, "sweeps": 40}
    assert ev["ts"] >= 0 and ev["dur"] >= 0


def test_span_annotates_exception():
    tl = Timeline()
    with pytest.raises(RuntimeError):
        with tl.span("doomed"):
            raise RuntimeError("boom")
    ev = tl.events()[-1]
    assert ev["args"]["error"] == "RuntimeError"


def test_tracks_get_stable_tids_and_metadata():
    tl = Timeline()
    tl.instant("a", track="alpha")
    tl.instant("b", track="beta")
    tl.instant("c", track="alpha")
    events = tl.events()
    names = {e["tid"]: e["args"]["name"] for e in events if e["ph"] == "M"}
    assert sorted(names.values()) == ["alpha", "beta"]
    a_tid = next(t for t, n in names.items() if n == "alpha")
    assert [e["tid"] for e in events if e["ph"] == "i"] == [
        a_tid, next(t for t, n in names.items() if n == "beta"), a_tid
    ]


def test_flow_events_and_counter():
    tl = Timeline()
    tl.flow_start("job:x", "x", track="intake")
    tl.flow_step("job:x", "x", track="bucket")
    tl.flow_end("job:x", "x", track="bucket", state="done")
    tl.counter("queue", {"depth": 2})
    phs = [e["ph"] for e in tl.events() if e["ph"] not in ("M",)]
    assert phs == ["s", "t", "f", "C"]
    fin = next(e for e in tl.events() if e["ph"] == "f")
    assert fin["bp"] == "e" and fin["id"] == "x"


def test_write_roundtrips_through_validator(tmp_path):
    tl = Timeline()
    with tl.span("compile"):
        pass
    tl.flow_start("j", 1)
    tl.flow_end("j", 1)
    path = tl.write(str(tmp_path / "out.trace.json"))
    with open(path) as f:
        data = json.load(f)
    summary = validate_trace(
        data, require_spans=["compile"], require_balanced_flows=True
    )
    assert summary["n_spans"] == 1
    assert summary["open_flows"] == 0


def test_null_timeline_is_inert_and_allocation_free():
    nt = NullTimeline()
    assert nt.span("a") is nt.span("b") is _NULL_SPAN
    with nt.span("a") as sp:
        assert sp.annotate(x=1) is sp
    nt.instant("x")
    nt.counter("c", {"v": 1})
    nt.flow_start("f", 1)
    assert len(nt) == 0 and nt.events() == []
    with pytest.raises(RuntimeError, match="records nothing"):
        nt.write("/tmp/never.json")


# ---------- trace validator -----------------------------------------------------


def _good_trace():
    return {"traceEvents": [
        {"name": "thread_name", "ph": "M", "pid": 1, "tid": 1,
         "args": {"name": "main"}},
        {"name": "chunk", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": 5.0},
    ]}


def test_validate_trace_accepts_good_and_summarizes():
    s = validate_trace(_good_trace(), require_spans=["chunk"])
    assert s["n_spans"] == 1 and s["tracks"] == ["main"]


@pytest.mark.parametrize("mutate, match", [
    (lambda d: d.pop("traceEvents"), "traceEvents"),
    (lambda d: d["traceEvents"][1].update(ph="Z"), "unknown or missing ph"),
    (lambda d: d["traceEvents"][1].pop("tid"), "tid"),
    (lambda d: d["traceEvents"][1].update(dur=-1), "dur"),
    (lambda d: d["traceEvents"][1].update(ts=-1), "ts"),
    (lambda d: d["traceEvents"][1].update(name=""), "name"),
])
def test_validate_trace_rejects_structural_violations(mutate, match):
    data = _good_trace()
    mutate(data)
    with pytest.raises(TraceError, match=match):
        validate_trace(data)


def test_validate_trace_required_span_and_flow_balance():
    with pytest.raises(TraceError, match="required span 'adapt'"):
        validate_trace(_good_trace(), require_spans=["adapt"])
    data = _good_trace()
    data["traceEvents"].append(
        {"name": "j", "ph": "s", "pid": 1, "tid": 1, "ts": 1.0, "id": "7"}
    )
    assert validate_trace(data)["open_flows"] == 1
    with pytest.raises(TraceError, match="unfinished flows"):
        validate_trace(data, require_balanced_flows=True)


# ---------- zero-overhead-off (the structural contract) -------------------------


def test_obs_off_engine_never_touches_obs_layer(monkeypatch):
    """With ``obs=None`` the host loop must not construct `_EngineObs`,
    record a single event, or touch a single metric — enforced by making
    every obs entry point raise and running the engine anyway."""
    import repro.engine.driver as driver_mod
    import repro.obs.metrics as metrics_mod
    import repro.obs.timeline as timeline_mod

    def bomb(*a, **k):
        raise AssertionError("obs layer touched on the obs-off path")

    monkeypatch.setattr(driver_mod._EngineObs, "__init__", bomb)
    for cls in (timeline_mod.Timeline,):
        for meth in ("span", "complete", "instant", "counter"):
            monkeypatch.setattr(cls, meth, bomb)
    for name in ("counter", "gauge", "histogram"):
        monkeypatch.setattr(metrics_mod.MetricsRegistry, name, bomb)

    eng = _engine()
    assert eng._eobs is None and eng.obs is None
    st = eng.init(jax.random.key(0), TEMPS)
    st, res = eng.run(st, 16)
    assert res.n_sweeps == 16


def test_mega_step_jaxpr_identical_obs_on_and_off():
    """Instrumentation lives in the host loop only: the compiled computation
    must be byte-identical with obs attached."""
    eng_off = _engine()
    eng_on = _engine(obs=Observability.create(timeline=True))
    st_off = eng_off.init(jax.random.key(0), TEMPS)
    st_on = eng_on.init(jax.random.key(0), TEMPS)
    jx = lambda e, s: str(jax.make_jaxpr(e._make_mega(2, s))(
        s.pt, s.stats, s.betas
    ))
    assert jx(eng_off, st_off) == jx(eng_on, st_on)


def test_obs_on_run_bit_equal_to_obs_off():
    eng_off = _engine()
    eng_on = _engine(obs=Observability.create(timeline=True))
    st_off, _ = eng_off.run(eng_off.init(jax.random.key(3), TEMPS), 24)
    st_on, _ = eng_on.run(eng_on.init(jax.random.key(3), TEMPS), 24)
    np.testing.assert_array_equal(
        np.asarray(st_off.pt.states), np.asarray(st_on.pt.states)
    )
    np.testing.assert_array_equal(
        np.asarray(st_off.pt.energy), np.asarray(st_on.pt.energy)
    )
    np.testing.assert_array_equal(
        np.asarray(st_off.pt.rung), np.asarray(st_on.pt.rung)
    )


def test_obs_detach_restores_bare_engine():
    eng = _engine(obs=Observability.create(timeline=False))
    assert isinstance(eng._eobs, _EngineObs)
    eng.obs = None
    assert eng._eobs is None and eng.obs is None


# ---------- obs-on engine instrumentation ---------------------------------------


def test_engine_metrics_and_spans_populated():
    obs = Observability.create(timeline=True)
    eng = _engine(obs=obs)
    st = eng.init(jax.random.key(1), TEMPS)
    eng.run(st, 16)  # 8 intervals = 4 chunks of 2

    snap = obs.metrics.snapshot()
    value = lambda n: snap[n]["samples"][0]["value"]
    assert value("engine_compiles_total") == 1
    assert value("engine_chunks_total") == 4
    assert value("engine_sweeps_total") == 16
    assert snap["engine_chunk_seconds"]["samples"][0]["count"] == 4
    assert value("engine_compile_seconds_total") > 0
    # live per-rung gauges: R-1 pair children, R rung children
    assert len(snap["pt_swap_acceptance"]["samples"]) == R - 1
    assert len(snap["pt_flow_up_fraction"]["samples"]) == R

    names = {e["name"] for e in obs.timeline.events() if e["ph"] == "X"}
    assert {"compile", "device_wait", "chunk"} <= names
    chunk_ev = next(
        e for e in obs.timeline.events()
        if e["ph"] == "X" and e["name"] == "chunk"
    )
    # lattice systems annotate the modeled HBM traffic per chunk launch
    assert chunk_ev["args"]["modeled_hbm_bytes"] > 0


def test_engine_checkpoint_span_and_counter(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    obs = Observability.create(timeline=True)
    eng = _engine(obs=obs)
    st = eng.init(jax.random.key(1), TEMPS)
    eng.run(st, 16, checkpoint=CheckpointManager(str(tmp_path)),
            checkpoint_every_chunks=2)
    snap = obs.metrics.snapshot()
    assert snap["engine_checkpoints_total"]["samples"][0]["value"] == 2
    names = [e["name"] for e in obs.timeline.events() if e["ph"] == "X"]
    assert names.count("checkpoint") == 2


# ---------- ObsCallback through a full Session ----------------------------------


def _spec(**kw):
    from repro.api import (
        EngineSpec, LadderSpec, PhaseSpec, RunSpec, ScheduleSpec, SystemSpec,
    )

    return RunSpec(
        system=SystemSpec("ising", {"length": L}),
        ladder=LadderSpec(kind="geometric", n_replicas=R, t_min=1.5, t_max=3.5),
        engine=EngineSpec(swap_interval=2, chunk_intervals=2),
        schedule=ScheduleSpec(phases=(
            PhaseSpec("burn", 8), PhaseSpec("measure", 8, reset_stats=True),
        )),
        observables=("mag",),
        seed=0,
        **kw,
    )


def test_obs_callback_writes_artifacts_through_session(tmp_path):
    from repro.api import ObsCallback, Session

    trace_path = str(tmp_path / "run.trace.json")
    prom_path = str(tmp_path / "metrics.prom")
    cb = ObsCallback(timeline_path=trace_path, metrics_path=prom_path)
    Session(_spec(), callbacks=[cb]).run()

    with open(trace_path) as f:
        summary = validate_trace(json.load(f), require_spans=[
            "compile", "chunk", "device_wait", "phase:burn", "phase:measure",
        ])
    assert "session" in summary["tracks"]
    text = open(prom_path).read()
    # 2 phases x 8 sweeps = 2 phases x 2 chunks of 2 intervals
    assert "engine_chunks_total 4" in text
    assert "engine_sweeps_total 16" in text


def test_obs_callback_session_result_bit_equal_to_bare_session(tmp_path):
    from repro.api import ObsCallback, Session

    bare = Session(_spec()).run()
    cb = ObsCallback(timeline_path=str(tmp_path / "t.json"),
                     metrics_path=str(tmp_path / "m.prom"))
    instrumented = Session(_spec(), callbacks=[cb]).run()
    np.testing.assert_array_equal(
        bare.final_energies(), instrumented.final_energies()
    )


# ---------- serve scheduler telemetry -------------------------------------------


def _serve_spec(seed=0):
    from repro.api import (
        EngineSpec, LadderSpec, PhaseSpec, RunSpec, ScheduleSpec, SystemSpec,
    )

    return RunSpec(
        system=SystemSpec("ising", {"length": 4}),
        ladder=LadderSpec(kind="geometric", n_replicas=4, t_min=1.5, t_max=3.5),
        engine=EngineSpec(swap_interval=2, chunk_intervals=2),
        schedule=ScheduleSpec(phases=(PhaseSpec("burn", 8),)),
        observables=("mag",),
        seed=seed,
    )


def test_scheduler_metrics_exposed():
    from repro.serve import Scheduler

    obs = Observability.create(timeline=True)
    sched = Scheduler(obs=obs)
    jobs = [sched.submit(_serve_spec(seed=s)) for s in range(3)]
    sched.run_until_idle()
    for job in jobs:
        job.result(timeout=30)

    snap = sched.metrics()
    value = lambda n: snap[n]["samples"][0]["value"]
    assert value("serve_queue_depth") == 0
    assert value("serve_quanta_total") >= 1
    # 3 same-shaped jobs amortize exactly one compile
    assert value("serve_jobs_packed_per_compile") == 3.0
    assert snap["serve_quantum_seconds"]["samples"][0]["count"] >= 1
    assert snap["serve_time_in_queue_seconds"]["samples"][0]["count"] == 3
    assert len(snap["serve_job_sweeps"]["samples"]) == 3
    # the job flows opened at submit are all closed by completion
    summary = validate_trace(obs.timeline.to_dict(), require_balanced_flows=True)
    assert summary["open_flows"] == 0


def test_scheduler_metrics_without_obs_still_available():
    from repro.serve import Scheduler

    sched = Scheduler()  # internal registry, NULL timeline
    job = sched.submit(_serve_spec())
    sched.run_until_idle()
    job.result(timeout=30)
    assert "serve_quanta_total" in sched.metrics()


def test_scheduler_condvar_shutdown_is_prompt():
    """shutdown(wait=True) must block on the idle condition (not a poll
    loop) and return promptly once the queue drains."""
    from repro.serve import Scheduler

    sched = Scheduler()
    sched.start()
    job = sched.submit(_serve_spec())
    job.result(timeout=60)
    t0 = time.perf_counter()
    sched.shutdown(wait=True)
    assert time.perf_counter() - t0 < 5.0
    assert sched._thread is None or not sched._thread.is_alive()
    assert sched.metrics()["serve_wakeup_latency_seconds"]["samples"][0]["count"] >= 1


def test_scheduler_periodic_metrics_file(tmp_path):
    from repro.serve import Scheduler

    path = str(tmp_path / "metrics.prom")
    sched = Scheduler(metrics_every=1, metrics_path=path)
    job = sched.submit(_serve_spec())
    sched.run_until_idle()
    job.result(timeout=30)
    assert "serve_quanta_total" in open(path).read()


# ---------- diagnostics fallback warning ----------------------------------------


def test_legacy_trace_fallback_warns():
    t, r = 6, 4
    legacy = {
        "swap_accept": np.ones((t, r)),
        "swap_prob": np.full((t, r), 0.5),
    }
    with pytest.warns(RuntimeWarning, match="swap_attempt"):
        rate = diagnostics.swap_acceptance_rate(legacy)
    assert rate.shape == (r - 1,)


def test_engine_trace_with_attempts_does_not_warn(recwarn):
    t, r = 6, 4
    trace = {
        "swap_accept": np.ones((t, r)),
        "swap_attempt": np.ones((t, r)),
        "swap_prob": np.full((t, r), 0.5),
    }
    rate = diagnostics.swap_acceptance_rate(trace)
    assert not [w for w in recwarn.list if issubclass(w.category, RuntimeWarning)]
    np.testing.assert_allclose(rate, 1.0)


def test_legacy_fallback_rate_matches_nonzero_prob_counting():
    t, r = 4, 3
    prob = np.zeros((t, r))
    prob[:2, :] = 0.7  # only two attempts visible per rung
    acc = np.zeros((t, r))
    acc[0, :] = 1.0
    with pytest.warns(RuntimeWarning):
        rate = diagnostics.swap_acceptance_rate(
            {"swap_accept": acc, "swap_prob": prob}
        )
    np.testing.assert_allclose(rate, 0.5)


# ---------- the <5% wall-clock budget (benchmark-grade, slow) -------------------


@pytest.mark.slow
def test_obs_on_overhead_under_budget():
    obs_overhead = pytest.importorskip("benchmarks.obs_overhead")
    m = obs_overhead.measure(length=32, sweeps=256, repeats=9)
    assert m["ratio"] <= 1.05, f"obs-on overhead {m['ratio']:.3f} > 1.05"
    assert m["n_compiles_off"] == m["n_compiles_on"] == 1
