"""Substrate tests: checkpoint manager (fault tolerance), data pipeline,
optimizer, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import SyntheticLM
from repro.train import grad_compress as gc
from repro.train import optimizer as opt_lib


# --------------------------- checkpointing -----------------------------------
def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8)), "b": {"c": jnp.arange(5)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t, meta={"note": "x"})
    got, meta = mgr.restore_latest(jax.tree_util.tree_map(np.zeros_like, t))
    assert meta["step"] == 10 and meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]  # retention dropped 1, 2
    _, meta = mgr.restore_latest(_tree())
    assert meta["step"] == 4


def test_checkpoint_corruption_fallback(tmp_path):
    """Fault tolerance: a truncated newest checkpoint falls back to older."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    # corrupt step 2's array file
    bad = os.path.join(str(tmp_path), "step_0000000002", "arrays_p0.npz")
    with open(bad, "wb") as f:
        f.write(b"not a zip")
    got, meta = mgr.restore_latest(_tree())
    assert meta["step"] == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, _tree(7), blocking=False)
    mgr.wait()
    assert mgr.steps() == [7]


def test_checkpoint_registered_dataclass_roundtrip(tmp_path):
    from repro.train.train_step import TrainState

    params = {"w": jnp.ones((3, 3))}
    st = TrainState(params=params, opt=opt_lib.init(params), step=jnp.int32(5))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, st)
    got, _ = mgr.restore(5, jax.tree_util.tree_map(np.zeros_like, st))
    assert int(got.step) == 5
    np.testing.assert_array_equal(np.asarray(got.params["w"]), np.ones((3, 3)))


# --------------------------- data pipeline ------------------------------------
def test_data_deterministic_restart():
    ds = SyntheticLM(vocab=128, seq_len=16, global_batch=8)
    b1 = ds.batch(5)
    b2 = ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch(6)["tokens"], b1["tokens"])


def test_data_host_sharding_partitions_global_batch():
    full = SyntheticLM(vocab=128, seq_len=8, global_batch=8)
    shards = [
        SyntheticLM(vocab=128, seq_len=8, global_batch=8, host_index=i, host_count=2)
        for i in range(2)
    ]
    assert all(s.local_batch == 4 for s in shards)
    # each host's stream is independent of the other's existence
    a0 = shards[0].batch(3)["tokens"]
    a1 = shards[1].batch(3)["tokens"]
    assert a0.shape == (4, 8) and a1.shape == (4, 8)
    assert not np.array_equal(a0, a1)


def test_data_labels_are_shifted_tokens():
    ds = SyntheticLM(vocab=64, seq_len=12, global_batch=2)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# --------------------------- optimizer -----------------------------------------
def test_adamw_converges_quadratic():
    cfg = opt_lib.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt_lib.init(params)

    def loss(p):
        return jnp.sum((p["x"] - jnp.asarray([1.0, 2.0])) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt_lib.apply(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["x"]), [1.0, 2.0], atol=1e-2)


def test_adamw_grad_clip_and_schedule():
    cfg = opt_lib.AdamWConfig(lr=1e-2, grad_clip=1.0, warmup_steps=10, total_steps=100)
    params = {"x": jnp.zeros((3,))}
    state = opt_lib.init(params)
    g = {"x": jnp.full((3,), 100.0)}
    params, state, m = opt_lib.apply(cfg, params, g, state)
    assert float(m["grad_norm"]) > 100.0
    assert float(m["lr"]) == pytest.approx(1e-2 / 10, rel=1e-4)  # warmup step 1
    # clipped step magnitude bounded by lr * (1 + eps-ish)
    assert np.all(np.abs(np.asarray(params["x"])) < 2e-2)


# --------------------------- grad compression -----------------------------------
def test_quantize_roundtrip_error_bounded():
    x = np.random.default_rng(0).normal(size=(256,)).astype(np.float32)
    q, s = gc.quantize_int8(jnp.asarray(x))
    err = np.asarray(gc.dequantize(q, s)) - x
    assert np.abs(err).max() <= float(s) / 2 + 1e-7


def test_error_feedback_reduces_bias():
    """With feedback, the *accumulated* quantization error stays bounded
    (doesn't grow with steps) and the running sum converges to the truth."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 1e-3)
    err = jnp.zeros_like(g_true)
    acc_q = np.zeros_like(np.asarray(g_true))
    for step in range(50):
        q, s, err = gc.compress_with_feedback(g_true, err)
        acc_q += np.asarray(gc.dequantize(q, s))
    # mean dequantized gradient ≈ true gradient (error feedback kills bias)
    np.testing.assert_allclose(acc_q / 50, np.asarray(g_true), atol=2e-5)


def test_compressed_psum_matches_full_precision():
    import jax.experimental.shard_map as shmap
    from jax.sharding import Mesh, PartitionSpec as P

    n = jax.device_count()  # 1 on CPU: still exercises the code path
    mesh = Mesh(np.array(jax.devices()), ("d",))
    g = jnp.linspace(-1, 1, 32)
    err = jnp.zeros_like(g)

    def f(g, err):
        return gc.compressed_psum(g, err, "d")

    out, new_err = shmap.shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())
    )(g, err)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g) * n, atol=2e-2)
