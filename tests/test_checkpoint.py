"""Checkpoint-manager hardening for concurrent multi-job use (§Serve).

The serve scheduler runs one `CheckpointManager` per bucket, potentially
many in one process.  Pinned here: unique staging dirs + the per-directory
swap lock mean two managers never clobber each other's step dirs — even
aimed at the *same* directory and step from racing threads — and `child`
gives each job a disjoint step namespace.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree(value: float):
    return {"x": np.full((4,), value, np.float32),
            "y": np.arange(3, dtype=np.int32)}


def test_child_managers_use_disjoint_subdirectories(tmp_path):
    root = CheckpointManager(str(tmp_path), keep=5)
    a = root.child("job-a")
    b = root.child("job-b")
    a.save(3, tree(1.0))
    b.save(3, tree(2.0))
    assert a.dir == os.path.join(root.dir, "job-a")
    assert sorted(os.listdir(root.dir)) == ["job-a", "job-b"]
    ra, _ = a.restore_latest(tree(0.0))
    rb, _ = b.restore_latest(tree(0.0))
    assert np.all(ra["x"] == 1.0) and np.all(rb["x"] == 2.0)
    assert b.keep == root.keep


def test_concurrent_managers_same_directory_never_clobber(tmp_path):
    """Two managers hammering the same dir + step from threads: every step
    dir left behind is whole (staged elsewhere, swapped under the lock)."""
    managers = [CheckpointManager(str(tmp_path), keep=0) for _ in range(2)]
    steps = list(range(1, 9))
    errors = []

    def worker(mgr, value):
        try:
            for s in steps:
                mgr.save(s, tree(value), meta={"writer": value})
        except Exception as e:  # pragma: no cover - the regression itself
            errors.append(e)

    threads = [
        threading.Thread(target=worker, args=(m, float(i)))
        for i, m in enumerate(managers)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    mgr = managers[0]
    assert mgr.steps() == steps  # all steps present, none half-written
    for s in steps:
        restored, meta = mgr.restore(s, tree(0.0))
        writer = meta["writer"]
        assert writer in (0.0, 1.0)
        # whichever writer won the swap, its payload is internally consistent
        assert np.all(restored["x"] == writer)
    # no staging leftovers once both writers are done
    assert not [n for n in os.listdir(mgr.dir) if n.endswith(".tmp")]


def test_concurrent_async_saves_across_children(tmp_path):
    root = CheckpointManager(str(tmp_path))
    children = [root.child(f"job-{i}") for i in range(4)]
    for step in (1, 2):
        for i, mgr in enumerate(children):
            mgr.save(step, tree(10.0 * i + step), blocking=False)
    for i, mgr in enumerate(children):
        mgr.wait()
        restored, _ = mgr.restore_latest(tree(0.0))
        assert np.all(restored["x"] == 10.0 * i + 2)


def test_staging_dirs_are_unique_and_filtered_from_steps(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr._staging_dir(5) != mgr._staging_dir(5)  # per-save token
    assert mgr._staging_dir(5).endswith(".tmp")
    # a crashed save's leftover staging dir is invisible to steps()
    os.makedirs(os.path.join(str(tmp_path), "step_0000000007.123-0.tmp"))
    mgr.save(1, tree(1.0))
    assert mgr.steps() == [1]


def test_save_spec_concurrent_writers_leave_valid_json(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    payloads = [json.dumps({"writer": i, "pad": "x" * 4096}) for i in range(2)]
    threads = [
        threading.Thread(target=lambda p=p: [mgr.save_spec(p) for _ in range(20)])
        for p in payloads
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    loaded = mgr.load_spec()  # atomic replace: always one whole payload
    assert loaded["writer"] in (0, 1) and len(loaded["pad"]) == 4096
