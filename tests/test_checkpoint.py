"""Checkpoint-manager hardening for concurrent multi-job use (§Serve).

The serve scheduler runs one `CheckpointManager` per bucket, potentially
many in one process.  Pinned here: unique staging dirs + the per-directory
swap lock mean two managers never clobber each other's step dirs — even
aimed at the *same* directory and step from racing threads — and `child`
gives each job a disjoint step namespace.
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree(value: float):
    return {"x": np.full((4,), value, np.float32),
            "y": np.arange(3, dtype=np.int32)}


def test_child_managers_use_disjoint_subdirectories(tmp_path):
    root = CheckpointManager(str(tmp_path), keep=5)
    a = root.child("job-a")
    b = root.child("job-b")
    a.save(3, tree(1.0))
    b.save(3, tree(2.0))
    assert a.dir == os.path.join(root.dir, "job-a")
    assert sorted(os.listdir(root.dir)) == ["job-a", "job-b"]
    ra, _ = a.restore_latest(tree(0.0))
    rb, _ = b.restore_latest(tree(0.0))
    assert np.all(ra["x"] == 1.0) and np.all(rb["x"] == 2.0)
    assert b.keep == root.keep


def test_concurrent_managers_same_directory_never_clobber(tmp_path):
    """Two managers hammering the same dir + step from threads: every step
    dir left behind is whole (staged elsewhere, swapped under the lock)."""
    managers = [CheckpointManager(str(tmp_path), keep=0) for _ in range(2)]
    steps = list(range(1, 9))
    errors = []

    def worker(mgr, value):
        try:
            for s in steps:
                mgr.save(s, tree(value), meta={"writer": value})
        except Exception as e:  # pragma: no cover - the regression itself
            errors.append(e)

    threads = [
        threading.Thread(target=worker, args=(m, float(i)))
        for i, m in enumerate(managers)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    mgr = managers[0]
    assert mgr.steps() == steps  # all steps present, none half-written
    for s in steps:
        restored, meta = mgr.restore(s, tree(0.0))
        writer = meta["writer"]
        assert writer in (0.0, 1.0)
        # whichever writer won the swap, its payload is internally consistent
        assert np.all(restored["x"] == writer)
    # no staging leftovers once both writers are done
    assert not [n for n in os.listdir(mgr.dir) if n.endswith(".tmp")]


def test_concurrent_async_saves_across_children(tmp_path):
    root = CheckpointManager(str(tmp_path))
    children = [root.child(f"job-{i}") for i in range(4)]
    for step in (1, 2):
        for i, mgr in enumerate(children):
            mgr.save(step, tree(10.0 * i + step), blocking=False)
    for i, mgr in enumerate(children):
        mgr.wait()
        restored, _ = mgr.restore_latest(tree(0.0))
        assert np.all(restored["x"] == 10.0 * i + 2)


def test_staging_dirs_are_unique_and_filtered_from_steps(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr._staging_dir(5) != mgr._staging_dir(5)  # per-save token
    assert mgr._staging_dir(5).endswith(".tmp")
    # a crashed save's leftover staging dir is invisible to steps()
    os.makedirs(os.path.join(str(tmp_path), "step_0000000007.123-0.tmp"))
    mgr.save(1, tree(1.0))
    assert mgr.steps() == [1]


def test_save_spec_concurrent_writers_leave_valid_json(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    payloads = [json.dumps({"writer": i, "pad": "x" * 4096}) for i in range(2)]
    threads = [
        threading.Thread(target=lambda p=p: [mgr.save_spec(p) for _ in range(20)])
        for p in payloads
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    loaded = mgr.load_spec()  # atomic replace: always one whole payload
    assert loaded["writer"] in (0, 1) and len(loaded["pad"]) == 4096


# -- integrity + fault injection (§Resilience) ---------------------------------


def test_torn_write_detected_and_fallback_one_generation(tmp_path):
    from repro.resilience import Fault, FaultPlan

    # keep=0 disables GC so the torn generation stays on disk and the
    # fallback has to happen at restore time
    plan = FaultPlan([Fault("checkpoint.write.torn", at=(2,))])
    mgr = CheckpointManager(str(tmp_path), keep=0, faults=plan)
    for s in (1, 2, 3):
        mgr.save(s, tree(float(s)))
    assert plan.fired() == 1
    assert mgr.steps() == [1, 2, 3]
    assert mgr.readable_steps() == [1, 2]
    restored, _ = mgr.restore_latest(tree(0.0))
    assert np.all(restored["x"] == 2.0)
    assert mgr.last_restore_fallback == 1


def test_corrupt_write_fails_sha256_and_falls_back(tmp_path):
    from repro.checkpoint.manager import CheckpointCorrupt
    from repro.resilience import Fault, FaultPlan

    plan = FaultPlan([Fault("checkpoint.write.corrupt", at=(1,))])
    mgr = CheckpointManager(str(tmp_path), keep=5, faults=plan)
    mgr.save(1, tree(1.0))
    mgr.save(2, tree(2.0))
    # size matches, so only the digest catches the flipped byte
    assert mgr.step_readable(2)
    with pytest.raises(CheckpointCorrupt):
        mgr.restore(2, tree(0.0))
    restored, _ = mgr.restore_latest(tree(0.0))
    assert np.all(restored["x"] == 1.0)
    assert mgr.last_restore_fallback == 1


def test_kill_during_write_both_sides_of_rename(tmp_path):
    from repro.resilience import Fault, FaultPlan, InjectedCrash

    # occurrence counters advance only when a site is reached: the save
    # killed *before* its rename never reaches the after-rename site, so
    # both faults arm at their own site's occurrence 1.
    plan = FaultPlan([
        Fault("checkpoint.write.crash_before_rename", at=(1,)),
        Fault("checkpoint.write.crash_after_rename", at=(1,)),
    ])
    mgr = CheckpointManager(str(tmp_path), keep=5, faults=plan)
    mgr.save(1, tree(1.0))
    with pytest.raises(InjectedCrash, match="before renaming"):
        mgr.save(2, tree(2.0))
    # the step dir never appeared; only its staging leftover did
    assert mgr.steps() == [1]
    assert [n for n in os.listdir(mgr.dir) if n.endswith(".tmp")]
    with pytest.raises(InjectedCrash, match="after renaming"):
        mgr.save(3, tree(3.0))
    # crashed after the swap: the generation landed whole and restorable
    assert mgr.steps() == [1, 3]
    restored, _ = mgr.restore_latest(tree(0.0))
    assert np.all(restored["x"] == 3.0)
    assert mgr.last_restore_fallback == 0


def test_gc_never_prunes_last_intact_generation(tmp_path):
    from repro.resilience import Fault, FaultPlan

    # every save after the first is torn; keep=2 must still protect the
    # intact generation instead of counting the readable-in-name-only ones
    plan = FaultPlan([Fault("checkpoint.write.torn", at=tuple(range(1, 16)))])
    mgr = CheckpointManager(str(tmp_path), keep=2, faults=plan)
    for s in range(1, 7):
        mgr.save(s, tree(float(s)))
    # GC pruned every torn generation as garbage but kept the intact one,
    # even though five raw step numbers landed after it
    assert mgr.steps() == [1]
    restored, _ = mgr.restore_latest(tree(0.0))
    assert np.all(restored["x"] == 1.0)
    assert mgr.last_restore_fallback == 0


def test_all_generations_corrupt_raises_instead_of_garbage(tmp_path):
    from repro.resilience import Fault, FaultPlan

    plan = FaultPlan([Fault("checkpoint.write.torn", at=(0, 1))])
    mgr = CheckpointManager(str(tmp_path), keep=5, faults=plan)
    mgr.save(1, tree(1.0))
    mgr.save(2, tree(2.0))
    with pytest.raises(RuntimeError, match="no restorable checkpoint"):
        mgr.restore_latest(tree(0.0))


def test_integrity_meta_written_and_honest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, tree(4.0), meta={"writer": 9})
    _, meta = mgr.restore(4, tree(0.0))
    integ = meta["integrity"][mgr._arrays_name()]
    assert integ["sha256"] and integ["bytes"] > 0
    assert meta["writer"] == 9
    mgr._verify(4)  # digest recomputed from disk matches
