"""Ising-system correctness: energies, flips, detailed balance vs exact
Boltzmann weights on an enumerable lattice."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising, ladder, pt


def brute_force_energy(spins, j, b):
    """O(L^2) direct double-count-free energy (paper Eq. 3, PBC)."""
    L = spins.shape[0]
    e = 0.0
    for r in range(L):
        for c in range(L):
            s = float(spins[r, c])
            e += b * s
            e -= j * s * float(spins[r, (c + 1) % L])
            e -= j * s * float(spins[(r + 1) % L, c])
    return e


@pytest.mark.parametrize("L,j,b", [(3, 1.0, 0.0), (4, 1.0, 0.5), (5, -1.0, -0.2)])
def test_lattice_energy_matches_brute_force(L, j, b, rng):
    spins = rng.choice(np.array([-1, 1], dtype=np.int8), size=(L, L))
    got = float(ising.lattice_energy(jnp.asarray(spins), j, b))
    want = brute_force_energy(spins, j, b)
    assert abs(got - want) < 1e-4 * max(1.0, abs(want))


def test_antiferromagnet_ground_state_energy():
    # J < 0 favours the checkerboard; on an even lattice that is the minimum.
    L = 4
    ii, jj = np.indices((L, L))
    stag = np.where((ii + jj) % 2 == 0, 1, -1).astype(np.int8)
    e = float(ising.lattice_energy(jnp.asarray(stag), -1.0, 0.0))
    assert e == -2 * L * L  # 2L^2 bonds, each contributing -|J|


def test_delta_e_consistency_checkerboard(rng):
    """Incremental delta-E from a sweep equals recomputed energy difference."""
    from repro.kernels import ref

    spins = rng.choice(np.array([-1, 1], dtype=np.int8), size=(6, 8, 8))
    u = rng.random((6, 2, 8, 8), dtype=np.float32)
    betas = np.linspace(0.3, 1.2, 6).astype(np.float32)
    j, b = 1.0, 0.25
    new, de, _ = ref.ising_sweep(
        jnp.asarray(spins), jnp.asarray(u), jnp.asarray(betas), j=j, b=b
    )
    e0 = ising.lattice_energy(jnp.asarray(spins), j, b)
    e1 = ising.lattice_energy(np.asarray(new), j, b)
    np.testing.assert_allclose(np.asarray(e1 - e0), np.asarray(de), rtol=1e-5, atol=1e-3)


def test_single_flip_delta_e(rng):
    system = ising.IsingSystem(length=8, j=1.0, b=0.1, update="single_flip", flips_per_step=32)
    key = jax.random.key(3)
    spins = system.init_state(key)
    e0 = system.energy(spins)
    new, de, nacc = system.mcmc_step(jax.random.key(7), spins, jnp.float32(0.7))
    e1 = system.energy(new)
    np.testing.assert_allclose(float(e1 - e0), float(de), rtol=1e-5, atol=1e-3)
    assert 0 <= int(nacc) <= 32


def _exact_boltzmann_2x2(beta, j=1.0, b=0.0):
    """Exact distribution over all 16 states of a 2x2 PBC lattice."""
    states, probs = [], []
    for bits in itertools.product([-1, 1], repeat=4):
        s = np.array(bits, dtype=np.int8).reshape(2, 2)
        e = brute_force_energy(s, j, b)
        states.append(s)
        probs.append(np.exp(-beta * e))
    probs = np.array(probs)
    return states, probs / probs.sum()


@pytest.mark.parametrize(
    "update,rule",
    [
        ("checkerboard", "glauber"),
        ("single_flip", "metropolis"),
        ("single_flip", "glauber"),
    ],
)
def test_detailed_balance_2x2(update, rule):
    """Empirical MH distribution matches the exact Boltzmann law.

    This is the fundamental MCMC correctness property (paper §2.1): run many
    parallel chains on the 16-state 2x2 lattice and compare state frequencies
    with the exact probabilities.

    NOTE: checkerboard+metropolis is deliberately excluded — simultaneous
    Metropolis flips are deterministic at dE<=0 and the 2x2 torus then has an
    absorbing stripe 2-cycle (a genuine property of that update, not a bug;
    see `repro.kernels.ref.accept_prob`).  Glauber acceptance restores
    ergodicity; on physical lattice sizes (L>=8, test below) the metropolis
    checkerboard reproduces the known phase diagram.
    """
    beta = 0.45
    n_chains, n_sweeps = 192, 400
    system = ising.IsingSystem(
        length=2, update=update, flips_per_step=4, accept_rule=rule
    )
    keys = jax.random.split(jax.random.key(0), n_chains)
    spins = jax.vmap(system.init_state)(keys)

    def chain_step(carry, t):
        spins, key = carry
        key, sub = jax.random.split(key)
        ks = jax.random.split(sub, n_chains)
        betas = jnp.full((n_chains,), beta)
        new, _, _ = system.batched_mcmc_step(ks, spins, betas)
        return (new, key), new

    (_, _), samples = jax.lax.scan(
        chain_step, (spins, jax.random.key(1)), jnp.arange(n_sweeps)
    )
    # discard burn-in, flatten
    samples = np.asarray(samples[100:]).reshape(-1, 2, 2)
    # state index: 4-bit code
    code = (
        (samples[:, 0, 0] > 0) * 8
        + (samples[:, 0, 1] > 0) * 4
        + (samples[:, 1, 0] > 0) * 2
        + (samples[:, 1, 1] > 0) * 1
    )
    emp = np.bincount(code, minlength=16) / len(code)
    states, exact = _exact_boltzmann_2x2(beta)
    codes = [
        int((s[0, 0] > 0) * 8 + (s[0, 1] > 0) * 4 + (s[1, 0] > 0) * 2 + (s[1, 1] > 0))
        for s in states
    ]
    exact_by_code = np.zeros(16)
    for c, p in zip(codes, exact):
        exact_by_code[c] = p
    tv = 0.5 * np.abs(emp - exact_by_code).sum()
    assert tv < 0.03, f"total variation {tv} vs exact Boltzmann"


def test_phase_transition_with_pt():
    """Paper Fig. 3a: ferromagnetic order below T_c≈2.27, disorder above."""
    R, L = 12, 12
    system = ising.IsingSystem(length=L)
    temps = tuple(float(t) for t in ladder.linear_ladder(R, 1.0, 4.0))
    cfg = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=10, swap_mode="temp")
    st = pt.init(system, cfg, jax.random.key(5))
    obs = {"absmag": lambda s: jnp.abs(ising.magnetization(s))}
    st, trace = pt.run(system, cfg, st, 2000, observables=obs)
    from repro.core import diagnostics

    m = diagnostics.grand_mean_by_rung(trace, "absmag")
    assert m[0] > 0.8, m
    assert m[-1] < 0.4, m
    assert m[0] > m[-1] + 0.4
