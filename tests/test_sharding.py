"""Sharding-policy unit tests (pure metadata — no devices needed beyond 1)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import sharding as shard_lib


class FakeMesh:
    """Duck-typed mesh for spec selection (shape dict + axis names)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def _spec(path, shape, fsdp=False):
    return shard_lib._leaf_spec(MESH, path, shape, fsdp=fsdp)


def test_attention_weights_tp():
    assert _spec("['wq']", (5120, 64, 128)) == P(None, "model", None)
    assert _spec("['wo']", (64, 128, 5120)) == P("model", None, None)


def test_kv_heads_fallback_when_indivisible():
    # kv=8 heads cannot shard over model=16 -> falls back to sharding D
    assert _spec("['wk']", (5120, 8, 128)) == P("model", None, None)
    # MQA kv=1, d_model also indivisible -> fully replicated
    assert _spec("['wk']", (2048, 1, 256)) == P("model", None, None)


def test_moe_expert_parallel_vs_tp_fallback():
    # qwen3-moe: 128 experts / 16 = EP
    assert _spec("['w_up']", (128, 4096, 1536)) == P("model", None, None)
    # mixtral: 8 experts < 16 -> intra-expert TP on F
    assert _spec("['w_up']", (8, 6144, 16384)) == P(None, None, "model")
    assert _spec("['w_down']", (8, 16384, 6144)) == P(None, "model", None)


def test_stacked_groups_get_leading_none():
    s = _spec("['groups']['0_attn']['attn']['wq']", (64, 5120, 64, 128))
    assert s == P(None, None, "model", None)


def test_ffn_2d_rules():
    assert _spec("['ffn']['w_up']", (4096, 14336)) == P(None, "model")
    assert _spec("['ffn']['w_down']", (14336, 4096)) == P("model", None)


def test_embed_vocab_sharded():
    assert _spec("['embed']", (151936, 5120)) == P("model", None)
    assert _spec("['unembed']", (5120, 151936)) == P(None, "model")


def test_fsdp_adds_data_axis():
    # wq (D,H,hd): model on H; fsdp shards D (largest free, divisible) on data
    assert _spec("['wq']", (5120, 64, 128), fsdp=True) == P("data", "model", None)
    # replicated fallback still gets a data shard on the largest dim
    assert _spec("['router']", (4096, 128), fsdp=True) == P("data", None)


def test_fsdp_skips_indivisible():
    s = _spec("['wq']", (100, 4, 30), fsdp=True)
    assert s == P(None, None, None)  # nothing divides by 16
    # but a divisible smaller dim is still picked up
    s = _spec("['wq']", (100, 4, 32), fsdp=True)
    assert s == P(None, None, "data")


def test_real_mesh_end_to_end_single_device():
    """With the real 1-device CPU mesh every rule must degrade gracefully."""
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    shapes = {
        "embed": jax.ShapeDtypeStruct((512, 64), jax.numpy.float32),
        "groups": {"0_attn": {"attn": {"wq": jax.ShapeDtypeStruct((2, 64, 4, 16), jax.numpy.float32)}}},
    }
    tree = shard_lib.param_shardings(mesh, shapes)
    specs = jax.tree_util.tree_leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))
    assert all(hasattr(s, "spec") for s in specs)
