"""Child process for tests/test_distributed.py: runs on 8 simulated devices.

Tier-1 tests run on the single real CPU device (tests/conftest.py), and
``--xla_force_host_platform_device_count`` must be set before jax is
imported — so everything multi-device happens here, in a subprocess with
the flag in its environment.  Usage:

    python tests/_mesh_child.py OUTDIR

Writes ``OUTDIR/mesh8.npz`` with the final state of each scenario (the
parent re-runs them on one device and asserts bit-equality) and a
checkpoint under ``OUTDIR/ckpt`` saved mid-run on the 8-device mesh (the
parent resumes it on one device).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint.manager import CheckpointManager
from repro.core import ising, ladder
from repro.core.distributed import MeshSpec
from repro.engine import Engine, EngineConfig

R, L = 8, 8
SWEEPS = 60
CKPT_SWEEPS = 40


def _engine(mesh, **sys_kw):
    system = ising.IsingSystem(length=L, **sys_kw)
    cfg = EngineConfig(
        n_replicas=R, swap_interval=5, chunk_intervals=2, mesh=mesh
    )
    eng = Engine(system, cfg)
    state = eng.init(jax.random.key(21), np.asarray(ladder.linear_ladder(R, 1.0, 3.5)))
    return eng, state


def main(outdir: str) -> int:
    assert jax.device_count() >= 8, (
        f"child needs 8 simulated devices, got {jax.device_count()}"
    )
    out = {}
    mesh = MeshSpec(ensemble=1, replica=8)

    # DEO, per-sweep path, sharded over all 8 devices
    eng, st = _engine(mesh)
    st, _ = eng.run(st, SWEEPS)
    out["deo_energy"] = np.asarray(st.pt.energy)
    out["deo_rung"] = np.asarray(st.pt.rung)
    out["deo_states"] = np.asarray(st.pt.states)

    # interval-fused kernel path (in-kernel counter PRNG + replica offset)
    eng, st = _engine(mesh, use_fused=True, use_pallas=True)
    st, _ = eng.run(st, SWEEPS)
    out["fused_energy"] = np.asarray(st.pt.energy)
    out["fused_states"] = np.asarray(st.pt.states)

    # whole-round fused path (sharded analogue: per-shard fused sweeps with
    # replica_offset + device-resident counter-stream exchange); r_local=1
    # at r_blk=8 also exercises pad > R_local with a nonzero offset, packed
    eng, st = _engine(
        mesh, use_fused=True, use_pallas=True, use_fused_round=True,
        pack_bits=True,
    )
    st, _ = eng.run(st, SWEEPS)
    out["round_energy"] = np.asarray(st.pt.energy)
    out["round_rung"] = np.asarray(st.pt.rung)
    out["round_states"] = np.asarray(st.pt.states)

    # capacity: fused-kernel VMEM working set > 16 MB on one chip, runs
    # sharded (the parent checks the model numbers; here it must execute)
    big = ising.IsingSystem(length=128)
    cfg = EngineConfig(
        n_replicas=64, swap_interval=5, chunk_intervals=2, mesh=mesh
    )
    eng_big = Engine(big, cfg)
    st_big = eng_big.init(
        jax.random.key(22), np.asarray(ladder.linear_ladder(64, 1.0, 3.5))
    )
    st_big, _ = eng_big.run(st_big, 10)
    out["capacity_energy"] = np.asarray(st_big.pt.energy)
    out["capacity_t"] = np.asarray(st_big.pt.t)

    # checkpoint saved mid-run on the 8-device mesh
    mgr = CheckpointManager(os.path.join(outdir, "ckpt"), keep=2)
    eng, st = _engine(mesh)
    st, _ = eng.run(st, CKPT_SWEEPS, checkpoint=mgr, checkpoint_every_chunks=1)

    np.savez(os.path.join(outdir, "mesh8.npz"), **out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1]))
