"""Pallas-kernel vs oracle sweeps (shapes / dtypes / block sizes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ising_sweep as isk
from repro.kernels import ops, potts_sweep as psk, ref


def _rand_ising(key, r, l):
    k1, k2, k3 = jax.random.split(key, 3)
    spins = jnp.where(jax.random.uniform(k1, (r, l, l)) < 0.5, 1, -1).astype(jnp.int8)
    u = jax.random.uniform(k2, (r, 2, l, l), jnp.float32)
    betas = jax.random.uniform(k3, (r,), minval=0.1, maxval=1.5)
    return spins, u, betas


@pytest.mark.parametrize("r,l,r_blk", [
    (1, 4, 1), (2, 8, 2), (8, 16, 4), (8, 16, 8), (5, 12, 2),  # pad path
    (16, 30, 8),   # odd (non-128-aligned) lattice like the paper's 300
    (3, 7, 4),     # odd lattice side AND padded replicas
])
@pytest.mark.parametrize("jb", [(1.0, 0.0), (1.0, 0.4), (-0.7, -0.2)])
def test_ising_kernel_matches_oracle(r, l, r_blk, jb):
    j, b = jb
    spins, u, betas = _rand_ising(jax.random.key(r * 100 + l), r, l)
    got = ops.ising_sweep(spins, u, betas, j=j, b=b, r_blk=r_blk, use_pallas=True)
    want = ref.ising_sweep(spins, u, betas, j=j, b=b)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_ising_kernel_block_size_invariance():
    """Fig-6 analogue invariant: the tile size must not change the result."""
    spins, u, betas = _rand_ising(jax.random.key(0), 16, 10)
    outs = [
        ops.ising_sweep(spins, u, betas, j=1.0, b=0.0, r_blk=rb, use_pallas=True)[0]
        for rb in (1, 2, 4, 8, 16)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


def test_ising_vmem_model_monotonic():
    assert isk.vmem_working_set_bytes(8, 300) > isk.vmem_working_set_bytes(4, 300)
    assert isk.vmem_working_set_bytes(8, 300) < 16 * 2**20  # fits v5e VMEM


# ---------- replica-padding path regression (R not a multiple of r_blk) ---------
@pytest.mark.parametrize("r", [1, 2, 3, 5, 7, 9, 11, 15, 17])
def test_ising_padding_path_bit_equal(r):
    """ops.ising_sweep pads R up to r_blk=8 with beta=0 junk replicas; every
    non-multiple R must still be BIT-equal to the unpadded oracle."""
    spins, u, betas = _rand_ising(jax.random.key(1000 + r), r, 6)
    got = ops.ising_sweep(spins, u, betas, j=1.0, b=0.1, r_blk=8, use_pallas=True)
    want = ref.ising_sweep(spins, u, betas, j=1.0, b=0.1)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6, atol=1e-3)


def test_vmem_working_set_documented_budget():
    """The documented v5e budget for the paper's L=300 config must hold: the
    Ising kernel's r_blk=8 working set is the 18 B/cell (~12.4 MiB) modelled
    in its module docstring and stays inside a v5e core's 16 MB VMEM; the
    Potts kernel (30 B/cell) fits the same budget at its documented
    r_blk=4 default."""
    ising_bytes = isk.vmem_working_set_bytes(8, 300)
    assert ising_bytes == 18 * 8 * 300 * 300  # 18 bytes/cell model, ~12.4 MiB
    assert ising_bytes < 16 * 2**20
    potts_bytes = psk.vmem_working_set_bytes(4, 300, 300)
    assert potts_bytes == 30 * 4 * 300 * 300  # 30 bytes/cell (module docstring)
    assert potts_bytes < 16 * 2**20
    # both models are monotone in every argument (sanity of the estimator)
    assert psk.vmem_working_set_bytes(8, 300, 300) > potts_bytes
    assert psk.vmem_working_set_bytes(4, 300, 302) > potts_bytes


# ---------- Potts kernel vs oracle ----------------------------------------------
def _rand_potts(key, r, h, w, q):
    k1, k2, k3 = jax.random.split(key, 3)
    states = jax.random.randint(k1, (r, h, w), 0, q).astype(jnp.int8)
    u = jax.random.uniform(k2, (r, 2, 2, h, w), jnp.float32)
    betas = jax.random.uniform(k3, (r,), minval=0.1, maxval=1.5)
    return states, u, betas


@pytest.mark.parametrize("r,h,w,r_blk,q", [
    (1, 4, 4, 1, 3), (2, 8, 6, 2, 3), (8, 16, 16, 4, 4), (5, 12, 10, 2, 3),
    (3, 7, 9, 4, 5),   # pad path AND odd lattice dims
    (16, 30, 30, 8, 2),  # q=2 (Ising twin), non-128-aligned like the paper
])
@pytest.mark.parametrize("rule", ["metropolis", "glauber"])
def test_potts_kernel_matches_oracle(r, h, w, r_blk, q, rule):
    states, u, betas = _rand_potts(jax.random.key(r * 100 + h + q), r, h, w, q)
    got = ops.potts_sweep(states, u, betas, q=q, j=0.8, rule=rule,
                          r_blk=r_blk, use_pallas=True)
    want = ref.potts_sweep(states, u, betas, q=q, j=0.8, rule=rule)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_potts_kernel_block_size_invariance():
    """Same Fig-6 invariant as Ising: the replica tile size must not change
    the sweep's result."""
    states, u, betas = _rand_potts(jax.random.key(0), 16, 8, 8, 3)
    outs = [
        ops.potts_sweep(states, u, betas, q=3, j=1.0, r_blk=rb, use_pallas=True)[0]
        for rb in (1, 2, 4, 8, 16)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


def test_potts_proposals_never_propose_current_colour():
    """d in {1..q-1} guarantees every proposal differs from the current
    colour; with acceptance u=0 (always accept) every unmasked site of each
    colour class must change."""
    r, h, w, q = 2, 4, 4, 5
    states = jnp.zeros((r, h, w), jnp.int8)
    u = jnp.zeros((r, 2, 2, h, w), jnp.float32)
    u = u.at[:, :, 0].set(jax.random.uniform(jax.random.key(3), (r, 2, h, w)))
    new, _, nacc = ref.potts_sweep(states, u, jnp.ones((r,)), q=q, j=1.0)
    assert np.all(np.asarray(new) != 0)  # every site flipped away from 0
    assert np.all(np.asarray(nacc) == h * w)


def _rand_wkv(key, bh, t, dk, dv, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (bh, t, dk), dtype)
    k = jax.random.normal(ks[1], (bh, t, dk), dtype)
    v = jax.random.normal(ks[2], (bh, t, dv), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, t, dk), dtype))
    u = jax.random.normal(ks[4], (bh, dk), dtype)
    return r, k, v, w, u


@pytest.mark.parametrize("bh,t,dk,dv,chunk", [
    (1, 8, 4, 4, 4), (2, 32, 8, 16, 8), (4, 33, 8, 8, 16),  # pad path
    (3, 64, 64, 64, 32), (2, 16, 16, 8, 16),
])
def test_wkv6_kernel_matches_oracle(bh, t, dk, dv, chunk):
    r, k, v, w, u = _rand_wkv(jax.random.key(bh * 7 + t), bh, t, dk, dv)
    o1, s1 = ops.wkv6(r, k, v, w, u, chunk=chunk, use_pallas=True)
    o2, s2 = ops.wkv6(r, k, v, w, u, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=3e-5, atol=3e-5)


def test_wkv6_initial_state_threading():
    """Chunked decode: running T=32 in two halves == one shot (cache reuse)."""
    bh, t, dk, dv = 2, 32, 8, 8
    r, k, v, w, u = _rand_wkv(jax.random.key(5), bh, t, dk, dv)
    o_full, s_full = ops.wkv6(r, k, v, w, u, chunk=8)
    o1, s1 = ops.wkv6(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, chunk=8)
    o2, s2 = ops.wkv6(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s1, chunk=8)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(jnp.concatenate([o1, o2], 1)), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=3e-5, atol=3e-5)


def test_wkv6_decay_semantics():
    """w=1, k=0 must be the identity (state preserved, output = r @ S)."""
    bh, dk, dv = 1, 4, 4
    s0 = jnp.arange(dk * dv, dtype=jnp.float32).reshape(1, dk, dv)
    r = jnp.ones((1, 2, dk))
    k = jnp.zeros((1, 2, dk))
    v = jnp.zeros((1, 2, dv))
    w = jnp.ones((1, 2, dk))
    u = jnp.zeros((1, dk))
    o, s = ops.wkv6(r, k, v, w, u, s0, chunk=2)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s0), rtol=1e-6)
    want = np.asarray(jnp.einsum("bk,bkv->bv", r[:, 0], s0))
    np.testing.assert_allclose(np.asarray(o[0, 0]), want[0], rtol=1e-6)
