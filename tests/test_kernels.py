"""Pallas-kernel vs oracle sweeps (shapes / dtypes / block sizes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ising_sweep as isk
from repro.kernels import ops, potts_sweep as psk, prng, ref


def _rand_ising(key, r, l):
    k1, k2, k3 = jax.random.split(key, 3)
    spins = jnp.where(jax.random.uniform(k1, (r, l, l)) < 0.5, 1, -1).astype(jnp.int8)
    u = jax.random.uniform(k2, (r, 2, l, l), jnp.float32)
    betas = jax.random.uniform(k3, (r,), minval=0.1, maxval=1.5)
    return spins, u, betas


@pytest.mark.parametrize("r,l,r_blk", [
    (1, 4, 1), (2, 8, 2), (8, 16, 4), (8, 16, 8), (5, 12, 2),  # pad path
    (16, 30, 8),   # odd (non-128-aligned) lattice like the paper's 300
    (3, 7, 4),     # odd lattice side AND padded replicas
])
@pytest.mark.parametrize("jb", [(1.0, 0.0), (1.0, 0.4), (-0.7, -0.2)])
def test_ising_kernel_matches_oracle(r, l, r_blk, jb):
    j, b = jb
    spins, u, betas = _rand_ising(jax.random.key(r * 100 + l), r, l)
    got = ops.ising_sweep(spins, u, betas, j=j, b=b, r_blk=r_blk, use_pallas=True)
    want = ref.ising_sweep(spins, u, betas, j=j, b=b)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_ising_kernel_block_size_invariance():
    """Fig-6 analogue invariant: the tile size must not change the result."""
    spins, u, betas = _rand_ising(jax.random.key(0), 16, 10)
    outs = [
        ops.ising_sweep(spins, u, betas, j=1.0, b=0.0, r_blk=rb, use_pallas=True)[0]
        for rb in (1, 2, 4, 8, 16)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


def test_ising_vmem_model_monotonic():
    assert isk.vmem_working_set_bytes(8, 300) > isk.vmem_working_set_bytes(4, 300)
    assert isk.vmem_working_set_bytes(8, 300) < 16 * 2**20  # fits v5e VMEM


# ---------- replica-padding path regression (R not a multiple of r_blk) ---------
@pytest.mark.parametrize("r", [1, 2, 3, 5, 7, 9, 11, 15, 17])
def test_ising_padding_path_bit_equal(r):
    """ops.ising_sweep pads R up to r_blk=8 with beta=0 junk replicas; every
    non-multiple R must still be BIT-equal to the unpadded oracle."""
    spins, u, betas = _rand_ising(jax.random.key(1000 + r), r, 6)
    got = ops.ising_sweep(spins, u, betas, j=1.0, b=0.1, r_blk=8, use_pallas=True)
    want = ref.ising_sweep(spins, u, betas, j=1.0, b=0.1)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6, atol=1e-3)


# ---------- counter PRNG (the fused kernels' random stream) ---------------------
def test_threefry_known_answer_vectors():
    """Threefry-2x32-20 against the published Random123 test vectors — the
    stream contract is the cipher itself, not 'whatever this build computes'."""
    kat = [
        ((0, 0), (0, 0), (0x6B200159, 0x99BA4EFE)),
        ((0xFFFFFFFF, 0xFFFFFFFF), (0xFFFFFFFF, 0xFFFFFFFF),
         (0x1CB996FC, 0xBB002BE7)),
        ((0x13198A2E, 0x03707344), (0x243F6A88, 0x85A308D3),
         (0xC4923A9C, 0x483DF7A0)),
    ]
    for key, ctr, want in kat:
        got = prng.threefry2x32(
            jnp.uint32(key[0]), jnp.uint32(key[1]),
            jnp.uint32(ctr[0]), jnp.uint32(ctr[1]),
        )
        assert (int(got[0]), int(got[1])) == want


def test_prng_uniforms_range_and_moments():
    """[0,1) half-open contract plus crude moment sanity (catches a broken
    rotation/injection far faster than the conformance gate would)."""
    u = np.asarray(prng.plane_uniforms(
        jnp.arange(8, dtype=jnp.uint32), jnp.arange(8, 16, dtype=jnp.uint32),
        0, 64, 64,
    ))
    assert u.min() >= 0.0 and u.max() < 1.0
    n = u.size
    assert abs(u.mean() - 0.5) < 4.0 / np.sqrt(12 * n)
    assert abs(u.var() - 1.0 / 12.0) < 0.002


def test_prng_stream_distinct_across_counter_axes():
    """Distinct (sweep, replica, plane) must give distinct lattices — the
    injectivity the counter layout is designed for."""
    words = prng.key_words(jax.random.key(3))
    rep = jnp.arange(4, dtype=jnp.uint32)
    base = np.asarray(prng.ising_sweep_uniforms(words, 5, rep, 8))
    other_t = np.asarray(prng.ising_sweep_uniforms(words, 6, rep, 8))
    assert not np.array_equal(base, other_t)
    for r in range(1, 4):  # replica axis
        assert not np.array_equal(base[0], base[r])
    assert not np.array_equal(base[:, 0], base[:, 1])  # colour planes


# ---------- interval-fused kernels vs the per-sweep oracle stream ---------------
@pytest.mark.parametrize("n_sweeps", [1, 3])
@pytest.mark.parametrize("r,l,r_blk", [
    (1, 4, 1), (8, 10, 4), (5, 12, 2),  # pad path
    (3, 6, 8),   # pad > R (regression: tiled padding)
    (4, 30, 4),  # odd (non-128-aligned) lattice like the paper's 300
])
def test_ising_fused_bit_equals_persweep_oracle_stream(r, l, r_blk, n_sweeps):
    """The fused kernel over S sweeps must be BIT-equal (spins, ΔE and
    acceptance counts included — same f32 association order) to S
    applications of the per-sweep oracle fed the same counter stream."""
    key = jax.random.key(r * 10 + l)
    spins, _, betas = _rand_ising(key, r, l)
    t0 = 17
    got = ops.ising_sweep_fused(
        spins, key, jnp.int32(t0), betas, n_sweeps=n_sweeps, j=1.0, b=0.3,
        r_blk=r_blk, use_pallas=True,
    )
    words = prng.key_words(key)
    rep = jnp.arange(r, dtype=jnp.uint32)
    s, de, na = spins, jnp.zeros((r,), jnp.float32), jnp.zeros((r,), jnp.int32)
    for i in range(n_sweeps):
        u = prng.ising_sweep_uniforms(words, t0 + i, rep, l)
        s, d, n = ref.ising_sweep(s, u, betas, j=1.0, b=0.3)
        de, na = de + d, na + n
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(de))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(na))
    # and the pure-JAX fused reference is the same stream, bit-for-bit
    rf = ops.ising_sweep_fused(
        spins, key, jnp.int32(t0), betas, n_sweeps=n_sweeps, j=1.0, b=0.3,
        r_blk=r_blk, use_pallas=False,
    )
    for a, b in zip(got, rf):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n_sweeps", [1, 3])
@pytest.mark.parametrize("r,h,w,r_blk,q", [
    (1, 4, 4, 1, 3), (5, 8, 6, 2, 4),  # pad path
    (3, 6, 6, 8, 3),  # pad > R (regression: tiled padding)
])
@pytest.mark.parametrize("rule", ["metropolis", "glauber"])
def test_potts_fused_bit_equals_persweep_oracle_stream(r, h, w, r_blk, q, rule, n_sweeps):
    key = jax.random.key(r * 7 + h + q)
    states, _, betas = _rand_potts(key, r, h, w, q)
    t0 = 5
    got = ops.potts_sweep_fused(
        states, key, jnp.int32(t0), betas, n_sweeps=n_sweeps, q=q, j=0.8,
        rule=rule, r_blk=r_blk, use_pallas=True,
    )
    words = prng.key_words(key)
    rep = jnp.arange(r, dtype=jnp.uint32)
    s, de, na = states, jnp.zeros((r,), jnp.float32), jnp.zeros((r,), jnp.int32)
    for i in range(n_sweeps):
        u = prng.potts_sweep_uniforms(words, t0 + i, rep, h, w)
        s, d, n = ref.potts_sweep(s, u, betas, q=q, j=0.8, rule=rule)
        de, na = de + d, na + n
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(de))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(na))
    rf = ops.potts_sweep_fused(
        states, key, jnp.int32(t0), betas, n_sweeps=n_sweeps, q=q, j=0.8,
        rule=rule, r_blk=r_blk, use_pallas=False,
    )
    for a, b in zip(got, rf):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ising_fused_block_size_invariance():
    """Fig-6 invariant extended to the fused kernel: neither the replica
    tile size nor the padding it implies may change the stream (real
    replicas keep counter indices 0..R-1)."""
    key = jax.random.key(2)
    spins, _, betas = _rand_ising(key, 6, 8)
    outs = [
        ops.ising_sweep_fused(
            spins, key, jnp.int32(0), betas, n_sweeps=2, r_blk=rb,
            use_pallas=True,
        )[0]
        for rb in (1, 2, 3, 6, 8)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


def test_fused_interval_equals_split_intervals():
    """Chunking invariance: one fused 4-sweep interval == two fused 2-sweep
    intervals with the counter advanced — what makes engine chunk/interval
    boundaries invisible to the fused chain."""
    key = jax.random.key(9)
    spins, _, betas = _rand_ising(key, 4, 6)
    whole = ops.ising_sweep_fused(
        spins, key, jnp.int32(10), betas, n_sweeps=4, use_pallas=True
    )
    s1, de1, na1 = ops.ising_sweep_fused(
        spins, key, jnp.int32(10), betas, n_sweeps=2, use_pallas=True
    )
    s2, de2, na2 = ops.ising_sweep_fused(
        s1, key, jnp.int32(12), betas, n_sweeps=2, use_pallas=True
    )
    np.testing.assert_array_equal(np.asarray(whole[0]), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(whole[2]), np.asarray(na1 + na2))
    np.testing.assert_allclose(
        np.asarray(whole[1]), np.asarray(de1 + de2), rtol=1e-6, atol=1e-3
    )


# ---------- per-sweep padding regression: pad > R (e.g. R=3 at r_blk=8) ---------
@pytest.mark.parametrize("r,r_blk", [(3, 8), (2, 8), (1, 4), (5, 16)])
def test_potts_padding_exceeding_r_bit_equal(r, r_blk):
    """`ops` wrappers must tile the replica padding: with pad > R the old
    `x[:pad]` under-padded states/u while betas padded fully, leaving the
    kernel mismatched shapes."""
    states, u, betas = _rand_potts(jax.random.key(40 + r), r, 6, 6, 3)
    got = ops.potts_sweep(states, u, betas, q=3, j=1.0, r_blk=r_blk,
                          use_pallas=True)
    want = ref.potts_sweep(states, u, betas, q=3, j=1.0)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-6, atol=1e-3)


def test_vmem_working_set_documented_budget():
    """The documented v5e budget for the paper's L=300 config must hold: the
    Ising kernel's r_blk=8 working set is the 18 B/cell (~12.4 MiB) modelled
    in its module docstring and stays inside a v5e core's 16 MB VMEM; the
    Potts kernel (30 B/cell) fits the same budget at its documented
    r_blk=4 default."""
    ising_bytes = isk.vmem_working_set_bytes(8, 300)
    assert ising_bytes == 18 * 8 * 300 * 300  # 18 bytes/cell model, ~12.4 MiB
    assert ising_bytes < 16 * 2**20
    potts_bytes = psk.vmem_working_set_bytes(4, 300, 300)
    assert potts_bytes == 30 * 4 * 300 * 300  # 30 bytes/cell (module docstring)
    assert potts_bytes < 16 * 2**20
    # both models are monotone in every argument (sanity of the estimator)
    assert psk.vmem_working_set_bytes(8, 300, 300) > potts_bytes
    assert psk.vmem_working_set_bytes(4, 300, 302) > potts_bytes


def test_vmem_fused_documented_budget():
    """The fused kernels' working sets at the documented blocks must still
    fit a v5e core's 16 MB: 18 B/cell Ising (+O(r_blk) RNG state) and
    22 B/cell Potts — fusion trades the uniforms input block for one
    in-flight plane of PRNG draws, so VMEM stays flat while HBM traffic
    collapses."""
    ising = isk.vmem_working_set_bytes_fused(8, 300)
    assert ising == 18 * 8 * 300 * 300 + 16 * 8
    assert ising < 16 * 2**20
    potts = psk.vmem_working_set_bytes_fused(4, 300, 300)
    assert potts == 22 * 4 * 300 * 300 + 16 * 4
    assert potts < 16 * 2**20
    # fused never exceeds the per-sweep working set by more than the RNG state
    assert ising <= isk.vmem_working_set_bytes(8, 300) + 16 * 8
    assert potts <= psk.vmem_working_set_bytes(4, 300, 300)


def test_vmem_packed_documented_budget():
    """The packed working-set models must match their module docstrings: the
    bit-plane Ising kernel lands at 17.5 B/cell for r_blk=8 (vs 18 unpacked)
    and the int8-lane Potts kernel at 16 B/cell (vs 22) — packing never
    costs VMEM at the documented blocks."""
    ising_packed = isk.vmem_working_set_bytes_packed(8, 300)
    assert ising_packed == 12_600_128  # 17.5 B/cell + RNG state at L=300
    assert ising_packed < isk.vmem_working_set_bytes_fused(8, 300)
    assert ising_packed < 16 * 2**20
    # a second uint32 word only appears past 32 replicas per block
    per_cell_32 = (isk.vmem_working_set_bytes_packed(32, 300) - 16 * 32) / (
        32 * 300 * 300
    )
    assert per_cell_32 == pytest.approx(15.625)
    potts_packed = psk.vmem_working_set_bytes_packed(4, 300, 300)
    assert potts_packed == 16 * 4 * 300 * 300 + 16 * 4
    assert potts_packed < psk.vmem_working_set_bytes_fused(4, 300, 300)
    assert potts_packed < 16 * 2**20


def test_hbm_traffic_model_rounds_axis():
    """Whole-round fusion extends the amortization to S*K sweeps per launch,
    in both kernel modules and the shared `hlo.traffic` source of truth."""
    from repro.hlo import traffic

    assert isk.hbm_bytes_per_cell_sweep(
        fused=True, sweeps_per_interval=4, rounds_per_launch=2
    ) == pytest.approx(0.25)
    for s, k in ((1, 1), (4, 2), (5, 16)):
        want = 2.0 / (s * k)
        for fn in (
            isk.hbm_bytes_per_cell_sweep,
            psk.hbm_bytes_per_cell_sweep,
            lambda **kw: traffic.hbm_bytes_per_cell_sweep(**kw),
        ):
            assert fn(
                fused=True, sweeps_per_interval=s, rounds_per_launch=k
            ) == pytest.approx(want)
    # rounds never change the unfused model, and zero rounds is an error
    assert isk.hbm_bytes_per_cell_sweep(fused=False) == 18.0
    with pytest.raises(ValueError, match="rounds_per_launch"):
        isk.hbm_bytes_per_cell_sweep(
            fused=True, sweeps_per_interval=1, rounds_per_launch=0
        )


def test_hbm_traffic_model_fused_speedup():
    """The acceptance bar for this optimisation: modeled HBM bytes per cell
    per sweep must drop >= 5x on the fused Ising path — already 9x at one
    sweep per interval (18 -> 2 B), scaling linearly with the interval."""
    unfused = isk.hbm_bytes_per_cell_sweep(fused=False)
    assert unfused == 18.0
    assert unfused >= 5 * isk.hbm_bytes_per_cell_sweep(
        fused=True, sweeps_per_interval=1
    )
    assert isk.hbm_bytes_per_cell_sweep(fused=True, sweeps_per_interval=100) == (
        pytest.approx(0.02)
    )
    # Potts: 34 -> 2/S B per cell per sweep
    assert psk.hbm_bytes_per_cell_sweep(fused=False) == 34.0
    assert psk.hbm_bytes_per_cell_sweep(fused=False) >= 5 * (
        psk.hbm_bytes_per_cell_sweep(fused=True, sweeps_per_interval=1)
    )
    # the kernel modules keep their models local (self-contained kernel code,
    # like _roll1/_accept_prob) — pin the fused branches against silent
    # divergence: both amortize the same int8 in+out over the interval
    for s in (1, 4, 100):
        assert isk.hbm_bytes_per_cell_sweep(
            fused=True, sweeps_per_interval=s
        ) == psk.hbm_bytes_per_cell_sweep(fused=True, sweeps_per_interval=s)


# ---------- Potts kernel vs oracle ----------------------------------------------
def _rand_potts(key, r, h, w, q):
    k1, k2, k3 = jax.random.split(key, 3)
    states = jax.random.randint(k1, (r, h, w), 0, q).astype(jnp.int8)
    u = jax.random.uniform(k2, (r, 2, 2, h, w), jnp.float32)
    betas = jax.random.uniform(k3, (r,), minval=0.1, maxval=1.5)
    return states, u, betas


@pytest.mark.parametrize("r,h,w,r_blk,q", [
    (1, 4, 4, 1, 3), (2, 8, 6, 2, 3), (8, 16, 16, 4, 4), (5, 12, 10, 2, 3),
    (3, 7, 9, 4, 5),   # pad path AND odd lattice dims
    (16, 30, 30, 8, 2),  # q=2 (Ising twin), non-128-aligned like the paper
])
@pytest.mark.parametrize("rule", ["metropolis", "glauber"])
def test_potts_kernel_matches_oracle(r, h, w, r_blk, q, rule):
    states, u, betas = _rand_potts(jax.random.key(r * 100 + h + q), r, h, w, q)
    got = ops.potts_sweep(states, u, betas, q=q, j=0.8, rule=rule,
                          r_blk=r_blk, use_pallas=True)
    want = ref.potts_sweep(states, u, betas, q=q, j=0.8, rule=rule)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), rtol=1e-6, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_potts_kernel_block_size_invariance():
    """Same Fig-6 invariant as Ising: the replica tile size must not change
    the sweep's result."""
    states, u, betas = _rand_potts(jax.random.key(0), 16, 8, 8, 3)
    outs = [
        ops.potts_sweep(states, u, betas, q=3, j=1.0, r_blk=rb, use_pallas=True)[0]
        for rb in (1, 2, 4, 8, 16)
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


def test_potts_proposals_never_propose_current_colour():
    """d in {1..q-1} guarantees every proposal differs from the current
    colour; with acceptance u=0 (always accept) every unmasked site of each
    colour class must change."""
    r, h, w, q = 2, 4, 4, 5
    states = jnp.zeros((r, h, w), jnp.int8)
    u = jnp.zeros((r, 2, 2, h, w), jnp.float32)
    u = u.at[:, :, 0].set(jax.random.uniform(jax.random.key(3), (r, 2, h, w)))
    new, _, nacc = ref.potts_sweep(states, u, jnp.ones((r,)), q=q, j=1.0)
    assert np.all(np.asarray(new) != 0)  # every site flipped away from 0
    assert np.all(np.asarray(nacc) == h * w)


def _rand_wkv(key, bh, t, dk, dv, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (bh, t, dk), dtype)
    k = jax.random.normal(ks[1], (bh, t, dk), dtype)
    v = jax.random.normal(ks[2], (bh, t, dv), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, t, dk), dtype))
    u = jax.random.normal(ks[4], (bh, dk), dtype)
    return r, k, v, w, u


@pytest.mark.parametrize("bh,t,dk,dv,chunk", [
    (1, 8, 4, 4, 4), (2, 32, 8, 16, 8), (4, 33, 8, 8, 16),  # pad path
    (3, 64, 64, 64, 32), (2, 16, 16, 8, 16),
])
def test_wkv6_kernel_matches_oracle(bh, t, dk, dv, chunk):
    r, k, v, w, u = _rand_wkv(jax.random.key(bh * 7 + t), bh, t, dk, dv)
    o1, s1 = ops.wkv6(r, k, v, w, u, chunk=chunk, use_pallas=True)
    o2, s2 = ops.wkv6(r, k, v, w, u, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=3e-5, atol=3e-5)


def test_wkv6_initial_state_threading():
    """Chunked decode: running T=32 in two halves == one shot (cache reuse)."""
    bh, t, dk, dv = 2, 32, 8, 8
    r, k, v, w, u = _rand_wkv(jax.random.key(5), bh, t, dk, dv)
    o_full, s_full = ops.wkv6(r, k, v, w, u, chunk=8)
    o1, s1 = ops.wkv6(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, chunk=8)
    o2, s2 = ops.wkv6(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s1, chunk=8)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(jnp.concatenate([o1, o2], 1)), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=3e-5, atol=3e-5)


def test_wkv6_decay_semantics():
    """w=1, k=0 must be the identity (state preserved, output = r @ S)."""
    bh, dk, dv = 1, 4, 4
    s0 = jnp.arange(dk * dv, dtype=jnp.float32).reshape(1, dk, dv)
    r = jnp.ones((1, 2, dk))
    k = jnp.zeros((1, 2, dk))
    v = jnp.zeros((1, 2, dv))
    w = jnp.ones((1, 2, dk))
    u = jnp.zeros((1, dk))
    o, s = ops.wkv6(r, k, v, w, u, s0, chunk=2)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s0), rtol=1e-6)
    want = np.asarray(jnp.einsum("bk,bkv->bv", r[:, 0], s0))
    np.testing.assert_allclose(np.asarray(o[0, 0]), want[0], rtol=1e-6)
