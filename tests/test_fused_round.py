"""Whole-PT-round fused kernels (DESIGN.md §6): the in-kernel exchange vs
the strategy + `accept_pairs` oracle, round kernels vs sweep+exchange
composition, bit-plane/int8 packing bit-equality, launch-split invariance,
and the structural single-launch evidence on the engine's interval step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ising, ladder, pt, swap as core_swap
from repro.core.potts import PottsSystem
from repro.engine import Engine, EngineConfig
from repro.engine.driver import StepSpec, make_interval_step
from repro.exchange import make_strategy
from repro.kernels import exchange as kx, ops, prng

R, L = 6, 8
TEMPS = np.asarray(ladder.linear_ladder(R, 1.0, 3.5))
BETAS = jnp.asarray(1.0 / TEMPS, jnp.float32)  # rung order, cold -> hot


def _rand_slots(key, r):
    """Random slot->rung permutation + per-slot energies."""
    k1, k2 = jax.random.split(key)
    rung = jax.random.permutation(k1, jnp.arange(r, dtype=jnp.int32))
    energy = jax.random.normal(k2, (r,), jnp.float32) * 10.0
    return rung, energy


def _rand_ising(key, r, l):
    k1, k2 = jax.random.split(key)
    spins = jnp.where(
        jax.random.uniform(k1, (r, l, l)) < 0.5, 1, -1
    ).astype(jnp.int8)
    betas = jnp.sort(jax.random.uniform(k2, (r,), minval=0.25, maxval=1.0))[::-1]
    return spins, betas


# ---------- in-kernel exchange vs the strategy + accept_pairs oracle ------------
@pytest.mark.parametrize("criterion", ["logistic", "metropolis"])
@pytest.mark.parametrize("pairing", ["deo", "seo"])
@pytest.mark.parametrize("phase", [0, 1, 7])
def test_exchange_step_matches_accept_pairs_oracle(pairing, criterion, phase):
    """`kernels.exchange.exchange_step` must be BIT-equal to the PR 4
    strategy path (`core.swap.pair_partners` + `accept_pairs`) fed the same
    counter-stream uniforms — the Mosaic-safe one-hot forms may not change
    a single bit of the decision."""
    key = jax.random.key(31 + phase)
    rung, energy = _rand_slots(key, R)
    words = prng.key_words(key)
    got_rung, got_acc, got_prob, got_att, got_e = kx.exchange_step(
        rung, energy, BETAS, phase, words, pairing=pairing,
        criterion=criterion,
    )
    # oracle: inversion via argsort, partners from core.swap, decision from
    # accept_pairs with the uniforms injected from the same swap stream
    inv = jnp.argsort(rung)
    e_rung = energy[inv]
    eff_phase = phase if pairing == "deo" else prng.seo_coin(words, phase)
    partner = core_swap.pair_partners(R, eff_phase)
    u = prng.swap_uniforms(words, phase, R)
    perm, acc, prob, att = core_swap.accept_pairs(
        jax.random.key(0), partner, BETAS, e_rung, criterion, uniforms=u
    )
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(e_rung))
    np.testing.assert_array_equal(np.asarray(got_acc), np.asarray(acc))
    np.testing.assert_array_equal(np.asarray(got_prob), np.asarray(prob))
    np.testing.assert_array_equal(np.asarray(got_att), np.asarray(att))
    np.testing.assert_array_equal(np.asarray(got_rung), np.asarray(perm[rung]))


def test_exchange_step_rejects_unknown_pairing():
    rung, energy = _rand_slots(jax.random.key(0), R)
    with pytest.raises(ValueError, match="pairings"):
        kx.exchange_step(
            rung, energy, BETAS, 0, prng.key_words(jax.random.key(0)),
            pairing="windowed", criterion="logistic",
        )


# ---------- round kernels vs sweep + exchange composition -----------------------
def _ising_round_oracle(spins, key, t0, phase0, rung, energy, betas, *,
                        n_sweeps, n_rounds, pairing, criterion):
    """n_rounds x (fused interval at slot betas, then exchange_step)."""
    words = prng.key_words(key)
    na_tot = jnp.zeros((spins.shape[0],), jnp.int32)
    accs, probs, atts = [], [], []
    for k in range(n_rounds):
        spins, de, na = ops.ising_sweep_fused(
            spins, key, jnp.int32(t0 + k * n_sweeps), betas[rung],
            n_sweeps=n_sweeps, use_pallas=False,
        )
        energy = energy + de
        na_tot = na_tot + na
        rung, acc, prob, att, _ = kx.exchange_step(
            rung, energy, betas, phase0 + k, words,
            pairing=pairing, criterion=criterion,
        )
        accs.append(acc); probs.append(prob); atts.append(att)
    return (spins, rung, energy, na_tot,
            jnp.stack(accs), jnp.stack(probs), jnp.stack(atts))


@pytest.mark.parametrize("pack_bits", [False, True])
@pytest.mark.parametrize("pairing", ["deo", "seo"])
def test_ising_round_fused_matches_composition_oracle(pairing, pack_bits):
    """One launch = n_rounds full PT rounds: the round kernel must be
    BIT-equal (spins, rung map, energies, diagnostics) to the composition
    of the interval-fused sweep stream and the in-kernel exchange — and the
    pure-JAX reference path must match the Pallas kernel bit-for-bit."""
    key = jax.random.key(5)
    spins, betas = _rand_ising(key, R, L)
    rung, _ = _rand_slots(key, R)
    energy = ising.lattice_energy(spins, 1.0, 0.0)
    kw = dict(n_sweeps=2, n_rounds=3, pairing=pairing, criterion="logistic")
    want = _ising_round_oracle(
        spins, key, 11, 4, rung, energy, betas, **kw
    )
    got = ops.ising_round_fused(
        spins, key, jnp.int32(11), jnp.int32(4), rung, energy, betas,
        use_pallas=True, pack_bits=pack_bits, **kw
    )
    ref = ops.ising_round_fused(
        spins, key, jnp.int32(11), jnp.int32(4), rung, energy, betas,
        use_pallas=False, pack_bits=pack_bits, **kw
    )
    for g, r_, w in zip(got, ref, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        np.testing.assert_array_equal(np.asarray(r_), np.asarray(w))


@pytest.mark.parametrize("pack_bits", [False, True])
def test_potts_round_fused_matches_composition_oracle(pack_bits):
    q, h = 3, 6
    key = jax.random.key(8)
    states = jax.random.randint(key, (5, h, h), 0, q).astype(jnp.int8)
    betas = jnp.sort(
        jax.random.uniform(jax.random.fold_in(key, 1), (5,), minval=0.2,
                           maxval=1.2)
    )[::-1]
    rung, _ = _rand_slots(key, 5)
    from repro.core.potts import potts_energy

    energy = potts_energy(states, q, 1.0)
    words = prng.key_words(key)
    s, e, ru = states, energy, rung
    na_tot = jnp.zeros((5,), jnp.int32)
    accs = []
    for k in range(2):
        s, de, na = ops.potts_sweep_fused(
            s, key, jnp.int32(3 + k * 2), betas[ru], n_sweeps=2, q=q,
            use_pallas=False,
        )
        e = e + de
        na_tot = na_tot + na
        ru, acc, _, _, _ = kx.exchange_step(
            ru, e, betas, 1 + k, words, pairing="seo", criterion="metropolis"
        )
        accs.append(acc)
    got = ops.potts_round_fused(
        states, key, jnp.int32(3), jnp.int32(1), rung, energy, betas,
        n_sweeps=2, q=q, n_rounds=2, pairing="seo", criterion="metropolis",
        pack_bits=pack_bits, use_pallas=True,
    )
    for g, w in zip(got, (s, ru, e, na_tot, jnp.stack(accs))):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_round_launch_split_invariance():
    """K rounds in one launch == K single-round launches with the sweep
    counter and swap phase advanced — what makes the engine's one-round-
    per-interval calls the same chain as any benchmark multi-round launch."""
    key = jax.random.key(13)
    spins, betas = _rand_ising(key, R, L)
    rung, _ = _rand_slots(key, R)
    energy = ising.lattice_energy(spins, 1.0, 0.0)
    whole = ops.ising_round_fused(
        spins, key, jnp.int32(0), jnp.int32(0), rung, energy, betas,
        n_sweeps=2, n_rounds=3, use_pallas=True,
    )
    s, ru, e = spins, rung, energy
    for k in range(3):
        s, ru, e, _, _, _, _ = ops.ising_round_fused(
            s, key, jnp.int32(2 * k), jnp.int32(k), ru, e, betas,
            n_sweeps=2, n_rounds=1, use_pallas=True,
        )
    np.testing.assert_array_equal(np.asarray(whole[0]), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(whole[1]), np.asarray(ru))
    np.testing.assert_array_equal(np.asarray(whole[2]), np.asarray(e))


# ---------- packed interval kernels: bitwise-identical storage knob -------------
@pytest.mark.parametrize("r,r_blk", [(3, 8), (6, 4), (8, 8), (33, 64)])
def test_ising_packed_interval_bit_equal(r, r_blk):
    """pack_bits is storage only: bit-plane multispin updates must reproduce
    the unpacked fused kernel bit-for-bit — including pad > R tiles and a
    block wide enough (r_blk=64) to need a second uint32 bit-plane word."""
    key = jax.random.key(60 + r)
    spins, betas = _rand_ising(key, r, L)
    kw = dict(n_sweeps=3, j=1.0, b=0.3, r_blk=r_blk, use_pallas=True)
    plain = ops.ising_sweep_fused(spins, key, jnp.int32(7), betas, **kw)
    packed = ops.ising_sweep_fused(
        spins, key, jnp.int32(7), betas, pack_bits=True, **kw
    )
    for a, b in zip(packed, plain):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("r,r_blk,q", [(3, 8, 3), (5, 2, 5)])
def test_potts_packed_interval_bit_equal(r, r_blk, q):
    key = jax.random.key(70 + r)
    states = jax.random.randint(key, (r, 6, 8), 0, q).astype(jnp.int8)
    betas = jax.random.uniform(
        jax.random.fold_in(key, 1), (r,), minval=0.2, maxval=1.2
    )
    kw = dict(n_sweeps=2, q=q, r_blk=r_blk, use_pallas=True)
    plain = ops.potts_sweep_fused(states, key, jnp.int32(2), betas, **kw)
    packed = ops.potts_sweep_fused(
        states, key, jnp.int32(2), betas, pack_bits=True, **kw
    )
    for a, b in zip(packed, plain):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_potts_pack_bits_rejects_large_q():
    states = jnp.zeros((2, 4, 4), jnp.int8)
    betas = jnp.ones((2,), jnp.float32)
    with pytest.raises(ValueError, match="q <= 64"):
        ops.potts_sweep_fused(
            states, jax.random.key(0), jnp.int32(0), betas, n_sweeps=1,
            q=65, pack_bits=True, use_pallas=True,
        )
    with pytest.raises(ValueError, match="q <= 64"):
        PottsSystem(shape=(4, 4), q=65, pack_bits=True)


# ---------- engine integration: one launch per PT round -------------------------
def _engine_state(**sys_kw):
    system = ising.IsingSystem(length=L, **sys_kw)
    cfg = EngineConfig(
        n_replicas=R, swap_interval=4, chunk_intervals=3, record_trace=True
    )
    eng = Engine(system, cfg, observables={
        "am": lambda s: jnp.abs(ising.magnetization(s))
    })
    st = eng.init(jax.random.key(3), TEMPS)
    return eng, st


def test_engine_round_path_ref_pallas_packed_bit_equal():
    """use_fused_round through the engine: the pure-JAX reference, the Pallas
    round kernel and its bit-packed variant are one chain, bit-for-bit, and
    the carried incremental energy tracks the true lattice energy."""
    results = {}
    for tag, kw in {
        "ref": dict(use_pallas=False),
        "pallas": dict(use_pallas=True),
        "packed": dict(use_pallas=True, pack_bits=True),
    }.items():
        eng, st0 = _engine_state(use_fused=True, use_fused_round=True, **kw)
        results[tag] = eng.run(st0, 36)
    st_ref, res_ref = results["ref"]
    for tag in ("pallas", "packed"):
        st, res = results[tag]
        np.testing.assert_array_equal(
            np.asarray(st.pt.states), np.asarray(st_ref.pt.states), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(st.pt.rung), np.asarray(st_ref.pt.rung), err_msg=tag
        )
        np.testing.assert_array_equal(
            np.asarray(st.pt.energy), np.asarray(st_ref.pt.energy), err_msg=tag
        )
        for k in res_ref.trace:
            np.testing.assert_array_equal(
                res.trace[k], res_ref.trace[k], err_msg=f"{tag}/{k}"
            )
    system = ising.IsingSystem(length=L)
    e_true = np.asarray(jax.vmap(system.energy)(st_ref.pt.states))
    np.testing.assert_allclose(
        np.asarray(st_ref.pt.energy), e_true, rtol=0, atol=1e-3
    )
    assert res_ref.trace["swap_attempt"].any()
    assert res_ref.trace["swap_accept"].any()


def test_round_interval_step_is_single_launch():
    """The structural claim of this optimisation: with use_fused_round the
    whole interval (sweeps AND exchange) is ONE pallas_call, and no
    `jax.random` traffic (threefry) remains in the step — the per-interval
    fused path still re-enters `jax.random` for its swap draw."""
    spec = StepSpec(n_replicas=R, sweeps_per_interval=4)
    st = pt.init_replicas(
        ising.IsingSystem(length=L, use_pallas=True, use_fused=True,
                          use_fused_round=True),
        R, jax.random.key(0),
    )
    step = make_interval_step(
        ising.IsingSystem(length=L, use_pallas=True, use_fused=True,
                          use_fused_round=True),
        spec,
    )
    txt = str(jax.make_jaxpr(step)(st, BETAS))
    assert txt.count("pallas_call") == 1
    # no host-side PRNG remains: only random_unwrap (key -> raw words for the
    # in-kernel counter PRNG), never a fold_in or a bits draw
    assert "random_fold_in" not in txt and "random_bits" not in txt
    # contrast: the interval-fused (non-round) path exits the kernel for the
    # swap phase and draws its uniforms from jax.random
    step_fused = make_interval_step(
        ising.IsingSystem(length=L, use_pallas=True, use_fused=True), spec
    )
    txt_fused = str(jax.make_jaxpr(step_fused)(st, BETAS))
    assert "random_fold_in" in txt_fused and "random_bits" in txt_fused


@pytest.mark.parametrize("bad_spec,match", [
    (dict(do_swap=False), "swaps on"),
    (dict(swap_mode="state"), "temp"),
    (dict(exchange=make_strategy("windowed")), "DEO/SEO"),
    (dict(exchange=make_strategy("vmpt")), "DEO/SEO"),
])
def test_round_path_rejects_incompatible_spec(bad_spec, match):
    """An unsupported spec must fail loudly at build time — silently falling
    back to the strategy path would change the random stream underfoot."""
    system = ising.IsingSystem(
        length=L, use_pallas=True, use_fused=True, use_fused_round=True
    )
    spec = StepSpec(n_replicas=R, sweeps_per_interval=4, **bad_spec)
    with pytest.raises(ValueError, match=match):
        make_interval_step(system, spec)


def test_use_fused_round_requires_use_fused():
    with pytest.raises(ValueError, match="use_fused=True"):
        ising.IsingSystem(length=L, use_fused_round=True)
    with pytest.raises(ValueError, match="use_fused=True"):
        PottsSystem(shape=(4, 4), use_fused_round=True)
