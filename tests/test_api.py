"""The declarative RunSpec API (DESIGN.md §API).

Covers the spec tree's lossless JSON round-trip (every registered system +
hypothesis-generated specs), strict rejection of unknown versions/keys, the
Session-vs-raw-Engine bit-equality contract, the callback pipeline
(checkpoint/early-stop/trace streaming), resume-from-record, and the
``python -m repro`` CLI surface.
"""
import json
import os

import numpy as np
import pytest

import jax

from repro.api import (
    AdaptSpec,
    Callback,
    CheckpointCallback,
    EarlyStopCallback,
    EngineSpec,
    ExchangeSpec,
    LadderSpec,
    PhaseSpec,
    RunSpec,
    ScheduleSpec,
    Session,
    SystemSpec,
    TraceWriterCallback,
    simple_schedule,
)
from repro.api.cli import main as cli_main
from repro.checkpoint.manager import CheckpointManager
from repro.core import systems
from repro.engine import AdaptConfig, Engine, EngineConfig
from repro.validate.conformance import entry_runspec


def tiny_ising_spec(**overrides) -> RunSpec:
    base = dict(
        system=SystemSpec("ising", {"length": 4, "accept_rule": "glauber"}),
        ladder=LadderSpec(kind="custom", n_replicas=4,
                          temps=(1.5, 2.2, 3.1, 4.4)),
        engine=EngineSpec(swap_interval=5, chunk_intervals=4),
        schedule=ScheduleSpec(phases=(PhaseSpec(name="measure", n_sweeps=60),)),
        observables=("absmag",),
        seed=2,
    )
    base.update(overrides)
    return RunSpec(**base)


# ---------- JSON round-trip -----------------------------------------------------


@pytest.mark.parametrize("name", sorted(systems.REGISTRY))
def test_roundtrip_every_registered_system(name):
    """from_json(to_json(s)) == s for the conformance spec of every system."""
    spec = entry_runspec(systems.REGISTRY[name], seed=3)
    assert RunSpec.from_json(spec.to_json()) == spec
    # and the dict form too (what the CLI reads)
    assert RunSpec.from_dict(json.loads(spec.to_json())) == spec


def test_roundtrip_preserves_defaults_and_none_adapt():
    spec = tiny_ising_spec()
    assert spec.adapt is None
    again = RunSpec.from_json(spec.to_json())
    assert again == spec
    assert again.adapt is None
    assert again.engine == EngineSpec(swap_interval=5, chunk_intervals=4)


def test_roundtrip_hypothesis_generated_specs():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @st.composite
    def runspecs(draw):
        r = draw(st.integers(2, 6))
        kind = draw(st.sampled_from(["paper", "linear", "geometric", "custom"]))
        t_min = draw(st.floats(0.5, 2.0, allow_nan=False))
        t_max = t_min + draw(st.floats(0.5, 8.0, allow_nan=False))
        temps = None
        if kind == "custom":
            temps = tuple(float(t) for t in np.linspace(t_min, t_max, r))
        interval = draw(st.integers(1, 20))
        n_phases = draw(st.integers(1, 4))
        phases = tuple(
            PhaseSpec(
                name=f"p{i}",
                n_sweeps=interval * draw(st.integers(1, 40)),
                adapt=draw(st.booleans()),
                reset_stats=draw(st.booleans()),
            )
            for i in range(n_phases)
        )
        name = draw(st.sampled_from(sorted(systems.REGISTRY)))
        return RunSpec(
            system=SystemSpec(name, dict(systems.REGISTRY[name].params)),
            ladder=LadderSpec(kind=kind, n_replicas=r, t_min=t_min,
                              t_max=t_max, temps=temps),
            engine=EngineSpec(
                swap_interval=interval,
                criterion=draw(st.sampled_from(["logistic", "metropolis"])),
                swap_mode=draw(st.sampled_from(["temp", "state"])),
                chunk_intervals=draw(st.integers(1, 64)),
                n_chains=draw(st.integers(1, 4)),
                record_trace=draw(st.booleans()),
            ),
            adapt=AdaptSpec(
                target=draw(st.floats(0.05, 0.9, allow_nan=False)),
                max_rounds=draw(st.one_of(st.none(), st.integers(1, 9))),
            ),
            schedule=ScheduleSpec(phases=phases),
            observables=tuple(systems.REGISTRY[name].observable_names),
            seed=draw(st.integers(0, 2**31 - 1)),
        )

    @hyp.given(runspecs())
    @hyp.settings(max_examples=60, deadline=None)
    def check(spec):
        assert RunSpec.from_json(spec.to_json()) == spec

    check()


def test_unknown_spec_version_rejected():
    data = json.loads(tiny_ising_spec().to_json())
    data["spec_version"] = 99
    with pytest.raises(ValueError, match="spec_version"):
        RunSpec.from_dict(data)
    with pytest.raises(ValueError, match="spec_version"):
        tiny_ising_spec(spec_version=0)


def test_unknown_keys_rejected_everywhere():
    good = json.loads(tiny_ising_spec().to_json())
    for path in (("bogus",), ("system", "bogus"), ("ladder", "bogus"),
                 ("engine", "bogus"), ("exchange", "bogus")):
        data = json.loads(json.dumps(good))
        node = data
        for k in path[:-1]:
            node = node[k]
        node[path[-1]] = 1
        with pytest.raises(ValueError, match="unknown key"):
            RunSpec.from_dict(data)


def test_enum_valued_fields_rejected_at_parse_time():
    """Satellite guard: a typo'd enum value must fail in `from_json` with
    the field and its allowed values named — not deep inside the engine."""
    good = json.loads(tiny_ising_spec().to_json())
    cases = [
        (("engine", "criterion"), "boltzman", "criterion.*allowed"),
        (("engine", "swap_mode"), "both", "swap_mode.*allowed"),
        (("ladder", "kind"), "logarithmic", "bad ladder kind"),
        (("exchange", "strategy"), "qpam", "strategy.*allowed"),
    ]
    for path, val, match in cases:
        data = json.loads(json.dumps(good))
        node = data
        for k in path[:-1]:
            node = node[k]
        node[path[-1]] = val
        with pytest.raises(ValueError, match=match):
            RunSpec.from_dict(data)
    # adapt.mode rides on a spec that actually has an adapt block
    with_adapt = json.loads(tiny_ising_spec(
        adapt=AdaptSpec(target=0.3)
    ).to_json())
    with_adapt["adapt"]["mode"] = "osmosis"
    with pytest.raises(ValueError, match="adapt mode.*allowed"):
        RunSpec.from_dict(with_adapt)
    # and the constructors reject the same values directly
    with pytest.raises(ValueError, match="allowed"):
        EngineSpec(criterion="boltzman")
    with pytest.raises(ValueError, match="allowed"):
        ExchangeSpec(strategy="qpam")
    with pytest.raises(ValueError, match="allowed"):
        AdaptSpec(mode="osmosis")


def test_exchange_spec_roundtrip_and_default():
    spec = tiny_ising_spec()
    assert spec.exchange == ExchangeSpec()  # deo is the default
    for strat in ("seo", "windowed", "vmpt"):
        s = tiny_ising_spec(exchange=ExchangeSpec(strategy=strat, window=5))
        again = RunSpec.from_json(s.to_json())
        assert again == s
        assert again.exchange.strategy == strat
    # a pre-exchange JSON (no "exchange" key) parses to the default
    data = json.loads(spec.to_json())
    del data["exchange"]
    assert RunSpec.from_dict(data).exchange == ExchangeSpec()


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="custom ladder"):
        LadderSpec(kind="custom", n_replicas=4)
    with pytest.raises(ValueError, match="bad ladder kind"):
        LadderSpec(kind="nope")
    with pytest.raises(ValueError, match="multiple of the engine interval"):
        tiny_ising_spec(schedule=ScheduleSpec(
            phases=(PhaseSpec(name="m", n_sweeps=7),)
        ))
    with pytest.raises(ValueError, match="no AdaptSpec"):
        tiny_ising_spec(schedule=ScheduleSpec(
            phases=(PhaseSpec(name="m", n_sweeps=10, adapt=True),)
        ))
    with pytest.raises(ValueError, match="duplicate phase"):
        ScheduleSpec(phases=(PhaseSpec(name="m", n_sweeps=5),
                             PhaseSpec(name="m", n_sweeps=5)))
    with pytest.raises(KeyError, match="unknown system"):
        SystemSpec("not_a_system").build()
    spec = tiny_ising_spec(observables=("not_an_obs",))
    with pytest.raises(KeyError, match="no observable"):
        Session(spec)


def test_ladder_kinds_build_expected_shapes():
    for kind in ("paper", "linear", "geometric"):
        t = LadderSpec(kind=kind, n_replicas=6, t_min=1.0, t_max=4.0).build()
        assert t.shape == (6,)
        assert np.all(np.diff(t) > 0)
    lin = LadderSpec(kind="linear", n_replicas=5, t_min=1.0, t_max=4.0).build()
    np.testing.assert_allclose(lin, np.linspace(1.0, 4.0, 5), rtol=1e-6)


# ---------- Session execution contract ------------------------------------------


def test_session_bit_equal_to_raw_engine_fixed_ladder():
    """Acceptance criterion: Session.run == hand-driven Engine, bit-for-bit."""
    spec = tiny_ising_spec(
        schedule=ScheduleSpec(phases=(
            PhaseSpec(name="burn", n_sweeps=40),
            PhaseSpec(name="measure", n_sweeps=60, reset_stats=True),
        )),
    )
    result = Session(spec).run()

    system = systems.make_system("ising", {"length": 4, "accept_rule": "glauber"})
    eng = Engine(
        system,
        EngineConfig(n_replicas=4, swap_interval=5, chunk_intervals=4),
        observables=systems.named_observables("ising", system, ["absmag"]),
    )
    st = eng.init(jax.random.key(2), np.asarray(spec.ladder.temps))
    st, _ = eng.run(st, 40)
    st = eng.reset_stats(st)
    st, res = eng.run(st, 60)
    e = np.asarray(st.pt.energy)[np.argsort(np.asarray(st.pt.rung))]
    np.testing.assert_array_equal(e, result.final_energies())
    np.testing.assert_array_equal(
        res.summary["mean_absmag"],
        result.phases["measure"].summary["mean_absmag"],
    )


def test_session_adaptive_matches_raw_engine():
    spec = tiny_ising_spec(
        adapt=AdaptSpec(target=0.3, min_attempts_per_pair=2, max_rounds=2),
        schedule=ScheduleSpec(phases=(
            PhaseSpec(name="burn", n_sweeps=100, adapt=True),
            PhaseSpec(name="measure", n_sweeps=50, reset_stats=True),
        )),
    )
    result = Session(spec).run()
    assert len(result.phases["burn"].ladder_history) == 3  # initial + 2 retunes

    system = spec.system.build()
    eng = Engine(
        system,
        EngineConfig(n_replicas=4, swap_interval=5, chunk_intervals=4),
        observables=spec.system.observables(system, spec.observables),
        adapt=AdaptConfig(target=0.3, min_attempts_per_pair=2, max_rounds=2),
    )
    st = eng.init(jax.random.key(2), np.asarray(spec.ladder.temps))
    st, _ = eng.run(st, 100)
    eng.adapt = None
    st = eng.reset_stats(st)
    st, _ = eng.run(st, 50)
    np.testing.assert_array_equal(np.asarray(st.betas),
                                  np.asarray(result.state.betas))
    e = np.asarray(st.pt.energy)[np.argsort(np.asarray(st.pt.rung))]
    np.testing.assert_array_equal(e, result.final_energies())


def test_callback_order_and_payloads():
    events = []

    class Recorder(Callback):
        def on_phase_start(self, session, phase):
            events.append(("start", phase.name))

        def on_chunk(self, session, info):
            events.append(("chunk", info.index, info.sweeps_done))

        def on_phase_end(self, session, phase, result):
            events.append(("end", phase.name, result.n_sweeps))

    spec = tiny_ising_spec(schedule=ScheduleSpec(phases=(
        PhaseSpec(name="a", n_sweeps=40),  # 8 intervals = 2 chunks
        PhaseSpec(name="b", n_sweeps=20),  # 4 intervals = 1 chunk
    )))
    Session(spec, callbacks=[Recorder()]).run()
    assert events == [
        ("start", "a"), ("chunk", 1, 20), ("chunk", 2, 40), ("end", "a", 40),
        ("start", "b"), ("chunk", 1, 20), ("end", "b", 20),
    ]


def test_early_stop_callback():
    spec = tiny_ising_spec(schedule=ScheduleSpec(phases=(
        PhaseSpec(name="long", n_sweeps=200),
        PhaseSpec(name="never", n_sweeps=20),
    )))
    stop_after = EarlyStopCallback(lambda info: info.sweeps_done >= 40)
    result = Session(spec, callbacks=[stop_after]).run()
    assert result.stopped_early
    assert list(result.phases) == ["long"]
    assert result.phases["long"].stopped_early
    assert result.phases["long"].n_sweeps == 40
    assert int(np.asarray(result.state.pt.t)) == 40


def test_early_stop_on_final_chunk_still_skips_later_phases():
    """A stop request landing exactly on a phase's last chunk must not be
    silently dropped: the remaining phases stay skipped."""
    spec = tiny_ising_spec(schedule=ScheduleSpec(phases=(
        PhaseSpec(name="first", n_sweeps=20),  # exactly one chunk
        PhaseSpec(name="never", n_sweeps=20),
    )))
    result = Session(spec, callbacks=[EarlyStopCallback(lambda i: True)]).run()
    assert result.stopped_early
    assert list(result.phases) == ["first"]
    assert result.phases["first"].n_sweeps == 20  # budget completed...
    assert result.phases["first"].stopped_early  # ...but the stop registered


def test_trace_writer_streams_chunks(tmp_path):
    spec = tiny_ising_spec(
        engine=EngineSpec(swap_interval=5, chunk_intervals=4, record_trace=True),
        schedule=ScheduleSpec(phases=(PhaseSpec(name="m", n_sweeps=60),)),
    )
    reference = Session(spec).run()  # no consumer -> trace in the result
    result = Session(spec, callbacks=[TraceWriterCallback(tmp_path)]).run()
    # the writer consumes the stream, so the engine must NOT also buffer it
    assert result.phases["m"].trace is None
    assert reference.phases["m"].trace is not None
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 3  # 12 intervals = 3 chunks of 4
    streamed = np.concatenate(
        [np.load(tmp_path / f)["energy"] for f in files], axis=0
    )
    np.testing.assert_array_equal(streamed, reference.phases["m"].trace["energy"])


# ---------- resume from (spec, state) -------------------------------------------


@pytest.mark.parametrize("resume_from_step", [20, 40, 60, 80])
def test_checkpoint_resume_bit_equal(tmp_path, resume_from_step):
    """Resume from ANY checkpoint — including mid-adapt-phase ones, where the
    adaptation window baselines must come back from the step meta — and land
    on the exact same final state as the uninterrupted run."""
    spec = tiny_ising_spec(
        adapt=AdaptSpec(target=0.3, min_attempts_per_pair=2, max_rounds=2),
        schedule=ScheduleSpec(phases=(
            PhaseSpec(name="burn", n_sweeps=60, adapt=True),
            PhaseSpec(name="measure", n_sweeps=40, reset_stats=True),
        )),
    )
    ref = Session(spec).run()
    assert len(ref.phases["burn"].ladder_history) > 1  # adaptation did fire

    ckdir = tmp_path / "ck"
    full = Session(
        spec, callbacks=[CheckpointCallback(ckdir, every_chunks=1, keep=0)]
    ).run()
    np.testing.assert_array_equal(ref.final_energies(), full.final_energies())
    np.testing.assert_array_equal(np.asarray(ref.state.betas),
                                  np.asarray(full.state.betas))

    # Roll back to the chosen checkpoint and resume from the directory alone.
    import shutil

    mgr = CheckpointManager(str(ckdir), keep=0)
    steps = mgr.steps()
    assert resume_from_step in steps
    for s in steps:
        if s > resume_from_step:
            shutil.rmtree(mgr._step_dir(s))
    resumed = Session.from_checkpoint(str(ckdir)).run()
    np.testing.assert_array_equal(ref.final_energies(), resumed.final_energies())
    np.testing.assert_array_equal(np.asarray(ref.state.betas),
                                  np.asarray(resumed.state.betas))
    assert int(np.asarray(resumed.state.pt.t)) == 100


def test_checkpoint_meta_carries_exact_f64_ladder(tmp_path):
    """meta['temps'] must be the engine's authoritative f64 ladder, not the
    ulp-lossy 1/f32(betas) inversion — resumed retunes depend on it."""
    spec = tiny_ising_spec(
        adapt=AdaptSpec(target=0.3, min_attempts_per_pair=2, max_rounds=2),
        schedule=ScheduleSpec(phases=(
            PhaseSpec(name="burn", n_sweeps=60, adapt=True),
        )),
    )
    ckdir = tmp_path / "ck"
    session = Session(spec, callbacks=[CheckpointCallback(ckdir, keep=0)])
    session.run()
    mgr = CheckpointManager(str(ckdir), keep=0)
    _, meta = mgr.restore(mgr.steps()[-1], session.state)
    np.testing.assert_array_equal(
        np.asarray(meta["temps"], np.float64), session.engine._temps
    )
    assert "adapt_attempts_base" in meta and meta["adapt_rounds"] >= 1


def test_engine_reinit_resets_adaptation_window():
    """A re-init'd engine must adapt again: fresh states restart the swap
    counters at zero, so stale window baselines would starve the feedback."""
    system = systems.make_system("ising", {"length": 4, "accept_rule": "glauber"})
    eng = Engine(
        system,
        EngineConfig(n_replicas=4, swap_interval=5, chunk_intervals=2),
        adapt=AdaptConfig(target=0.3, min_attempts_per_pair=2),
    )
    temps = np.asarray([1.5, 2.2, 3.1, 4.4])
    st = eng.init(jax.random.key(0), temps)
    _, res1 = eng.run(st, 100)
    assert len(res1.ladder_history) > 1  # adaptation fired
    st2 = eng.init(jax.random.key(1), temps)
    _, res2 = eng.run(st2, 100)
    assert len(res2.ladder_history) > 1  # ...and fires again after re-init


def test_save_spec_load_spec_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.load_spec() is None
    spec = tiny_ising_spec()
    mgr.save_spec(spec.to_json())
    assert RunSpec.from_dict(mgr.load_spec()) == spec
    with pytest.raises(json.JSONDecodeError):
        mgr.save_spec("{not json")


def test_resume_without_spec_or_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="spec.json"):
        Session.from_checkpoint(str(tmp_path / "empty"))
    mgr = CheckpointManager(str(tmp_path / "speconly"))
    mgr.save_spec(tiny_ising_spec().to_json())
    with pytest.raises(FileNotFoundError, match="checkpoint"):
        Session.from_checkpoint(str(tmp_path / "speconly"))


# ---------- CLI -----------------------------------------------------------------


def test_cli_run_writes_manifest_and_reproduces_session(tmp_path, capsys):
    spec = tiny_ising_spec()
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(spec.to_json())
    out = tmp_path / "out"
    rc = cli_main(["run", str(spec_path), "--out", str(out), "--quiet"])
    assert rc == 0
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["spec_version"] == 1
    assert RunSpec.from_dict(manifest["spec"]) == spec
    ref = Session(spec).run()
    np.testing.assert_array_equal(
        np.asarray(manifest["final"]["energy"]), ref.final_energies()
    )
    assert (out / "checkpoints" / "spec.json").exists()
    # manifest path printed on stdout (shell-composable)
    assert capsys.readouterr().out.strip().endswith("manifest.json")


def test_cli_list_systems(capsys):
    assert cli_main(["list-systems"]) == 0
    out = capsys.readouterr().out
    for name in systems.CONSTRUCTORS:
        assert name in out


def test_cli_validate_unknown_system(capsys):
    assert cli_main(["validate", "not_a_system"]) == 2
