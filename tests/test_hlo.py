"""HLO parser tests: collective-byte accounting, trip-count correction,
traffic estimator, and the cost_analysis per-partition convention."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.hlo.collectives import parse_collectives, _shape_bytes
from repro.hlo.traffic import hbm_traffic_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert _shape_bytes("bf16[16]") == 32
    assert _shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert _shape_bytes("pred[]") == 1


@pytest.fixture(scope="module")
def mesh8():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dry-run only)")
    return jax.make_mesh((jax.device_count(),), ("d",))


def test_synthetic_hlo_parsing():
    hlo = """
HloModule test

%body.1 (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,128]{1,0} get-tuple-element(%p), index=1
  %ar = f32[64,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[64,128]) tuple(%ip, %ar)
}

%cond.1 (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[64,128], b: f32[32,16]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %b = f32[32,16]{1,0} parameter(1)
  %ag = f32[128,16]{1,0} all-gather(%b), channel_id=2, replica_groups=[4,4]<=[16], dimensions={0}
  %t0 = (s32[], f32[64,128]) tuple(%i0, %a)
  %w = (s32[], f32[64,128]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[64,128]{1,0} get-tuple-element(%w), index=1
}
"""
    st = parse_collectives(hlo)
    ar_bytes = 64 * 128 * 4
    ag_bytes = 32 * 16 * 4  # operand (pre-gather shard)
    # all-reduce inside while body: x12 trip count
    assert st.by_op["all-reduce"] == ar_bytes * 12
    assert st.by_op["all-gather"] == ag_bytes
    # wire model: AR factor 2 * (4-1)/4 ; AG factor 1 * 3/4
    want_wire = ar_bytes * 12 * 2 * 0.75 + ag_bytes * 0.75
    assert st.wire_bytes == pytest.approx(want_wire)


def test_parse_real_sharded_program():
    """End-to-end on a real compiled module (single CPU device: collectives
    may be absent; with >1 fake device the matmul TP produces an all-reduce).
    This asserts the parser runs on real XLA output without error."""
    def f(w, x):
        return jnp.mean((x @ w) ** 2)

    w = jnp.ones((64, 32))
    x = jnp.ones((16, 64))
    compiled = jax.jit(f).lower(w, x).compile()
    st = parse_collectives(compiled.as_text())
    assert st.payload_bytes >= 0
    t = hbm_traffic_bytes(compiled.as_text())
    # traffic must at least cover reading both inputs once and be far below
    # the pathological everything-counted bound
    assert t >= (64 * 32 + 16 * 64) * 4
    assert t < 100 * (64 * 32 + 16 * 64) * 4


def test_traffic_excludes_fusion_internals():
    hlo = """
HloModule t

%fused_computation (a: f32[1024]) -> f32[1024] {
  %a = f32[1024]{0} parameter(0)
  %big = f32[1024]{0} exponential(%a)
  %big2 = f32[1024]{0} add(%big, %big)
  ROOT %r = f32[1024]{0} multiply(%big2, %big2)
}

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024]{0} parameter(0)
  ROOT %f = f32[1024]{0} fusion(%x), kind=kLoop, calls=%fused_computation
}
"""
    t = hbm_traffic_bytes(hlo)
    # only the fusion op itself: read x (4KB) + write result (4KB)
    assert t == 1024 * 4 * 2


def test_cost_analysis_is_per_partition():
    """Documented convention check (DESIGN.md §7): flops from cost_analysis
    are per-partition on this backend."""
    def f(x):
        return x @ x

    x = jnp.ones((128, 128))
    ca = jax.jit(f).lower(x).compile().cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] == pytest.approx(2 * 128**3, rel=0.01)
