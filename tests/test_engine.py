"""Chunked streaming engine correctness: chunked-vs-monolithic
bit-equivalence, online-stats vs post-hoc diagnostics agreement, in-loop
adaptive-ladder convergence, ensemble-axis independence, and mid-run
checkpoint resume."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import diagnostics, gaussian, ising, ladder, pt
from repro.engine import (
    AdaptConfig,
    Engine,
    EngineConfig,
    combine_chains,
    init_stats,
    summarize,
    update_stats,
)

R, L = 6, 8
TEMPS = np.asarray(ladder.linear_ladder(R, 1.0, 3.5))
OBS = {"am": lambda s: jnp.abs(ising.magnetization(s))}


def _engine(**kw):
    system = ising.IsingSystem(length=L)
    defaults = dict(n_replicas=R, swap_interval=5, chunk_intervals=3)
    defaults.update({k: v for k, v in kw.items() if k in EngineConfig.__dataclass_fields__})
    cfg = EngineConfig(**defaults)
    adapt = kw.get("adapt")
    return system, Engine(system, cfg, observables=OBS, adapt=adapt)


# ---------- chunked == monolithic (same PRNG streams) ---------------------------
@pytest.mark.parametrize("swap_mode", ["temp", "state"])
@pytest.mark.parametrize("chunk_intervals", [1, 3, 16])
def test_chunked_bit_equals_monolithic(swap_mode, chunk_intervals):
    """Chunk boundaries must be invisible: the engine's streamed trace and
    final state are bit-identical to the seed one-scan `pt.run`."""
    sweeps = 60
    system, eng = _engine(
        swap_mode=swap_mode, chunk_intervals=chunk_intervals, record_trace=True
    )
    cfg = pt.PTConfig(
        n_replicas=R,
        temps=tuple(float(t) for t in TEMPS),
        swap_interval=5,
        swap_mode=swap_mode,
    )
    st = pt.init(system, cfg, jax.random.key(1))
    st_mono, trace = pt.run(system, cfg, st, sweeps, observables=OBS)

    est = eng.init(jax.random.key(1), TEMPS)
    est, res = eng.run(est, sweeps)

    for k in trace:
        np.testing.assert_array_equal(np.asarray(trace[k]), res.trace[k], err_msg=k)
    np.testing.assert_array_equal(np.asarray(st_mono.states), np.asarray(est.pt.states))
    np.testing.assert_array_equal(np.asarray(st_mono.energy), np.asarray(est.pt.energy))
    np.testing.assert_array_equal(np.asarray(st_mono.rung), np.asarray(est.pt.rung))


def test_compile_cost_is_constant_in_run_length():
    """Arbitrarily long runs reuse one executable (plus one remainder)."""
    _, eng = _engine(chunk_intervals=4)
    st = eng.init(jax.random.key(0), TEMPS)
    st, _ = eng.run(st, 200)  # 40 intervals = 10 full chunks
    st, _ = eng.run(st, 430)  # 86 intervals = 21 full + remainder of 2
    assert set(eng._executables) == {4, 2}


# ---------- online stats == post-hoc diagnostics --------------------------------
def test_online_stats_match_posthoc_diagnostics():
    sweeps = 100
    _, eng = _engine(record_trace=True, chunk_intervals=4)
    st = eng.init(jax.random.key(2), TEMPS)
    st, res = eng.run(st, sweeps)
    trace = res.trace

    # Welford mean/var per rung == numpy over the full trace
    for k in ("energy", "am"):
        np.testing.assert_allclose(
            res.summary[f"mean_{k}"], trace[k].mean(axis=0), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            res.summary[f"var_{k}"], trace[k].var(axis=0, ddof=1), rtol=1e-4, atol=1e-5
        )
    # swap counters == diagnostics.swap_acceptance_rate on the same trace
    np.testing.assert_allclose(
        res.summary["swap_acceptance"],
        diagnostics.swap_acceptance_rate(trace),
        rtol=1e-12,
    )


def test_round_trip_and_flow_tracking():
    """On a 2-rung ladder every accepted swap pair completes half a cycle:
    round trips must be counted and flow fractions must be in [0, 1]."""
    system = gaussian.GaussianMixture(mus=(-1.0, 1.0), sigmas=(1.0, 1.0), step_size=1.0)
    cfg = EngineConfig(n_replicas=2, swap_interval=1, chunk_intervals=50)
    eng = Engine(system, cfg)
    st = eng.init(jax.random.key(4), np.asarray([1.0, 2.0]))
    st, res = eng.run(st, 200)
    assert res.summary["round_trips"].sum() > 0
    assert (res.summary["flow_up"] >= 0).all() and (res.summary["flow_up"] <= 1).all()
    # reset_stats zeroes the counters but keeps the flow labels — direction
    # is chain state, so in-progress round trips survive a measurement reset
    st2 = eng.reset_stats(st)
    np.testing.assert_array_equal(
        np.asarray(st2.stats.direction), np.asarray(st.stats.direction)
    )
    assert int(np.asarray(st2.stats.n_records)) == 0
    assert int(np.asarray(st2.stats.round_trips).sum()) == 0


def test_welford_combine_chains_matches_concatenated_data(rng):
    """Chan's merge over the chain axis == one-pass stats on pooled data."""
    c, n, r = 3, 40, 5
    data = rng.normal(size=(c, n, r)).astype(np.float32)
    per_chain = []
    for ci in range(c):
        s = init_stats(r, ["energy"])
        for t in range(n):
            rec = {
                "energy": jnp.asarray(data[ci, t]),
                "swap_accept": jnp.zeros((r,), bool),
                "swap_prob": jnp.zeros((r,)),
                "swap_attempt": jnp.zeros((r,), bool),
            }
            s = update_stats(s, rec, jnp.arange(r, dtype=jnp.int32))
        per_chain.append(s)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_chain)
    pooled = combine_chains(stacked)
    flat = data.reshape(c * n, r).astype(np.float64)
    np.testing.assert_allclose(pooled["mean_energy"], flat.mean(axis=0), rtol=1e-5)
    np.testing.assert_allclose(
        pooled["var_energy"], flat.var(axis=0, ddof=1), rtol=1e-4
    )


def test_combine_chains_fractional_weights_unbiased():
    """Regression: per-rung chain weights were normalized by ``max(ws, 1)``,
    so a pooled estimator weight below 1 (early-run VMPT, fractional
    per-record weights) scaled the grand mean by ws — biasing it toward
    zero; and the variance-denominator clamp ``max(wsum - 1, 1)`` silently
    inflated the denominator for pooled weights in (1, 2)."""
    r = 3
    s = init_stats(r, ["energy"], n_chains=2)
    ws = jnp.asarray([[0.3, 1.0, 0.0], [0.1, 0.5, 0.0]], jnp.float32)
    means = jnp.asarray([[10.0, 10.0, 0.0], [20.0, 16.0, 0.0]], jnp.float32)
    s = dataclasses.replace(
        s, weight_sum=ws, mean={"energy": means},
        n_records=jnp.asarray([4, 4], jnp.int32),
    )
    pooled = combine_chains(s)
    # rung 0 (pooled weight 0.4 < 1): true weighted mean, not 0.4x of it
    np.testing.assert_allclose(pooled["mean_energy"][0], 12.5, rtol=1e-6)
    np.testing.assert_allclose(pooled["mean_energy"][1], 12.0, rtol=1e-6)
    # rung 1 (pooled weight 1.5): denominator is wsum - 1 = 0.5, not the
    # clamped 1; m2 here is purely the between-chain spread = 12
    np.testing.assert_allclose(pooled["var_energy"][1], 24.0, rtol=1e-6)
    # a rung with zero total weight stays finite (explicit zero guard)
    assert pooled["mean_energy"][2] == 0.0


# ---------- in-loop adaptive ladders --------------------------------------------
def test_adaptive_ladder_moves_acceptance_toward_target():
    """Feedback between chunks should pull the measured per-pair acceptance
    toward the target relative to the initial (deliberately skewed) ladder."""
    system = ising.IsingSystem(length=L)
    target = 0.4
    temps0 = np.asarray(ladder.linear_ladder(R, 1.0, 4.0))
    cfg = EngineConfig(
        n_replicas=R, swap_interval=2, chunk_intervals=50, n_chains=4
    )

    def spread(adapt):
        eng = Engine(system, cfg, adapt=adapt)
        st = eng.init(jax.random.key(5), temps0)
        st, _ = eng.run(st, 800)
        # measure on a fresh window with the (possibly retuned) final ladder
        st = eng.reset_stats(st)
        st, _ = eng.run(st, 400)
        acc = combine_chains(st.stats)["swap_acceptance"]
        return float(np.abs(acc - target).mean()), eng

    err_fixed, _ = spread(None)
    err_adapted, eng = spread(
        AdaptConfig(target=target, min_attempts_per_pair=20)
    )
    assert err_adapted < err_fixed, (err_adapted, err_fixed)


def test_adapt_retunes_without_recompiling():
    """Betas are traced: a retune must re-enter the same executable."""
    system, eng = _engine(
        swap_interval=2,
        chunk_intervals=20,
        adapt=AdaptConfig(target=0.4, min_attempts_per_pair=5),
    )
    st = eng.init(jax.random.key(6), TEMPS)
    st, res = eng.run(st, 400)
    assert len(res.ladder_history) > 1  # it did retune...
    assert len(eng._executables) == 1  # ...with zero extra compiles
    # endpoints stay pinned
    np.testing.assert_allclose(res.ladder_history[-1][0], TEMPS[0], rtol=1e-5)
    np.testing.assert_allclose(res.ladder_history[-1][-1], TEMPS[-1], rtol=1e-4)


# ---------- ensemble axis --------------------------------------------------------
def test_ensemble_chains_independent_of_ensemble_size():
    """Chain c's stream derives from fold_in(key, c): its trajectory and
    trace must be bit-identical whether it runs in a C=2 or C=4 ensemble."""
    out = {}
    for c in (2, 4):
        _, eng = _engine(n_chains=c, record_trace=True)
        st = eng.init(jax.random.key(7), TEMPS)
        st, res = eng.run(st, 30)
        out[c] = (np.asarray(st.pt.energy), np.asarray(st.pt.states), res.trace)
    np.testing.assert_array_equal(out[2][0], out[4][0][:2])
    np.testing.assert_array_equal(out[2][1], out[4][1][:2])
    for k in out[2][2]:
        np.testing.assert_array_equal(out[2][2][k], out[4][2][k][:2], err_msg=k)


def test_ensemble_composes_with_sharding():
    """With n_chains > 1, `cfg.mesh` places whole chains on the ensemble
    axis and routes the run through the shard_map mega-step; on a 1x1 mesh
    the sharded trajectory must stay bit-equal to the plain path (real
    multi-device meshes are covered by tests/test_distributed.py)."""
    from repro.core.distributed import MeshSpec

    system = ising.IsingSystem(length=L)
    out = {}
    for mesh in (None, MeshSpec(ensemble=1, replica=1)):
        cfg = EngineConfig(
            n_replicas=R, swap_interval=5, chunk_intervals=2, n_chains=2,
            mesh=mesh,
        )
        eng = Engine(system, cfg, observables=OBS)
        st = eng.init(jax.random.key(11), TEMPS)
        st, res = eng.run(st, 20)
        out[mesh is not None] = (st, res)
    st, res = out[True]
    assert np.asarray(st.pt.states).shape == (2, R, L, L)
    assert res.summary["mean_energy"].shape == (2, R)
    plain, plain_res = out[False]
    np.testing.assert_array_equal(
        np.asarray(st.pt.energy), np.asarray(plain.pt.energy)
    )
    np.testing.assert_array_equal(
        np.asarray(st.pt.states), np.asarray(plain.pt.states)
    )
    np.testing.assert_array_equal(
        np.asarray(res.summary["mean_energy"]),
        np.asarray(plain_res.summary["mean_energy"]),
    )


def test_ensemble_shapes_and_pooling():
    c = 3
    _, eng = _engine(n_chains=c)
    st = eng.init(jax.random.key(8), TEMPS)
    st, res = eng.run(st, 30)
    assert np.asarray(st.pt.states).shape == (c, R, L, L)
    assert res.summary["mean_energy"].shape == (c, R)
    pooled = combine_chains(st.stats)
    assert pooled["mean_energy"].shape == (R,)
    assert pooled["n_records"] == c * 6


# ---------- checkpoint: save/resume engine state mid-run -------------------------
def test_checkpoint_resume_mid_run_bit_equal(tmp_path):
    system, eng = _engine(chunk_intervals=2)
    st0 = eng.init(jax.random.key(9), TEMPS)

    # uninterrupted reference
    ref, _ = eng.run(st0, 60)

    # interrupted: save every chunk, "crash" after 40 sweeps, resume latest
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = eng.init(jax.random.key(9), TEMPS)
    st, _ = eng.run(st, 40, checkpoint=mgr, checkpoint_every_chunks=1)
    restored, meta = eng.restore(mgr)
    assert meta["step"] == 40
    resumed, _ = eng.run(restored, 20)

    np.testing.assert_array_equal(np.asarray(ref.pt.states), np.asarray(resumed.pt.states))
    np.testing.assert_array_equal(np.asarray(ref.pt.energy), np.asarray(resumed.pt.energy))
    np.testing.assert_array_equal(np.asarray(ref.betas), np.asarray(resumed.betas))
    # stats survive too: accumulators continue, not restart
    assert int(np.asarray(resumed.stats.n_records)) == 12


def test_checkpoint_preserves_adapted_ladder(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    system, eng = _engine(
        swap_interval=2,
        chunk_intervals=20,
        adapt=AdaptConfig(target=0.4, min_attempts_per_pair=5),
    )
    st = eng.init(jax.random.key(10), TEMPS)
    st, res = eng.run(st, 400, checkpoint=mgr, checkpoint_every_chunks=1)
    assert len(res.ladder_history) > 1
    restored, meta = eng.restore(mgr)
    np.testing.assert_array_equal(np.asarray(st.betas), np.asarray(restored.betas))
    np.testing.assert_allclose(1.0 / np.asarray(meta["temps"]), np.asarray(st.betas), rtol=1e-6)


# ---------- guard rails -----------------------------------------------------------
# ---------- interval-fused kernel fast path -------------------------------------
def test_fused_interval_chunking_invariance_and_energy():
    """The fused fast path must keep the engine's two core contracts: chunk
    boundaries are invisible (counter PRNG keys on the global sweep counter,
    not on call structure), and the incrementally tracked energy matches a
    from-scratch recompute."""
    from repro.core.systems import batched_energy

    results = []
    for chunk_intervals in (1, 4):
        system = ising.IsingSystem(
            length=L, accept_rule="glauber", use_fused=True, use_pallas=True
        )
        eng = Engine(system, EngineConfig(
            n_replicas=R, swap_interval=5, chunk_intervals=chunk_intervals
        ), observables=OBS)
        st = eng.init(jax.random.key(3), TEMPS)
        st, _ = eng.run(st, 40)
        results.append(st)
    np.testing.assert_array_equal(
        np.asarray(results[0].pt.states), np.asarray(results[1].pt.states)
    )
    np.testing.assert_array_equal(
        np.asarray(results[0].pt.rung), np.asarray(results[1].pt.rung)
    )
    st = results[0]
    np.testing.assert_allclose(
        np.asarray(st.pt.energy),
        np.asarray(batched_energy(
            ising.IsingSystem(length=L), st.pt.states
        )),
        rtol=1e-5, atol=1e-3,
    )
    assert int(np.asarray(st.pt.t)) == 40


def test_fused_off_by_default_keeps_persweep_path():
    """`use_fused` is opt-in: a default system must take the per-sweep scan
    (the fused counter stream is deliberately different), so default engine
    trajectories stay bit-equal to pre-fused builds."""
    from repro.engine.driver import _batched_interval

    assert _batched_interval(ising.IsingSystem(length=L)) is None
    assert _batched_interval(gaussian.GaussianMixture(
        mus=(-1.0, 1.0), sigmas=(1.0, 1.0), weights=(0.5, 0.5)
    )) is None
    assert _batched_interval(
        ising.IsingSystem(length=L, use_fused=True)
    ) is not None


def test_run_rejects_non_interval_multiple():
    _, eng = _engine(swap_interval=5)
    st = eng.init(jax.random.key(0), TEMPS)
    with pytest.raises(ValueError, match="multiple"):
        eng.run(st, 17)


def test_init_rejects_wrong_ladder_shape():
    _, eng = _engine()
    with pytest.raises(ValueError, match="ladder shape"):
        eng.init(jax.random.key(0), np.ones(R + 1))
