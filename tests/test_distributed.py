"""Sharded mega-step correctness (DESIGN.md §Distributed).

In-process tests run on the single real CPU device with a 1x1 mesh — the
shard_map path must be bit-equal to the plain path there, for every
exchange strategy.  The real multi-device claims (8-way replica sharding
bit-equal to one device, beyond-single-chip capacity, checkpoint
portability across mesh shapes) run in a subprocess via
``tests/_mesh_child.py`` because ``--xla_force_host_platform_device_count``
must be set before jax is imported and tier-1 pins the parent to one
device (tests/conftest.py).

Set ``REPRO_SKIP_MESH_SUBPROCESS=1`` to skip the subprocess half (e.g. on
a machine where spawning 8 simulated devices is too slow).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core import ising, ladder
from repro.core.distributed import (
    CHAIN_AXIS,
    REPLICA_AXIS,
    MeshSpec,
    pt_partition_specs,
)
from repro.engine import Engine, EngineConfig
from repro.exchange import available_strategies

R, L = 8, 8
TEMPS = np.asarray(ladder.linear_ladder(R, 1.0, 3.5))


def _run(mesh, *, sweeps=30, exchange="deo", n_chains=1, chunk_intervals=3,
         **sys_kw):
    system = ising.IsingSystem(length=L, **sys_kw)
    cfg = EngineConfig(
        n_replicas=R, swap_interval=5, chunk_intervals=chunk_intervals,
        mesh=mesh, exchange=exchange, n_chains=n_chains,
    )
    eng = Engine(system, cfg)
    st = eng.init(jax.random.key(21), TEMPS)
    return eng.run(st, sweeps)


# ---------- MeshSpec --------------------------------------------------------------
def test_mesh_spec_validation():
    assert MeshSpec().n_devices == 1
    assert MeshSpec(ensemble=2, replica=4).n_devices == 8
    with pytest.raises(ValueError, match=">= 1"):
        MeshSpec(ensemble=0)
    with pytest.raises(ValueError, match="divide"):
        MeshSpec(replica=3).validate(n_replicas=8, n_chains=1)
    with pytest.raises(ValueError, match="divide"):
        MeshSpec(ensemble=2).validate(n_replicas=8, n_chains=3)
    MeshSpec(ensemble=2, replica=4).validate(n_replicas=8, n_chains=2)


def test_mesh_build_needs_enough_devices():
    spec = MeshSpec(ensemble=1, replica=1 + jax.device_count())
    with pytest.raises(ValueError, match="xla_force_host_platform_device_count"):
        spec.build()


def test_state_mode_rejects_replica_sharding():
    with pytest.raises(ValueError, match="temp"):
        EngineConfig(
            n_replicas=R, swap_interval=5, swap_mode="state",
            mesh=MeshSpec(ensemble=1, replica=2),
        )


def test_partition_specs_cover_the_state_tree():
    eng = Engine(ising.IsingSystem(length=L), EngineConfig(
        n_replicas=R, swap_interval=5, n_chains=2,
    ))
    st = eng.init(jax.random.key(0), TEMPS)
    specs = pt_partition_specs(st.pt, n_chains=2)
    assert tuple(specs.states)[:2] == (CHAIN_AXIS, REPLICA_AXIS)
    assert tuple(specs.energy)[:2] == (CHAIN_AXIS, REPLICA_AXIS)
    # per-chain scalars carry the chain axis only
    assert tuple(specs.key) == (CHAIN_AXIS,)
    assert tuple(specs.t) == (CHAIN_AXIS,)


# ---------- 1x1 mesh: shard_map path bit-equal in-process -------------------------
@pytest.mark.parametrize("exchange", sorted(available_strategies()))
def test_single_device_mesh_bit_equal(exchange):
    """The shard_map mega-step (gather O(R) rows -> full-ladder decision ->
    pull back local block) must reproduce the plain path bit-for-bit on a
    1x1 mesh — same PRNG streams, same swap decisions, same stats."""
    st_plain, res_plain = _run(None, exchange=exchange)
    st_mesh, res_mesh = _run(MeshSpec(), exchange=exchange)
    np.testing.assert_array_equal(
        np.asarray(st_plain.pt.energy), np.asarray(st_mesh.pt.energy)
    )
    np.testing.assert_array_equal(
        np.asarray(st_plain.pt.rung), np.asarray(st_mesh.pt.rung)
    )
    np.testing.assert_array_equal(
        np.asarray(st_plain.pt.states), np.asarray(st_mesh.pt.states)
    )
    for k, v in res_plain.summary.items():
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(res_mesh.summary[k]), err_msg=k
        )


def test_single_device_mesh_bit_equal_fused():
    st_plain, _ = _run(None, use_fused=True, use_pallas=True)
    st_mesh, _ = _run(MeshSpec(), use_fused=True, use_pallas=True)
    np.testing.assert_array_equal(
        np.asarray(st_plain.pt.states), np.asarray(st_mesh.pt.states)
    )
    np.testing.assert_array_equal(
        np.asarray(st_plain.pt.rung), np.asarray(st_mesh.pt.rung)
    )


# ---------- 8 simulated devices (subprocess) --------------------------------------
_SKIP_SUB = os.environ.get("REPRO_SKIP_MESH_SUBPROCESS") == "1"


@pytest.fixture(scope="module")
def mesh8(tmp_path_factory):
    """Run tests/_mesh_child.py once on 8 simulated devices; yield its
    output dir (mesh8.npz + a checkpoint saved on the 8-device mesh)."""
    if _SKIP_SUB:
        pytest.skip("REPRO_SKIP_MESH_SUBPROCESS=1")
    outdir = tmp_path_factory.mktemp("mesh8")
    child = os.path.join(os.path.dirname(__file__), "_mesh_child.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, child, str(outdir)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, (
        f"mesh child failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    return outdir


def test_sharded_deo_bit_equal_to_single_device(mesh8):
    """Same seeds, 8-way replica sharding: the child's trajectory must be
    bit-identical to this (single-device, unsharded) run."""
    out = np.load(mesh8 / "mesh8.npz")
    st, _ = _run(None, sweeps=60, chunk_intervals=2)
    np.testing.assert_array_equal(np.asarray(st.pt.energy), out["deo_energy"])
    np.testing.assert_array_equal(np.asarray(st.pt.rung), out["deo_rung"])
    np.testing.assert_array_equal(np.asarray(st.pt.states), out["deo_states"])


def test_sharded_fused_bit_equal_to_single_device(mesh8):
    """The fused kernel's counter PRNG keys on the *global* replica slot
    (replica_offset), so sharding must not change its stream."""
    out = np.load(mesh8 / "mesh8.npz")
    st, _ = _run(None, sweeps=60, chunk_intervals=2,
                 use_fused=True, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(st.pt.energy), out["fused_energy"])
    np.testing.assert_array_equal(np.asarray(st.pt.states), out["fused_states"])


def test_sharded_round_fused_bit_equal_to_single_device(mesh8):
    """The whole-round path sharded 8 ways (r_local=1, so the r_blk=8 kernel
    pads past R_local and the counter streams ride a nonzero replica_offset;
    the exchange reruns redundantly per device from the counter-PRNG swap
    stream) must be bit-identical to the single-device round launch."""
    out = np.load(mesh8 / "mesh8.npz")
    st, _ = _run(None, sweeps=60, chunk_intervals=2,
                 use_fused=True, use_pallas=True, use_fused_round=True,
                 pack_bits=True)
    np.testing.assert_array_equal(np.asarray(st.pt.energy), out["round_energy"])
    np.testing.assert_array_equal(np.asarray(st.pt.rung), out["round_rung"])
    np.testing.assert_array_equal(np.asarray(st.pt.states), out["round_states"])


def test_capacity_beyond_single_chip_vmem(mesh8):
    """The child ran an (R=64, L=128) ladder whose fused working set the
    static model puts past one chip's 16 MB VMEM; per-shard it fits."""
    from repro.kernels.ising_sweep import vmem_working_set_bytes_fused

    assert vmem_working_set_bytes_fused(64, 128) > 16 * 2**20
    assert vmem_working_set_bytes_fused(64 // 8, 128) <= 16 * 2**20
    out = np.load(mesh8 / "mesh8.npz")
    assert out["capacity_energy"].shape == (64,)
    assert np.all(np.isfinite(out["capacity_energy"]))
    assert int(out["capacity_t"]) == 10


def test_checkpoint_from_mesh_resumes_on_one_device(mesh8):
    """Checkpoints are mesh-shape independent: one saved mid-run on the
    8-device mesh restores on a single device and finishes bit-equal to an
    uninterrupted single-device run."""
    out = np.load(mesh8 / "mesh8.npz")
    system = ising.IsingSystem(length=L)
    cfg = EngineConfig(n_replicas=R, swap_interval=5, chunk_intervals=2)
    eng = Engine(system, cfg)
    restored, meta = eng.restore(CheckpointManager(str(mesh8 / "ckpt")))
    assert meta["step"] == 40
    resumed, _ = eng.run(restored, 20)
    np.testing.assert_array_equal(np.asarray(resumed.pt.energy), out["deo_energy"])
    np.testing.assert_array_equal(np.asarray(resumed.pt.states), out["deo_states"])
