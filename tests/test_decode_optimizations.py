"""Decode-path optimization correctness: ring cache == full cache for
windowed attention; prefix consistency of decode vs full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib


def _drive(cfg, params, n_steps, tokens):
    state = model_lib.init_decode_state(cfg, tokens.shape[0], max_seq=n_steps)
    outs = []
    for pos in range(n_steps):
        logits, state = model_lib.decode_step(
            params, cfg, state, tokens[:, pos : pos + 1], pos
        )
        outs.append(np.asarray(logits))
    return np.stack(outs, axis=1)


def test_ring_cache_matches_full_cache_small_dense():
    """Tier-1 ring-cache gate: a small dense arch with a tiny SWA window so
    the ring wraps three times cheaply — the heavyweight mixtral (MoE) and
    recurrentgemma equivalence runs live in the opt-in slow tier."""
    base = get_config("gemma_2b", reduced=True)
    base = dataclasses.replace(base, swa_window=4)
    ring = dataclasses.replace(base, ring_cache=True)
    params = model_lib.init_params(base, jax.random.key(7))
    n = 8  # ring wraps twice; each extra step costs a full CPU retrace
    tokens = jax.random.randint(jax.random.key(8), (2, n), 0, base.vocab)
    full_logits = _drive(base, params, n, tokens)
    ring_logits = _drive(ring, params, n, tokens)
    np.testing.assert_allclose(full_logits, ring_logits, rtol=2e-2, atol=2e-2)
    np.testing.assert_array_equal(full_logits.argmax(-1), ring_logits.argmax(-1))


@pytest.mark.slow
def test_ring_cache_matches_full_cache_swa():
    """Past the window, ring and full caches must agree exactly (mixtral-style
    SWA with a tiny window so the ring wraps several times)."""
    base = get_config("mixtral_8x22b", reduced=True)  # swa_window=16
    base = dataclasses.replace(base, swa_window=4)
    ring = dataclasses.replace(base, ring_cache=True)
    params = model_lib.init_params(base, jax.random.key(0))
    n = 12  # 3x the window
    tokens = jax.random.randint(jax.random.key(1), (2, n), 0, base.vocab)
    full_logits = _drive(base, params, n, tokens)
    ring_logits = _drive(ring, params, n, tokens)
    np.testing.assert_allclose(full_logits, ring_logits, rtol=2e-2, atol=2e-2)
    # and strictly: same argmax decisions everywhere
    np.testing.assert_array_equal(
        full_logits.argmax(-1), ring_logits.argmax(-1)
    )


@pytest.mark.slow
def test_ring_cache_matches_full_cache_local_attn():
    base = get_config("recurrentgemma_9b", reduced=True)  # local_window=16
    base = dataclasses.replace(base, local_window=4)
    ring = dataclasses.replace(base, ring_cache=True)
    params = model_lib.init_params(base, jax.random.key(3))
    n = 10
    tokens = jax.random.randint(jax.random.key(4), (2, n), 0, base.vocab)
    full_logits = _drive(base, params, n, tokens)
    ring_logits = _drive(ring, params, n, tokens)
    np.testing.assert_allclose(full_logits, ring_logits, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize(
    "arch",
    [
        "qwen3_32b",
        # the recurrent families re-trace every step -> minutes on CPU; they
        # stay covered in the opt-in slow tier
        pytest.param("rwkv6_7b", marks=pytest.mark.slow),
        pytest.param("recurrentgemma_9b", marks=pytest.mark.slow),
    ],
)
def test_decode_matches_full_forward(arch):
    """Greedy decode logits at position t must match the full-sequence
    forward's logits at position t (cache correctness end-to-end)."""
    from repro.models import transformer

    cfg = get_config(arch, reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(5))
    n = 6  # each position re-traces on CPU; 6 steps already cross the cache
    tokens = jax.random.randint(jax.random.key(6), (2, n), 0, cfg.vocab)
    step_logits = _drive(cfg, params, n, tokens)  # (B, n, V)

    hidden = transformer.backbone(params, cfg, tokens)
    w = transformer.unembed_matrix(params, cfg).astype(cfg.compute_dtype)
    full = np.asarray(
        jnp.einsum("bsd,dv->bsv", hidden.astype(cfg.compute_dtype), w,
                   preferred_element_type=jnp.float32)
    )
    np.testing.assert_allclose(step_logits, full, rtol=3e-2, atol=3e-2)
