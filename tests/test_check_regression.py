"""Unit tests for the perf-trajectory gate (benchmarks/check_regression.py):
tolerance-class routing, and the non-numeric hardening — string metrics are
provenance (warn + skip the drift arithmetic), booleans are structural facts
(exact-fail on change even outside the EXACT name set)."""
import json
import sys

import pytest

sys.path.insert(0, ".")  # repo root: benchmarks is a plain package
from benchmarks.check_regression import compare_group, main  # noqa: E402


def _write(dirpath, records):
    (dirpath / "BENCH_kernels.json").write_text(
        json.dumps({"records": records})
    )


def _rows(base_rec, fresh_rec, tmp_path):
    b, f = tmp_path / "base", tmp_path / "fresh"
    b.mkdir(), f.mkdir()
    _write(b, [base_rec])
    _write(f, [fresh_rec])
    return list(compare_group("kernels", str(b), str(f)))


def _severities(rows):
    return [s for s, _ in rows]


def test_string_metric_change_warns_and_skips_drift(tmp_path):
    """A string metric (e.g. a backend/layout tag) must never reach the
    float drift arithmetic: changed -> warn, not a TypeError or a fail."""
    rows = _rows(
        {"name": "r1", "metrics": {"backend": "interpret", "n_sweeps": 4}},
        {"name": "r1", "metrics": {"backend": "mosaic", "n_sweeps": 4}},
        tmp_path,
    )
    assert _severities(rows) == ["warn", "ok"]
    assert "skipped drift check" in rows[0][1]


def test_equal_string_metric_is_silent(tmp_path):
    rows = _rows(
        {"name": "r1", "metrics": {"backend": "interpret"}},
        {"name": "r1", "metrics": {"backend": "interpret"}},
        tmp_path,
    )
    assert _severities(rows) == ["ok"]


def test_boolean_metric_change_fails_even_outside_exact_set(tmp_path):
    """Booleans are structural facts: a True->False flip on a name NOT in
    the EXACT set must still fail instead of floor-dividing into the float
    tolerance classes (bool is an int subclass — 1.0 vs 0.0 would have
    sailed through the advisory branch)."""
    rows = _rows(
        {"name": "r1", "metrics": {"packing_ok": True}},
        {"name": "r1", "metrics": {"packing_ok": False}},
        tmp_path,
    )
    assert _severities(rows) == ["fail", "ok"]
    assert "boolean metric changed" in rows[0][1]


def test_exact_and_model_classes_route_correctly(tmp_path):
    rows = _rows(
        {"name": "r1", "metrics": {
            "rounds_per_launch": 2,       # EXACT
            "vmem_bytes_packed": 1000.0,  # MODEL (1%)
            "seconds_per_sweep": 1.0,     # advisory
        }},
        {"name": "r1", "metrics": {
            "rounds_per_launch": 4,
            "vmem_bytes_packed": 1020.0,
            "seconds_per_sweep": 40.0,
        }},
        tmp_path,
    )
    sev = dict.fromkeys(("fail", "warn"), 0)
    for s, _ in rows:
        if s in sev:
            sev[s] += 1
    assert sev["fail"] == 2  # exact change + 2% model drift
    assert sev["warn"] == 1  # advisory timing note


def test_missing_record_and_missing_metric_fail(tmp_path):
    b, f = tmp_path / "base", tmp_path / "fresh"
    b.mkdir(), f.mkdir()
    _write(b, [
        {"name": "gone", "metrics": {}},
        {"name": "kept", "metrics": {"n_sweeps": 4}},
    ])
    _write(f, [{"name": "kept", "metrics": {}}])
    rows = list(compare_group("kernels", str(b), str(f)))
    fails = [m for s, m in rows if s == "fail"]
    assert any("record missing" in m for m in fails)
    assert any("metric disappeared" in m for m in fails)


def test_main_exit_codes(tmp_path):
    b, f = tmp_path / "base", tmp_path / "fresh"
    b.mkdir(), f.mkdir()
    _write(b, [{"name": "r1", "metrics": {"n_sweeps": 4}}])
    _write(f, [{"name": "r1", "metrics": {"n_sweeps": 4}}])
    argv = ["--baseline-dir", str(b), "--fresh-dir", str(f), "kernels"]
    assert main(argv) == 0
    _write(f, [{"name": "r1", "metrics": {"n_sweeps": 8}}])
    assert main(argv) == 1
