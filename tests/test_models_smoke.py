"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU — asserts output shapes and no NaNs (brief item (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as model_lib
from repro.models.common import param_count
from repro.train import optimizer as opt_lib
from repro.train.train_step import init_state, make_train_step

B, S = 2, 32

# the two heaviest reduced configs on CPU (~20s/~13s per train-step test);
# they run in the opt-in slow tier, the other eight keep tier-1 coverage
_SLOW_ARCHS = {"recurrentgemma_9b", "llama32_vision_11b"}
_ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
    for a in ARCH_IDS
]


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["img"] = jax.random.normal(ks[2], (B, cfg.img_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.key(0)
    batch = _batch(cfg, jax.random.key(1))
    state = init_state(cfg, key)
    step = make_train_step(cfg, opt_lib.AdamWConfig(lr=1e-3, warmup_steps=1))
    state2, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    # reduced vocab=512 -> random-init CE should be near log(512)=6.24
    assert 2.0 < loss < 12.0, loss
    # params changed and stayed finite
    leaves = jax.tree_util.tree_leaves(state2.params)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), arch
    # second step decreases nothing pathological (no NaN propagation)
    state3, m3 = jax.jit(step)(state2, batch)
    assert np.isfinite(float(m3["loss"]))


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    state = model_lib.init_decode_state(cfg, B, max_seq=16)
    ctx = None
    if cfg.family == "encdec":
        from repro.models import whisper

        frames = jax.random.normal(jax.random.key(2), (B, cfg.enc_seq, cfg.d_model))
        ctx = whisper.encode(params, cfg, frames)
    elif cfg.family == "vlm":
        ctx = jax.random.normal(jax.random.key(2), (B, cfg.img_tokens, cfg.d_model))
    @jax.jit
    def step(state, token, pos):
        return model_lib.decode_step(params, cfg, state, token, pos, ctx=ctx)

    logits, state = step(state, jnp.ones((B, 1), jnp.int32), 0)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # feed a DIFFERENT token: the cached history must now influence step 2
    logits2, state = step(state, jnp.full((B, 1), 7, jnp.int32), 1)
    assert np.isfinite(np.asarray(logits2)).all(), arch
    logits3, state = step(state, jnp.full((B, 1), 7, jnp.int32), 2)
    assert np.isfinite(np.asarray(logits3)).all(), arch
    # same input token at positions 2 vs 1: history differs -> logits differ
    assert not np.allclose(np.asarray(logits2), np.asarray(logits3)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_analytic_matches_actual(arch):
    """The analytic count (used for MODEL_FLOPS) must track actual leaves."""
    cfg = get_config(arch, reduced=True)
    params = model_lib.init_params(cfg, jax.random.key(0))
    actual = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
    analytic = param_count(cfg)
    # within 5% (analytic model skips tiny vectors: norms, biases, mus)
    assert abs(actual - analytic) / actual < 0.05, (arch, actual, analytic)


def test_full_configs_construct_and_count():
    """Full configs build (no allocation) and have plausible sizes."""
    expected_range = {
        "qwen3_32b": (28e9, 36e9),
        "gemma_2b": (2e9, 3.5e9),
        "minitron_4b": (3.5e9, 5.5e9),
        "stablelm_3b": (2.5e9, 4e9),
        "qwen3_moe_235b": (200e9, 260e9),
        "mixtral_8x22b": (125e9, 150e9),
        "recurrentgemma_9b": (7.5e9, 11e9),
        "rwkv6_7b": (6e9, 8.5e9),
        "whisper_medium": (0.6e9, 1.0e9),  # 24 enc + 24 dec ≈ 769M published
        "llama32_vision_11b": (8.5e9, 12e9),
    }
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = param_count(cfg)
        lo, hi = expected_range[arch]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
