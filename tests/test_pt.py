"""PT driver & swap-scheduler correctness: pairing rules, permutations,
acceptance law, bimodal mixing advantage, elastic rebalance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed, gaussian, ising, ladder, pt, swap


# ---------- paper's pairing rules (section 3) --------------------------------
@pytest.mark.parametrize("n", [2, 3, 8, 9, 16, 31])
@pytest.mark.parametrize("phase", [0, 1])
def test_pair_partners_rules(n, phase):
    p = np.asarray(swap.pair_partners(n, phase))
    # involution: partner of my partner is me (each replica swaps at most once)
    np.testing.assert_array_equal(p[p], np.arange(n))
    # neighbours only
    assert np.all(np.abs(p - np.arange(n)) <= 1)
    # even phase pairs (0,1),(2,3)...; odd phase pairs (1,2),(3,4)...
    for i in range(n):
        if p[i] != i:
            lo = min(i, p[i])
            assert lo % 2 == (0 if phase == 0 else 1)


def test_pairing_alternation_covers_all_adjacent_pairs():
    n = 8
    pairs = set()
    for phase in (0, 1):
        p = np.asarray(swap.pair_partners(n, phase))
        for i in range(n):
            if p[i] != i:
                pairs.add((min(i, p[i]), max(i, p[i])))
    assert pairs == {(i, i + 1) for i in range(n - 1)}


# ---------- acceptance law ----------------------------------------------------
def test_logistic_probability_matches_paper_formula():
    b = jnp.asarray([1.0, 0.5])
    e = jnp.asarray([-10.0, -14.0])
    arg = (b[0] - b[1]) * (e[0] - e[1])
    want = float(jnp.exp(arg) / (1 + jnp.exp(arg)))
    got = float(swap.swap_probability(b[0], b[1], e[0], e[1], "logistic"))
    assert abs(got - want) < 1e-6


def test_logistic_relabel_invariance_and_complement():
    # Relabeling the pair negates BOTH factors -> same probability (the
    # decision must not depend on which member computes it) ...
    p1 = float(swap.swap_probability(1.0, 0.5, -3.0, -9.0, "logistic"))
    p2 = float(swap.swap_probability(0.5, 1.0, -9.0, -3.0, "logistic"))
    assert abs(p1 - p2) < 1e-6
    # ... while reversing only the energy order complements it (Barker rule).
    p3 = float(swap.swap_probability(1.0, 0.5, -9.0, -3.0, "logistic"))
    assert abs(p1 + p3 - 1.0) < 1e-6


def test_metropolis_caps_at_one():
    assert float(swap.swap_probability(1.0, 0.2, 100.0, -100.0, "metropolis")) == 1.0


def test_swap_permutation_is_permutation():
    n = 9
    key = jax.random.key(0)
    betas = jnp.linspace(1.0, 0.25, n)
    for phase in (0, 1):
        for seed in range(5):
            e = jax.random.normal(jax.random.fold_in(key, seed), (n,)) * 10
            perm, acc, prob, att = swap.swap_permutation(
                jax.random.fold_in(key, 100 + seed), phase, betas, e, n=n
            )
            p = np.asarray(perm)
            assert sorted(p.tolist()) == list(range(n))
            np.testing.assert_array_equal(p[p], np.arange(n))  # involution


def test_swap_acceptance_statistics():
    """Accepted fraction over many draws matches the analytic probability."""
    n = 2
    betas = jnp.asarray([1.0, 0.5])
    e = jnp.asarray([-5.0, -8.0])
    p_exact = float(swap.swap_probability(betas[0], betas[1], e[0], e[1], "logistic"))
    keys = jax.random.split(jax.random.key(2), 4000)
    accepted = jax.vmap(
        lambda k: swap.swap_permutation(k, 0, betas, e, n=n)[1][0]
    )(keys)
    rate = float(jnp.mean(accepted.astype(jnp.float32)))
    assert abs(rate - p_exact) < 0.03


# ---------- driver invariants --------------------------------------------------
def _tiny_run(swap_mode, n_sweeps=200):
    R = 6
    system = ising.IsingSystem(length=8)
    temps = tuple(float(t) for t in ladder.paper_ladder(R))
    cfg = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=5, swap_mode=swap_mode)
    st = pt.init(system, cfg, jax.random.key(1))
    return system, cfg, *pt.run(system, cfg, st, n_sweeps)


@pytest.mark.parametrize("swap_mode", ["temp", "state"])
def test_energy_tracking_exact(swap_mode):
    system, cfg, st, _ = _tiny_run(swap_mode)
    direct = jax.vmap(system.energy)(st.states)
    np.testing.assert_allclose(
        np.asarray(st.energy), np.asarray(direct), rtol=1e-4, atol=1e-2
    )


@pytest.mark.parametrize("swap_mode", ["temp", "state"])
def test_rung_is_always_a_permutation(swap_mode):
    _, _, st, _ = _tiny_run(swap_mode)
    assert sorted(np.asarray(st.rung).tolist()) == list(range(6))


def test_state_mode_keeps_identity_rung():
    _, _, st, _ = _tiny_run("state")
    np.testing.assert_array_equal(np.asarray(st.rung), np.arange(6))


def test_temp_and_state_modes_same_law():
    """Both swap modes must produce the same *distribution* — compare the
    per-rung mean |m| of two long runs (same system, different bookkeeping)."""
    R, L = 8, 8
    system = ising.IsingSystem(length=L)
    temps = tuple(float(t) for t in ladder.linear_ladder(R, 1.5, 3.5))
    obs = {"am": lambda s: jnp.abs(ising.magnetization(s))}
    res = {}
    for mode in ("temp", "state"):
        cfg = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=5, swap_mode=mode)
        st = pt.init(system, cfg, jax.random.key(9))
        _, trace = pt.run(system, cfg, st, 3000, observables=obs)
        from repro.core import diagnostics

        res[mode] = diagnostics.grand_mean_by_rung(trace, "am")
    np.testing.assert_allclose(res["temp"], res["state"], atol=0.08)


def test_no_swap_interval_zero():
    R = 4
    system = ising.IsingSystem(length=8)
    temps = tuple(float(t) for t in ladder.paper_ladder(R))
    cfg = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=0)
    st = pt.init(system, cfg, jax.random.key(1))
    st, trace = pt.run(system, cfg, st, 50)
    assert not np.asarray(trace["swap_accept"]).any()


# ---------- the paper's core claim: PT explores better -------------------------
def test_pt_mixes_bimodal_better_than_mh():
    """A cold chain alone stays in its starting mode; PT lets it cross."""
    sysm = gaussian.GaussianMixture(mus=(-4.0, 4.0), sigmas=(0.6, 0.6), step_size=0.8)
    R = 8
    temps = tuple(float(t) for t in ladder.geometric_ladder(R, 1.0, 30.0))

    # plain MH at T=1: all replicas cold (equal-T "swaps" are no-ops for the
    # law), start in left mode.  Trace granularity = one record per interval.
    cfg0 = pt.PTConfig(n_replicas=R, temps=(1.0,) * R, swap_interval=5)
    st0 = pt.init(sysm, cfg0, jax.random.key(3))
    _, tr0 = pt.run(sysm, cfg0, st0, 3000, observables={"x": lambda s: s})
    # PT with a hot ladder
    cfg1 = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=5, swap_mode="temp")
    st1 = pt.init(sysm, cfg1, jax.random.key(3))
    _, tr1 = pt.run(sysm, cfg1, st1, 3000, observables={"x": lambda s: s})

    x0 = np.asarray(tr0["x"])  # (600, R) — all rungs cold
    x1 = np.asarray(tr1["x"])  # (600, R) — rung 0 cold
    frac_right_mh = float(np.mean(x0[:, :] > 0))  # any cold chain crossing
    frac_right_pt = float(np.mean(x1[len(x1) // 2 :, 0] > 0))
    # MH cold chains stay left; PT cold rung should see the right mode ~half
    # the time after burn-in.
    assert frac_right_mh < 0.05, frac_right_mh
    assert 0.2 < frac_right_pt < 0.8, frac_right_pt


# ---------- elastic rebalance ---------------------------------------------------
@pytest.mark.parametrize("new_r", [4, 6, 12])
def test_rebalance_state(new_r):
    R = 6
    system = ising.IsingSystem(length=8)
    temps = tuple(float(t) for t in ladder.paper_ladder(R))
    cfg = pt.PTConfig(n_replicas=R, temps=temps, swap_interval=5)
    st = pt.init(system, cfg, jax.random.key(0))
    st, _ = pt.run(system, cfg, st, 20)
    st2 = distributed.rebalance_state(st, new_r)
    assert st2.energy.shape == (new_r,)
    assert st2.states.shape == (new_r, 8, 8)
    # energies stay consistent with states
    direct = jax.vmap(system.energy)(st2.states)
    np.testing.assert_allclose(np.asarray(st2.energy), np.asarray(direct), atol=1e-2)
    # ladder rebalance preserves endpoints
    t2 = distributed.rebalance_ladder(np.asarray(temps), new_r)
    assert abs(t2[0] - temps[0]) < 1e-5 and abs(t2[-1] - temps[-1]) < 1e-5


def test_ladder_tuning_moves_toward_uniform_acceptance():
    temps = np.geomspace(1.0, 8.0, 6).astype(np.float32)
    acc = np.array([0.9, 0.6, 0.2, 0.05, 0.01])  # too-dense cold end
    new = ladder.tune_ladder(temps, acc, target=0.3)
    gaps_old = np.diff(np.log(temps))
    gaps_new = np.diff(np.log(new))
    # over-accepting cold gaps widen relative to under-accepting hot gaps
    assert (gaps_new[0] / gaps_old[0]) > (gaps_new[-1] / gaps_old[-1])
    assert abs(new[0] - 1.0) < 1e-5 and abs(new[-1] - 8.0) < 1e-4
