"""Property-based tests (hypothesis) for system, swap and rebalance invariants.

Generators come from the shared strategies in `conftest.py` (ladders, lattice
shapes, system configs) — the same pool the conformance suite draws on.
Skipped cleanly when `hypothesis` isn't installed (it's an optional test
dependency — `pip install -e .[test]`), so a bare environment still runs the
rest of the tier-1 suite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from conftest import (
    ising_systems,
    lattice_shapes,
    potts_systems,
    rung_energies,
    temp_ladders,
)
from repro.core import distributed, ising, ladder, swap
from repro.core.pt import PTState
from repro.engine.driver import StepSpec, _swap_phase
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(2, 64), phase=st.integers(0, 5))
@settings(**SETTINGS)
def test_pairing_involution_property(n, phase):
    p = np.asarray(swap.pair_partners(n, phase))
    np.testing.assert_array_equal(p[p], np.arange(n))
    assert np.all(np.abs(p - np.arange(n)) <= 1)


@given(system=ising_systems(), seed=st.integers(0, 2**20))
@settings(**SETTINGS)
def test_sweep_energy_delta_property(system, seed):
    """For ANY even (L, J, B): incremental dE == recomputed energy difference
    and spins stay in {-1, +1}."""
    l, j, b = system.length, system.j, system.b
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    spins = jnp.where(jax.random.uniform(k1, (2, l, l)) < 0.5, 1, -1).astype(jnp.int8)
    u = jax.random.uniform(k2, (2, 2, l, l))
    betas = jax.random.uniform(k3, (2,), minval=0.05, maxval=2.0)
    new, de, nacc = ref.ising_sweep(spins, u, betas, j=j, b=b, rule=system.accept_rule)
    e0 = ising.lattice_energy(spins, j, b)
    e1 = ising.lattice_energy(new, j, b)
    np.testing.assert_allclose(np.asarray(e1 - e0), np.asarray(de), rtol=1e-4, atol=1e-2)
    assert set(np.unique(np.asarray(new))).issubset({-1, 1})
    assert (np.asarray(nacc) >= 0).all() and (np.asarray(nacc) <= 2 * l * l).all()


@given(system=potts_systems(), seed=st.integers(0, 2**20))
@settings(**SETTINGS)
def test_potts_sweep_energy_delta_property(system, seed):
    """Potts mirror of the Ising delta property: incremental dE is exact,
    colours stay in {0..q-1}, and at q=2 the sweep is a valid Ising twin."""
    h, w = system.shape
    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    states = jax.random.randint(k1, (2, h, w), 0, system.q).astype(jnp.int8)
    u = jax.random.uniform(k2, (2, 2, 2, h, w))
    betas = jax.random.uniform(k3, (2,), minval=0.05, maxval=2.0)
    new, de, nacc = ref.potts_sweep(
        states, u, betas, q=system.q, j=system.j, rule=system.accept_rule
    )
    from repro.core.potts import potts_energy

    e0 = potts_energy(states, system.q, system.j)
    e1 = potts_energy(new, system.q, system.j)
    np.testing.assert_allclose(np.asarray(e1 - e0), np.asarray(de), rtol=1e-4, atol=1e-2)
    got = set(np.unique(np.asarray(new)))
    assert got.issubset(set(range(system.q)))
    assert (np.asarray(nacc) >= 0).all() and (np.asarray(nacc) <= h * w).all()


@given(
    system=ising_systems(),
    seed=st.integers(0, 2**20),
    n_sweeps=st.integers(1, 3),
    r=st.integers(1, 5),
)
@settings(**SETTINGS)
def test_fused_interval_matches_persweep_oracle_property(system, seed, n_sweeps, r):
    """For ANY checkerboard Ising config / replica count / interval length:
    the interval-fused kernel is bit-equal to repeated per-sweep oracle
    application on the shared counter stream (`repro.kernels.prng`) — the
    property form of the pinned cases in test_kernels.py."""
    from repro.kernels import ops, prng

    l = system.length
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    spins = jnp.where(jax.random.uniform(k1, (r, l, l)) < 0.5, 1, -1).astype(jnp.int8)
    betas = jax.random.uniform(k2, (r,), minval=0.05, maxval=2.0)
    got = ops.ising_sweep_fused(
        spins, key, jnp.int32(seed % 1000), betas, n_sweeps=n_sweeps,
        j=system.j, b=system.b, rule=system.accept_rule, r_blk=4,
        use_pallas=True,
    )
    words = prng.key_words(key)
    rep = jnp.arange(r, dtype=jnp.uint32)
    s = spins
    na = jnp.zeros((r,), jnp.int32)
    for i in range(n_sweeps):
        u = prng.ising_sweep_uniforms(words, seed % 1000 + i, rep, l)
        s, _, n = ref.ising_sweep(
            s, u, betas, j=system.j, b=system.b, rule=system.accept_rule
        )
        na = na + n
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(na))


@given(seed=st.integers(0, 2**20), n=st.integers(2, 32))
@settings(**SETTINGS)
def test_swap_probability_bounds_and_symmetry(seed, n):
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    betas = jnp.sort(jax.random.uniform(k1, (n,), minval=0.1, maxval=2.0))[::-1]
    e = jax.random.normal(k2, (n,)) * 50
    p = swap.swap_probability(betas[:-1], betas[1:], e[:-1], e[1:], "logistic")
    # relabel invariance: negating both factors keeps p unchanged
    q = swap.swap_probability(betas[1:], betas[:-1], e[1:], e[:-1], "logistic")
    # Barker complement: reversing only the energies complements p
    q2 = swap.swap_probability(betas[:-1], betas[1:], e[1:], e[:-1], "logistic")
    assert np.all((np.asarray(p) >= 0) & (np.asarray(p) <= 1))
    np.testing.assert_allclose(np.asarray(p), np.asarray(q), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p + q2), 1.0, rtol=1e-5)


# ---------- swap.py invariants through the driver's swap phase ------------------
@given(
    temps=temp_ladders(min_rungs=2, max_rungs=12),
    data=st.data(),
    seed=st.integers(0, 2**16),
    phases=st.integers(1, 6),
)
@settings(**SETTINGS)
def test_temp_mode_swap_conserves_energy_and_permutation(temps, data, seed, phases):
    """For ANY ladder / energies / phase count: `temp`-mode swap phases only
    relabel rungs — the rung vector stays a permutation, and the slot energy
    and state vectors are bit-untouched (the O(R·L²) -> O(R) guarantee)."""
    r = len(temps)
    energies = data.draw(rung_energies(r))
    betas = jnp.asarray(1.0 / np.asarray(temps), jnp.float32)
    spec = StepSpec(n_replicas=r, sweeps_per_interval=1, swap_mode="temp")
    st_pt = PTState(
        states=jnp.arange(r, dtype=jnp.int32),  # sentinel payload per slot
        energy=jnp.asarray(energies),
        rung=jnp.arange(r, dtype=jnp.int32),
        key=jax.random.key(seed),
        phase=jnp.int32(0),
        t=jnp.int32(1 + seed % 7),
    )
    for _ in range(phases):
        st_pt, diag = _swap_phase(spec, betas, st_pt)
        assert sorted(np.asarray(st_pt.rung).tolist()) == list(range(r))
        np.testing.assert_array_equal(np.asarray(st_pt.states), np.arange(r))
        np.testing.assert_array_equal(np.asarray(st_pt.energy), energies)
        # diagnostics mask structure: attempts only at lower pair members
        att = np.asarray(diag["swap_attempt"])
        assert not att[-1]
        assert np.asarray(diag["swap_accept"])[~att].sum() == 0


@given(
    temps=temp_ladders(min_rungs=2, max_rungs=12),
    data=st.data(),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_state_mode_swap_permutes_payload_with_energy(temps, data, seed):
    """`state`-mode swaps move states and energies with the SAME gather: the
    (payload, energy) pairing per replica must survive any accepted swap."""
    r = len(temps)
    energies = data.draw(rung_energies(r))
    betas = jnp.asarray(1.0 / np.asarray(temps), jnp.float32)
    spec = StepSpec(n_replicas=r, sweeps_per_interval=1, swap_mode="state")
    payload = jnp.asarray(energies)  # states mirror energies exactly
    st_pt = PTState(
        states=payload,
        energy=jnp.asarray(energies),
        rung=jnp.arange(r, dtype=jnp.int32),
        key=jax.random.key(seed),
        phase=jnp.int32(seed % 2),
        t=jnp.int32(0),
    )
    st_pt, _ = _swap_phase(spec, betas, st_pt)
    np.testing.assert_array_equal(np.asarray(st_pt.states), np.asarray(st_pt.energy))
    # multiset of energies conserved; rung binding stays the identity
    np.testing.assert_array_equal(
        np.sort(np.asarray(st_pt.energy)), np.sort(energies)
    )
    np.testing.assert_array_equal(np.asarray(st_pt.rung), np.arange(r))


# ---------- elastic rebalance properties ----------------------------------------
@given(temps=temp_ladders(min_rungs=2, max_rungs=24), new_r=st.integers(2, 40))
@settings(**SETTINGS)
def test_rebalance_ladder_properties(temps, new_r):
    """Any resample preserves endpoints and strict cold->hot monotonicity."""
    out = distributed.rebalance_ladder(np.asarray(temps), new_r)
    assert out.shape == (new_r,)
    np.testing.assert_allclose(out[0], temps[0], rtol=1e-5)
    np.testing.assert_allclose(out[-1], temps[-1], rtol=1e-5)
    assert np.all(np.diff(out) > 0)


def _pt_state(r, perm_seed):
    """Synthetic PTState with distinct payloads and a random rung permutation."""
    rng_ = np.random.default_rng(perm_seed)
    rung = rng_.permutation(r).astype(np.int32)
    return PTState(
        states=jnp.arange(r, dtype=jnp.float32) * 10.0,
        energy=jnp.arange(r, dtype=jnp.float32),
        rung=jnp.asarray(rung),
        key=jax.random.key(0),
        phase=jnp.int32(0),
        t=jnp.int32(0),
    )


@given(
    r_old=st.integers(2, 16),
    new_r=st.integers(2, 16),
    perm_seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_rebalance_state_shrink_grow_properties(r_old, new_r, perm_seed):
    """Elastic resize invariants over the whole (r_old, new_r) domain:

    * a no-op resize returns the state untouched (rung permutation intact);
    * otherwise the result has ``new_r`` replicas with identity rungs;
    * every (state, energy) pair comes from the source population intact;
    * shrinking never duplicates a surviving replica (the tiny-ladder
      duplicate guard in `distributed.rebalance_state`'s shrink path) and
      keeps both ladder endpoints' replicas.
    """
    st_pt = _pt_state(r_old, perm_seed)
    out = distributed.rebalance_state(st_pt, new_r)
    if new_r == r_old:
        assert out is st_pt
        return
    states = np.asarray(out.states)
    energy = np.asarray(out.energy)
    assert states.shape == (new_r,) and energy.shape == (new_r,)
    np.testing.assert_array_equal(np.asarray(out.rung), np.arange(new_r))
    # payload-energy binding survives the gather
    np.testing.assert_allclose(states, energy * 10.0)
    assert set(energy.tolist()) <= set(range(r_old))
    if new_r < r_old:
        # shrink path: no duplicates, endpoints preserved in rung order
        assert len(set(energy.tolist())) == new_r
        inv = np.argsort(np.asarray(st_pt.rung))
        assert energy[0] == np.asarray(st_pt.energy)[inv[0]]
        assert energy[-1] == np.asarray(st_pt.energy)[inv[r_old - 1]]


@given(r_old=st.integers(2, 12), grow_to=st.integers(13, 32), perm_seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_rebalance_grow_then_shrink_round_trip(r_old, grow_to, perm_seed):
    """Grow -> shrink back to r_old keeps population membership, count and
    the duplicate-free guarantee (clones may replace originals, but every
    survivor is a valid replica and the cold-end replica survives)."""
    st_pt = _pt_state(r_old, perm_seed)
    grown = distributed.rebalance_state(st_pt, grow_to)
    assert np.asarray(grown.energy).shape == (grow_to,)
    # growth tiles existing replicas: every clone is a source replica
    np.testing.assert_array_equal(
        np.asarray(grown.energy), np.arange(grow_to) % r_old
    )
    back = distributed.rebalance_state(grown, r_old)
    energy = np.asarray(back.energy)
    assert energy.shape == (r_old,)
    assert set(energy.tolist()) <= set(range(r_old))
    assert energy[0] == np.asarray(grown.energy)[0]  # cold endpoint preserved


@given(n=st.integers(2, 40))
@settings(**SETTINGS)
def test_paper_ladder_property(n):
    t = np.asarray(ladder.paper_ladder(n))
    assert abs(t[0] - 1.0) < 1e-6
    assert np.all(np.diff(t) > 0)
    np.testing.assert_allclose(np.diff(t), 3.0 / n, rtol=1e-5)
    assert t[-1] < 4.0  # paper's formula is exclusive at the hot end


@given(shape=lattice_shapes(min_side=2, max_side=8), seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_lattice_shapes_strategy_is_checkerboardable(shape, seed):
    """The shared shape strategy must only emit PBC-2-colourable lattices."""
    h, w = shape
    assert h % 2 == 0 and w % 2 == 0


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_wkv6_linearity_in_v(seed):
    """The recurrence is linear in v: wkv6(..., 2v) == 2*wkv6(..., v)."""
    key = jax.random.key(seed)
    ks = jax.random.split(key, 5)
    bh, t, dk, dv = 1, 12, 4, 4
    r = jax.random.normal(ks[0], (bh, t, dk))
    k = jax.random.normal(ks[1], (bh, t, dk))
    v = jax.random.normal(ks[2], (bh, t, dv))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, t, dk)))
    u = jax.random.normal(ks[4], (bh, dk))
    o1, s1 = ref.wkv6(r, k, v, w, u)
    o2, s2 = ref.wkv6(r, k, 2 * v, w, u)
    np.testing.assert_allclose(np.asarray(o2), 2 * np.asarray(o1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), 2 * np.asarray(s1), rtol=1e-5, atol=1e-5)
